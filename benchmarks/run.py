"""Benchmark runner — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke]

``--smoke`` runs only the deconv traffic + autotune comparison with tiny
rep counts and emits BENCH_deconv.json (the CI perf-trajectory artifact).
Emits a ``name,us_per_call,derived`` CSV summary at the end (harness
convention) plus the full per-table reports above it."""
from __future__ import annotations

import sys


def main() -> None:
    fast = "--fast" in sys.argv
    smoke = "--smoke" in sys.argv
    reps = 10 if fast else 50

    from . import bench_deconv, bench_dse, bench_resource, bench_sparsity

    if smoke:
        print("=" * 72)
        print("Smoke: deconv HBM traffic (modeled vs measured) + autotuned "
              "vs fixed tiles")
        print("=" * 72)
        bench_deconv.main(smoke=True)
        # the artifact CI archives must validate: every section present,
        # required row keys intact, no NaN/inf leaked by a timing division
        from repro.analysis.check import check_bench_json

        report = check_bench_json("BENCH_deconv.json")
        print(report.render(strict=True))
        report.raise_if_failed(strict=True)
        return

    print("=" * 72)
    print("Table II — throughput / run-to-run variation (reverse-loop vs "
          "zero-insertion)")
    print("=" * 72)
    t2 = bench_deconv.main(reps=reps)

    print()
    print("=" * 72)
    print("Fig. 5 — design-space exploration")
    print("=" * 72)
    bench_dse.main()

    print()
    print("=" * 72)
    print("Table I — resource budget at the chosen design point")
    print("=" * 72)
    bench_resource.main()

    print()
    print("=" * 72)
    print("Fig. 6 — sparsity vs quality (zero-skipping + MMD + Eq. 6)")
    print("=" * 72)
    bench_sparsity.main()

    # ---- harness CSV summary ----------------------------------------------
    print()
    print("name,us_per_call,derived")
    for r in t2:
        if r["layer"].endswith("tpu-model") or r["rl_us"] == 0.0:
            continue
        name = f"{r['net']}_{r['layer']}"
        print(f"{name}_reverse_loop,{r['rl_us']:.1f},"
              f"gops={r['rl_gops']:.2f};cv={r['rl_cv']:.3f}")
        print(f"{name}_zero_insertion,{r['zi_us']:.1f},"
              f"gops={r['zi_gops']:.2f};cv={r['zi_cv']:.3f}")


if __name__ == "__main__":
    main()
