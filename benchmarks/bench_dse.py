"""Fig. 5 reproduction: design-space exploration curves.

For each network and device (the paper's PYNQ-Z2 point design and our TPU
v5e target), emit every legal (T_OH, CTC, attainable GOps/s) point, the
bandwidth-bound flag (left of the slope), and the chosen unified tiling
factor."""
from __future__ import annotations

from repro.core.dse import PYNQ_Z2, TPU_V5E, layer_dse, optimize_unified_tile, per_layer_optimum
from repro.models.dcnn import CELEBA_DCNN, MNIST_DCNN


def run():
    out = {}
    for cfg in (MNIST_DCNN, CELEBA_DCNN):
        geoms = cfg.geometries()
        for dev in (PYNQ_Z2, TPU_V5E):
            co = 32 if dev is PYNQ_Z2 else 128
            best, scores = optimize_unified_tile(geoms, dev, co_tile=co)
            per_layer = per_layer_optimum(geoms, dev, co_tile=co)
            curves = {f"L{i+1}": [(p.t_oh, p.ctc, p.attainable_ops,
                                   p.bandwidth_bound)
                                  for p in layer_dse(g, dev, co_tile=co)]
                      for i, g in enumerate(geoms)}
            out[(cfg.name, dev.name)] = {
                "unified_t_oh": best,
                "unified_scores": scores,
                "per_layer_best": [(p.t_oh, p.attainable_ops)
                                   for p in per_layer],
                "curves": curves,
            }
    return out


def main():
    res = run()
    print("# Fig. 5 analogue: unified tiling factor by network x device")
    for (net, dev), r in res.items():
        print(f"\n{net} on {dev}: unified T_OH = {r['unified_t_oh']} "
              f"(net attainable {r['unified_scores'][r['unified_t_oh']]/1e9:.2f} GOps/s)")
        print("  per-layer optimum (paper future work): "
              + ", ".join(f"T={t} ({a/1e9:.1f}G)" for t, a in r["per_layer_best"]))
        for lname, pts in r["curves"].items():
            bw = sum(1 for p in pts if p[3])
            print(f"  {lname}: {len(pts)} legal tiles, {bw} bandwidth-bound")
    # paper reference points: T_OH=12 (MNIST), 24 (CelebA) on PYNQ-Z2
    print("\npaper reference: MNIST T_OH=12, CelebA T_OH=24 (Table I)")
    return res


if __name__ == "__main__":
    main()
