"""Table II reproduction: per-layer throughput and run-to-run variation of
the reverse-loop deconvolution vs the conventional zero-insertion baseline.

The paper measures GOps/s/W on FPGA vs Jetson GPU.  This container is
CPU-only, so we report:
  * measured GOps/s per layer for BOTH formulations (XLA-compiled), with
    mean(std) over 50 runs — the paper's variation methodology;
  * the useful-MAC ratio (reverse-loop executes no zero-insertion MACs:
    the algorithmic advantage the FPGA exploits);
  * modeled TPU-v5e GOps/s/W from the DSE attainable throughput and a
    220 W/chip envelope (reported as modeled, not measured).
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import deconv_traffic_report, measured_bytes
from repro.core.deconv import deconv2d_reverse_loop, deconv2d_zero_insertion
from repro.core.dse import TPU_V5E, layer_dse, tile_attainable
from repro.kernels.autotune import choose_tiles, fallback_tiles
from repro.kernels.deconv2d import deconv2d
from repro.models.dcnn import CELEBA_DCNN, MNIST_DCNN, generator_init

from .common import time_fn

TPU_WATTS = 220.0  # v5e chip power envelope (modeled)
BATCH = 8


def run(reps: int = 50):
    rows = []
    for cfg in (MNIST_DCNN, CELEBA_DCNN):
        geoms = cfg.geometries()
        key = jax.random.PRNGKey(0)
        net = {"rl": [], "zi": [], "ops": []}
        for li, g in enumerate(geoms):
            x = jax.random.normal(key, (BATCH, g.in_h, g.in_w, g.c_in),
                                  jnp.float32)
            w = jax.random.normal(key, (g.kernel, g.kernel, g.c_in, g.c_out),
                                  jnp.float32) * 0.1
            b = jnp.zeros((g.c_out,), jnp.float32)
            f_rl = jax.jit(lambda x, w, b, s=g.stride, p=g.padding:
                           deconv2d_reverse_loop(x, w, b, s, p))
            f_zi = jax.jit(lambda x, w, b, s=g.stride, p=g.padding:
                           deconv2d_zero_insertion(x, w, b, s, p))
            m_rl, s_rl, _ = time_fn(f_rl, x, w, b, reps=reps)
            m_zi, s_zi, _ = time_fn(f_zi, x, w, b, reps=reps)
            ops = g.ops * BATCH
            # zero-insertion executes S^2 x the MACs (dilated input zeros)
            zi_ops = ops * g.stride ** 2
            gops_rl = ops / m_rl / 1e9
            gops_zi = ops / m_zi / 1e9
            rows.append({
                "net": cfg.name, "layer": f"L{li+1}",
                "rl_gops": gops_rl, "rl_cv": s_rl / m_rl,
                "zi_gops": gops_zi, "zi_cv": s_zi / m_zi,
                "useful_mac_ratio_zi": ops / zi_ops,
                "rl_us": m_rl * 1e6, "zi_us": m_zi * 1e6,
            })
            net["rl"].append(m_rl)
            net["zi"].append(m_zi)
            net["ops"].append(ops)
        # paper's total-network metric: sum ops / sum time
        tot_ops = sum(net["ops"])
        rows.append({
            "net": cfg.name, "layer": "Total",
            "rl_gops": tot_ops / sum(net["rl"]) / 1e9, "rl_cv": 0.0,
            "zi_gops": tot_ops / sum(net["zi"]) / 1e9, "zi_cv": 0.0,
            "useful_mac_ratio_zi": float(np.mean(
                [o / (o * g.stride ** 2) for o, g in zip(net["ops"], geoms)])),
            "rl_us": sum(net["rl"]) * 1e6, "zi_us": sum(net["zi"]) * 1e6,
        })
        # modeled TPU efficiency from DSE attainable throughput
        for li, g in enumerate(geoms):
            pts = layer_dse(g, TPU_V5E)
            best = max(pts, key=lambda p: p.attainable_ops)
            rows.append({
                "net": cfg.name, "layer": f"L{li+1}-tpu-model",
                "rl_gops": best.attainable_ops / 1e9, "rl_cv": 0.0,
                "zi_gops": best.attainable_ops / 1e9 / TPU_WATTS, "zi_cv": 0.0,
                "useful_mac_ratio_zi": 1.0,
                "rl_us": 0.0, "zi_us": 0.0,
            })
    return rows


def traffic_rows(batch: int = 1, measure: bool = True):
    """Modeled (halo vs full-image) and measured HBM bytes per layer.

    The halo-vs-full comparison runs at the *fixed* ~32x32 tiling so both
    pipelines move the same grid — the reduction isolates the BlockSpec
    change (the autotuner often collapses small layers to one tile, where
    the two pipelines coincide by construction).  Measured bytes come from
    the trip-count-aware HLO analyzer on the jitted kernel wrapper (on CPU
    the interpret-mode inlining makes it a proxy)."""
    rows = []
    dtype_bytes = 4
    for cfg in (MNIST_DCNN, CELEBA_DCNN):
        for li, g in enumerate(cfg.geometries()):
            c = fallback_tiles(g, dtype_bytes)
            tuned = choose_tiles(g, jnp.float32, backend="pallas")
            rep = deconv_traffic_report(g, c.t_oh, c.t_ow, c.t_ci, c.t_co,
                                        dtype_bytes)
            row = {
                "net": cfg.name, "layer": f"L{li+1}",
                "tiles": c.as_kwargs(), "tuned_tiles": tuned.as_kwargs(),
                **rep,
                "halo_total_bytes_batch": rep["halo_total_bytes"] * batch,
            }
            if measure:
                key = jax.random.PRNGKey(0)
                x = jax.random.normal(key, (batch, g.in_h, g.in_w, g.c_in),
                                      jnp.float32)
                w = jax.random.normal(key, (g.kernel, g.kernel, g.c_in,
                                            g.c_out), jnp.float32)
                row["measured_bytes"] = measured_bytes(
                    lambda x, w: deconv2d(x, w, None, g.stride, g.padding,
                                          **c.as_kwargs()), x, w)
            rows.append(row)
    return rows


def scaling_rows():
    """Bytes/tile vs image size at one fixed tiling (CelebA L5 layer type).

    The Eq. 5 input window is constant while the legacy pipeline's
    per-tile stream grows with the image — the acceptance property 'HBM
    bytes/tile independent of image size' made visible."""
    from repro.core.tiling import DeconvGeometry

    rows = []
    for in_hw in (16, 32, 64, 128):
        g = DeconvGeometry(in_hw, in_hw, 128, 3, 4, 2, 1)
        rep = deconv_traffic_report(g, 32, 32, 128, 8, 4)
        rows.append({
            "in_hw": in_hw, "out_hw": g.out_h,
            "halo_in_bytes_per_tile": rep["in_bytes_per_tile"],
            "full_in_bytes_per_tile": rep["full_image_in_bytes_per_tile"],
            "n_tiles": rep["n_tiles"],
        })
    return rows


def autotune_rows(reps: int = 10, batch: int = 2):
    """Autotuned tiles vs the fixed ~32x32 defaults on every generator
    layer (the acceptance comparison recorded in BENCH_deconv.json)."""
    rows = []
    key = jax.random.PRNGKey(0)
    for cfg in (MNIST_DCNN, CELEBA_DCNN):
        for li, g in enumerate(cfg.geometries()):
            x = jax.random.normal(key, (batch, g.in_h, g.in_w, g.c_in),
                                  jnp.float32)
            w = jax.random.normal(key, (g.kernel, g.kernel, g.c_in, g.c_out),
                                  jnp.float32) * 0.1
            b = jnp.zeros((g.c_out,), jnp.float32)
            fixed = fallback_tiles(g)
            tuned = choose_tiles(g, jnp.float32, backend="pallas")

            def f(x, w, b, kw):
                return deconv2d(x, w, b, g.stride, g.padding, **kw)

            same = fixed.as_kwargs() == tuned.as_kwargs()
            m_fix, s_fix, _ = time_fn(f, x, w, b, fixed.as_kwargs(),
                                      reps=reps)
            if same:
                # identical static config => identical kernel; re-timing it
                # would only record noise as a fake (anti-)speedup.
                m_tun, s_tun = m_fix, s_fix
            else:
                m_tun, s_tun, _ = time_fn(f, x, w, b, tuned.as_kwargs(),
                                          reps=reps)
            ops = g.ops * batch
            rows.append({
                "net": cfg.name, "layer": f"L{li+1}",
                "fixed_tiles": fixed.as_kwargs(),
                "tuned_tiles": tuned.as_kwargs(),
                "tuned_source": tuned.source,
                "same_tiles": same,
                "fixed_us": m_fix * 1e6, "fixed_cv": s_fix / max(m_fix, 1e-12),
                "tuned_us": m_tun * 1e6, "tuned_cv": s_tun / max(m_tun, 1e-12),
                "fixed_gops": ops / m_fix / 1e9,
                "tuned_gops": ops / m_tun / 1e9,
                "speedup": m_fix / max(m_tun, 1e-12),
            })
    return rows


def batch_sweep_rows(batches=(8, 64), reps: int = 3):
    """Tentpole acceptance: batch-fused kernel (autotuned t_n) vs the
    per-image-grid kernel (t_n=1, same spatial/channel tiles) on the
    fat-channel first generator layers — throughput, p50/p99 latency and
    run-to-run CV (the paper's Table III variation methodology), with the
    modeled roofline attainable recorded alongside.  On CPU CI the kernels
    run in interpret mode, so the measured speedup is a proxy (fewer grid
    programs); the modeled numbers carry the MXU-fill/weight-amortization
    story."""
    key = jax.random.PRNGKey(0)
    layers = [("dcnn-celeba", "L1", CELEBA_DCNN.geometries()[0]),
              ("dcnn-mnist", "L1", MNIST_DCNN.geometries()[0])]
    rows = []
    for net, lname, g in layers:
        for batch in batches:
            x = jax.random.normal(key, (batch, g.in_h, g.in_w, g.c_in),
                                  jnp.float32)
            w = jax.random.normal(key, (g.kernel, g.kernel, g.c_in, g.c_out),
                                  jnp.float32) * 0.1
            b = jnp.zeros((g.c_out,), jnp.float32)
            fused = choose_tiles(g, jnp.float32, backend="pallas",
                                 batch=batch)
            per_image = dict(fused.as_kwargs(), t_n=1)

            def f(x, w, b, kw):
                return deconv2d(x, w, b, g.stride, g.padding, **kw)

            m_pi, s_pi, t_pi = time_fn(f, x, w, b, per_image, reps=reps)
            m_bf, s_bf, t_bf = time_fn(f, x, w, b, fused.as_kwargs(),
                                       reps=reps)
            att_pi = tile_attainable(g, fused.t_oh, fused.t_ow, fused.t_ci,
                                     fused.t_co, TPU_V5E, t_n=1, batch=batch)
            att_bf = tile_attainable(g, fused.t_oh, fused.t_ow, fused.t_ci,
                                     fused.t_co, TPU_V5E, t_n=fused.t_n,
                                     batch=batch)
            rows.append({
                "net": net, "layer": lname, "batch": batch,
                "tiles": fused.as_kwargs(),
                "per_image_us": m_pi * 1e6,
                "fused_us": m_bf * 1e6,
                "per_image_cv": s_pi / max(m_pi, 1e-12),
                "fused_cv": s_bf / max(m_bf, 1e-12),
                "per_image_p50_us": float(np.percentile(t_pi, 50)) * 1e6,
                "per_image_p99_us": float(np.percentile(t_pi, 99)) * 1e6,
                "fused_p50_us": float(np.percentile(t_bf, 50)) * 1e6,
                "fused_p99_us": float(np.percentile(t_bf, 99)) * 1e6,
                "per_image_img_s": batch / m_pi,
                "fused_img_s": batch / m_bf,
                "speedup": m_pi / max(m_bf, 1e-12),
                "modeled_per_image_gops": att_pi.attainable_ops / 1e9,
                "modeled_fused_gops": att_bf.attainable_ops / 1e9,
                "modeled_speedup": att_bf.attainable_ops
                / max(att_pi.attainable_ops, 1.0),
            })
    return rows


def quant_rows(batch: int = 64, mmd_n: int = 16, calib_n: int = 32):
    """int8 quantization acceptance: modeled speedup + measured quality.

    Per network: the DSE-modeled whole-network throughput of the
    dtype-aware autotuned tiles at ``batch`` — int8 (1-byte traffic, int8
    MXU peak) over fp32 (4-byte traffic) — plus the measured MMD between
    int8-generated and fp32-generated images per calibration strategy
    (the statistical-clipping comparison of quant.evaluate).  The modeled
    speedup is the acceptance number: >= 1.5x at batch 64."""
    from repro.quant.evaluate import mmd_degradation

    rows = []
    for cfg, n_mmd in ((MNIST_DCNN, mmd_n), (CELEBA_DCNN, max(8, mmd_n // 2))):
        per_dtype = {}
        geoms = cfg.geometries()
        for label, dtype, dbytes in (("fp32", jnp.float32, 4),
                                     ("int8", jnp.int8, 1)):
            total_time = 0.0
            total_ops = 0.0
            for li, g in enumerate(geoms):
                # the int8 chain's last layer emits f32 images; price its
                # output block accordingly (matches network_tiles)
                ob = 4 if dbytes == 1 and li == len(geoms) - 1 else None
                c = choose_tiles(g, dtype, backend="pallas", batch=batch,
                                 out_dtype_bytes=ob)
                att = tile_attainable(g, c.t_oh, c.t_ow, c.t_ci, c.t_co,
                                      TPU_V5E, t_n=c.t_n, batch=batch,
                                      dtype_bytes=dbytes,
                                      out_dtype_bytes=ob)
                total_ops += g.ops * batch
                total_time += g.ops * batch / att.attainable_ops
            per_dtype[label] = total_ops / total_time
        params, _ = generator_init(jax.random.PRNGKey(0), cfg)
        quality = mmd_degradation(params, cfg, jax.random.PRNGKey(1),
                                  n=n_mmd, calib_n=calib_n)
        rows.append({
            "net": cfg.name, "batch": batch,
            "modeled_fp32_gops": per_dtype["fp32"] / 1e9,
            "modeled_int8_gops": per_dtype["int8"] / 1e9,
            "modeled_speedup": per_dtype["int8"] / per_dtype["fp32"],
            "mmd": quality,
        })
    return rows


def print_quant(rows):
    print("# int8 quantization: DSE-modeled network speedup (dtype-aware "
          "tiles) + measured MMD vs fp32 per calibration strategy")
    print(f"{'net':13s} {'batch':>5s} {'fp32 GOps/s':>12s} "
          f"{'int8 GOps/s':>12s} {'speedup':>8s}  mmd-vs-fp32 by strategy")
    for r in rows:
        mmds = ", ".join(f"{q['strategy']}={q['mmd_vs_fp32']:.4f}"
                         for q in r["mmd"])
        print(f"{r['net']:13s} {r['batch']:5d} "
              f"{r['modeled_fp32_gops']:12.1f} "
              f"{r['modeled_int8_gops']:12.1f} "
              f"{r['modeled_speedup']:7.2f}x  {mmds}")


def plan_rows(batch: int = 64, stream=(3, 5, 8, 2, 8, 7)):
    """Plan/execute acceptance: plan building is a one-time cost, never a
    per-call one.

    Per network: wall-clock of a cold `build_network_plan` (autotune
    cache interaction included) vs a warm rebuild, JSON round-trip
    hash-equality, and the plan's modeled network throughput.  Then the
    MNIST generator serves a mixed-size stream through the
    EngineConfig-driven engine and the row pins zero per-call
    re-planning: plan builds == buckets touched == compile count
    (trace_counts match the PR 4 serving numbers — one trace per
    bucket)."""
    import time as _time

    from repro.plan import NetworkPlan, build_network_plan
    from repro.serve import DcnnServeEngine, EngineConfig

    rows = []
    for cfg in (MNIST_DCNN, CELEBA_DCNN):
        t0 = _time.perf_counter()
        plan = build_network_plan(cfg, batch=batch, backend="pallas")
        cold_s = _time.perf_counter() - t0
        t0 = _time.perf_counter()
        build_network_plan(cfg, batch=batch, backend="pallas")
        warm_s = _time.perf_counter() - t0
        rt = NetworkPlan.from_json(plan.to_json())
        row = {
            "net": cfg.name, "batch": batch,
            "plan_build_cold_s": cold_s,
            "plan_build_warm_s": warm_s,
            "roundtrip_hash_equal": rt.stable_hash() == plan.stable_hash(),
            "modeled_network_gops": plan.modeled_network_ops() / 1e9,
        }
        if cfg is MNIST_DCNN:
            params, _ = generator_init(jax.random.PRNGKey(0), cfg)
            eng = DcnnServeEngine.from_config(
                EngineConfig(model=cfg, backend="pallas",
                             buckets=(1, 2, 4, 8), warmup=True), params)
            builds_after_warmup = eng.plan_stats["builds"]
            rng = np.random.RandomState(0)
            for n in stream:
                eng.generate(rng.randn(n, cfg.z_dim).astype(np.float32))
            row.update({
                "serve_buckets": list(eng.buckets),
                "serve_trace_counts": {str(k): v
                                       for k, v in eng.trace_counts.items()},
                "serve_plan_builds": eng.plan_stats["builds"],
                "serve_plan_build_s": eng.plan_stats["build_seconds"],
                # the acceptance bit: the request stream triggered zero
                # re-planning beyond the per-bucket warmup builds
                "replan_calls_after_warmup":
                    eng.plan_stats["builds"] - builds_after_warmup,
            })
        rows.append(row)
    return rows


def print_plan_rows(rows):
    print("# plan/execute: one-time plan build cost, JSON round-trip, and "
          "zero per-call re-planning through the EngineConfig engine")
    for r in rows:
        extra = ""
        if "serve_plan_builds" in r:
            extra = (f" serve: builds={r['serve_plan_builds']} "
                     f"replans-after-warmup={r['replan_calls_after_warmup']} "
                     f"traces={r['serve_trace_counts']}")
        print(f"{r['net']:13s} build {r['plan_build_cold_s']*1e3:7.1f} ms "
              f"cold / {r['plan_build_warm_s']*1e3:6.1f} ms warm, "
              f"roundtrip={'ok' if r['roundtrip_hash_equal'] else 'FAIL'}, "
              f"modeled {r['modeled_network_gops']:8.0f} GOps/s{extra}")


def table2_obs_rows(specs=((MNIST_DCNN, ("fp32", "int8")),
                           (CELEBA_DCNN, ("fp32",))),
                    buckets=(1, 2, 4), calls=4):
    """The paper's Table II via the obs layer: run-to-run mean/std/CV of
    the healthy dispatch wall clock per net x precision (x bucket), from
    the `engine.dispatch_seconds` histogram of instrumented serving
    engines — not an ad-hoc timing loop.  Interpret-mode numbers: the
    variation methodology is the deliverable, the absolute throughput is
    a CPU proxy.  ``warmup=True`` pays each bucket's compile before the
    measured calls, so every sample is steady-state (the engine's
    outcome tagging would exclude compiles anyway)."""
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.report import table2_rows
    from repro.serve import DcnnServeEngine, EngineConfig

    reg = MetricsRegistry()
    for cfg, precisions in specs:
        params, _ = generator_init(jax.random.PRNGKey(0), cfg)
        for precision in precisions:
            eng = DcnnServeEngine.from_config(
                EngineConfig(model=cfg, backend="pallas",
                             precision=precision, buckets=tuple(buckets),
                             warmup=True, calib_batch=16),
                params, metrics=reg)
            rng = np.random.RandomState(0)
            for _ in range(calls):
                for b in buckets:
                    eng.generate(rng.randn(b, cfg.z_dim).astype(np.float32))
            eng.close()
    return table2_rows(reg)


def print_table2_obs(rows):
    from repro.obs.report import render_table2

    print("# Table II (obs.report): run-to-run variation of healthy "
          "dispatches per net x precision x bucket (interpret-mode "
          "wall clock; 'all' rows roll buckets up)")
    print(render_table2(rows))


def workloads_rows(workload_names=("sr", "denoise"), buckets=(1, 2, 4),
                   calls=3, precisions=("fp32", "int8")):
    """The workload zoo through the serving engine: each registered
    workload (SR head, denoising decoder, ...) is resolved from the
    registry by name, planned and served at every bucket x precision,
    and the dispatch histogram reduces to per-workload Table II rows —
    the model-agnosticity proof that new deconv towers get the same
    run-to-run-stability accounting as the paper's generators."""
    import repro.workloads as workloads
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.report import table2_rows
    from repro.serve import DcnnServeEngine, EngineConfig

    reg = MetricsRegistry()
    for name in workload_names:
        w = workloads.get(name)
        params, _ = w.init(jax.random.PRNGKey(0))
        for precision in precisions:
            eng = DcnnServeEngine.from_config(
                EngineConfig(model=name, backend="pallas",
                             precision=precision, buckets=tuple(buckets),
                             warmup=True, calib_batch=16),
                params, metrics=reg)
            for c in range(calls):
                for b in buckets:
                    x = w.calibration_batch(c + 1, b)
                    eng.generate(np.asarray(x, np.float32))
            eng.close()
    return table2_rows(reg)


def print_workloads(rows):
    from repro.obs.report import render_table2

    print("# workload zoo (repro.workloads): SR / denoising heads served "
          "through the bucketed engine, Table II statistics per "
          "workload x precision x bucket")
    print(render_table2(rows))


def serving_sweep_rows(reps: int = 3, stream=(3, 5, 1, 8, 2, 6, 4, 7)):
    """Bucketed serving engine on the MNIST generator: a mixed-size request
    stream through `DcnnServeEngine.submit/collect`, reporting end-to-end
    throughput, latency percentiles and the compile count (the
    no-per-request-recompilation acceptance: <= len(buckets))."""
    import time as _time

    from repro.serve import DcnnServeEngine, EngineConfig

    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    eng = DcnnServeEngine.from_config(
        EngineConfig(model=MNIST_DCNN, backend="pallas",
                     buckets=(1, 2, 4, 8), warmup=True), params)
    rng = np.random.RandomState(0)
    lat = []
    n_imgs = 0
    for _ in range(reps):
        for n in stream:
            z = rng.randn(n, MNIST_DCNN.z_dim).astype(np.float32)
            t0 = _time.perf_counter()
            rid = eng.submit(z)
            eng.collect(rid)
            lat.append(_time.perf_counter() - t0)
            n_imgs += n
    lat = np.asarray(lat)
    return {
        "stream": list(stream), "reps": reps,
        "buckets": list(eng.buckets),
        "compiles": eng.total_compiles,
        "trace_counts": {str(k): v for k, v in eng.trace_counts.items()},
        "throughput_img_s": n_imgs / lat.sum(),
        "p50_ms": float(np.percentile(lat, 50)) * 1e3,
        "p99_ms": float(np.percentile(lat, 99)) * 1e3,
        "cv": float(lat.std() / lat.mean()),
        "padded_images": eng.stats["padded_images"],
    }


def sharded_rows(devices: int = 8, stream=(5, 8, 19)):
    """Mesh-sharded bucket serving on forced host devices.

    Runs in a subprocess because the XLA device-count flag must be set
    before jax initializes (this process already holds a 1-device CPU
    client).  Reports bucket rounding, throughput (global and per device)
    and numerical parity vs the single-device engine; interpret-mode
    timings are a dispatch-count proxy, the structure (devices x
    per-shard tiles) is what carries over to TPU."""
    import os
    import subprocess
    import sys
    import textwrap

    import repro

    src_dir = os.path.dirname(os.path.dirname(
        os.path.abspath(repro.__file__)))
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        import jax
        import numpy as np
        from repro.launch.mesh import make_serving_mesh
        from repro.models.dcnn import MNIST_DCNN, generator_init
        from repro.serve import DcnnServeEngine, EngineConfig

        params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
        mesh = make_serving_mesh()
        eng = DcnnServeEngine.from_config(
            EngineConfig(model=MNIST_DCNN, backend="pallas", mesh=mesh,
                         buckets=(1, 2, 4, 8, 16), warmup=True), params)
        ref = DcnnServeEngine.from_config(
            EngineConfig(model=MNIST_DCNN, backend="pallas",
                         buckets=eng.buckets), params)
        rng = np.random.RandomState(0)
        err = 0.0
        for n in {tuple(stream)}:
            z = rng.randn(n, MNIST_DCNN.z_dim).astype(np.float32)
            err = max(err, float(np.abs(eng.generate(z)
                                        - ref.generate(z)).max()))
        print(json.dumps({{
            "devices": eng.n_devices,
            "buckets": list(eng.buckets),
            "stream": list({tuple(stream)}),
            "compiles": eng.total_compiles,
            "padded_images": eng.stats["padded_images"],
            "parity_max_err": err,
            "throughput": {{str(k): v for k, v in
                            eng.throughput().items()}},
        }}))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=1800,
        env={**os.environ, "PYTHONPATH": src_dir},
    )
    if proc.returncode != 0:
        return {"error": proc.stderr[-2000:]}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def print_sharded(row):
    if not row:
        return
    print("# mesh-sharded bucket serving (MNIST generator, forced host "
          "devices; per-shard autotuned tiles)")
    if "error" in row:
        print(f"sharded bench failed:\n{row['error']}")
        return
    tput = {k: f"{v['img_per_s']:.1f}" for k, v in row["throughput"].items()}
    print(f"devices={row['devices']} buckets={row['buckets']} "
          f"compiles={row['compiles']} padded={row['padded_images']} "
          f"parity_err={row['parity_max_err']:.2e} img/s per bucket={tput}")


def degraded_rows(devices: int = 8, keep: int = 4, stream=(5, 8, 19),
                  reps: int = 3):
    """Degraded-mode serving: throughput before / after losing half the
    mesh, and the cost of the elastic recovery itself.

    Same subprocess pattern as `sharded_rows` (the XLA device-count flag
    must precede jax init).  Phases: warm the full mesh and stream
    `reps` rounds for the pre-loss throughput/CV, then arm a DeviceLoss
    at the next dispatch and time the request that rides through the
    remesh (re-bucket, re-plan, re-shard), then stream again on the
    survivors for the post-loss numbers.  Plan hashes across the remesh
    come from the engine's own remesh event — on CPU interpret mode the
    absolute img/s is a dispatch proxy, but the pre/post ratio and the
    recovery split (remesh vs first-request) carry over."""
    import os
    import subprocess
    import sys
    import textwrap

    import repro

    src_dir = os.path.dirname(os.path.dirname(
        os.path.abspath(repro.__file__)))
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={devices}")
        os.environ["JAX_PLATFORMS"] = "cpu"
        import json
        import time
        import jax
        import numpy as np
        from repro.dist.inject import DeviceLoss, FaultInjector
        from repro.launch.mesh import make_serving_mesh
        from repro.models.dcnn import MNIST_DCNN, generator_init
        from repro.serve import DcnnServeEngine, EngineConfig

        params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
        inj = FaultInjector()
        eng = DcnnServeEngine.from_config(
            EngineConfig(model=MNIST_DCNN, backend="pallas",
                         mesh=make_serving_mesh(),
                         buckets=(1, 2, 4, 8, 16), warmup=True),
            params, fault_injector=inj)
        rng = np.random.RandomState(0)
        stream = {tuple(stream)}
        zs = [rng.randn(n, MNIST_DCNN.z_dim).astype(np.float32)
              for n in stream]

        def run_stream(reps):
            t0 = time.perf_counter()
            imgs = 0
            for _ in range(reps):
                for z in zs:
                    eng.collect(eng.submit(z))
                    imgs += z.shape[0]
            return imgs / (time.perf_counter() - t0)

        buckets_before = list(eng.buckets)
        pre_img_s = run_stream({reps})
        pre = {{str(k): v for k, v in eng.throughput().items()}}
        eng.bucket_stats.clear()

        # arm the loss for the very next dispatch; the request that
        # triggers it pays the full recovery (remesh + re-plan + re-run)
        inj.schedule(DeviceLoss(at_call=inj.calls, keep={keep}))
        t0 = time.perf_counter()
        eng.collect(eng.submit(zs[0]))
        recovery_s = time.perf_counter() - t0
        ev = eng.fault_stats["remesh_events"][0]

        eng.bucket_stats.clear()
        post_img_s = run_stream({reps})
        post = {{str(k): v for k, v in eng.throughput().items()}}
        print(json.dumps({{
            "devices_before": ev["devices_before"],
            "devices_after": ev["devices_after"],
            "buckets_before": buckets_before,
            "buckets_after": list(eng.buckets),
            "stream": list(stream), "reps": {reps},
            "pre_loss_img_s": pre_img_s,
            "post_loss_img_s": post_img_s,
            "pre_loss_buckets": pre,
            "post_loss_buckets": post,
            "recovery_s": recovery_s,
            "remesh_s": ev["seconds"],
            "plan_hash_matches": ev["plan_hash_matches"],
            "retries": eng.fault_stats["retries"],
        }}))
    """)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=1800,
        env={**os.environ, "PYTHONPATH": src_dir},
    )
    if proc.returncode != 0:
        return {"error": proc.stderr[-2000:]}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def print_degraded(row):
    if not row:
        return
    print("# degraded-mode serving: elastic recovery after losing half the "
          "mesh (forced host devices; img/s is a dispatch proxy)")
    if "error" in row:
        print(f"degraded bench failed:\n{row['error']}")
        return
    matches = row["plan_hash_matches"]
    print(f"devices {row['devices_before']} -> {row['devices_after']}  "
          f"buckets {row['buckets_before']} -> {row['buckets_after']}")
    print(f"pre-loss {row['pre_loss_img_s']:.1f} img/s  "
          f"post-loss {row['post_loss_img_s']:.1f} img/s "
          f"({row['post_loss_img_s'] / row['pre_loss_img_s']:.2f}x)  "
          f"recovery {row['recovery_s'] * 1e3:.0f} ms "
          f"(remesh {row['remesh_s'] * 1e3:.0f} ms)")
    print(f"plan hashes re-derived identically for shared per-device "
          f"batches: {matches} "
          f"({'all match' if all(matches.values()) else 'MISMATCH'})")
    for label, key in (("pre", "pre_loss_buckets"),
                       ("post", "post_loss_buckets")):
        tput = {k: f"{v['img_per_s']:.1f} (cv {v.get('cv', 0):.3f})"
                for k, v in row[key].items()}
        print(f"  {label}-loss per bucket img/s: {tput}")


def slo_rows(loads=(0.5, 1.0, 2.0), n_requests: int = 24,
             req_rows: int = 4, prime_reps: int = 2):
    """SLO-aware async frontend under an offered-load sweep.

    Capacity is *measured* first (`prime` feeds the service model), then
    each load point paces ``n_requests`` submissions at ``load`` x that
    capacity through two tenant classes — gold (SLO-bound, priority 0,
    degrade-tolerant) and std (no deadline) — and records the typed
    outcome mix: completed / downgraded / shed at admission / shed late,
    plus per-tenant p50/p99/CV of end-to-end latency.  The overload
    claims this pins: at 0.5x capacity nothing sheds, and at 2x the
    excess resolves as typed backpressure (AdmissionRejected), never a
    hang — the CI `test-slo` gate asserts exactly that off this JSON."""
    import time as _time

    from repro.serve import (AdmissionRejected, AsyncServeFrontend,
                             EngineConfig, TenantClass)

    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    fe = AsyncServeFrontend.from_config(
        EngineConfig(model=MNIST_DCNN, backend="pallas",
                     buckets=(1, 2, 4, 8)),
        params,
        [TenantClass("gold", slo_ms=None, priority=0),  # slo set per load
         TenantClass("std", slo_ms=None, priority=1)],
        precisions=("fp32", "int8"), prime=prime_reps,
        max_queue_rows=4 * req_rows)
    try:
        service_s = fe._model.service_seconds("fp32", req_rows,
                                              fe._buckets)
        if not service_s:
            return {"error": "prime() produced no fp32 service estimate"}
        # a gold SLO the measured fp32 path comfortably meets when the
        # queue is short: admission sheds on *load*, not on jitter
        gold_slo_ms = max(50.0, 20.0 * service_s * 1e3)
        capacity_rps = 1.0 / service_s
        rng = np.random.RandomState(0)
        rows = []
        for load in loads:
            fe.reset_stats()
            interval = 1.0 / (load * capacity_rps)
            rids, rejected = [], 0
            t_start = _time.perf_counter()
            for i in range(n_requests):
                z = rng.randn(req_rows, MNIST_DCNN.z_dim).astype(
                    np.float32)
                tenant = "gold" if i % 2 == 0 else "std"
                try:
                    rids.append(fe.submit(
                        z, tenant,
                        slo_ms=gold_slo_ms if tenant == "gold" else None))
                except AdmissionRejected:
                    rejected += 1
                _time.sleep(interval)
            hangs = 0
            for rid in rids:
                try:
                    fe.result(rid, timeout_s=120)
                except AdmissionRejected:
                    pass            # typed late shed: resolved, not hung
                except Exception:
                    hangs += 1
            wall = _time.perf_counter() - t_start
            st = fe.stats()
            shed = sum(t["shed"] for t in st["tenants"].values())
            rows.append({
                "load": load,
                "offered_rps": load * capacity_rps,
                "achieved_rps": len(rids) / wall,
                "requests": n_requests,
                "admitted": len(rids),
                "rejected_at_submit": rejected,
                "shed_total": shed,
                "hangs": hangs,
                "gold_slo_ms": gold_slo_ms,
                "tenants": st["tenants"],
                "estimates_s": st["estimates_s"],
            })
        return {"capacity_rps": capacity_rps, "req_rows": req_rows,
                "buckets": list(fe._buckets), "sweep": rows}
    finally:
        fe.close()


def print_slo(row):
    if not row:
        return
    print("# SLO-aware async frontend: offered-load sweep (gold = "
          "SLO-bound priority tenant, std = no deadline)")
    if "error" in row:
        print(f"slo bench failed:\n{row['error']}")
        return
    print(f"measured capacity ~{row['capacity_rps']:.1f} req/s at "
          f"{row['req_rows']} rows/request, buckets={row['buckets']}")
    for r in row["sweep"]:
        g = r["tenants"]["gold"]
        p99 = f"{g['p99_ms']:.1f}" if "p99_ms" in g else "n/a"
        print(f"  {r['load']:.1f}x load: admitted {r['admitted']}/"
              f"{r['requests']} shed={r['shed_total']} "
              f"downgraded={sum(t['downgraded'] for t in r['tenants'].values())} "
              f"hangs={r['hangs']} gold p99={p99} ms "
              f"(slo {r['gold_slo_ms']:.0f} ms)")


def write_json(path: str, table2, traffic, autotune, scaling,
               batch_sweep=None, serving=None, sharded=None, quant=None,
               plan=None, degraded=None, slo=None, workloads=None):
    with open(path, "w") as f:
        json.dump({"table2": table2, "traffic": traffic,
                   "autotune": autotune, "scaling": scaling,
                   "batch_sweep": batch_sweep or [],
                   "serving": serving or {},
                   "sharded": sharded or {},
                   "quant": quant or [],
                   "plan": plan or [],
                   "degraded": degraded or {},
                   "slo": slo or {},
                   "workloads": workloads or []},
                  f, indent=1, default=float)
    print(f"[bench_deconv] wrote {path}")


def print_traffic(rows):
    print("# HBM traffic per layer: modeled halo-streaming vs legacy "
          "full-image pipeline (bytes, per batch element)")
    print(f"{'net':13s} {'layer':6s} {'in-bytes/tile':>13s} {'halo-total':>12s} "
          f"{'full-image':>12s} {'reduction':>9s} {'measured':>12s}")
    for r in rows:
        meas = f"{r.get('measured_bytes', 0):12.3g}" if "measured_bytes" in r \
            else "         n/a"
        print(f"{r['net']:13s} {r['layer']:6s} {r['in_bytes_per_tile']:13d} "
              f"{r['halo_total_bytes']:12d} {r['full_image_total_bytes']:12d} "
              f"{r['traffic_reduction']:8.1f}x {meas}")


def print_autotune(rows):
    print("# autotuned tiles vs fixed ~32x32 defaults (interpret mode on "
          "CPU; identical choices are exact ties)")
    print(f"{'net':13s} {'layer':6s} {'fixed us':>10s} {'tuned us':>10s} "
          f"{'speedup':>8s}  tiles fixed -> tuned")
    for r in rows:
        ft, tt = r["fixed_tiles"], r["tuned_tiles"]
        note = " (same tiles)" if r["same_tiles"] else f" [{r['tuned_source']}]"
        print(f"{r['net']:13s} {r['layer']:6s} {r['fixed_us']:10.1f} "
              f"{r['tuned_us']:10.1f} {r['speedup']:7.2f}x  "
              f"{ft['t_oh']}x{ft['t_ow']}/{ft['t_ci']}/{ft['t_co']} -> "
              f"{tt['t_oh']}x{tt['t_ow']}/{tt['t_ci']}/{tt['t_co']}{note}")


def print_batch_sweep(rows):
    print("# batch-fused kernel (autotuned t_n) vs per-image grid (t_n=1) — "
          "interpret-mode proxy on CPU; modeled TPU roofline alongside")
    print(f"{'net':13s} {'layer':5s} {'batch':>5s} {'t_n':>4s} "
          f"{'per-img img/s':>13s} {'fused img/s':>11s} {'speedup':>8s} "
          f"{'modeled':>8s}")
    for r in rows:
        print(f"{r['net']:13s} {r['layer']:5s} {r['batch']:5d} "
              f"{r['tiles']['t_n']:4d} {r['per_image_img_s']:13.1f} "
              f"{r['fused_img_s']:11.1f} {r['speedup']:7.2f}x "
              f"{r['modeled_speedup']:7.2f}x")


def print_serving(row):
    if not row:
        return
    print("# bucketed serving engine (MNIST generator, pallas backend): "
          "mixed-size submit/collect stream")
    print(f"buckets={row['buckets']} compiles={row['compiles']} "
          f"(<= {len(row['buckets'])}) "
          f"throughput={row['throughput_img_s']:.1f} img/s "
          f"p50={row['p50_ms']:.1f} ms p99={row['p99_ms']:.1f} ms "
          f"cv={row['cv']:.3f} padded={row['padded_images']}")


def print_scaling(rows):
    print("# Eq. 5 property: input bytes/tile vs image size at a fixed "
          "32x32/128/8 tiling (CelebA-L5 layer type)")
    print(f"{'in':>4s} {'out':>4s} {'tiles':>6s} {'halo in-bytes/tile':>19s} "
          f"{'full-image in-bytes/tile':>25s}")
    for r in rows:
        print(f"{r['in_hw']:4d} {r['out_hw']:4d} {r['n_tiles']:6d} "
              f"{r['halo_in_bytes_per_tile']:19d} "
              f"{r['full_in_bytes_per_tile']:25d}")


def main(reps: int = 50, smoke: bool = False,
         json_path: str = "BENCH_deconv.json"):
    if smoke:
        t_rows = traffic_rows(batch=1, measure=True)
        s_rows = scaling_rows()
        a_rows = autotune_rows(reps=3, batch=1)
        b_rows = batch_sweep_rows(batches=(8, 64), reps=3)
        serving = serving_sweep_rows(reps=1)
        sharded = sharded_rows(devices=8, stream=(5, 8))
        degraded = degraded_rows(devices=8, keep=4, stream=(5, 8), reps=1)
        slo = slo_rows(loads=(0.5, 2.0), n_requests=8, prime_reps=1)
        q_rows = quant_rows(batch=64, mmd_n=16, calib_n=32)
        p_rows = plan_rows(batch=64)
        t2_rows = table2_obs_rows(
            specs=((MNIST_DCNN, ("fp32", "int8")), (CELEBA_DCNN, ("fp32",))),
            buckets=(1, 2, 4), calls=4)
        w_rows = workloads_rows(buckets=(1, 2), calls=2)
        print_table2_obs(t2_rows)
        print()
        print_workloads(w_rows)
        print()
        print_traffic(t_rows)
        print()
        print_scaling(s_rows)
        print()
        print_autotune(a_rows)
        print()
        print_batch_sweep(b_rows)
        print()
        print_serving(serving)
        print()
        print_sharded(sharded)
        print()
        print_degraded(degraded)
        print()
        print_slo(slo)
        print()
        print_quant(q_rows)
        print()
        print_plan_rows(p_rows)
        write_json(json_path, t2_rows, t_rows, a_rows, s_rows, b_rows,
                   serving, sharded, q_rows, p_rows, degraded, slo,
                   workloads=w_rows)
        return t2_rows
    rows = run(reps)
    print("# Table II analogue: GOps/s mean (cv) per layer; cv = run-to-run "
          "std/mean over 50 runs")
    print(f"{'net':13s} {'layer':14s} {'reverse-loop':>18s} "
          f"{'zero-insertion':>18s} {'zi-useful-MACs':>14s}")
    for r in rows:
        if r["layer"].endswith("tpu-model"):
            print(f"{r['net']:13s} {r['layer']:14s} "
                  f"{r['rl_gops']:11.1f} GOps/s (modeled; "
                  f"{r['zi_gops']:.2f} GOps/s/W @220W)")
        else:
            print(f"{r['net']:13s} {r['layer']:14s} "
                  f"{r['rl_gops']:9.2f} ({r['rl_cv']:.3f}) "
                  f"{r['zi_gops']:9.2f} ({r['zi_cv']:.3f}) "
                  f"{r['useful_mac_ratio_zi']:13.2f}")
    print()
    t_rows = traffic_rows(batch=1, measure=True)
    print_traffic(t_rows)
    print()
    s_rows = scaling_rows()
    print_scaling(s_rows)
    print()
    a_rows = autotune_rows(reps=max(3, reps // 5))
    print_autotune(a_rows)
    print()
    b_rows = batch_sweep_rows(batches=(8, 64), reps=max(3, reps // 5))
    print_batch_sweep(b_rows)
    print()
    serving = serving_sweep_rows(reps=3)
    print_serving(serving)
    print()
    sharded = sharded_rows(devices=8)
    print_sharded(sharded)
    print()
    degraded = degraded_rows(devices=8, keep=4)
    print_degraded(degraded)
    print()
    slo = slo_rows()
    print_slo(slo)
    print()
    q_rows = quant_rows(batch=64, mmd_n=32, calib_n=64)
    print_quant(q_rows)
    print()
    p_rows = plan_rows(batch=64)
    print_plan_rows(p_rows)
    print()
    t2_rows = table2_obs_rows(calls=max(4, reps // 5))
    print_table2_obs(t2_rows)
    print()
    w_rows = workloads_rows(calls=max(3, reps // 10))
    print_workloads(w_rows)
    # the artifact carries both shapes (legacy sweep + obs statistics);
    # callers iterating the return value still get only the sweep rows
    write_json(json_path, rows + t2_rows, t_rows, a_rows, s_rows, b_rows,
               serving, sharded, q_rows, p_rows, degraded, slo,
               workloads=w_rows)
    return rows


if __name__ == "__main__":
    main()
