"""Table II reproduction: per-layer throughput and run-to-run variation of
the reverse-loop deconvolution vs the conventional zero-insertion baseline.

The paper measures GOps/s/W on FPGA vs Jetson GPU.  This container is
CPU-only, so we report:
  * measured GOps/s per layer for BOTH formulations (XLA-compiled), with
    mean(std) over 50 runs — the paper's variation methodology;
  * the useful-MAC ratio (reverse-loop executes no zero-insertion MACs:
    the algorithmic advantage the FPGA exploits);
  * modeled TPU-v5e GOps/s/W from the DSE attainable throughput and a
    220 W/chip envelope (reported as modeled, not measured).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deconv import deconv2d_reverse_loop, deconv2d_zero_insertion
from repro.core.dse import TPU_V5E, layer_dse
from repro.models.dcnn import CELEBA_DCNN, MNIST_DCNN

from .common import time_fn

TPU_WATTS = 220.0  # v5e chip power envelope (modeled)
BATCH = 8


def run(reps: int = 50):
    rows = []
    for cfg in (MNIST_DCNN, CELEBA_DCNN):
        geoms = cfg.geometries()
        key = jax.random.PRNGKey(0)
        net = {"rl": [], "zi": [], "ops": []}
        for li, g in enumerate(geoms):
            x = jax.random.normal(key, (BATCH, g.in_h, g.in_w, g.c_in),
                                  jnp.float32)
            w = jax.random.normal(key, (g.kernel, g.kernel, g.c_in, g.c_out),
                                  jnp.float32) * 0.1
            b = jnp.zeros((g.c_out,), jnp.float32)
            f_rl = jax.jit(lambda x, w, b, s=g.stride, p=g.padding:
                           deconv2d_reverse_loop(x, w, b, s, p))
            f_zi = jax.jit(lambda x, w, b, s=g.stride, p=g.padding:
                           deconv2d_zero_insertion(x, w, b, s, p))
            m_rl, s_rl, _ = time_fn(f_rl, x, w, b, reps=reps)
            m_zi, s_zi, _ = time_fn(f_zi, x, w, b, reps=reps)
            ops = g.ops * BATCH
            # zero-insertion executes S^2 x the MACs (dilated input zeros)
            zi_ops = ops * g.stride ** 2
            gops_rl = ops / m_rl / 1e9
            gops_zi = ops / m_zi / 1e9
            rows.append({
                "net": cfg.name, "layer": f"L{li+1}",
                "rl_gops": gops_rl, "rl_cv": s_rl / m_rl,
                "zi_gops": gops_zi, "zi_cv": s_zi / m_zi,
                "useful_mac_ratio_zi": ops / zi_ops,
                "rl_us": m_rl * 1e6, "zi_us": m_zi * 1e6,
            })
            net["rl"].append(m_rl)
            net["zi"].append(m_zi)
            net["ops"].append(ops)
        # paper's total-network metric: sum ops / sum time
        tot_ops = sum(net["ops"])
        rows.append({
            "net": cfg.name, "layer": "Total",
            "rl_gops": tot_ops / sum(net["rl"]) / 1e9, "rl_cv": 0.0,
            "zi_gops": tot_ops / sum(net["zi"]) / 1e9, "zi_cv": 0.0,
            "useful_mac_ratio_zi": float(np.mean(
                [o / (o * g.stride ** 2) for o, g in zip(net["ops"], geoms)])),
            "rl_us": sum(net["rl"]) * 1e6, "zi_us": sum(net["zi"]) * 1e6,
        })
        # modeled TPU efficiency from DSE attainable throughput
        for li, g in enumerate(geoms):
            pts = layer_dse(g, TPU_V5E)
            best = max(pts, key=lambda p: p.attainable_ops)
            rows.append({
                "net": cfg.name, "layer": f"L{li+1}-tpu-model",
                "rl_gops": best.attainable_ops / 1e9, "rl_cv": 0.0,
                "zi_gops": best.attainable_ops / 1e9 / TPU_WATTS, "zi_cv": 0.0,
                "useful_mac_ratio_zi": 1.0,
                "rl_us": 0.0, "zi_us": 0.0,
            })
    return rows


def main(reps: int = 50):
    rows = run(reps)
    print("# Table II analogue: GOps/s mean (cv) per layer; cv = run-to-run "
          "std/mean over 50 runs")
    print(f"{'net':13s} {'layer':14s} {'reverse-loop':>18s} "
          f"{'zero-insertion':>18s} {'zi-useful-MACs':>14s}")
    for r in rows:
        if r["layer"].endswith("tpu-model"):
            print(f"{r['net']:13s} {r['layer']:14s} "
                  f"{r['rl_gops']:11.1f} GOps/s (modeled; "
                  f"{r['zi_gops']:.2f} GOps/s/W @220W)")
        else:
            print(f"{r['net']:13s} {r['layer']:14s} "
                  f"{r['rl_gops']:9.2f} ({r['rl_cv']:.3f}) "
                  f"{r['zi_gops']:9.2f} ({r['zi_cv']:.3f}) "
                  f"{r['useful_mac_ratio_zi']:13.2f}")
    return rows


if __name__ == "__main__":
    main()
