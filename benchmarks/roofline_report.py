"""§Roofline report generator: reads the dry-run JSON records and emits the
per-(arch x shape x mesh) three-term roofline table (markdown + CSV)."""
from __future__ import annotations

import glob
import json
import os

from repro.analysis.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, Roofline
from repro.configs import LM_CONFIGS, SHAPES


def load(dryrun_dir: str = "experiments/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        if rec["status"] != "ok":
            rows.append(rec)
            continue
        r = Roofline(
            arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
            chips=rec["chips"],
            flops_per_device=rec["flops_per_device"],
            bytes_per_device=rec["bytes_per_device"],
            collective_bytes_per_device=rec["collective_bytes_per_device"],
            collectives=rec.get("collectives", {}),
            peak_bytes_per_device=rec["memory_analysis"].get(
                "temp_size_in_bytes", 0)
            + rec["memory_analysis"].get("argument_size_in_bytes", 0),
            model_flops_global=rec["model_flops"],
        )
        rows.append({"status": "ok", "roofline": r, **rec})
    return rows


def table(rows, mesh: str = "pod") -> str:
    hdr = ("| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | bottleneck "
           "| MODEL/HLO | roofline-frac | HBM GB |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for rec in rows:
        if rec["mesh"] != mesh:
            continue
        if rec["status"] == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | ERROR: "
                         f"{rec.get('error','?')[:40]} |")
            continue
        r = rec["roofline"]
        lines.append(
            f"| {r.arch} | {r.shape} | {r.t_compute:.3f} | {r.t_memory:.3f} "
            f"| {r.t_collective:.3f} | {r.bottleneck} "
            f"| {r.useful_flops_ratio:.2f} | {r.roofline_fraction:.3f} "
            f"| {r.peak_bytes_per_device/2**30:.1f} |")
    return "\n".join(lines)


def main():
    rows = load()
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"# roofline: {len(ok)} compiled cells, "
          f"{sum(1 for r in rows if r['status']=='skipped')} skipped")
    print("\n## single-pod (16x16 = 256 chips)\n")
    print(table(rows, "pod"))
    print("\n## multi-pod (2x16x16 = 512 chips)\n")
    print(table(rows, "multipod"))
    # the three hillclimb candidates
    pods = [r["roofline"] for r in ok if r["mesh"] == "pod"]
    if pods:
        worst = min(pods, key=lambda r: r.roofline_fraction)
        coll = max(pods, key=lambda r: r.t_collective
                   / max(r.step_time_bound, 1e-30))
        print(f"\nworst roofline fraction: {worst.arch} x {worst.shape} "
              f"({worst.roofline_fraction:.3f})")
        print(f"most collective-bound: {coll.arch} x {coll.shape} "
              f"(t_coll {coll.t_collective:.2f}s)")
    return rows


if __name__ == "__main__":
    main()
