"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, Tuple

import jax
import numpy as np


def time_fn(fn: Callable, *args, reps: int = 50, warmup: int = 3
            ) -> Tuple[float, float, np.ndarray]:
    """Returns (mean_s, std_s, samples) over `reps` runs — the paper's
    run-to-run variation methodology (Table II reports mean and std over 50
    runs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = np.empty(reps)
    for i in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts[i] = time.perf_counter() - t0
    return float(ts.mean()), float(ts.std()), ts


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
