"""Table I reproduction: resource budget of the chosen design point.

The paper reports DSP48s/BRAMs/FFs/LUTs at T_OH=12 (MNIST) / 24 (CelebA) on
the PYNQ-Z2.  The TPU analogue of the constrained resource is VMEM: we
report, per network, the DSE-chosen unified tiling factor and the VMEM
footprint of every layer's kernel invocation at that tile (vs the 16 MiB
budget), plus the paper's own FPGA figures for the eq5 dataflow model."""
from __future__ import annotations

from repro.core.dse import PYNQ_Z2, TPU_V5E, optimize_unified_tile
from repro.core.tiling import vmem_footprint
from repro.models.dcnn import CELEBA_DCNN, MNIST_DCNN

PAPER_TABLE1 = {
    "dcnn-mnist": {"t_oh": 12, "dsp": 134, "bram": 50, "ff": 43218, "lut": 36469},
    "dcnn-celeba": {"t_oh": 24, "dsp": 134, "bram": 74, "ff": 48938, "lut": 40923},
}


def run():
    out = {}
    for cfg in (MNIST_DCNN, CELEBA_DCNN):
        geoms = cfg.geometries()
        t_tpu, _ = optimize_unified_tile(geoms, TPU_V5E)
        t_pynq, _ = optimize_unified_tile(geoms, PYNQ_Z2, co_tile=32)
        layers = []
        for g in geoms:
            t_eff = min(t_tpu, g.out_h + (-g.out_h) % g.stride)
            layers.append({
                "geom": f"{g.in_h}x{g.in_w}x{g.c_in}->"
                        f"{g.out_h}x{g.out_w}x{g.c_out} K{g.kernel}S{g.stride}",
                "vmem_bytes": vmem_footprint(g, t_eff, 128, 2),
                "pynq_bram_bytes": vmem_footprint(g, min(t_pynq, g.out_h),
                                                  32, 4, "eq5"),
            })
        out[cfg.name] = {"t_oh_tpu": t_tpu, "t_oh_pynq": t_pynq,
                         "layers": layers,
                         "paper": PAPER_TABLE1[cfg.name]}
    return out


def main():
    res = run()
    print("# Table I analogue: unified tile + on-chip budget per layer")
    for net, r in res.items():
        pp = r["paper"]
        print(f"\n{net}: unified T_OH tpu={r['t_oh_tpu']} "
              f"pynq={r['t_oh_pynq']} (paper: {pp['t_oh']}; "
              f"paper resources: {pp['dsp']} DSP48, {pp['bram']} BRAM)")
        for l in r["layers"]:
            print(f"  {l['geom']:34s} vmem {l['vmem_bytes']/2**20:6.2f} MiB"
                  f" / 16  |  pynq-eq5 {l['pynq_bram_bytes']/2**10:7.1f} KiB"
                  f" / 614")
    return res


if __name__ == "__main__":
    main()
