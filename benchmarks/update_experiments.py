"""Inject the generated roofline tables into EXPERIMENTS.md (between the
ROOFLINE_TABLE marker and §Perf)."""
from __future__ import annotations

import re

from . import roofline_report


def main():
    rows = roofline_report.load()
    ok = [r for r in rows if r["status"] == "ok"]
    n_skip = sum(1 for r in rows if r["status"] == "skipped")
    pods = [r["roofline"] for r in ok if r["mesh"] == "pod"]
    worst = min(pods, key=lambda r: r.roofline_fraction)
    coll = max(pods, key=lambda r: r.t_collective / max(r.step_time_bound, 1e-30))
    frac_nonzero = [r for r in pods if r.model_flops_global > 0]

    parts = [
        f"{len(ok)} compiled cells ({n_skip} documented skips), both meshes.",
        "",
        "### single-pod (16×16 = 256 chips)",
        "",
        roofline_report.table(rows, "pod"),
        "",
        "### multi-pod (2×16×16 = 512 chips)",
        "",
        roofline_report.table(rows, "multipod"),
        "",
        f"Post-hillclimb extremes (pod): worst roofline fraction "
        f"{worst.arch} × {worst.shape} ({worst.roofline_fraction:.3f}); "
        f"most collective-bound {coll.arch} × {coll.shape} "
        f"(t_coll {coll.t_collective:.2f}s of bound "
        f"{coll.step_time_bound:.2f}s).",
        "",
        "Decode cells show roofline-frac ~0 by construction: one token per",
        "sequence against a 32k cache is pure cache-bandwidth (the *useful*",
        "FLOPs are 2·N·B while the bound is reading the cache) — the metric",
        "that matters there is t_memory, which the int8-KV work (H2) drives.",
    ]
    block = "\n".join(parts)

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    pattern = re.compile(re.escape(marker) + r".*?(?=\n## §Perf)", re.S)
    text = pattern.sub(marker + "\n\n" + block + "\n", text)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated with", len(ok), "cells")


if __name__ == "__main__":
    main()
