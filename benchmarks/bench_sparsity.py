"""Fig. 6 reproduction: sparsity vs speedup vs generative quality, and the
Eq. 6 operating-point metric.

We train a small WGAN-GP generator on synthetic digits (enough steps for
structure), magnitude-prune at each sparsity level, and measure
  (a) the zero-skip latency model (element-level = the paper's FPGA;
      block-level = our static TPU schedule),
  (b) MMD distance to the reference distribution (median-heuristic Gaussian
      kernel, as the paper),
  (c) the Eq. 6 metric (d0/dp)(t0/tp) whose peak picks the sparsity."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dse import PYNQ_Z2
from repro.core.metric import optimal_sparsity
from repro.core.mmd import mmd
from repro.core.sparsity import prune_tree, zero_skip_stats
from repro.models.dcnn import MNIST_DCNN, generator_apply, generator_init
from repro.optim.optimizer import AdamW
from repro.train.wgan import train_wgan
from repro.data.pipeline import image_source

SPARSITIES = [0.0, 0.2, 0.4, 0.6, 0.8, 0.9, 0.95, 0.99, 0.999]


def run(train_steps: int = 12, n_samples: int = 32):
    cfg = MNIST_DCNN
    src = image_source("mnist", seed=0, batch=16)
    gp, _, _ = train_wgan(
        cfg, src, steps=train_steps, key=jax.random.PRNGKey(0),
        g_opt=AdamW(lr=2e-4, b1=0.5, b2=0.9),
        d_opt=AdamW(lr=2e-4, b1=0.5, b2=0.9),
        n_critic=2, log_every=10)

    key = jax.random.PRNGKey(42)
    z = jax.random.normal(key, (n_samples, cfg.z_dim), jnp.float32)
    # ground truth P_g = the synthetic data distribution (as the paper)
    real = jnp.asarray(np.concatenate(
        [src.batch(999)["images"], src.batch(1000)["images"]])[:n_samples])

    def pool(x):  # 28x28 -> 7x7 mean-pool: the Gaussian kernel saturates
        n = x.shape[0]  # in 784-d; low-d MMD is the sensitive comparison
        return x.reshape(n, 7, 4, 7, 4, -1).mean(axis=(2, 4)).reshape(n, -1)

    bw = None
    rows = []
    for s in SPARSITIES:
        pruned = prune_tree(gp, s)
        imgs = generator_apply(pruned, cfg, z)
        d = float(mmd(pool(real), pool(imgs))) + 1e-6
        # Latency model of the paper\'s pipelined accelerator (enhancement
        # (3)): per layer t = max(stream_time, executed_MACs / peak) — DDR
        # streaming does not shrink with weight sparsity, so zero-skip
        # speedup SATURATES at high sparsity (paper Fig. 6a shape).
        t_elem = t_blk = 0.0
        for i, (g, l) in enumerate(zip(cfg.geometries(), cfg.layers)):
            st = zero_skip_stats(np.asarray(pruned[f"l{i}"]["w"]),
                                 block_ci=8, block_co=32)
            t_mac = g.ops / PYNQ_Z2.peak_ops
            io_bytes = (g.in_h * g.in_w * g.c_in
                        + g.out_h * g.out_w * g.c_out) * PYNQ_Z2.dtype_bytes
            t_stream = io_bytes / PYNQ_Z2.bandwidth
            t_elem += max(t_stream, t_mac * st.element_macs / st.total_macs)
            t_blk += max(t_stream, t_mac * st.block_macs / st.total_macs)
        rows.append({"sparsity": s, "mmd": d,
                     "t_element": t_elem, "t_block": t_blk})

    t0e, d0 = rows[0]["t_element"], rows[0]["mmd"]
    best_e, curve_e = optimal_sparsity(
        SPARSITIES, t0e, d0, [r["t_element"] for r in rows],
        [r["mmd"] for r in rows])
    t0b = rows[0]["t_block"]
    best_b, curve_b = optimal_sparsity(
        SPARSITIES, t0b, d0, [r["t_block"] for r in rows],
        [r["mmd"] for r in rows])
    return rows, (best_e, curve_e), (best_b, curve_b)


def main():
    rows, (be, ce), (bb, cb) = run()
    print("# Fig. 6 analogue: sparsity sweep (element = FPGA zero-skip; "
          "block = TPU static schedule)")
    print(f"{'sparsity':>8s} {'speedup_elem':>12s} {'speedup_blk':>12s} "
          f"{'MMD':>8s} {'metric_elem':>11s} {'metric_blk':>11s}")
    t0e, t0b = rows[0]["t_element"], rows[0]["t_block"]
    for r, me, mb in zip(rows, ce, cb):
        print(f"{r['sparsity']:8.2f} {t0e/r['t_element']:12.2f} "
              f"{t0b/r['t_block']:12.2f} {r['mmd']:8.4f} {me:11.3f} {mb:11.3f}")
    print(f"\nEq.6 optimal sparsity: element-level {be:.2f}, "
          f"block-level {bb:.2f}")
    return rows


if __name__ == "__main__":
    main()
