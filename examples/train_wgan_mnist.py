"""End-to-end driver: train the paper's MNIST DCNN with WGAN-GP for a few
hundred steps on synthetic digits, with async checkpointing, and report the
MMD quality trajectory.

    PYTHONPATH=src python examples/train_wgan_mnist.py [--steps 200]
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import AsyncCheckpointer
from repro.core.mmd import mmd
from repro.data.pipeline import image_source
from repro.models.dcnn import MNIST_DCNN, generator_apply
from repro.optim.optimizer import AdamW
from repro.train.wgan import train_wgan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="resume (params + optimizer states + step) from "
                         "the newest checkpoint in --ckpt-dir")
    ap.add_argument("--mesh", action="store_true",
                    help="shard the critic/generator steps over every "
                         "visible device (data-parallel shard_map)")
    ap.add_argument("--backend", default="reverse_loop",
                    choices=["reverse_loop", "xla", "pallas"],
                    help="generator forward for the training loss "
                         "(pallas = batch-fused serving kernels with the "
                         "reverse-loop VJP)")
    args = ap.parse_args()

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "wgan_mnist_ckpt")
    cfg = MNIST_DCNN
    src = image_source("mnist", seed=0, batch=args.batch)
    ck = AsyncCheckpointer(ckpt_dir, keep=2)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh()
        print(f"mesh: {mesh.shape}")

    gp, dp, hist = train_wgan(
        cfg, src, steps=args.steps, key=jax.random.PRNGKey(0),
        g_opt=AdamW(lr=2e-4, b1=0.5, b2=0.9),
        d_opt=AdamW(lr=2e-4, b1=0.5, b2=0.9),
        n_critic=5, log_every=max(args.steps // 10, 1),
        ckpt=ck, ckpt_every=max(args.steps // 4, 1),
        backend=args.backend, mesh=mesh,
        resume_from=ckpt_dir if args.resume else None)
    ck.wait()

    for h in hist:
        print(f"step {h['step']:4d}  d_loss {h['d_loss']:+.4f}  "
              f"g_loss {h['g_loss']:+.4f}  wdist {h['wdist']:+.4f}  "
              f"gp {h['gp']:.4f}")

    # quality: MMD between generated samples and held-out synthetic data
    z = jax.random.normal(jax.random.PRNGKey(7), (64, cfg.z_dim))
    fake = generator_apply(gp, cfg, z).reshape(64, -1)
    # enough held-out batches to reach 64 rows whatever --batch is
    held = np.concatenate([src.batch(10_000 + i)["images"]
                           for i in range(-(-64 // args.batch))])[:64]
    real = jnp.asarray(held).reshape(64, -1)
    print(f"\nfinal MMD(fake, real) = {float(mmd(real, fake)):.4f}")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
