"""Quickstart: the paper's pipeline in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. reverse-loop deconvolution (Pallas kernel) vs the XLA baseline,
2. design-space exploration for the tiling factor (Fig. 5),
3. a few WGAN-GP training steps on synthetic digits,
4. plan/execute serving: build a NetworkPlan once (the paper's
   plan-then-execute split — geometry, tiles, precision pinned like a
   bitstream), then serve it through the EngineConfig-driven engine.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TPU_V5E, optimize_unified_tile
from repro.core.tiling import DeconvGeometry
from repro.data.pipeline import image_source
from repro.kernels.deconv2d import deconv2d, deconv2d_ref
from repro.models.dcnn import MNIST_DCNN
from repro.optim.optimizer import AdamW
from repro.plan import build_layer_plan, build_network_plan
from repro.serve import DcnnServeEngine, EngineConfig
from repro.train.wgan import train_wgan


def main():
    # 1 — the kernel, dispatched through a per-layer DeconvPlan
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 7, 7, 256), jnp.float32)
    w = jax.random.normal(key, (4, 4, 256, 128), jnp.float32) * 0.05
    b = jnp.zeros((128,), jnp.float32)
    lplan = build_layer_plan(DeconvGeometry(7, 7, 256, 128, 4, 2, 1),
                             batch=2)
    y = deconv2d(x, w, b, plan=lplan)
    y_ref = deconv2d_ref(x, w, b, 2, 1)
    print(f"[kernel] out {y.shape} via plan {lplan.tiles.as_kwargs()}, "
          f"max|err| vs oracle = {float(jnp.abs(y - y_ref).max()):.2e}")

    # 2 — DSE (paper Fig. 5)
    best, scores = optimize_unified_tile(MNIST_DCNN.geometries(), TPU_V5E)
    print(f"[dse] unified T_OH = {best} "
          f"(attainable {scores[best]/1e12:.2f} TOps/s on v5e)")

    # 3 — WGAN-GP training (paper's training framework)
    src = image_source("mnist", seed=0, batch=16)
    gp, dp, hist = train_wgan(
        MNIST_DCNN, src, steps=5, key=key,
        g_opt=AdamW(lr=2e-4, b1=0.5, b2=0.9),
        d_opt=AdamW(lr=2e-4, b1=0.5, b2=0.9),
        n_critic=2, log_every=1)
    print(f"[wgan] d_loss {hist[0]['d_loss']:.3f} -> {hist[-1]['d_loss']:.3f}"
          f", gp {hist[-1]['gp']:.3f}")

    # 4 — plan/execute serving (the paper's inference workload): the
    # network plan pins tiles + epilogues once; the engine executes it
    nplan = build_network_plan(MNIST_DCNN, batch=8, backend="pallas")
    print(f"[plan] {nplan.name} hash={nplan.stable_hash()} "
          f"modeled {nplan.modeled_network_ops()/1e9:.0f} GOps/s at batch 8")
    eng = DcnnServeEngine.from_config(
        EngineConfig(model=MNIST_DCNN, backend="pallas", buckets=(1, 2, 4, 8)),
        gp, plan=nplan)
    imgs = eng.generate(np.random.randn(8, 100).astype(np.float32))
    print(f"[serve] generated {imgs.shape} images in "
          f"[{imgs.min():.2f}, {imgs.max():.2f}] "
          f"({eng.plan_stats['builds']} plan builds beyond the pinned one)")


if __name__ == "__main__":
    main()
