"""Train an assigned LM architecture (reduced, family-faithful config) for a
few hundred steps with the resilient driver — exercises the same code path
the production launcher uses.

    PYTHONPATH=src python examples/train_lm.py --arch recurrentgemma-2b --steps 50
"""
import subprocess
import sys

if __name__ == "__main__":
    args = sys.argv[1:] or ["--arch", "deepseek-7b", "--steps", "50",
                            "--batch", "4", "--seq", "64"]
    if "--reduced" not in args:
        args.append("--reduced")
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.train", *args]))
