"""Serving driver (the paper's actual workload): batched DCNN inference
through the reverse-loop accelerator path, with the paper's throughput and
run-to-run-variation measurement.

    PYTHONPATH=src python examples/serve_dcnn.py [--net celeba] [--reqs 20]
"""
import argparse
import time

import jax
import numpy as np

from repro.models.dcnn import CELEBA_DCNN, MNIST_DCNN, generator_init
from repro.serve.engine import DcnnServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", choices=["mnist", "celeba"], default="mnist")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--reqs", type=int, default=20)
    ap.add_argument("--backend", default="reverse_loop",
                    choices=["reverse_loop", "xla", "pallas"])
    args = ap.parse_args()

    cfg = MNIST_DCNN if args.net == "mnist" else CELEBA_DCNN
    params, _ = generator_init(jax.random.PRNGKey(0), cfg)
    eng = DcnnServeEngine(cfg, params, backend=args.backend)

    ops_per_img = sum(g.ops for g in cfg.geometries())
    rng = np.random.RandomState(0)
    # warmup (compile)
    eng.generate(rng.randn(args.batch, cfg.z_dim).astype(np.float32))

    lat = []
    for _ in range(args.reqs):
        z = rng.randn(args.batch, cfg.z_dim).astype(np.float32)
        t0 = time.perf_counter()
        imgs = eng.generate(z)
        lat.append(time.perf_counter() - t0)
    lat = np.array(lat)
    gops = ops_per_img * args.batch / lat / 1e9
    print(f"{cfg.name} x{args.batch} via {args.backend}: "
          f"{gops.mean():.2f} GOps/s (std {gops.std():.2f}; "
          f"cv {lat.std()/lat.mean():.3f}) — "
          f"{1000*lat.mean():.1f} ms/request, images {imgs.shape}")


if __name__ == "__main__":
    main()
