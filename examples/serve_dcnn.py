"""Serving driver (the paper's actual workload): batched DCNN inference
through the plan/execute engine, with the paper's throughput and
run-to-run-variation measurement.

    PYTHONPATH=src python examples/serve_dcnn.py [--net celeba] [--reqs 20]
                                                 [--precision int8]
                                                 [--plan-json plan.json]
                                                 [--async [--slo-ms 50]]

``--plan-json`` writes the engine's largest-bucket NetworkPlan to disk —
the artifact a deployment pins next to its checkpoint and reloads with
``NetworkPlan.load`` to serve exactly the validated configuration.  If
the file already exists it is instead *loaded*: the static plan DRC
(`repro.analysis.check`) runs before the engine is built, and a plan
that fails prints the rule-by-rule report and exits 2 instead of
tracebacking out of the middle of engine setup.

``--async`` routes the stream through the SLO-aware `AsyncServeFrontend`
instead of the raw engine: requests carry a per-tenant deadline
(``--slo-ms``), admission control sheds typed what cannot make it, and
the scheduler downgrades fp32 requests onto the pinned int8 chain when
that is the only way to hold the SLO.

``--trace out.json`` turns on the `repro.obs` span tracer for the run
and writes a Chrome/Perfetto ``trace_event`` JSON on exit — open it at
https://ui.perfetto.dev to see admission, EDF queue wait, wave dispatch,
per-bucket kernel calls and collect as one timeline, with retries and
remesh events as instant markers.
"""
import argparse
import os
import sys
import time

import jax
import numpy as np

import repro.workloads as workloads
from repro.models.dcnn import generator_init
from repro.serve import (AdmissionRejected, AsyncServeFrontend,
                         DcnnServeEngine, EngineConfig, TenantClass)


def run_async(cfg, params, args):
    """Mixed gold/std tenant stream through the async frontend."""
    fe = AsyncServeFrontend.from_config(
        EngineConfig(model=cfg, backend=args.backend,
                     max_batch=args.batch, calib_batch=32),
        params,
        [TenantClass("gold", slo_ms=args.slo_ms, priority=0),
         TenantClass("std", slo_ms=None, priority=1)],
        precisions=("fp32", "int8"), prime=1)
    try:
        rng = np.random.RandomState(0)
        rids, rejected = [], 0
        for i in range(args.reqs):
            n = args.batch if i % 3 else max(1, args.batch - i % 5)
            z = rng.randn(n, *cfg.input_shape).astype(np.float32)
            try:
                rids.append(fe.submit(z, "gold" if i % 2 == 0 else "std"))
            except AdmissionRejected as e:
                rejected += 1
                print(f"  req {i}: shed at admission ({e.stage})")
        for rid in rids:
            try:
                fe.result(rid, timeout_s=300)
            except AdmissionRejected as e:
                print(f"  req {rid}: shed in queue ({e.stage})")
        st = fe.stats()
        print(f"{cfg.name} async serving, gold slo={args.slo_ms} ms "
              f"(admission rejected {rejected}):")
        for name, t in st["tenants"].items():
            p99 = f"{t['p99_ms']:.1f} ms" if "p99_ms" in t else "n/a"
            print(f"  {name}: completed={t['completed']} "
                  f"downgraded={t['downgraded']} shed={t['shed']} "
                  f"p99={p99}")
        print(f"  pinned plans: {sorted(fe.plan_fingerprints())}")
    finally:
        fe.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="mnist", metavar="WORKLOAD",
                    help="a registered repro.workloads name "
                         f"({', '.join(workloads.names())}); unknown "
                         "names fail typed, never fall back")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--reqs", type=int, default=20)
    ap.add_argument("--backend", default="reverse_loop",
                    choices=["reverse_loop", "xla", "pallas"])
    ap.add_argument("--precision", default="fp32", choices=["fp32", "int8"])
    ap.add_argument("--plan-json", default=None,
                    help="write the largest bucket's NetworkPlan here")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="serve through the SLO-aware async frontend")
    ap.add_argument("--slo-ms", type=float, default=200.0,
                    help="gold-tenant latency SLO for --async (ms)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Perfetto trace of the run to this path")
    args = ap.parse_args()

    if args.trace:
        from repro.obs import trace as obstrace

        obstrace.enable(clear=True)

    try:
        cfg = workloads.resolve_model(args.net)
    except workloads.WorkloadError as e:
        print(e)
        sys.exit(2)
    params, _ = generator_init(jax.random.PRNGKey(0), cfg)
    try:
        if args.use_async:
            run_async(cfg, params, args)
            return
        run_sync(cfg, params, args)
    finally:
        if args.trace:
            obstrace.disable()
            n = obstrace.get_tracer().export(args.trace)
            print(f"trace: {n} events -> {args.trace} "
                  f"(open at https://ui.perfetto.dev)")


def run_sync(cfg, params, args):
    # a pre-existing --plan-json is a pinned deployment artifact: DRC it
    # statically and serve it; a fresh path is written at the end instead
    pinned = None
    if args.plan_json and os.path.exists(args.plan_json):
        from repro.analysis.check import check_plan_json
        from repro.plan import NetworkPlan

        report = check_plan_json(args.plan_json)
        if not report.ok():
            print(f"pinned plan {args.plan_json} failed design-rule check:")
            print(report.render())
            sys.exit(2)
        pinned = NetworkPlan.load(args.plan_json)
        print(f"pinned plan {pinned.stable_hash()} <- {args.plan_json} "
              f"(DRC clean: {len(report.rules_run)} rules)")

    # plan/execute engine: one EngineConfig instead of a kwarg pile, one
    # pinned NetworkPlan + compiled executable per power-of-two bucket,
    # pre-compiled by warmup; mixed request sizes never recompile.
    eng = DcnnServeEngine.from_config(
        EngineConfig(model=cfg, backend=args.backend,
                     precision=args.precision, max_batch=args.batch,
                     warmup=True, calib_batch=32),
        params, plan=pinned)

    ops_per_img = sum(g.ops for g in cfg.geometries())
    rng = np.random.RandomState(0)

    lat = []
    imgs = None
    for i in range(args.reqs):
        # mixed sizes: full batches interleaved with ragged stragglers
        n = args.batch if i % 3 else max(1, args.batch - i % 5)
        z = rng.randn(n, *cfg.input_shape).astype(np.float32)
        t0 = time.perf_counter()
        rid = eng.submit(z)
        imgs = eng.collect(rid)
        lat.append((time.perf_counter() - t0) / n)
    lat = np.array(lat)
    gops = ops_per_img / lat / 1e9
    print(f"{cfg.name} x<= {args.batch} via {args.backend}/{args.precision}: "
          f"{gops.mean():.2f} GOps/s (std {gops.std():.2f}; "
          f"cv {lat.std()/lat.mean():.3f}) — "
          f"{1000*lat.mean():.2f} ms/image, last images {imgs.shape}, "
          f"{eng.total_compiles} compiles / {eng.plan_stats['builds']} plan "
          f"builds over {len(eng.buckets)} buckets")
    if args.plan_json and pinned is None:
        plan = eng.plans[eng.max_bucket]
        plan.to_json(args.plan_json)
        print(f"pinned plan {plan.stable_hash()} -> {args.plan_json}")


if __name__ == "__main__":
    main()
