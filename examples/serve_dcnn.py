"""Serving driver (the paper's actual workload): batched DCNN inference
through the reverse-loop accelerator path, with the paper's throughput and
run-to-run-variation measurement.

    PYTHONPATH=src python examples/serve_dcnn.py [--net celeba] [--reqs 20]
"""
import argparse
import time

import jax
import numpy as np

from repro.models.dcnn import CELEBA_DCNN, MNIST_DCNN, generator_init
from repro.serve.engine import DcnnServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", choices=["mnist", "celeba"], default="mnist")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--reqs", type=int, default=20)
    ap.add_argument("--backend", default="reverse_loop",
                    choices=["reverse_loop", "xla", "pallas"])
    args = ap.parse_args()

    cfg = MNIST_DCNN if args.net == "mnist" else CELEBA_DCNN
    params, _ = generator_init(jax.random.PRNGKey(0), cfg)
    # bucketed engine: one compiled executable per power-of-two bucket,
    # pre-compiled by warmup; mixed request sizes never recompile.
    eng = DcnnServeEngine(cfg, params, backend=args.backend,
                          max_batch=args.batch, warmup=True)

    ops_per_img = sum(g.ops for g in cfg.geometries())
    rng = np.random.RandomState(0)

    lat = []
    imgs = None
    for i in range(args.reqs):
        # mixed sizes: full batches interleaved with ragged stragglers
        n = args.batch if i % 3 else max(1, args.batch - i % 5)
        z = rng.randn(n, cfg.z_dim).astype(np.float32)
        t0 = time.perf_counter()
        rid = eng.submit(z)
        imgs = eng.collect(rid)
        lat.append((time.perf_counter() - t0) / n)
    lat = np.array(lat)
    gops = ops_per_img / lat / 1e9
    print(f"{cfg.name} x<= {args.batch} via {args.backend}: "
          f"{gops.mean():.2f} GOps/s (std {gops.std():.2f}; "
          f"cv {lat.std()/lat.mean():.3f}) — "
          f"{1000*lat.mean():.2f} ms/image, last images {imgs.shape}, "
          f"{eng.total_compiles} compiles over {len(eng.buckets)} buckets")


if __name__ == "__main__":
    main()
