"""Workload-zoo walkthrough: train a super-resolution head, pin its
plan, design-rule-check it, and serve it — one artifact end to end.

    PYTHONPATH=src python examples/serve_sr.py [--workload sr]
                                               [--steps 20] [--batch 8]
                                               [--plan-json sr_plan.json]

This is the zoo's contract in miniature: `SupervisedTrainer` with
``backend="pallas"`` trains through the *same* `build_network_plan`
executables the serving engine runs, so the plan pinned from training
is byte-for-byte the plan serving validates and loads.  The script

  1. trains the registered SR workload for a few masked-MSE steps,
  2. writes the trainer's largest-bucket `NetworkPlan` to JSON,
  3. runs the static plan DRC on the artifact (exit 2 on violation),
  4. serves one batch through `DcnnServeEngine` pinned to that plan,
  5. asserts the served output matches the reverse-loop reference.
"""
import argparse
import sys

import jax
import numpy as np

import repro.workloads as workloads
from repro.analysis.check import check_plan_json
from repro.optim.optimizer import AdamW
from repro.plan import NetworkPlan
from repro.serve import DcnnServeEngine, EngineConfig
from repro.train.supervised import train_supervised


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="sr", metavar="NAME",
                    help="a registered supervised workload "
                         f"({', '.join(workloads.names())})")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--plan-json", default="sr_plan.json")
    args = ap.parse_args()

    try:
        w = workloads.get(args.workload)
    except workloads.WorkloadError as e:
        print(e)
        sys.exit(2)
    if w.kind != "supervised":
        print(f"workload {w.name!r} is {w.kind}, not supervised; "
              "use examples/serve_dcnn.py / train_wgan_mnist.py")
        sys.exit(2)

    # 1. train on the pallas plan path (the serving executables)
    params, trainer, history = train_supervised(
        w, args.steps, jax.random.PRNGKey(0),
        AdamW(lr=1e-3), batch=args.batch, backend="pallas")
    print(f"{w.name}: trained {args.steps} steps, "
          f"loss {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f} "
          f"({trainer.total_compiles} compiles)")

    # 2. pin the largest bucket's plan as the deployment artifact
    bucket = max(trainer.plans)
    plan = trainer.plans[bucket]
    plan.to_json(args.plan_json)
    print(f"pinned plan {plan.stable_hash()} -> {args.plan_json}")

    # 3. static design-rule check before anything serves it
    report = check_plan_json(args.plan_json)
    if not report.ok():
        print(f"pinned plan {args.plan_json} failed design-rule check:")
        print(report.render())
        sys.exit(2)
    print(f"DRC clean ({len(report.rules_run)} rules, incl. "
          "drc.input_root on the image-rooted tower)")

    # 4. serve one batch through the engine pinned to the same plan
    pinned = NetworkPlan.load(args.plan_json)
    eng = DcnnServeEngine.from_config(
        EngineConfig(model=w.name, backend="pallas", precision="fp32",
                     max_batch=bucket, warmup=True, calib_batch=16),
        params, plan=pinned)
    x, _y = w.training_pairs(123, args.batch)
    out = eng.collect(eng.submit(np.asarray(x, np.float32)))

    # 5. served output must match the reference bit-for-bit (fp32)
    ref = np.asarray(w.ref(params, np.asarray(x, np.float32)))
    err = float(np.max(np.abs(np.asarray(out) - ref)))
    trained = trainer.plan_fingerprints()[bucket]
    served = eng.plans[bucket].stable_hash()
    print(f"served {out.shape} via plan {served} "
          f"(trainer pinned {trained}); max|serve - ref| = {err:.2e}")
    if served != trained:
        print("plan fingerprint mismatch between training and serving")
        sys.exit(1)
    if err > 1e-5:
        print("served output diverged from the reverse-loop reference")
        sys.exit(1)
    print("ok: train -> pin -> DRC -> serve round trip holds")


if __name__ == "__main__":
    main()
