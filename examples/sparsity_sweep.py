"""Fig. 6 workflow: train -> prune -> measure speedup + MMD -> Eq. 6 peak.

    PYTHONPATH=src python examples/sparsity_sweep.py
"""
from benchmarks.bench_sparsity import main

if __name__ == "__main__":
    main()
