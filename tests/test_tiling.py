"""Eq. 5 tile calculus properties (exhaustive small-geometry enumeration)."""
import itertools

from repro.core.tiling import (
    DeconvGeometry, exact_input_extent, in_size_for, input_tile_extent,
    legal_tile_factors, out_size, vmem_footprint,
)

GEOMS = list(itertools.product(
    range(1, 9),    # K
    range(1, 5),    # S
    range(0, 6),    # P
    range(1, 17),   # T_OH multiplier
))


def test_eq5_bounds_exact_extent():
    for k, s, p, tm in GEOMS:
        if p >= k:  # degenerate geometry (output smaller than padding)
            continue
        t_oh = tm * s  # stride-aligned tiles, as in the kernel
        exact = exact_input_extent(t_oh, k, s, p)
        bound = input_tile_extent(t_oh, k, s)
        assert exact <= bound + 1  # Eq. 5 (+1 covers the P=0 corner the
        #                            paper absorbs into its ceil)


def test_out_in_roundtrip():
    for i, k, s in itertools.product(range(1, 33), range(1, 9), range(1, 5)):
        p = min(k - 1, 1)
        o = out_size(i, k, s, p)
        assert in_size_for(o, k, s, p) == i


def test_legal_tiles_stride_aligned():
    g = DeconvGeometry(7, 7, 256, 128, 4, 2, 1)
    tiles = legal_tile_factors(g)
    assert tiles, "some tile must be legal"
    assert all(t % g.stride == 0 for t in tiles)
    for t in tiles:
        assert vmem_footprint(g, t) <= 12 * 1024 * 1024


def test_macs_and_ops():
    g = DeconvGeometry(7, 7, 256, 128, 4, 2, 1)
    assert g.ops == 2 * g.macs
    assert g.macs == 7 * 7 * 4 * 4 * 256 * 128
    assert g.out_h == 14
