"""Workload zoo: typed registry resolution, image-rooted tower parity
on the plan path (incl. the Algorithm-1 S=2/K=5 geometry), int8 chain
parity, supervised training on the serving executables with the
train -> pin -> DRC -> serve round trip, and engine/frontend serving
with workload-labeled metrics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from test_fault_serving import tmp_cache  # noqa: F401

import repro.workloads as workloads
from repro.models.dcnn import (DcnnConfig, DeconvLayerCfg, generator_apply,
                               generator_init, make_fused_generator,
                               tower_input)
from repro.obs import MetricsRegistry, table2_rows
from repro.optim.optimizer import AdamW
from repro.plan import NetworkPlan, build_network_plan
from repro.quant import (calibrate, quantize_params,
                         quantized_generator_apply, quantized_generator_ref)
from repro.serve import (AsyncServeFrontend, DcnnServeEngine, EngineConfig,
                         TenantClass)
from repro.train.supervised import SupervisedTrainer, train_supervised
from repro.train.wgan import WganTrainer

# the paper's Algorithm-1 stress geometry (S=2, K=5) on image roots:
# an SR-style single-channel chain and a denoiser-style channel hourglass
SR_K5S2 = DcnnConfig(
    name="sr-k5s2-test", z_dim=1, img_hw=25, img_c=1, in_hw=7,
    layers=(DeconvLayerCfg(1, 8, 5, 2, 2, "relu"),     # 7x7  -> 13x13
            DeconvLayerCfg(8, 1, 5, 2, 2, "tanh")))    # 13x13 -> 25x25
DAE_K5S2 = DcnnConfig(
    name="dae-k5s2-test", z_dim=1, img_hw=13, img_c=1, in_hw=4,
    layers=(DeconvLayerCfg(2, 6, 5, 2, 2, "relu"),     # 4x4  -> 7x7
            DeconvLayerCfg(6, 1, 5, 2, 2, "tanh")))    # 7x7  -> 13x13


# ---------------------------------------------------------------------------
# registry resolution (typed, never a silent fallback)
# ---------------------------------------------------------------------------
def test_builtin_names_and_aliases():
    assert set(workloads.names()) >= {"sr", "denoise", "mnist", "celeba"}
    sr = workloads.get("sr")
    assert workloads.get("sr-x2") is sr           # alias
    assert workloads.get("sr-espcn-x2") is sr     # cfg.name
    assert sr.cfg is workloads.SR_X2
    assert workloads.get("dae").cfg is workloads.DAE_DENOISE
    assert workloads.get("mnist").kind == "generative"


def test_unknown_workload_is_typed_error():
    with pytest.raises(workloads.UnknownWorkloadError) as ei:
        workloads.get("sr-typo")
    # typed: catchable as ValueError or KeyError, message lists names
    assert isinstance(ei.value, ValueError)
    assert isinstance(ei.value, KeyError)
    assert "sr" in str(ei.value) and "mnist" in str(ei.value)
    with pytest.raises(workloads.UnknownWorkloadError):
        workloads.resolve_model("mnsit")
    with pytest.raises(workloads.WorkloadError):
        workloads.resolve_model(42)
    # the engine surface: EngineConfig.model strings route through here
    with pytest.raises(workloads.UnknownWorkloadError):
        DcnnServeEngine.from_config(
            EngineConfig(model="no-such-net", buckets=(2,)), params={})


def test_resolve_model_passthrough_and_names():
    assert workloads.resolve_model("sr") is workloads.SR_X2
    assert workloads.resolve_model(SR_K5S2) is SR_K5S2
    assert workloads.workload_name_for(workloads.SR_X2) == "sr"
    # unregistered ad-hoc towers keep their own name (and still plan)
    assert workloads.workload_name_for(SR_K5S2) == "sr-k5s2-test"
    assert workloads.workload_for(SR_K5S2) is None


def test_register_collision_is_typed():
    with pytest.raises(workloads.WorkloadError):
        workloads.register(workloads.Workload(
            name="sr-clone", cfg=SR_K5S2, kind="generative",
            aliases=("sr",)))           # alias collides with builtin
    assert "sr-clone" not in workloads.names()   # nothing half-registered
    with pytest.raises(workloads.WorkloadError):
        workloads.Workload(name="bad", cfg=SR_K5S2, kind="supervised")


# ---------------------------------------------------------------------------
# input roots and calibration synthesis
# ---------------------------------------------------------------------------
def test_tower_input_rejects_workload_mixups():
    from repro.models.dcnn import MNIST_DCNN

    z = jnp.zeros((2, MNIST_DCNN.z_dim))
    assert tower_input(MNIST_DCNN, z).shape == (2, 1, 1, 100)
    img = jnp.zeros((2, 14, 14, 1))
    assert tower_input(workloads.SR_X2, img) is img
    with pytest.raises(ValueError, match="expects input rows"):
        tower_input(workloads.SR_X2, z)          # latents into an SR head
    with pytest.raises(ValueError, match="expects input rows"):
        tower_input(MNIST_DCNN, img)             # images into a latent tower


def test_calibration_input_latent_is_legacy_stable():
    from repro.models.dcnn import MNIST_DCNN

    got = workloads.calibration_input(MNIST_DCNN, seed=0, batch=8)
    want = jax.random.normal(jax.random.PRNGKey(0), (8, 100), jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_calibration_input_image_roots():
    # registered image workloads calibrate on their serving distribution
    got = workloads.calibration_input(workloads.SR_X2, seed=3, batch=4)
    want = workloads.get("sr").training_pairs(3, 4)[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # unregistered image towers fall back to unit normals over the root
    got = workloads.calibration_input(SR_K5S2, seed=1, batch=4)
    assert got.shape == (4, 7, 7, 1)


# ---------------------------------------------------------------------------
# plan-path parity: fp32 pallas vs the reverse-loop oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [SR_K5S2, DAE_K5S2],
                         ids=lambda c: c.name)
def test_alg1_s2k5_image_root_parity(tmp_cache, cfg):
    params, _ = generator_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4,) + cfg.input_shape)
    ref = generator_apply(params, cfg, x, backend="reverse_loop")
    plan = build_network_plan(cfg, batch=4, backend="pallas")
    out = make_fused_generator(cfg, plan=plan)(params, x)
    assert out.shape == (4, cfg.img_hw, cfg.img_hw, cfg.img_c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["sr", "denoise"])
def test_zoo_fp32_plan_parity_and_workload_tag(tmp_cache, name):
    w = workloads.get(name)
    params, _ = w.init(jax.random.PRNGKey(0))
    x = jnp.asarray(w.calibration_batch(0, 4))
    plan = build_network_plan(w.cfg, batch=4, backend="pallas")
    assert plan.workload == name                 # canonical registry name
    roundtrip = NetworkPlan.from_json(plan.to_json())
    assert roundtrip.workload == name
    assert roundtrip.stable_hash() == plan.stable_hash()
    out = make_fused_generator(w.cfg, plan=plan)(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w.ref(params, x)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["sr", "denoise"])
def test_zoo_int8_chain_parity(tmp_cache, name):
    w = workloads.get(name)
    params, _ = w.init(jax.random.PRNGKey(0))
    x_cal = workloads.calibration_input(w.cfg, seed=0, batch=8)
    qcfg = calibrate(params, w.cfg, x_cal)
    qp = quantize_params(params, w.cfg, qcfg)
    x = jnp.asarray(w.calibration_batch(1, 4))
    y = quantized_generator_apply(qp, w.cfg, qcfg, x)
    y_ref = quantized_generator_ref(qp, w.cfg, qcfg, x)
    assert y.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# supervised training on the serving executables
# ---------------------------------------------------------------------------
def test_supervised_masked_loss_and_bucket_reuse():
    w = workloads.get("sr")
    tr = SupervisedTrainer(w.cfg, AdamW(lr=1e-3))
    p, state = tr.init_state(jax.random.PRNGKey(0))
    x, y = w.training_pairs(0, 3)                # ragged: 3 -> bucket 4
    p2, state, met = tr.step(p, state, x, y)
    # the masked loss is the plain MSE over the 3 valid rows only
    pred = np.asarray(w.ref(p, jnp.asarray(x)))
    want = float(np.mean((pred - np.asarray(y)) ** 2))
    assert met["loss"] == pytest.approx(want, rel=1e-5)
    # a different raggedness in the same bucket must not retrace
    x4, y4 = w.training_pairs(1, 4)
    tr.step(p2, state, x4, y4)
    assert tr.trace_counts == {4: 1}


def test_supervised_trainer_rejects_bad_backends():
    w = workloads.get("denoise")
    with pytest.raises(ValueError, match="inference-only"):
        SupervisedTrainer(w.cfg, AdamW(lr=1e-3), backend="pallas_sparse")
    plan = object.__new__(NetworkPlan)           # never reached: typed first
    with pytest.raises(ValueError, match="pallas"):
        SupervisedTrainer(w.cfg, AdamW(lr=1e-3), backend="xla", plan=plan)


def test_train_pin_drc_serve_roundtrip_fp32(tmp_cache, tmp_path):
    from repro.analysis.check import check_plan_json

    w = workloads.get("sr")
    params, trainer, history = train_supervised(
        w, 3, jax.random.PRNGKey(0), AdamW(lr=1e-3), batch=4,
        backend="pallas")
    assert history[-1]["loss"] < history[0]["loss"]

    path = str(tmp_path / "sr_plan.json")
    trainer.plans[4].to_json(path)
    report = check_plan_json(path)
    assert report.ok(), report.render()
    assert "drc.input_root" in report.rules_run

    pinned = NetworkPlan.load(path)
    eng = DcnnServeEngine.from_config(
        EngineConfig(model="sr", backend="pallas", buckets=(4,),
                     calib_batch=8),
        params, plan=pinned)
    x, _ = w.training_pairs(7, 4)
    out = eng.generate(np.asarray(x, np.float32))
    # served bit-identically to the unplanned reverse-loop reference
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(w.ref(params, jnp.asarray(x))))
    assert eng.plan_stats["builds"] == 0         # pinned, not rebuilt
    assert eng.plans[4].stable_hash() == trainer.plan_fingerprints()[4]


def test_pin_serve_roundtrip_int8(tmp_cache, tmp_path):
    w = workloads.get("denoise")
    params, _ = w.init(jax.random.PRNGKey(0))
    plan = build_network_plan(w.cfg, batch=4, precision="int8",
                              params=params, calib_batch=8)
    pinned = NetworkPlan.from_json(plan.to_json())
    cfgE = EngineConfig(model="denoise", precision="int8", buckets=(4,),
                        calib_batch=8)
    eng = DcnnServeEngine.from_config(cfgE, params, plan=pinned)
    auto = DcnnServeEngine.from_config(cfgE, params)
    # image-root calibration is deterministic: the self-calibrating
    # engine derives the exact scales the pinned plan carries
    assert eng.quant_cfg == auto.quant_cfg
    x = np.asarray(w.calibration_batch(2, 4), np.float32)
    np.testing.assert_array_equal(eng.generate(x), auto.generate(x))
    qp = quantize_params(params, w.cfg, eng.quant_cfg)
    ref = quantized_generator_ref(qp, w.cfg, eng.quant_cfg, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(eng.generate(x)),
                               np.asarray(ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# trainer/serve plan-hash parity (generative side rides the same pin)
# ---------------------------------------------------------------------------
def test_wgan_trainer_consumes_pinned_plan(tmp_cache):
    from test_fault_serving import TINY

    plan = build_network_plan(TINY, batch=4, backend="pallas")
    tr = WganTrainer(TINY, AdamW(lr=1e-4), AdamW(lr=1e-4),
                     backend="pallas", plan=plan)
    tr._gen_for(4)
    assert tr.plans[4] is plan                   # substituted, not rebuilt
    assert tr.plan_fingerprints()[4] == plan.stable_hash()


def test_wgan_trainer_rejects_hash_drift(tmp_cache):
    from test_fault_serving import TINY

    plan = build_network_plan(TINY, batch=4, backend="pallas")
    drifted = dataclasses.replace(plan, workload="sr")
    tr = WganTrainer(TINY, AdamW(lr=1e-4), AdamW(lr=1e-4),
                     backend="pallas", plan=drifted)
    with pytest.raises(ValueError, match="re-pin"):
        tr._gen_for(4)


def test_supervised_trainer_rejects_hash_drift(tmp_cache):
    w = workloads.get("sr")
    plan = build_network_plan(w.cfg, batch=4, backend="pallas")
    drifted = dataclasses.replace(plan, workload="denoise")
    tr = SupervisedTrainer(w.cfg, AdamW(lr=1e-3), backend="pallas",
                           plan=drifted)
    with pytest.raises(ValueError, match="re-pin"):
        tr._gen_for(4)


# ---------------------------------------------------------------------------
# serving: workload label through engine stats, frontend and Table II
# ---------------------------------------------------------------------------
def test_frontend_serves_workload_with_labeled_metrics(tmp_cache):
    w = workloads.get("sr")
    params, _ = w.init(jax.random.PRNGKey(0))
    reg = MetricsRegistry()
    fe = AsyncServeFrontend.from_config(
        EngineConfig(model="sr", backend="pallas", buckets=(2,),
                     calib_batch=8),
        params, [TenantClass("default", slo_ms=None)],
        precisions=("fp32",), metrics=reg)
    try:
        x, _ = w.training_pairs(0, 2)
        outs = []
        for i in range(3):                       # >1 call: healthy samples
            rid = fe.submit(np.asarray(x, np.float32), "default")
            outs.append(fe.result(rid, timeout_s=300))
        st = fe.stats()
    finally:
        fe.close()
    assert st["workload"] == "sr"
    np.testing.assert_array_equal(
        np.asarray(outs[0]), np.asarray(w.ref(params, jnp.asarray(x))))
    rows = [r for r in table2_rows(reg) if r["workload"] == "sr"]
    assert rows and all(r["net"] == "sr-espcn-x2" for r in rows)
