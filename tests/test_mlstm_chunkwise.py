"""Chunkwise-parallel mLSTM == step recurrence (the §Perf H5 optimization
must be numerically equivalent, not an approximation)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.recurrent import mlstm_chunkwise


def _step_reference(q, k, v, log_i, log_f, c0, n0, m0):
    b, s, hh, dh = q.shape
    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)[..., None]
        ip = jnp.exp(li - m_new)[..., None]
        c = fp[..., None] * c + ip[..., None] * (vt[..., :, None] * kt[..., None, :])
        n = fp * n + ip * kt
        num = jnp.einsum("bhvk,bhk->bhv", c, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
        return (c, n, m_new), num / den[..., None]
    seq = tuple(t.transpose(1, 0, 2, 3).astype(jnp.float32) for t in (q, k, v)) + (
        log_i.transpose(1, 0, 2), log_f.transpose(1, 0, 2))
    (c, n, m), ys = jax.lax.scan(step, (c0, n0, m0), seq)
    return ys.transpose(1, 0, 2, 3), (c, n, m)


def test_chunkwise_equals_step():
    rng = np.random.RandomState(0)
    b, s, hh, dh = 2, 256, 2, 16
    q = jnp.array(rng.randn(b, s, hh, dh), jnp.float32)
    k = jnp.array(rng.randn(b, s, hh, dh), jnp.float32) * dh ** -0.5
    v = jnp.array(rng.randn(b, s, hh, dh), jnp.float32)
    li = jnp.array(rng.randn(b, s, hh), jnp.float32)
    lf = jnp.array(-np.abs(rng.randn(b, s, hh)), jnp.float32)
    c0 = jnp.zeros((b, hh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, hh, dh), jnp.float32)
    m0 = jnp.full((b, hh), -1e30, jnp.float32)
    h_cw, (c1, n1, m1) = mlstm_chunkwise(q, k, v, li, lf, c0, n0, m0, chunk=64)
    h_st, (c2, n2, m2) = _step_reference(q, k, v, li, lf, c0, n0, m0)
    np.testing.assert_allclose(h_cw, h_st, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(n1, n2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(c1, c2, rtol=2e-4, atol=2e-4)


def test_chunkwise_with_initial_state():
    """Chunk boundary must compose: running two halves == one pass."""
    rng = np.random.RandomState(1)
    b, s, hh, dh = 1, 256, 2, 8
    mk = lambda *sh: jnp.array(rng.randn(*sh), jnp.float32)
    q, k, v = mk(b, s, hh, dh), mk(b, s, hh, dh), mk(b, s, hh, dh)
    li, lf = mk(b, s, hh), -jnp.abs(mk(b, s, hh))
    c0 = jnp.zeros((b, hh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, hh, dh), jnp.float32)
    m0 = jnp.full((b, hh), -1e30, jnp.float32)
    h_full, st_full = mlstm_chunkwise(q, k, v, li, lf, c0, n0, m0, chunk=64)
    half = s // 2
    h1, st1 = mlstm_chunkwise(q[:, :half], k[:, :half], v[:, :half],
                              li[:, :half], lf[:, :half], c0, n0, m0, chunk=64)
    h2, st2 = mlstm_chunkwise(q[:, half:], k[:, half:], v[:, half:],
                              li[:, half:], lf[:, half:], *st1, chunk=64)
    np.testing.assert_allclose(jnp.concatenate([h1, h2], 1), h_full,
                               rtol=2e-4, atol=2e-4)
    for a, bb in zip(st2, st_full):
        np.testing.assert_allclose(a, bb, rtol=2e-4, atol=2e-4)
