"""DSE (Fig. 5), pruning, MMD, and the Eq. 6 metric."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import PYNQ_Z2, TPU_V5E, layer_dse, optimize_unified_tile, per_layer_optimum
from repro.core.metric import optimal_sparsity, quality_speed_metric
from repro.core.mmd import median_bandwidth, mmd, mmd2
from repro.core.sparsity import magnitude_prune, prune_tree
from repro.core.tiling import DeconvGeometry
from repro.models.dcnn import CELEBA_DCNN, MNIST_DCNN


def test_dse_legality_and_bandwidth_flag():
    g = MNIST_DCNN.geometries()[1]
    pts = layer_dse(g, TPU_V5E)
    assert pts
    for p in pts:
        assert p.t_oh % g.stride == 0
        assert p.attainable_ops <= TPU_V5E.peak_ops
        if p.bandwidth_bound:
            assert p.attainable_ops == pytest.approx(p.ctc * TPU_V5E.bandwidth)


def test_unified_tile_is_common_and_optimal():
    geoms = MNIST_DCNN.geometries()
    best, scores = optimize_unified_tile(geoms, TPU_V5E)
    assert best in scores
    assert scores[best] == max(scores.values())
    # per-layer reconfiguration (paper's future work) can only help
    per_layer = per_layer_optimum(geoms, TPU_V5E)
    total_ops = sum(g.ops for g in geoms)
    t_unified = sum(g.ops / scores[best] for g in geoms)  # = total/throughput
    t_per_layer = sum(g.ops / p.attainable_ops
                      for g, p in zip(geoms, per_layer))
    assert t_per_layer <= t_unified * (1 + 1e-9)


def test_dse_on_pynq_reproduces_fig5_regime():
    """On the paper's PYNQ-Z2 point design, small tiles are bandwidth-bound
    (left of the slope) and attainable throughput is monotone until the roof."""
    g = CELEBA_DCNN.geometries()[2]
    pts = layer_dse(g, PYNQ_Z2, co_tile=32)
    assert pts[0].bandwidth_bound
    atts = [p.attainable_ops for p in pts]
    assert max(atts) <= PYNQ_Z2.peak_ops


@pytest.mark.parametrize(
    "s", [0.1, 0.2, 0.33, 0.42, 0.5, 0.61, 0.7, 0.8, 0.85, 0.9])
def test_prune_fraction(s):
    rng = np.random.RandomState(0)
    w = jnp.array(rng.randn(16, 64), jnp.float32)
    wp, mask = magnitude_prune(w, s)
    frac = 1.0 - np.asarray(mask).mean()
    assert abs(frac - s) < 0.05
    # surviving weights are exactly the original large-magnitude ones
    assert np.all(np.asarray(wp)[~np.asarray(mask)] == 0)


def test_prune_tree_skips_biases(rng):
    params = {"w": jnp.array(rng.randn(8, 8), jnp.float32),
              "b": jnp.array(rng.randn(8), jnp.float32)}
    pruned = prune_tree(params, 0.9)
    assert (np.asarray(pruned["w"]) == 0).mean() > 0.8
    assert (np.asarray(pruned["b"]) == 0).mean() == 0.0


def test_mmd_zero_iff_identical(rng):
    x = jnp.array(rng.randn(64, 10), jnp.float32)
    assert float(mmd2(x, x, unbiased=False)) == pytest.approx(0.0, abs=1e-5)
    y = jnp.array(rng.randn(64, 10) + 3.0, jnp.float32)
    assert float(mmd(x, y)) > 0.3


def test_mmd_monotone_in_shift(rng):
    x = jnp.array(rng.randn(96, 8), jnp.float32)
    ds = [float(mmd(x, x + d)) for d in (0.0, 0.5, 1.0, 2.0)]
    assert ds == sorted(ds)


def test_median_bandwidth_positive(rng):
    x = jnp.array(rng.randn(32, 4), jnp.float32)
    assert float(median_bandwidth(x)) > 0


def test_eq6_metric_concave_peak():
    """Speedup grows with sparsity, quality degrades -> interior peak."""
    sparsities = np.linspace(0, 0.9, 10)
    tp = 1.0 / (1.0 + 2.0 * sparsities)          # latency falls (zero-skip)
    dp = 0.1 * (1.0 + np.exp(6 * (sparsities - 0.55)))  # MMD blows up late
    best, curve = optimal_sparsity(sparsities, tp[0], dp[0], tp, dp)
    assert 0.1 < best < 0.9
    peak = int(np.argmax(curve))
    assert 0 < peak < len(curve) - 1             # interior (concave shape)
