"""Halo-aware input tiling: BlockSpec geometry, parity vs the paper's
Algorithm 1 oracle on awkward shapes, fused epilogue, traffic invariants."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.deconv import deconv2d_algorithm1_numpy
from repro.core.tiling import (
    DeconvGeometry, deconv_traffic, exact_input_extent, full_image_traffic,
    halo_tile, kernel_vmem_bytes, out_size,
)
from repro.kernels.deconv2d import deconv2d, deconv2d_ref
from repro.kernels.deconv2d.kernel import x_halo_blockspec


# ---------------------------------------------------------------------------
# halo-tile geometry
# ---------------------------------------------------------------------------
def test_halo_extent_is_exact_input_extent():
    """The streamed window is exactly the max-over-tiles input span — no
    over-read (the whole point of the tentpole)."""
    for k, s, p in itertools.product(range(1, 8), range(1, 5), range(0, 4)):
        if p >= k:
            continue
        for tm in (1, 2, 3, 5):
            t = tm * s
            ht = halo_tile(t, k, s, p)
            assert ht.extent == exact_input_extent(t, k, s, p)
            assert ht.step == t // s
            assert ht.base >= 0  # host left-halo keeps every window in bounds
            assert ht.overlap == ht.extent - ht.step


def test_x_blockspec_shape_and_index_map():
    """Acceptance: the x BlockSpec no longer spans the full padded input —
    the per-program block is the halo window and its index map follows the
    *output* grid (element offsets advancing by t_oh/S per tile)."""
    k, s, p = 4, 2, 1
    t_oh, t_ow, t_ci = 8, 8, 32
    ht = halo_tile(t_oh, k, s, p)
    bs = x_halo_blockspec(ht, ht, t_ci)
    assert tuple(bs.block_shape) == (1, ht.extent, ht.extent, t_ci)
    assert ht.extent == 6  # 8/2 + delta span 2: constant, image-independent
    # index map follows the output-tile grid, not a constant (0, 0) base
    for oh_t, ow_t, ci_t in [(0, 0, 0), (1, 0, 0), (2, 3, 1), (5, 7, 2)]:
        got = bs.index_map(1, oh_t, ow_t, 0, ci_t)
        assert got == (1, oh_t * ht.step + ht.base,
                       ow_t * ht.step + ht.base, ci_t * t_ci)


def test_windows_cover_padded_input_exactly():
    """The last tile's window ends exactly at the padded extent the ops
    wrapper produces (no slack, no out-of-bounds)."""
    from repro.core.offsets import make_phase_plan

    for k, s, p, ih, t in [(4, 2, 1, 7, 4), (5, 2, 2, 4, 4), (3, 3, 1, 8, 9),
                           (7, 1, 0, 1, 7), (4, 2, 1, 16, 8)]:
        plan = make_phase_plan(k, s, p)
        oh = out_size(ih, k, s, p)
        ohp = -(-oh // t) * t
        n_h_pad = ohp // s
        pad_l = plan.left_halo
        pad_rh = max(0, (n_h_pad - 1 + plan.delta_max) - (ih - 1))
        ihp = ih + pad_l + pad_rh
        ht = halo_tile(t, k, s, p)
        need = ht.min_padded_extent(ohp // t)
        assert need <= ihp
        # ...and is tight whenever padding was actually added on the right
        if pad_rh > 0:
            assert need == ihp


# ---------------------------------------------------------------------------
# parity vs Algorithm 1 on non-stride-aligned / non-square shapes
# ---------------------------------------------------------------------------
ALG1_GEOMS = [
    # (ih, iw, ci, co, k, s, p, t) — OH=7, S=2, K=5: the CelebA-layer
    # geometry from the issue (odd output, ragged last tile)
    (4, 4, 6, 5, 5, 2, 2, 4),
    # non-square input AND output (oh=7, ow=11)
    (4, 6, 3, 4, 5, 2, 2, 4),
    # non-square with non-dividing tile on both dims
    (5, 3, 4, 7, 4, 2, 1, 6),
    # stride-3 ragged edge
    (4, 5, 2, 3, 5, 3, 1, 6),
]


@pytest.mark.parametrize("geom", ALG1_GEOMS)
def test_kernel_matches_algorithm1(geom, rng):
    ih, iw, ci, co, k, s, p, t = geom
    x = rng.randn(2, ih, iw, ci).astype(np.float32)
    w = (rng.randn(k, k, ci, co) * 0.1).astype(np.float32)
    b = (rng.randn(co) * 0.1).astype(np.float32)
    y = deconv2d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), s, p,
                 t_oh=t, t_ow=t)
    for n in range(x.shape[0]):
        y_ref, _ = deconv2d_algorithm1_numpy(x[n], w, b, s, p)
        np.testing.assert_allclose(
            np.asarray(y[n]), y_ref.astype(np.float32), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("geom", ALG1_GEOMS)
def test_batch_fused_kernel_matches_algorithm1(geom, rng):
    """The batch-tiled grid (t_n=2, batch 5: ragged last batch tile) is
    bit-compatible with the per-image Algorithm 1 oracle on the same
    awkward shapes."""
    ih, iw, ci, co, k, s, p, t = geom
    x = rng.randn(5, ih, iw, ci).astype(np.float32)
    w = (rng.randn(k, k, ci, co) * 0.1).astype(np.float32)
    b = (rng.randn(co) * 0.1).astype(np.float32)
    y = deconv2d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), s, p,
                 t_oh=t, t_ow=t, t_n=2)
    for n in range(x.shape[0]):
        y_ref, _ = deconv2d_algorithm1_numpy(x[n], w, b, s, p)
        np.testing.assert_allclose(
            np.asarray(y[n]), y_ref.astype(np.float32), rtol=1e-4, atol=1e-4)


def test_x_blockspec_batch_tile():
    """The batch-tiled x BlockSpec streams t_n images' windows per program;
    the (unblocked) index map advances by t_n elements on the batch dim."""
    k, s, p = 4, 2, 1
    t_oh, t_ci, t_n = 8, 32, 4
    ht = halo_tile(t_oh, k, s, p)
    bs = x_halo_blockspec(ht, ht, t_ci, t_n)
    assert tuple(bs.block_shape) == (t_n, ht.extent, ht.extent, t_ci)
    for nb, oh_t, ow_t, ci_t in [(0, 0, 0, 0), (3, 1, 2, 1), (7, 5, 0, 2)]:
        got = bs.index_map(nb, oh_t, ow_t, 0, ci_t)
        assert got == (nb * t_n, oh_t * ht.step + ht.base,
                       ow_t * ht.step + ht.base, ci_t * t_ci)


@pytest.mark.parametrize("activation", ["relu", "tanh"])
def test_fused_epilogue_matches_unfused(activation, rng):
    x = jnp.array(rng.randn(2, 5, 7, 8), jnp.float32)
    w = jnp.array(rng.randn(4, 4, 8, 12) * 0.1, jnp.float32)
    b = jnp.array(rng.randn(12) * 0.1, jnp.float32)
    y = deconv2d(x, w, b, 2, 1, activation=activation)
    y_ref = deconv2d_ref(x, w, b, 2, 1)
    y_ref = jnp.maximum(y_ref, 0) if activation == "relu" else jnp.tanh(y_ref)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_sparse_epilogue(rng):
    from repro.kernels.deconv2d_sparse import deconv2d_sparse

    x = jnp.array(rng.randn(1, 7, 7, 16), jnp.float32)
    w = jnp.array(rng.randn(4, 4, 16, 16) * 0.1, jnp.float32)
    b = jnp.array(rng.randn(16), jnp.float32)
    y = deconv2d_sparse(x, w, b, 2, 1, t_ci=8, t_co=8, activation="relu")
    y_ref = jnp.maximum(deconv2d_ref(x, w, b, 2, 1), 0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# traffic model invariants
# ---------------------------------------------------------------------------
def test_in_bytes_per_tile_independent_of_image_size():
    """Acceptance: modeled HBM bytes/tile do not grow with the image."""
    per_tile = set()
    for in_hw in (8, 16, 32, 64, 128):
        g = DeconvGeometry(in_hw, in_hw, 64, 16, 4, 2, 1)
        t = deconv_traffic(g, 16, 16, 64, 16, 4)
        per_tile.add((t.in_bytes_per_tile, t.w_bytes_per_tile,
                      t.out_bytes_per_tile))
    assert len(per_tile) == 1


def test_halo_traffic_below_full_image_when_tiled():
    g = DeconvGeometry(32, 32, 128, 3, 4, 2, 1)  # CelebA L5
    halo = deconv_traffic(g, 32, 32, 128, 8, 4)
    full = full_image_traffic(g, 32, 32, 128, 8, 4)
    # 4 spatial tiles share halos instead of re-streaming the image
    assert halo.total_bytes < full.total_bytes
    assert halo.in_bytes_per_tile < full.in_bytes_per_tile


def test_kernel_vmem_bytes_monotone_in_tiles():
    g = DeconvGeometry(16, 16, 256, 256, 4, 2, 1)
    small = kernel_vmem_bytes(g, 8, 8, 64, 64)
    big = kernel_vmem_bytes(g, 32, 32, 256, 256)
    assert small < big
    # ...and in the batch tile: x/y/acc scale with t_n, weights do not
    assert kernel_vmem_bytes(g, 8, 8, 64, 64, t_n=4) > small
    assert kernel_vmem_bytes(g, 8, 8, 64, 64, t_n=4) < 4 * small


def test_batched_traffic_amortizes_weights():
    """The batch-fused traffic model: per-image input/output bytes are
    t_n-invariant while per-image *weight* bytes fall by t_n (one slab per
    CI step serves t_n images) — the spatio-temporal amortization."""
    from repro.core.tiling import deconv_traffic_batched

    g = DeconvGeometry(1, 1, 100, 1024, 4, 1, 0)  # CelebA L1
    batch = 64
    t1 = deconv_traffic_batched(g, batch, 1, 4, 4, 104, 128)
    t64 = deconv_traffic_batched(g, batch, 64, 4, 4, 104, 128)
    # total bytes strictly fall with batch fusion...
    assert t64.total_bytes < t1.total_bytes
    # ...input stream per image unchanged (n_tiles shrank by 64, window x64)
    assert t64.in_bytes_per_tile == 64 * t1.in_bytes_per_tile
    assert t64.n_tiles * 64 == t1.n_tiles
    # ...and the whole saving is the amortized weight stream
    w1 = t1.n_tiles * t1.n_ci_steps * t1.w_bytes_per_tile
    w64 = t64.n_tiles * t64.n_ci_steps * t64.w_bytes_per_tile
    assert w64 * 64 == w1
    assert t1.total_bytes - t64.total_bytes == w1 - w64


def test_batched_attainable_improves_on_row_starved_layer():
    """DSE: on the 4x4-output fat-channel CelebA L1 (16 rows vs the 128-row
    MXU) the modeled attainable throughput strictly improves with t_n."""
    from repro.core.dse import TPU_V5E, tile_attainable

    g = DeconvGeometry(1, 1, 100, 1024, 4, 1, 0)
    a1 = tile_attainable(g, 4, 4, 104, 128, TPU_V5E, t_n=1, batch=64)
    a8 = tile_attainable(g, 4, 4, 104, 128, TPU_V5E, t_n=8, batch=64)
    a64 = tile_attainable(g, 4, 4, 104, 128, TPU_V5E, t_n=64, batch=64)
    assert a1.attainable_ops < a8.attainable_ops < a64.attainable_ops
