"""Launcher entry points run end-to-end (subprocess)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def test_train_launcher_reduced(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "chatglm3-6b",
         "--reduced", "--steps", "4", "--batch", "2", "--seq", "32",
         "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=600, env=ENV, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "done: 4 steps" in proc.stdout


def test_train_launcher_with_compression(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "deepseek-7b",
         "--reduced", "--steps", "3", "--batch", "2", "--seq", "16",
         "--grad-accum", "2", "--compress-grads"],
        capture_output=True, text=True, timeout=600, env=ENV, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "done: 3 steps" in proc.stdout
