"""Concurrency lint: the shipped serve stack is clean (regression for
the lock-discipline bugs this checker found and fixed), and synthetic
fixtures fire each lint rule by id."""
import textwrap

import pytest

from repro.analysis.check import (Allowlist, DEFAULT_ALLOWLIST, lint_file,
                                  lint_files)


def _lint_src(tmp_path, src, allowlist=None, name="mod.py"):
    path = tmp_path / name
    path.write_text(textwrap.dedent(src))
    return lint_file(str(path), allowlist=allowlist)


def _fired(report):
    return sorted({v.rule_id for v in report.failures(strict=True)})


# ---------------------------------------------------------------------------
# the real serve stack (regression: engine fault_stats writes now under
# _qlock, frontend start()/close() check-and-set under _cond)
# ---------------------------------------------------------------------------
def test_serve_stack_is_lint_clean():
    report = lint_files()
    assert report.ok(strict=True), report.render(strict=True)
    assert set(report.rules_run) >= {
        "lint.unguarded_write", "lint.unguarded_read", "lint.lock_order",
        "lint.callback_in_lock", "lint.check_then_act"}


def test_serve_stack_clean_even_without_read_allowlist():
    # the default allowlist only waives *reads* of snapshot dicts; the
    # write discipline must hold with no allowlist at all
    report = lint_files(allowlist=Allowlist([]))
    writes = [v for v in report.violations
              if v.rule_id == "lint.unguarded_write"]
    assert not writes, "\n".join(v.render() for v in writes)


# ---------------------------------------------------------------------------
# synthetic fixtures: one rule each
# ---------------------------------------------------------------------------
def test_unguarded_write_fires(tmp_path):
    report = _lint_src(tmp_path, """
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0

            def good(self):
                with self._lock:
                    self.count += 1

            def bad(self):
                self.count += 1
        """)
    assert _fired(report) == ["lint.unguarded_write"]
    v, = report.errors()
    assert "count" in v.message and "Counter.bad" in v.location


def test_unguarded_read_warns(tmp_path):
    report = _lint_src(tmp_path, """
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0

            def bump(self):
                with self._lock:
                    self.count += 1

            def peek(self):
                return self.count
        """)
    assert report.ok(strict=False)          # WARNING: gates only strictly
    assert _fired(report) == ["lint.unguarded_read"]


def test_lock_order_inversion_fires(tmp_path):
    report = _lint_src(tmp_path, """
        import threading

        class TwoLocks:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def one(self):
                with self._a:
                    with self._b:
                        pass

            def two(self):
                with self._b:
                    with self._a:
                        pass
        """)
    assert _fired(report) == ["lint.lock_order"]
    v, = report.errors()
    assert "_a" in v.message and "_b" in v.message


def test_callback_under_lock_warns(tmp_path):
    report = _lint_src(tmp_path, """
        import threading

        class Watcher:
            def __init__(self, cb):
                self._lock = threading.Lock()
                self.on_failure = cb

            def fire(self):
                with self._lock:
                    self.on_failure()
        """)
    assert _fired(report) == ["lint.callback_in_lock"]


def test_check_then_act_fires(tmp_path):
    report = _lint_src(tmp_path, """
        import threading

        class Startable:
            def __init__(self):
                self._lock = threading.Lock()
                self._started = False

            def start(self):
                if not self._started:
                    self._started = True
        """)
    assert _fired(report) == ["lint.check_then_act"]


def test_locked_helper_inherits_call_site_locks(tmp_path):
    # the repo convention: _foo_locked helpers run under their callers'
    # lock and must not be flagged
    report = _lint_src(tmp_path, """
        import threading

        class Queue:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []

            def put(self, x):
                with self._lock:
                    self.items = self.items + [x]

            def drain(self):
                with self._lock:
                    return self._drain_locked()

            def _drain_locked(self):
                out, self.items = self.items, []
                return out
        """)
    assert report.ok(strict=True), report.render(strict=True)


def test_locked_helper_with_unlocked_call_site_is_flagged(tmp_path):
    report = _lint_src(tmp_path, """
        import threading

        class Queue:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []

            def put(self, x):
                with self._lock:
                    self.items = self.items + [x]

            def drain(self):
                return self._drain_locked()   # caller forgot the lock

            def _drain_locked(self):
                out, self.items = self.items, []
                return out
        """)
    assert "lint.unguarded_write" in _fired(report)


def test_explicit_acquire_release_tracked(tmp_path):
    # the engine's collect() pattern: acquire(timeout=...) + try/finally
    report = _lint_src(tmp_path, """
        import threading

        class Collector:
            def __init__(self):
                self._lock = threading.Lock()
                self.results = {}

            def put(self, k, v):
                with self._lock:
                    self.results[k] = v

            def take(self, k):
                if not self._lock.acquire(timeout=1.0):
                    raise TimeoutError
                try:
                    return self.results.pop(k, None)
                finally:
                    self._lock.release()
        """)
    assert report.ok(strict=True), report.render(strict=True)


def test_lockless_class_is_not_linted(tmp_path):
    report = _lint_src(tmp_path, """
        class Plain:
            def __init__(self):
                self.x = 0

            def bump(self):
                self.x += 1
        """)
    assert report.ok(strict=True) and not report.violations


# ---------------------------------------------------------------------------
# allowlist
# ---------------------------------------------------------------------------
def test_allowlist_suppresses(tmp_path):
    src = """
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0

            def bump(self):
                with self._lock:
                    self.count += 1

            def bad(self):
                self.count += 1

            def peek(self):
                return self.count
        """
    assert _fired(_lint_src(tmp_path, src)) == [
        "lint.unguarded_read", "lint.unguarded_write"]
    # Counter.count:read waives only the read
    only_read = _lint_src(tmp_path, src,
                          allowlist=Allowlist(["Counter.count:read"]))
    assert _fired(only_read) == ["lint.unguarded_write"]
    # Counter.count waives both
    both = _lint_src(tmp_path, src, allowlist=Allowlist(["Counter.count"]))
    assert both.ok(strict=True)


def test_allowlist_parsing():
    a = Allowlist(["# comment", "", "C.x", "D.y:read  # inline"])
    assert a.allows("C", "x", "write") and a.allows("C", "x", "read")
    assert a.allows("D", "y", "read") and not a.allows("D", "y", "write")
    with pytest.raises(ValueError):
        Allowlist(["noclassattr"])
    with pytest.raises(ValueError):
        Allowlist(["C.x:sometimes"])


def test_allowlist_load(tmp_path):
    p = tmp_path / "allow.txt"
    p.write_text("# stats snapshots\nC.x:read\n")
    a = Allowlist.load(str(p))
    assert a.allows("C", "x", "read") and not a.allows("C", "x", "write")


def test_default_allowlist_documents_engine_stats():
    assert DEFAULT_ALLOWLIST.allows("DcnnServeEngine", "stats", "write")
    assert DEFAULT_ALLOWLIST.allows("DcnnServeEngine", "fault_stats",
                                    "read")
    assert not DEFAULT_ALLOWLIST.allows("DcnnServeEngine", "fault_stats",
                                        "write")
