"""Serving engine: generation correctness vs full recompute, continuous
batching, DCNN serving."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models.dcnn import MNIST_DCNN, generator_init
from repro.models.transformer import apply_lm, init_lm
from repro.serve.engine import DcnnServeEngine, Request, ServeEngine
from repro.serve.sampling import sample


def test_greedy_generation_matches_full_recompute(rng):
    cfg = reduced_config("deepseek-7b")
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    b, s, new = 2, 8, 4
    prompts = rng.randint(1, cfg.vocab_size, (b, s)).astype(np.int32)
    eng = ServeEngine(cfg, params, batch_size=b, max_len=s + new)
    out = eng.generate(prompts, max_new_tokens=new)
    assert out.shape == (b, new)
    # oracle: token-by-token argmax with full recompute each step
    seq = jnp.asarray(prompts)
    for t in range(new):
        logits, _, _ = apply_lm(params, cfg, seq, mode="train")
        nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        np.testing.assert_array_equal(np.asarray(nxt)[:, 0], out[:, t])
        seq = jnp.concatenate([seq, nxt.astype(jnp.int32)], axis=1)


def test_continuous_batching_slots(rng):
    cfg = reduced_config("chatglm3-6b")
    params, _ = init_lm(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    reqs = [Request(prompt=rng.randint(1, cfg.vocab_size, (np.random.randint(3, 7),)).astype(np.int32),
                    max_new_tokens=3) for _ in range(5)]
    done = eng.serve(reqs)
    assert len(done) == 5
    for r in done:
        assert r.out is not None and r.out.shape == (3,)


def test_sampling_modes(rng):
    logits = jnp.array(rng.randn(4, 50), jnp.float32)
    g = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(g), np.argmax(logits, -1))
    t = sample(logits, jax.random.PRNGKey(0), temperature=1.0, top_k=5)
    # top-k restricts support
    topk = np.argsort(np.asarray(logits), -1)[:, -5:]
    for i in range(4):
        assert int(t[i]) in topk[i]


def test_dcnn_serve_engine(rng):
    cfg = MNIST_DCNN
    p, _ = generator_init(jax.random.PRNGKey(0), cfg)
    eng = DcnnServeEngine(cfg, p, backend="pallas")
    imgs = eng.generate(rng.randn(4, cfg.z_dim).astype(np.float32))
    assert imgs.shape == (4, 28, 28, 1)
    assert np.isfinite(imgs).all() and np.abs(imgs).max() <= 1.0 + 1e-5
