"""Serving engine: generation correctness vs full recompute, continuous
batching, DCNN serving."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models.dcnn import MNIST_DCNN, generator_init
from repro.models.transformer import apply_lm, init_lm
from repro.serve.engine import DcnnServeEngine, Request, ServeEngine
from repro.serve.sampling import sample


def test_greedy_generation_matches_full_recompute(rng):
    cfg = reduced_config("deepseek-7b")
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    b, s, new = 2, 8, 4
    prompts = rng.randint(1, cfg.vocab_size, (b, s)).astype(np.int32)
    eng = ServeEngine(cfg, params, batch_size=b, max_len=s + new)
    out = eng.generate(prompts, max_new_tokens=new)
    assert out.shape == (b, new)
    # oracle: token-by-token argmax with full recompute each step
    seq = jnp.asarray(prompts)
    for t in range(new):
        logits, _, _ = apply_lm(params, cfg, seq, mode="train")
        nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        np.testing.assert_array_equal(np.asarray(nxt)[:, 0], out[:, t])
        seq = jnp.concatenate([seq, nxt.astype(jnp.int32)], axis=1)


def test_continuous_batching_slots(rng):
    cfg = reduced_config("chatglm3-6b")
    params, _ = init_lm(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    reqs = [Request(prompt=rng.randint(1, cfg.vocab_size, (np.random.randint(3, 7),)).astype(np.int32),
                    max_new_tokens=3) for _ in range(5)]
    done = eng.serve(reqs)
    assert len(done) == 5
    for r in done:
        assert r.out is not None and r.out.shape == (3,)


def test_continuous_batching_midflight_admission(rng):
    """Satellite fix: a queued request is admitted the moment a slot frees
    — mid-flight — instead of waiting for the whole chunk.  With budgets
    (1, 5, 3) on 2 slots, chunked scheduling needs max(1,5) + 3 = 8
    sampling steps; continuous batching finishes in 5.  Outputs are pinned
    to the greedy full-recompute oracle: solo semantics for requests
    admitted without padding, and the padded-history continuation for the
    mid-flight admission (left-pad tokens are visible to the causal,
    unmasked model — the engine's documented padding semantics)."""
    from repro.models.transformer import apply_lm as _apply_lm

    cfg = reduced_config("chatglm3-6b")
    params, _ = init_lm(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    prompts = [rng.randint(1, cfg.vocab_size, (4,)).astype(np.int32)
               for _ in range(3)]
    reqs = [Request(prompt=p, max_new_tokens=m)
            for p, m in zip(prompts, (1, 5, 3))]
    done = eng.serve(reqs)
    assert len(done) == 3
    assert eng.sample_steps == 5          # chunked would take 8
    assert eng.prefill_steps == 2         # t=0 admission + mid-flight one

    def greedy_oracle(seq, n_new):
        seq = jnp.asarray(np.asarray(seq, np.int32))[None]
        out = []
        for _ in range(n_new):
            logits, _, _ = _apply_lm(params, cfg, seq, mode="train")
            nxt = jnp.argmax(logits[:, -1], -1)
            out.append(int(nxt[0]))
            seq = jnp.concatenate([seq, nxt[:, None].astype(jnp.int32)], 1)
        return np.asarray(out, np.int32)

    # slots filled at t=0: exact solo semantics
    np.testing.assert_array_equal(reqs[0].out, greedy_oracle(prompts[0], 1))
    np.testing.assert_array_equal(reqs[1].out, greedy_oracle(prompts[1], 5))
    # admitted when req 0's slot freed (other slot at history 5): the
    # oracle continuation of its 1-token-left-padded history
    np.testing.assert_array_equal(
        reqs[2].out, greedy_oracle([0] + list(prompts[2]), 3))


def test_continuous_batching_heterogeneous_budgets(rng):
    """Every request generates exactly its own budget (no slot burns steps
    on a chunk-max budget) and all requests complete."""
    cfg = reduced_config("chatglm3-6b")
    params, _ = init_lm(jax.random.PRNGKey(2), cfg)
    eng = ServeEngine(cfg, params, batch_size=3, max_len=48)
    budgets = [2, 7, 1, 4, 3, 1, 5]
    reqs = [Request(prompt=rng.randint(1, cfg.vocab_size, (5,))
                    .astype(np.int32), max_new_tokens=m) for m in budgets]
    done = eng.serve(reqs)
    assert len(done) == len(budgets)
    for r in done:
        assert r.out.shape == (r.max_new_tokens,)
    # work-conserving bound: total sampled tokens can't exceed what a
    # perfectly packed schedule plus slot-idle tails would produce, and is
    # strictly below the chunked schedule's sum of per-chunk maxima
    chunked = 7 + 3 + 5   # chunks (2,7,1), (4,3,1), (5) at chunk-max each
    assert eng.sample_steps < chunked


def test_continuous_batching_zero_budget_and_overflow(rng):
    """Review regressions: a max_new_tokens=0 request completes (empty
    output) instead of pinning its slot forever, and a history+budget that
    would overflow the KV cache fails loudly instead of silently clamping
    cache writes."""
    import pytest

    cfg = reduced_config("chatglm3-6b")
    params, _ = init_lm(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    prompt = rng.randint(1, cfg.vocab_size, (4,)).astype(np.int32)
    reqs = [Request(prompt=prompt, max_new_tokens=0),
            Request(prompt=prompt, max_new_tokens=2),
            Request(prompt=prompt, max_new_tokens=0)]
    done = eng.serve(reqs)
    assert len(done) == 3
    assert reqs[0].out.shape == (0,) and reqs[2].out.shape == (0,)
    assert reqs[1].out.shape == (2,)
    # all-zero-budget stream terminates without touching the model
    done2 = eng.serve([Request(prompt=prompt, max_new_tokens=0)])
    assert len(done2) == 1 and eng.sample_steps == 0
    # budget overflow: 4-token prompt + 40 new > max_len=32
    with pytest.raises(AssertionError, match="max_len"):
        eng.serve([Request(prompt=prompt, max_new_tokens=40)])


def test_sampling_modes(rng):
    logits = jnp.array(rng.randn(4, 50), jnp.float32)
    g = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(g), np.argmax(logits, -1))
    t = sample(logits, jax.random.PRNGKey(0), temperature=1.0, top_k=5)
    # top-k restricts support
    topk = np.argsort(np.asarray(logits), -1)[:, -5:]
    for i in range(4):
        assert int(t[i]) in topk[i]


def test_dcnn_serve_engine(rng):
    cfg = MNIST_DCNN
    p, _ = generator_init(jax.random.PRNGKey(0), cfg)
    eng = DcnnServeEngine(cfg, p, backend="pallas")
    imgs = eng.generate(rng.randn(4, cfg.z_dim).astype(np.float32))
    assert imgs.shape == (4, 28, 28, 1)
    assert np.isfinite(imgs).all() and np.abs(imgs).max() <= 1.0 + 1e-5
