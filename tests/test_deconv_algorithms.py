"""Agreement of the three deconvolution formulations + Algorithm 1 MACs."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.deconv import (
    deconv2d_algorithm1_numpy, deconv2d_reverse_loop, deconv2d_zero_insertion,
)
from repro.core.sparsity import magnitude_prune

GEOMS = [
    # (ih, iw, ci, co, k, s, p)
    (7, 7, 8, 16, 4, 2, 1),     # MNIST L2 shape family
    (1, 1, 8, 16, 7, 1, 0),     # MNIST L1 (projection from z)
    (1, 1, 8, 16, 4, 1, 0),     # CelebA L1
    (5, 6, 3, 5, 3, 2, 0),
    (4, 4, 2, 3, 5, 3, 2),
    (6, 5, 4, 4, 4, 1, 2),
    (3, 3, 2, 2, 2, 4, 0),      # stride > kernel (holes)
]


@pytest.mark.parametrize("geom", GEOMS)
def test_reverse_loop_matches_zero_insertion(geom, rng):
    ih, iw, ci, co, k, s, p = geom
    x = jnp.array(rng.randn(2, ih, iw, ci), jnp.float32)
    w = jnp.array(rng.randn(k, k, ci, co), jnp.float32)
    b = jnp.array(rng.randn(co), jnp.float32)
    y_ref = deconv2d_zero_insertion(x, w, b, s, p)
    y_rl = deconv2d_reverse_loop(x, w, b, s, p)
    np.testing.assert_allclose(y_rl, y_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("geom", GEOMS[:5])
def test_algorithm1_literal_matches(geom, rng):
    ih, iw, ci, co, k, s, p = geom
    x = rng.randn(ih, iw, ci).astype(np.float32)
    w = rng.randn(k, k, ci, co).astype(np.float32)
    b = rng.randn(co).astype(np.float32)
    y_ref = np.asarray(deconv2d_zero_insertion(
        jnp.array(x[None]), jnp.array(w), jnp.array(b), s, p))[0]
    y_a1, macs = deconv2d_algorithm1_numpy(x, w, b, s, p)
    np.testing.assert_allclose(y_a1, y_ref, rtol=1e-4, atol=1e-4)
    assert macs > 0


def test_algorithm1_tiled_matches_untiled(rng):
    x = rng.randn(7, 7, 4).astype(np.float32)
    w = rng.randn(4, 4, 4, 8).astype(np.float32)
    y_full, macs_full = deconv2d_algorithm1_numpy(x, w, None, 2, 1)
    y_tile, macs_tile = deconv2d_algorithm1_numpy(x, w, None, 2, 1,
                                                  t_oh=6, t_ow=6)
    np.testing.assert_allclose(y_tile, y_full, rtol=1e-5, atol=1e-5)
    assert macs_full == macs_tile  # tiling changes order, not work


def test_zero_skip_reduces_macs_not_result(rng):
    x = rng.randn(5, 5, 6).astype(np.float32)
    w = jnp.array(rng.randn(4, 4, 6, 8), jnp.float32)
    wp, _ = magnitude_prune(w, 0.75)
    wp = np.asarray(wp)
    y_dense, macs_dense = deconv2d_algorithm1_numpy(x, wp, None, 2, 1)
    y_skip, macs_skip = deconv2d_algorithm1_numpy(x, wp, None, 2, 1,
                                                  zero_skip=True)
    np.testing.assert_allclose(y_skip, y_dense, rtol=1e-5, atol=1e-5)
    # 75% pruned -> ~4x fewer executed MACs (paper Fig. 6a mechanism)
    assert macs_skip < 0.3 * macs_dense
