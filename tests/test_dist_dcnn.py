"""Mesh-sharded DCNN serving + WGAN training (the paper's workloads on a
multi-device data-parallel mesh).

Each test runs a REAL 8-device SPMD program on forced host devices in a
subprocess (same pattern as test_dist_multidevice: the XLA flag must be set
before jax initializes and must never leak into the main process)."""
from test_dist_multidevice import run_sub

# CelebA layer *geometry* (kernel/stride/padding cascade 1->4->8->16->32->64)
# with cut-down channels so the interpret-mode sweep stays cheap.  Indented
# to match the inline test bodies (run_sub dedents the concatenation).
_CELEBA_SMALL = """
        from repro.models.dcnn import DcnnConfig, DeconvLayerCfg
        CELEBA_SMALL = DcnnConfig(
            name="dcnn-celeba-small", z_dim=24, img_hw=64, img_c=3,
            layers=(DeconvLayerCfg(24, 32, 4, 1, 0, "relu"),
                    DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),
                    DeconvLayerCfg(16, 16, 4, 2, 1, "relu"),
                    DeconvLayerCfg(16, 8, 4, 2, 1, "relu"),
                    DeconvLayerCfg(8, 3, 4, 2, 1, "tanh")))
"""

_TINY = """
        from repro.models.dcnn import DcnnConfig, DeconvLayerCfg
        TINY = DcnnConfig(
            name="tiny", z_dim=16, img_hw=16, img_c=1,
            layers=(DeconvLayerCfg(16, 32, 4, 1, 0, "relu"),
                    DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),
                    DeconvLayerCfg(16, 1, 4, 2, 1, "tanh")))
"""


def test_mesh_sharded_serving_matches_single_device():
    """Acceptance: a mesh-backed DcnnServeEngine on the CelebA geometry
    matches the single-device engine numerically, buckets are rounded up
    to device-count multiples, and the engine reports per-device rates."""
    out = run_sub(_CELEBA_SMALL + """
        import os, jax, numpy as np
        os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "/tmp/at_dist_serve.json")
        from repro.launch.mesh import make_serving_mesh
        from repro.models.dcnn import generator_init, generator_apply
        from repro.serve.engine import DcnnServeEngine
        import jax.numpy as jnp

        params, _ = generator_init(jax.random.PRNGKey(0), CELEBA_SMALL)
        mesh = make_serving_mesh()
        eng_m = DcnnServeEngine(CELEBA_SMALL, params, backend="pallas",
                                mesh=mesh, buckets=(1, 2, 4, 8, 16))
        # bucket/device-count rounding rule: every bucket a multiple of 8
        assert eng_m.buckets == (8, 16), eng_m.buckets
        assert eng_m.n_devices == 8
        assert eng_m.stats["device_count"] == 8
        # per-shard sub-batch feeds the autotuner
        eng_m._get_fn(16)
        assert eng_m.shard_batch(16) == 2
        for choice in eng_m.tile_choices[16].values():
            assert choice.t_n <= 2, choice

        eng_1 = DcnnServeEngine(CELEBA_SMALL, params, backend="pallas",
                                buckets=eng_m.buckets)
        rng = np.random.RandomState(0)
        z = rng.randn(19, CELEBA_SMALL.z_dim).astype(np.float32)
        y_m = eng_m.generate(z)
        y_1 = eng_1.generate(z)
        # float32 tolerance: per-shard tiles may differ from the
        # single-device bucket tiles (different accumulation grouping)
        np.testing.assert_allclose(y_m, y_1, rtol=1e-5, atol=1e-5)
        ref = np.asarray(generator_apply(params, CELEBA_SMALL,
                                         jnp.asarray(z),
                                         backend="reverse_loop"))
        np.testing.assert_allclose(y_m, ref, rtol=2e-3, atol=2e-3)
        # identical chunk plan => identical padding accounting
        assert eng_m.stats["padded_images"] == eng_1.stats["padded_images"]
        assert eng_m.total_compiles <= len(eng_m.buckets)
        # steady-state rates: the first (compiling) call per bucket is
        # excluded from the timers, so serve the stream once more
        eng_m.generate(z)
        tput = eng_m.throughput()
        assert tput, "no steady-state calls recorded"
        for bucket, row in tput.items():
            assert row["img_per_s"] > 0
            assert abs(row["img_per_s_per_device"] * 8
                       - row["img_per_s"]) < 1e-6
        print("OK")
    """, timeout=900)
    assert "OK" in out


def test_wgan_sharded_steps_match_single_device():
    """Acceptance: sharded critic+gen steps produce finite, mesh-invariant
    metrics — a 4-way data mesh matches a single-device trainer replaying
    the same per-shard key splits — and ragged batch sizes re-use one
    bucket executable (trace_counts probe)."""
    out = run_sub(_TINY + """
        import os, jax, numpy as np
        os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "/tmp/at_dist_wgan.json")
        from repro.launch.mesh import make_test_mesh
        from repro.optim.optimizer import AdamW
        from repro.train.wgan import WganTrainer

        class Src:
            sizes = (13, 14, 15, 16)   # ragged: all bucket to 16
            def batch(self, step):
                r = np.random.RandomState(step)
                n = self.sizes[step % len(self.sizes)]
                return {"images":
                        r.randn(n, 16, 16, 1).astype(np.float32) * 0.2}

        def opts():
            return (AdamW(lr=1e-4, b1=0.5, b2=0.9),
                    AdamW(lr=1e-4, b1=0.5, b2=0.9))

        mesh = make_test_mesh(4, 2)   # batch shards data=4; model unused
        tm = WganTrainer(TINY, *opts(), n_critic=2, mesh=mesh)
        t1 = WganTrainer(TINY, *opts(), n_critic=2, z_shards=4)
        gm, dm, hm = tm.fit(Src(), 4, jax.random.PRNGKey(1), log_every=1)
        g1, d1, h1 = t1.fit(Src(), 4, jax.random.PRNGKey(1), log_every=1)
        for a, b in zip(hm, h1):
            for k in ("d_loss", "g_loss", "wdist", "gp"):
                assert np.isfinite(a[k]), (k, a)
                assert abs(a[k] - b[k]) < 1e-3, (k, a[k], b[k])
        for a, b in zip(jax.tree_util.tree_leaves((gm, dm)),
                        jax.tree_util.tree_leaves((g1, d1))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
        # 4 distinct ragged sizes -> ONE bucket -> one trace per step kind
        assert tm.trace_counts["critic"] == {16: 1}, tm.trace_counts
        assert tm.trace_counts["gen"] == {16: 1}, tm.trace_counts
        print("OK")
    """, timeout=900)
    assert "OK" in out


def test_wgan_pallas_backend_trains_on_mesh():
    """The batch-fused Pallas generator forward (reverse-loop VJP) trains
    under the sharded step: finite metrics, params update."""
    out = run_sub(_TINY + """
        import os, jax, numpy as np
        os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "/tmp/at_dist_pl.json")
        from repro.launch.mesh import make_serving_mesh
        from repro.optim.optimizer import AdamW
        from repro.train.wgan import WganTrainer

        class Src:
            def batch(self, step):
                r = np.random.RandomState(step)
                return {"images":
                        r.randn(16, 16, 16, 1).astype(np.float32) * 0.2}

        t = WganTrainer(TINY, AdamW(lr=1e-4, b1=0.5, b2=0.9),
                        AdamW(lr=1e-4, b1=0.5, b2=0.9),
                        n_critic=1, backend="pallas",
                        mesh=make_serving_mesh())
        # same init-key derivation fit() uses: the delta below is training
        kinit, _ = jax.random.split(jax.random.PRNGKey(3))
        gp0 = t.init_state(kinit)[0]
        gp, dp, hist = t.fit(Src(), 2, jax.random.PRNGKey(3), log_every=1)
        assert all(np.isfinite(v) for h in hist for v in h.values()), hist
        # per-bucket fused tiles were resolved for the per-shard sub-batch
        assert t.tile_choices, t.tile_choices
        moved = sum(
            float(np.abs(np.asarray(a) - np.asarray(b)).max())
            for a, b in zip(jax.tree_util.tree_leaves(gp0),
                            jax.tree_util.tree_leaves(gp)))
        assert moved > 0.0
        print("OK")
    """, timeout=900)
    assert "OK" in out


def test_mesh_sharded_int8_serving_matches_single_device():
    """The int8 precision path rides the same bucket/mesh machinery:
    quantized params replicate on the mesh, per-shard tiles resolve at
    the int8 dtype, and the sharded engine matches the single-device
    int8 engine bit-for-bit (both serve the same QuantConfig)."""
    out = run_sub(_TINY + """
        import os, jax, numpy as np
        os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "/tmp/at_dist_q.json")
        import jax.numpy as jnp
        from repro.launch.mesh import make_serving_mesh
        from repro.models.dcnn import generator_init, generator_apply
        from repro.quant import calibrate
        from repro.serve.engine import DcnnServeEngine

        params, _ = generator_init(jax.random.PRNGKey(0), TINY)
        z_cal = jax.random.normal(jax.random.PRNGKey(7), (16, TINY.z_dim),
                                  jnp.float32)
        qcfg = calibrate(params, TINY, z_cal)
        mesh = make_serving_mesh()
        eng_m = DcnnServeEngine(TINY, params, backend="pallas", mesh=mesh,
                                precision="int8", quant_cfg=qcfg,
                                buckets=(8, 16))
        eng_1 = DcnnServeEngine(TINY, params, backend="pallas",
                                precision="int8", quant_cfg=qcfg,
                                buckets=(8, 16))
        assert eng_m.n_devices == 8
        rng = np.random.RandomState(0)
        z = rng.randn(11, TINY.z_dim).astype(np.float32)
        y_m = eng_m.generate(z)
        y_1 = eng_1.generate(z)
        # identical QuantConfig + integer-exact accumulation: the sharded
        # run is the same integer program partitioned over devices
        np.testing.assert_allclose(y_m, y_1, rtol=1e-6, atol=1e-6)
        ref = np.asarray(generator_apply(params, TINY, jnp.asarray(z),
                                         backend="reverse_loop"))
        assert np.abs(y_m - ref).max() < 0.1
        assert eng_m.total_compiles <= len(eng_m.buckets)
        print("OK")
    """, timeout=900)
    assert "OK" in out
