"""Paper DCNNs (Fig. 4): geometry, backend agreement, critic shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.dcnn import (
    CELEBA_DCNN, MNIST_DCNN, critic_apply, critic_init, generator_apply,
    generator_init,
)


def test_fig4_geometries():
    g = MNIST_DCNN.geometries()
    assert [(x.out_h, x.c_out) for x in g] == [(7, 256), (14, 128), (28, 1)]
    g = CELEBA_DCNN.geometries()
    assert [(x.out_h, x.c_out) for x in g] == [
        (4, 1024), (8, 512), (16, 256), (32, 128), (64, 3)]


@pytest.mark.parametrize("cfg", [MNIST_DCNN, CELEBA_DCNN],
                         ids=["mnist", "celeba"])
def test_generator_backends_agree(cfg, rng):
    key = jax.random.PRNGKey(0)
    p, _ = generator_init(key, cfg)
    z = jnp.array(rng.randn(2, cfg.z_dim), jnp.float32)
    y_rl = generator_apply(p, cfg, z, backend="reverse_loop")
    y_xla = generator_apply(p, cfg, z, backend="xla")
    y_pl = generator_apply(p, cfg, z, backend="pallas")
    assert y_rl.shape == (2, cfg.img_hw, cfg.img_hw, cfg.img_c)
    np.testing.assert_allclose(y_rl, y_xla, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_pl, y_xla, rtol=1e-4, atol=1e-4)
    assert float(jnp.abs(y_rl).max()) <= 1.0 + 1e-6  # tanh output


def test_generator_differentiable(rng):
    cfg = MNIST_DCNN
    p, _ = generator_init(jax.random.PRNGKey(0), cfg)
    z = jnp.array(rng.randn(2, cfg.z_dim), jnp.float32)
    g = jax.grad(lambda p_: jnp.sum(generator_apply(p_, cfg, z) ** 2))(p)
    norms = [float(jnp.abs(x).sum()) for x in jax.tree_util.tree_leaves(g)]
    assert all(np.isfinite(norms)) and sum(norms) > 0


def test_critic_shapes(rng):
    for cfg in (MNIST_DCNN, CELEBA_DCNN):
        p, _ = critic_init(jax.random.PRNGKey(1), cfg)
        x = jnp.array(rng.randn(3, cfg.img_hw, cfg.img_hw, cfg.img_c),
                      jnp.float32)
        y = critic_apply(p, cfg, x)
        assert y.shape == (3,)
        assert np.isfinite(np.asarray(y)).all()
