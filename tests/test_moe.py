"""MoE routing properties: no-drop equivalence to dense mixture, aux loss,
capacity dropping, group invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.ffn import moe_apply, moe_init


def _cfg(**kw):
    base = reduced_config("phi3.5-moe-42b-a6.6b")  # 8 experts top-2, no shared
    return dataclasses.replace(base, **kw)


def test_nodrop_matches_dense_mixture(rng):
    """With capacity >= all assignments, sorted dispatch must equal the
    dense weighted mixture of top-k expert outputs."""
    cfg = _cfg(moe_capacity_factor=100.0)
    p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.array(rng.randn(2, 8, cfg.d_model), jnp.float32)
    y, aux = moe_apply(p, cfg, x, capacity_factor=100.0)

    # dense oracle
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xf @ p["wg"][e]) * (xf @ p["wu"][e])
        outs.append(h @ p["wd"][e])
    outs = jnp.stack(outs, 1)  # (T, E, D)
    ref = jnp.zeros_like(xf)
    for j in range(cfg.moe_top_k):
        ref += top_p[:, j:j+1] * jnp.take_along_axis(
            outs, top_e[:, j][:, None, None], axis=1)[:, 0]
    np.testing.assert_allclose(y.reshape(-1, cfg.d_model), ref,
                               rtol=2e-3, atol=2e-3)


def test_capacity_drops_tokens(rng):
    """Tiny capacity must drop tokens (outputs partially zeroed), not crash."""
    cfg = _cfg()
    p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.array(rng.randn(2, 32, cfg.d_model), jnp.float32)
    y_full, _ = moe_apply(p, cfg, x, capacity_factor=100.0)
    y_tight, _ = moe_apply(p, cfg, x, capacity_factor=0.25)
    assert np.isfinite(np.asarray(y_tight)).all()
    assert float(jnp.abs(y_tight).sum()) < float(jnp.abs(y_full).sum())


def test_aux_loss_balanced_is_one(rng):
    """Uniform routing -> switch aux loss == 1 (its minimum under topk=all)."""
    cfg = _cfg()
    p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    # zero router weights => uniform probs => perfectly balanced
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"])
    x = jnp.array(rng.randn(2, 64, cfg.d_model), jnp.float32)
    _, aux = moe_apply(p, cfg, x)
    assert float(aux) == pytest.approx(1.0, rel=0.1)


def test_shared_experts_add(rng):
    cfg = dataclasses.replace(reduced_config("qwen2-moe-a2.7b"),
                              moe_capacity_factor=100.0)
    p, _ = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.array(rng.randn(1, 8, cfg.d_model), jnp.float32)
    y, _ = moe_apply(p, cfg, x, capacity_factor=100.0)
    # zeroing the shared expert changes the output
    p2 = jax.tree_util.tree_map(lambda a: a, p)
    p2["shared"]["wd"]["w"] = jnp.zeros_like(p2["shared"]["wd"]["w"])
    y2, _ = moe_apply(p2, cfg, x, capacity_factor=100.0)
    assert float(jnp.abs(y - y2).max()) > 1e-6
