"""Unified observability layer: typed metrics registry (streaming
mean/std/CV vs numpy ground truth), span tracing (nesting, cross-thread
begin/end, disabled-path zero allocation, ring bound), the Chrome/
Perfetto exporter round-trip, and the dual-write contract — the typed
registry and the legacy ``stats()``/``bucket_stats`` dicts are written
at the same sites, so they must agree exactly, single- or
multi-threaded.  Ends with the Table II reporter and a full
admission -> queue -> dispatch -> collect trace from a live frontend."""
import json
import threading

import numpy as np
import pytest
from test_fault_serving import TINY, tiny_setup, tmp_cache  # noqa: F401

from repro.obs import clock, trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               MetricTypeError)
from repro.obs.report import table2_rows
from repro.serve import (AsyncServeFrontend, DcnnServeEngine, EngineConfig,
                         TenantClass)


# ---------------------------------------------------------------------------
# metrics: statistics vs numpy, labels, registry
# ---------------------------------------------------------------------------
def test_histogram_stats_match_numpy():
    rng = np.random.RandomState(7)
    samples = rng.gamma(2.0, 0.01, size=500)
    h = Histogram("t")
    for s in samples:
        h.observe(float(s), net="a", bucket=4)
    st = h.summary(net="a", bucket=4)
    assert st["count"] == 500
    assert st["mean"] == pytest.approx(samples.mean(), rel=1e-9)
    assert st["std"] == pytest.approx(samples.std(), rel=1e-6)
    assert st["cv"] == pytest.approx(samples.std() / samples.mean(), rel=1e-6)
    assert st["min"] == pytest.approx(samples.min())
    assert st["max"] == pytest.approx(samples.max())
    # near-constant samples: cancellation must clamp, not go sqrt(-eps)
    h2 = Histogram("t2")
    for _ in range(100):
        h2.observe(0.123456789)
    assert h2.summary()["std"] == pytest.approx(0.0, abs=1e-9)


def test_histogram_merged_summary_pools_across_labels():
    rng = np.random.RandomState(3)
    a, b = rng.rand(40) + 1.0, rng.rand(60) + 2.0
    h = Histogram("t")
    for s in a:
        h.observe(float(s), net="x", bucket=2)
    for s in b:
        h.observe(float(s), net="x", bucket=4)
    pooled = np.concatenate([a, b])
    st = h.merged_summary(net="x")
    assert st["count"] == 100
    assert st["mean"] == pytest.approx(pooled.mean())
    assert st["std"] == pytest.approx(pooled.std(), rel=1e-6)
    # exact-match summary unaffected by the sibling series
    assert h.summary(net="x", bucket=2)["count"] == 40
    assert h.label_values("bucket") == ["2", "4"]


def test_histogram_bucket_counts_and_bounds_validation():
    h = Histogram("t", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    (row,) = h.snapshot()["series"]
    assert row["bucket_counts"] == [1, 1, 1, 1]   # last = overflow
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(1.0, 1.0, 2.0))
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))


def test_counter_and_gauge_semantics():
    c = Counter("c")
    c.inc(tenant="a", outcome="ok")
    c.inc(2, tenant="a", outcome="shed")
    c.inc(tenant="b", outcome="ok")
    assert c.value(tenant="a", outcome="ok") == 1
    assert c.total(tenant="a") == 3       # label-subset sum
    assert c.total() == 4
    assert c.value(tenant="zzz") == 0
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge("g")
    assert g.value(dev="all") is None
    g.set(8, dev="all")
    g.set(4, dev="all")                   # last write wins
    assert g.value(dev="all") == 4


def test_registry_get_or_create_and_type_conflict():
    reg = MetricsRegistry()
    c1 = reg.counter("x", "first help wins")
    assert reg.counter("x") is c1
    with pytest.raises(MetricTypeError):
        reg.gauge("x")
    reg.histogram("h")
    assert reg.names() == ["h", "x"]
    assert reg.get("nope") is None


def test_registry_snapshot_json_round_trip():
    reg = MetricsRegistry()
    reg.counter("c").inc(3, net="a", bucket=4)
    reg.gauge("g").set(1.5)
    reg.histogram("h").observe(0.25, net="a")
    doc = json.loads(json.dumps(reg.snapshot()))
    assert doc["c"]["type"] == "counter"
    # int label values stringify on the way in, so the round trip is exact
    assert doc["c"]["series"] == [
        {"labels": {"net": "a", "bucket": "4"}, "value": 3}]
    assert doc["h"]["series"][0]["count"] == 1
    assert doc["h"]["bounds"] == list(Histogram.DEFAULT_BUCKETS)


def test_registry_threaded_writes_lose_nothing():
    reg = MetricsRegistry()
    n, threads = 200, 8

    def work(i):
        c = reg.counter("ops")           # get-or-create raced deliberately
        h = reg.histogram("lat")
        for k in range(n):
            c.inc(worker=i % 2)
            h.observe(0.001 * (k + 1))

    ts = [threading.Thread(target=work, args=(i,)) for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.counter("ops").total() == n * threads
    assert reg.histogram("lat").summary()["count"] == n * threads


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------
def test_disabled_tracer_is_free_and_silent():
    t = trace.Tracer(enabled=False)
    assert t.span("a") is t.span("b")     # shared null object, no alloc
    with t.span("a"):
        pass
    t.complete("x", 0.0, 1.0)
    t.instant("y")
    t.end(t.begin("z"))
    assert len(t) == 0 and not t.enabled


def test_span_nesting_records_in_exit_order():
    t = trace.Tracer(enabled=True)
    with t.span("outer", rows=4):
        with t.span("inner"):
            pass
    inner, outer = t.events()
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"] == {"rows": 4}


def test_span_records_exception_class():
    t = trace.Tracer(enabled=True)
    with pytest.raises(RuntimeError):
        with t.span("boom"):
            raise RuntimeError("x")
    (ev,) = t.events()
    assert ev["args"]["error"] == "RuntimeError"


def test_begin_end_attributes_to_begin_thread():
    t = trace.Tracer(enabled=True)
    with t.span("marker"):               # pin the main thread's display tid
        pass
    h = t.begin("queue_wait", rid=1)
    worker = threading.Thread(target=lambda: t.end(h, outcome="dispatched"),
                              name="worker-0")
    worker.start()
    worker.join()
    marker, qw = t.events()
    assert qw["tid"] == marker["tid"]    # begin thread, not worker
    assert qw["args"] == {"rid": 1, "outcome": "dispatched"}
    assert qw["dur"] >= 0


def test_ring_buffer_keeps_newest():
    t = trace.Tracer(capacity=4, enabled=True)
    for i in range(10):
        t.instant(f"e{i}")
    assert len(t) == 4
    assert [e["name"] for e in t.events()] == ["e6", "e7", "e8", "e9"]


def test_perfetto_export_round_trip(tmp_path):
    t = trace.Tracer(enabled=True)
    t0 = clock.now()
    t.complete("dispatch b4", t0, t0 + 0.25, bucket=4)
    t.instant("retry", attempt=1)
    path = tmp_path / "trace.json"
    assert t.export(str(path)) == 2      # non-meta events
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert {"process_name", "thread_name"} <= {e["name"] for e in metas}
    (x,) = [e for e in evs if e["ph"] == "X"]
    assert x["dur"] == pytest.approx(0.25 * 1e6, rel=1e-6)   # microseconds
    (i,) = [e for e in evs if e["ph"] == "i"]
    assert i["s"] == "t"
    assert all({"ph", "name", "pid", "tid"} <= set(e) for e in evs)
    assert all("ts" in e for e in evs if e["ph"] != "M")


def test_clock_is_monotonic():
    ts = [clock.now() for _ in range(100)]
    assert all(b >= a for a, b in zip(ts, ts[1:]))


# ---------------------------------------------------------------------------
# dual-write contract + reporter, against live engines
# ---------------------------------------------------------------------------
def test_engine_registry_matches_bucket_stats(tmp_cache, tiny_setup):
    params, z, _ = tiny_setup
    reg = MetricsRegistry()
    eng = DcnnServeEngine.from_config(
        EngineConfig(model=TINY, backend="pallas", buckets=(2, 4),
                     warmup=True),
        params, metrics=reg)
    for _ in range(3):
        eng.generate(z)                   # 4 rows -> one b4 call
        eng.generate(z[:2])               # one b2 call
    hist = reg.histogram("engine.dispatch_seconds")
    for bucket, bs in eng.bucket_stats.items():
        # unregistered towers carry their cfg name as the workload label
        st = hist.summary(net=TINY.name, workload=TINY.name,
                          precision="fp32", bucket=bucket)
        assert st["count"] == bs["calls"]
        assert st["total"] == pytest.approx(bs["seconds"])
        mean = bs["seconds"] / bs["calls"]
        var = max(bs["sumsq_seconds"] / bs["calls"] - mean * mean, 0.0)
        assert st["std"] == pytest.approx(np.sqrt(var), abs=1e-12)
    assert reg.counter("engine.generate_calls").total() == 6
    assert reg.counter("engine.images").total() == 3 * 4 + 3 * 2
    assert reg.gauge("engine.device_count").value(
        net=TINY.name, workload=TINY.name,
        precision="fp32") == eng.n_devices

    rows = table2_rows(reg)
    by_bucket = {r["bucket"]: r for r in rows}
    assert set(by_bucket) == {2, 4, "all"}
    assert by_bucket[4]["calls"] == eng.bucket_stats[4]["calls"]
    assert by_bucket[4]["tainted_calls"] == 0
    assert by_bucket["all"]["calls"] == sum(
        bs["calls"] for bs in eng.bucket_stats.values())
    assert by_bucket["all"]["img_per_s"] > 0


def test_table2_rollup_weights_cv_by_calls():
    reg = MetricsRegistry()
    h = reg.histogram("engine.dispatch_seconds")
    for v in (1.0, 1.0, 1.0):                       # b2: cv == 0
        h.observe(v, net="n", precision="fp32", bucket=2)
    for v in (1.0, 3.0):                            # b4: cv == 0.5
        h.observe(v, net="n", precision="fp32", bucket=4)
    reg.counter("engine.tainted_calls").inc(
        net="n", precision="fp32", bucket=4)
    rows = table2_rows(reg)
    by_bucket = {r["bucket"]: r for r in rows}
    assert by_bucket[2]["cv"] == pytest.approx(0.0)
    assert by_bucket[4]["cv"] == pytest.approx(0.5)
    assert by_bucket[4]["tainted_calls"] == 1
    # rollup cv is the calls-weighted average, NOT pooled moments (which
    # would read ~0.47 here from the bucket-mean spread alone)
    assert by_bucket["all"]["cv"] == pytest.approx((0 * 3 + 0.5 * 2) / 5)
    assert by_bucket["all"]["mean_s"] == pytest.approx((3.0 + 4.0) / 5)


def test_table2_empty_registry_is_empty():
    assert table2_rows(MetricsRegistry()) == []


def test_frontend_registry_matches_stats(tmp_cache, tiny_setup):
    """Concurrent submitters: the typed counters and the legacy tenant
    dicts are incremented at the same sites under the same locks, so
    after the dust settles they agree exactly."""
    params, z, _ = tiny_setup
    reg = MetricsRegistry()
    engines = {"fp32": DcnnServeEngine.from_config(
        EngineConfig(model=TINY, backend="pallas", buckets=(2, 4),
                     warmup=True),
        params, metrics=reg)}
    fe = AsyncServeFrontend(engines, [TenantClass("default", slo_ms=None)],
                            metrics=reg)
    try:
        rids = []
        rlock = threading.Lock()

        def client(i):
            rid = fe.submit(z[: 1 + i % 4], "default")
            with rlock:
                rids.append(rid)

        ts = [threading.Thread(target=client, args=(i,)) for i in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        for rid in rids:
            fe.result(rid, timeout_s=120)
        st = fe.stats()["tenants"]["default"]
        req = fe.metrics.counter("frontend.requests")
        assert req.value(tenant="default", outcome="admitted") == 8
        assert req.value(tenant="default", outcome="completed") == 8
        assert st["admitted"] == 8 and st["completed"] == 8
        lat = fe.metrics.histogram("frontend.request_latency_seconds")
        lsum = lat.merged_summary(tenant="default")
        assert lsum["count"] == 8
        assert lsum["mean"] == pytest.approx(st["mean_ms"] / 1e3, rel=1e-6)
        qw = fe.metrics.histogram("frontend.queue_wait_seconds")
        assert qw.merged_summary(tenant="default")["count"] == 8
        fe.reset_stats()
        assert req.total() == 0
        assert fe.stats()["tenants"]["default"]["admitted"] == 0
        # engine series are cumulative state, not per-window statistics
        assert fe.metrics.counter("engine.generate_calls").total() > 0
    finally:
        fe.close()


def test_trace_covers_request_lifecycle(tmp_cache, tiny_setup, tmp_path):
    """One traced request renders the full admission -> queue wait ->
    wave dispatch -> per-bucket kernel -> collect timeline."""
    params, z, _ = tiny_setup
    engines = {"fp32": DcnnServeEngine.from_config(
        EngineConfig(model=TINY, backend="pallas", buckets=(4,),
                     warmup=True),
        params)}
    fe = AsyncServeFrontend(engines, [TenantClass("default", slo_ms=None)])
    trace.enable(clear=True)
    try:
        rid = fe.submit(z, "default")
        fe.result(rid, timeout_s=120)
    finally:
        trace.disable()
        fe.close()
    path = tmp_path / "t.json"
    tracer = trace.get_tracer()
    assert tracer.export(str(path)) == len(tracer.events())
    names = [e["name"] for e in tracer.events()]
    for expected in ("submit", "queue_wait", "wave_dispatch", "dispatch b4",
                     "generate", "collect"):
        assert any(n == expected for n in names), (expected, names)
    doc = json.loads(path.read_text())
    by_name = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X":
            by_name.setdefault(ev["name"], ev)
    # the kernel call nests inside the wave dispatch on the timeline
    wave, disp = by_name["wave_dispatch"], by_name["dispatch b4"]
    assert wave["ts"] <= disp["ts"]
    assert wave["ts"] + wave["dur"] >= disp["ts"] + disp["dur"]
    qw = by_name["queue_wait"]
    assert qw["args"]["outcome"] == "dispatched"
    assert qw["ts"] + qw["dur"] <= disp["ts"] + disp["dur"]
