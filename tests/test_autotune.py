"""DSE-driven tile autotuner: legality, VMEM clamping, cache behavior."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import TPU_V5E
from repro.core.tiling import DeconvGeometry, kernel_vmem_bytes
from repro.kernels import autotune
from repro.kernels.autotune import (
    TileChoice, choose_tiles, clear_cache, fallback_tiles,
    legal_tile_candidates,
)
from repro.kernels.deconv2d import deconv2d, deconv2d_ref

CELEBA_L2 = DeconvGeometry(4, 4, 1024, 512, 4, 2, 1)
MNIST_L2 = DeconvGeometry(7, 7, 256, 128, 4, 2, 1)


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Redirect the autotune cache into the test tmpdir."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setattr(autotune, "_cache", None)
    yield tmp_path / "at.json"
    monkeypatch.setattr(autotune, "_cache", None)


def _assert_legal(geom, c: TileChoice, dtype_bytes=4):
    s = geom.stride
    assert c.t_oh % s == 0 and c.t_ow % s == 0
    assert c.t_oh > 0 and c.t_ow > 0 and c.t_ci > 0 and c.t_co > 0
    assert c.t_n > 0
    fp = kernel_vmem_bytes(geom, c.t_oh, c.t_ow, c.t_ci, c.t_co, dtype_bytes,
                           t_n=c.t_n)
    assert fp <= TPU_V5E.onchip_bytes, f"tile {c} exceeds VMEM: {fp}"


@pytest.mark.parametrize("geom", [CELEBA_L2, MNIST_L2,
                                  DeconvGeometry(1, 1, 100, 1024, 4, 1, 0),
                                  DeconvGeometry(32, 32, 128, 3, 4, 2, 1)])
def test_chosen_tiles_legal_and_within_vmem(geom, tmp_cache):
    """Acceptance: the chosen tile is legal (stride-aligned) and within the
    VMEM cap, for every generator-layer geometry."""
    c = choose_tiles(geom, jnp.float32, backend="pallas")
    assert c.source in ("model", "fallback")
    _assert_legal(geom, c)


def test_candidates_all_fit_budget():
    for (t_oh, t_ow, t_ci, t_co, t_n) in legal_tile_candidates(
            CELEBA_L2, batch=16):
        assert t_n <= 16
        assert kernel_vmem_bytes(CELEBA_L2, t_oh, t_ow, t_ci, t_co, 4,
                                 t_n=t_n) <= TPU_V5E.onchip_bytes


def test_fallback_clamps_large_ci_co_layers():
    """Satellite bug: the fixed heuristic used to pick 32x32/128/128 blocks
    regardless of footprint; a fat-channel layer must now be clamped."""
    fat = DeconvGeometry(64, 64, 4096, 4096, 11, 1, 0)
    c = fallback_tiles(fat, dtype_bytes=4)
    _assert_legal(fat, c)
    # and an unclamped 32x32/128/128 choice would NOT have fit
    assert kernel_vmem_bytes(fat, 32, 32, 128, 128, 4) > TPU_V5E.onchip_bytes


def test_cache_roundtrip_and_clear(tmp_cache):
    c1 = choose_tiles(MNIST_L2, jnp.float32, backend="pallas")
    assert c1.source != "cache"
    assert tmp_cache.exists()
    c2 = choose_tiles(MNIST_L2, jnp.float32, backend="pallas")
    assert c2.source == "cache"
    assert c2.as_kwargs() == c1.as_kwargs()
    # distinct key per backend/dtype
    c3 = choose_tiles(MNIST_L2, jnp.bfloat16, backend="pallas")
    assert c3.source != "cache"
    clear_cache()
    assert not tmp_cache.exists()
    c4 = choose_tiles(MNIST_L2, jnp.float32, backend="pallas")
    assert c4.source != "cache"


def test_refine_times_candidates_and_persists(tmp_cache):
    g = DeconvGeometry(4, 4, 8, 8, 4, 2, 1)  # tiny: timing is cheap
    c = choose_tiles(g, jnp.float32, backend="pallas", refine=True,
                     refine_top_k=2)
    assert c.source == "timed"
    _assert_legal(g, c)
    assert choose_tiles(g, jnp.float32, backend="pallas").source == "cache"


def test_refine_not_suppressed_by_model_cache_entry(tmp_cache):
    """A stored model choice must not satisfy a refine=True request — only
    a timed entry does (the refinement then overwrites the model entry)."""
    g = DeconvGeometry(4, 4, 8, 8, 4, 2, 1)
    assert choose_tiles(g, jnp.float32, backend="pallas").source == "model"
    c = choose_tiles(g, jnp.float32, backend="pallas", refine=True,
                     refine_top_k=2)
    assert c.source == "timed"
    # and the timed entry now serves refine=True requests from cache
    c2 = choose_tiles(g, jnp.float32, backend="pallas", refine=True)
    assert c2.source == "cache"


def test_sparse_plan_tile_mismatch_rejected(tmp_cache, rng):
    from repro.kernels.deconv2d_sparse import deconv2d_sparse, make_sparse_plan

    x = jnp.array(rng.randn(1, 7, 7, 16), jnp.float32)
    w = (rng.randn(4, 4, 16, 32) * 0.1).astype(np.float32)
    plan = make_sparse_plan(w, 2, 1, t_ci=8, t_co=8)  # 4 C_out tiles
    with pytest.raises(ValueError, match="C_out tiles"):
        deconv2d_sparse(x, jnp.asarray(w), None, 2, 1,
                        t_ci=8, t_co=32, plan=plan)  # 1 C_out tile


def test_batch_tile_options_never_exceed_batch():
    """Review regression: a non-power-of-two batch must not enumerate a
    t_n beyond the batch (it would be scored with an MXU fill the clamped
    kernel can't reach)."""
    from repro.kernels.autotune import _batch_tile_options

    assert _batch_tile_options(6) == [1, 2, 4, 6]
    assert _batch_tile_options(1) == [1]
    assert _batch_tile_options(64) == [1, 2, 4, 8, 16, 32, 64]
    assert _batch_tile_options(100) == [1, 2, 4, 8, 16, 32, 64]  # cap
    for b in range(1, 70):
        assert all(t <= b for t in _batch_tile_options(b))


def test_choice_batch_aware_t_n(tmp_cache):
    """The batch tile is chosen jointly: batch=1 keeps the per-image grid,
    a batch-64 request on the row-starved CelebA L1 batch-fuses, and t_n
    never exceeds the batch it was fitted to."""
    l1 = DeconvGeometry(1, 1, 100, 1024, 4, 1, 0)
    c1 = choose_tiles(l1, jnp.float32, backend="pallas", batch=1)
    assert c1.t_n == 1
    c64 = choose_tiles(l1, jnp.float32, backend="pallas", batch=64)
    assert 1 < c64.t_n <= 64
    _assert_legal(l1, c64)
    # distinct cache entries per batch (the key carries the bucket)
    assert choose_tiles(l1, jnp.float32, backend="pallas",
                        batch=64).source == "cache"
    assert choose_tiles(l1, jnp.float32, backend="pallas",
                        batch=32).source != "cache"


def test_fallback_t_n_targets_mxu_rows(tmp_cache):
    """The clamped heuristic grows t_n (powers of two within the batch)
    until the tap matmuls reach ~128 contraction rows."""
    l1 = DeconvGeometry(1, 1, 100, 1024, 4, 1, 0)  # 4x4 out -> 16 rows/img
    c = fallback_tiles(l1, batch=64)
    assert c.t_n * (c.t_oh // l1.stride) * (c.t_ow // l1.stride) >= 128
    _assert_legal(l1, c)
    assert fallback_tiles(l1, batch=1).t_n == 1
    # a layer already at >=128 spatial rows stays per-image
    fat = DeconvGeometry(32, 32, 128, 3, 4, 2, 1)
    assert fallback_tiles(fat, batch=64).t_n == 1


def test_stale_v1_schema_entry_not_served(tmp_cache):
    """Satellite: a cache entry without the batch tile (the v1 4-tuple
    schema) must be dropped on load, not silently served as stale tiles."""
    import json

    from repro.kernels.autotune import cache_key

    key = cache_key(MNIST_L2, jnp.float32, "pallas")
    stale = {key: {"t_oh": 2, "t_ow": 2, "t_ci": 8, "t_co": 8,
                   "source": "timed", "attainable_ops": 1.0,
                   "vmem_bytes": 1}}   # no t_n: pre-t_n schema
    tmp_cache.write_text(json.dumps(stale))
    c = choose_tiles(MNIST_L2, jnp.float32, backend="pallas")
    assert c.source != "cache"
    assert c.as_kwargs() != {"t_oh": 2, "t_ow": 2, "t_ci": 8, "t_co": 8,
                             "t_n": 1}


def test_v3_schema_keys_dropped_on_load(tmp_cache):
    """Satellite: v4 derives keys from `DeconvPlan.stable_hash` instead of
    the v3 hand-assembled tuple string, so a v3 key — whose format could
    silently omit a new ranking input — is stale even when its value shape
    is valid.  Every key from a different schema version is dropped on
    load, and the next store persists a clean v4-only file."""
    import json

    from repro.kernels.autotune import _CACHE_VERSION, cache_key

    assert _CACHE_VERSION == 4
    key4 = cache_key(MNIST_L2, jnp.float32, "pallas")
    assert key4.startswith("v4|")
    # a v3-era key: hand-assembled readable tuple under the old version
    key3 = ("v3|cpu|tpu-v5e|pallas|float32|n1|i7x7|c256>128|k4s2p1")
    entry = {"t_oh": 2, "t_ow": 2, "t_ci": 8, "t_co": 8, "t_n": 1,
             "source": "timed", "attainable_ops": 1.0, "vmem_bytes": 1}
    tmp_cache.write_text(json.dumps({key3: entry}))
    c = choose_tiles(MNIST_L2, jnp.float32, backend="pallas")
    assert c.source != "cache"
    assert c.as_kwargs() != {"t_oh": 2, "t_ow": 2, "t_ci": 8, "t_co": 8,
                             "t_n": 1}
    blob = json.loads(tmp_cache.read_text())
    assert key3 not in blob            # stale schema purged on re-store
    assert key4 in blob


def test_v4_cache_key_is_plan_hash(tmp_cache):
    """The v4 key is derived from the plan's tile-scope stable hash: the
    same request hashes identically through either entry point, and every
    tile-relevant planning input (dtype, batch, backend, epilogue output
    width) produces a distinct key."""
    from repro.kernels.autotune import cache_key, plan_cache_key
    from repro.plan import DeconvPlan

    plan = DeconvPlan(geometry=MNIST_L2, batch=8, dtype="float32",
                      backend="pallas")
    key = cache_key(MNIST_L2, jnp.float32, "pallas", batch=8)
    assert key == plan_cache_key(plan)
    assert plan.stable_hash(scope="tiles") in key
    # a resolved plan keys identically to the bare request (the tiles are
    # the cached payload, not part of the key)
    resolved = choose_tiles(MNIST_L2, jnp.float32, backend="pallas", batch=8)
    import dataclasses
    assert plan_cache_key(dataclasses.replace(plan, tiles=resolved)) == key
    variants = [
        cache_key(MNIST_L2, jnp.int8, "pallas", batch=8),
        cache_key(MNIST_L2, jnp.float32, "pallas_sparse", batch=8),
        cache_key(MNIST_L2, jnp.float32, "pallas", batch=64),
        cache_key(MNIST_L2, jnp.float32, "pallas", batch=8,
                  out_dtype_bytes=4),
        cache_key(CELEBA_L2, jnp.float32, "pallas", batch=8),
    ]
    assert len(set(variants + [key])) == len(variants) + 1


def test_int8_dtype_distinct_cache_key(tmp_cache):
    """The dtype has always been in the key; v3 additionally ranks with
    it, so int8 and fp32 requests tune (and cache) independently."""
    c8 = choose_tiles(MNIST_L2, jnp.int8, backend="pallas")
    assert c8.source != "cache"
    assert choose_tiles(MNIST_L2, jnp.int8, backend="pallas").source == "cache"
    assert choose_tiles(MNIST_L2, jnp.float32,
                        backend="pallas").source != "cache"
    _assert_legal(MNIST_L2, c8, dtype_bytes=1)


def test_corrupt_cache_recovery(tmp_cache):
    """Corrupt JSON (truncated write, hand edit) and malformed entries
    recover to a re-tune instead of crashing or serving garbage."""
    import json

    from repro.kernels import autotune
    from repro.kernels.autotune import cache_key

    tmp_cache.write_text("{not json")
    c = choose_tiles(MNIST_L2, jnp.float32, backend="pallas")
    assert c.source == "model"
    _assert_legal(MNIST_L2, c)
    # the re-tuned entry was persisted over the corruption and now serves
    assert choose_tiles(MNIST_L2, jnp.float32,
                        backend="pallas").source == "cache"
    # malformed entry values (wrong types / non-dict) are dropped on load
    autotune._cache = None
    blob = json.loads(tmp_cache.read_text())
    blob[cache_key(CELEBA_L2, jnp.float32, "pallas")] = "bogus"
    blob[cache_key(CELEBA_L2, jnp.bfloat16, "pallas")] = {"t_oh": "four"}
    tmp_cache.write_text(json.dumps(blob))
    assert choose_tiles(MNIST_L2, jnp.float32,
                        backend="pallas").source == "cache"
    c2 = choose_tiles(CELEBA_L2, jnp.float32, backend="pallas")
    assert c2.source != "cache"
    _assert_legal(CELEBA_L2, c2)


def test_cache_roundtrip_includes_t_n(tmp_cache):
    """A batch-fused choice persists t_n and serves it back verbatim."""
    l1 = DeconvGeometry(1, 1, 100, 1024, 4, 1, 0)
    c = choose_tiles(l1, jnp.float32, backend="pallas", batch=64)
    assert c.t_n > 1
    hit = choose_tiles(l1, jnp.float32, backend="pallas", batch=64)
    assert hit.source == "cache"
    assert hit.as_kwargs() == c.as_kwargs()


def test_autotuned_kernel_matches_reference(tmp_cache, rng):
    """End to end: tiles resolved by the autotuner produce correct output."""
    x = jnp.array(rng.randn(2, 7, 7, 16), jnp.float32)
    w = jnp.array(rng.randn(4, 4, 16, 24) * 0.1, jnp.float32)
    b = jnp.array(rng.randn(24), jnp.float32)
    y = deconv2d(x, w, b, 2, 1)  # no explicit tiles -> autotuner
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(deconv2d_ref(x, w, b, 2, 1)),
        rtol=1e-4, atol=1e-4)
