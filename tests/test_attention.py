"""blocked_attention vs a naive full-softmax oracle: causal, windowed, GQA,
decode-style offsets, softcap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import apply_rope, blocked_attention


def naive_attention(q, k, v, causal=True, window=None, softcap=None,
                    scale=None):
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(s, bool)
    if causal:
        mask &= (kpos <= qpos)[None, None]
    if window:
        mask &= (kpos > qpos - window)[None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv).astype(q.dtype)


CASES = [
    dict(sq=32, skv=32, h=4, hkv=4, dh=8, causal=True, window=None, sc=None),
    dict(sq=33, skv=33, h=4, hkv=2, dh=8, causal=True, window=None, sc=None),
    dict(sq=48, skv=48, h=8, hkv=1, dh=16, causal=True, window=8, sc=None),
    dict(sq=40, skv=40, h=4, hkv=4, dh=8, causal=True, window=None, sc=30.0),
]


@pytest.mark.parametrize("c", CASES)
def test_blocked_matches_naive(c, rng):
    q = jnp.array(rng.randn(2, c["sq"], c["h"], c["dh"]), jnp.float32)
    k = jnp.array(rng.randn(2, c["skv"], c["hkv"], c["dh"]), jnp.float32)
    v = jnp.array(rng.randn(2, c["skv"], c["hkv"], c["dh"]), jnp.float32)
    out = blocked_attention(q, k, v, causal=c["causal"], window=c["window"],
                            softcap_val=c["sc"], block_q=16, block_k=16)
    ref = naive_attention(q, k, v, c["causal"], c["window"], c["sc"])
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_block_size_invariance(rng):
    q = jnp.array(rng.randn(1, 64, 4, 8), jnp.float32)
    k = jnp.array(rng.randn(1, 64, 2, 8), jnp.float32)
    v = jnp.array(rng.randn(1, 64, 2, 8), jnp.float32)
    outs = [blocked_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in ((8, 8), (16, 64), (64, 16), (64, 64))]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)


def test_rope_properties(rng):
    """RoPE preserves norms and is relative: scores depend on pos deltas."""
    x = jnp.array(rng.randn(1, 8, 2, 16), jnp.float32)
    pos = jnp.arange(8)[None, :]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
        rtol=1e-5, atol=1e-5)
    # shifting all positions leaves q·k scores unchanged
    q = jnp.array(rng.randn(1, 8, 2, 16), jnp.float32)
    k = jnp.array(rng.randn(1, 8, 2, 16), jnp.float32)
    s1 = jnp.einsum("bqhd,bkhd->bhqk", apply_rope(q, pos), apply_rope(k, pos))
    s2 = jnp.einsum("bqhd,bkhd->bhqk", apply_rope(q, pos + 100),
                    apply_rope(k, pos + 100))
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-3)


def test_mrope_text_equals_standard(rng):
    """M-RoPE with t=h=w positions (text) must equal standard RoPE."""
    x = jnp.array(rng.randn(1, 8, 2, 16), jnp.float32)
    pos = jnp.arange(8)[None, :]
    pos3 = jnp.broadcast_to(pos, (3, 1, 8))
    y1 = apply_rope(x, pos)
    y2 = apply_rope(x, pos3, mrope_sections=(4, 2, 2))
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
