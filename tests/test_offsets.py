"""Properties of the paper's Eq. 3 offsets and the phase decomposition.

Checked by exhaustive enumeration over the full small-geometry space
(K in [1,9], S in [1,5], P in [0,6]) — no sampling, every case runs.
"""
import itertools

import numpy as np

from repro.core.offsets import (
    make_phase_plan, modulo_op_count_naive, modulo_op_count_ours,
    modulo_op_count_paper, offset, offset_table, taps_for_phase,
)

GEOMS = list(itertools.product(range(1, 10), range(1, 6), range(0, 7)))


def test_offset_equals_phase_of_tap():
    for k_max, s, p in GEOMS:
        for k in range(k_max):
            # Eq. 3 == (k - P) mod S: the offset IS the output phase of tap k
            assert offset(k, s, p) == (k - p) % s


def test_offsets_in_range_and_table():
    for k_max, s, p in GEOMS:
        tab = offset_table(k_max, s, p)
        assert tab.shape == (k_max,)
        assert ((0 <= tab) & (tab < s)).all()


def test_taps_partition_kernel():
    """Every tap contributes to exactly one phase; phases partition [0, K)."""
    for k_max, s, p in GEOMS:
        seen = []
        for phase in range(s):
            seen += taps_for_phase(phase, k_max, s, p)
        assert sorted(seen) == list(range(k_max))


def test_phase_plan_exact_division():
    """delta = (phase + P - k)/S is exact for all planned taps (the modulo
    arithmetic of Eq. 4 is fully resolved at trace time)."""
    for k_max, s, p in GEOMS:
        plan = make_phase_plan(k_max, s, p)
        for phase, taps in plan.taps.items():
            for k, delta in taps:
                assert phase + p - k == delta * s


def test_modulo_op_counts():
    # paper reduces per-pixel modulos to 2K; our phase plan removes them all
    assert modulo_op_count_naive(4, 28, 28) == 2 * 16 * 28 * 28
    assert modulo_op_count_paper(4) == 8
    assert modulo_op_count_ours() == 0
