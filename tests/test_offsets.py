"""Properties of the paper's Eq. 3 offsets and the phase decomposition."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.offsets import (
    make_phase_plan, modulo_op_count_naive, modulo_op_count_ours,
    modulo_op_count_paper, offset, offset_table, taps_for_phase,
)

geom = st.tuples(
    st.integers(1, 9),    # K
    st.integers(1, 5),    # S
    st.integers(0, 6),    # P
)


@given(geom)
def test_offset_equals_phase_of_tap(g):
    k_max, s, p = g
    for k in range(k_max):
        # Eq. 3 == (k - P) mod S: the offset IS the output phase of tap k
        assert offset(k, s, p) == (k - p) % s


@given(geom)
def test_offsets_in_range_and_table(g):
    k_max, s, p = g
    tab = offset_table(k_max, s, p)
    assert tab.shape == (k_max,)
    assert ((0 <= tab) & (tab < s)).all()


@given(geom)
def test_taps_partition_kernel(g):
    """Every tap contributes to exactly one phase; phases partition [0, K)."""
    k_max, s, p = g
    seen = []
    for phase in range(s):
        seen += taps_for_phase(phase, k_max, s, p)
    assert sorted(seen) == list(range(k_max))


@given(geom)
def test_phase_plan_exact_division(g):
    """delta = (phase + P - k)/S is exact for all planned taps (the modulo
    arithmetic of Eq. 4 is fully resolved at trace time)."""
    k_max, s, p = g
    plan = make_phase_plan(k_max, s, p)
    for phase, taps in plan.taps.items():
        for k, delta in taps:
            assert phase + p - k == delta * s


def test_modulo_op_counts():
    # paper reduces per-pixel modulos to 2K; our phase plan removes them all
    assert modulo_op_count_naive(4, 28, 28) == 2 * 16 * 28 * 28
    assert modulo_op_count_paper(4) == 8
    assert modulo_op_count_ours() == 0
