"""Optimizer correctness + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compression import (
    compress_grads, compression_ratio, decompress_grads, init_error_feedback,
    quantize_leaf, dequantize_leaf,
)
from repro.optim.optimizer import SGD, AdamW, global_norm
from repro.optim.schedule import constant, warmup_cosine


def _reference_adam(w, gs, lr=0.1, b1=0.9, b2=0.999, eps=1e-8):
    m = np.zeros_like(w)
    v = np.zeros_like(w)
    for t, g in enumerate(gs, start=1):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        w = w - lr * mh / (np.sqrt(vh) + eps)
    return w


def test_adamw_matches_reference(rng):
    w0 = rng.randn(7).astype(np.float32)
    gs = [rng.randn(7).astype(np.float32) for _ in range(5)]
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.array(w0)}
    state = opt.init(params)
    for g in gs:
        params, state = opt.update({"w": jnp.array(g)}, state, params)
    np.testing.assert_allclose(params["w"], _reference_adam(w0, gs),
                               rtol=1e-5, atol=1e-6)


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.05)
    params = {"w": jnp.ones(4) * 5.0}
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_norm():
    opt = AdamW(lr=0.0, clip_norm=1.0)  # lr 0: only test no blow-up
    g = {"w": jnp.full((10,), 100.0)}
    assert float(global_norm(g)) > 100
    params = {"w": jnp.zeros(10)}
    params, _ = opt.update(g, opt.init(params), params)
    assert np.isfinite(np.asarray(params["w"])).all()


def test_schedules():
    s = warmup_cosine(1.0, 10, 100, final_frac=0.1)
    assert float(s(jnp.array(0))) == pytest.approx(0.0)
    assert float(s(jnp.array(10))) == pytest.approx(1.0, rel=0.1)
    assert float(s(jnp.array(100))) == pytest.approx(0.1, rel=0.01)
    assert float(constant(0.3)(jnp.array(5))) == pytest.approx(0.3)


def test_quantize_roundtrip_error_bound(rng):
    g = jnp.array(rng.randn(1000), jnp.float32)
    q, s = quantize_leaf(g)
    err = jnp.abs(dequantize_leaf(q, s) - g).max()
    assert float(err) <= float(s) * 0.5 + 1e-9  # half-step quantization error
    assert q.dtype == jnp.int8


def test_error_feedback_preserves_mean_signal(rng):
    """With EF, the accumulated dequantized stream tracks the accumulated
    true gradient (bias correction property)."""
    g_true = jnp.array(rng.randn(64), jnp.float32) * 0.01
    ef = init_error_feedback({"w": g_true})
    total = jnp.zeros(64)
    for _ in range(50):
        q, s, ef = compress_grads({"w": g_true}, ef)
        total = total + decompress_grads(q, s)["w"]
    np.testing.assert_allclose(total / 50, g_true, atol=float(
        jnp.abs(g_true).max()) * 0.05 + 1e-5)


def test_quantize_leaf_uses_shared_qmath(rng):
    """Satellite: one quantization math module, two call sites — the
    compression leaf ops are the shared `quant.qmath` symmetric int8
    helpers, bit-identical to calling them directly (and to the original
    hand-rolled numerics: scale = absmax/127 + 1e-12)."""
    from repro.quant.qmath import dequantize_symmetric, quantize_absmax

    g = jnp.array(rng.randn(257), jnp.float32)
    q, s = quantize_leaf(g)
    q2, s2 = quantize_absmax(g)
    assert float(s) == float(s2)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    # (f32 arithmetic inside jit vs python f64 here: compare to ulp)
    assert float(s) == pytest.approx(
        float(jnp.max(jnp.abs(g))) / 127.0 + 1e-12, rel=1e-6)
    np.testing.assert_array_equal(
        np.asarray(dequantize_leaf(q, s)),
        np.asarray(dequantize_symmetric(q, s)))


def test_roundtrip_and_error_feedback_regression(rng):
    """Round-trip + error-feedback invariants after the qmath refactor:
    the residual is exactly the round-trip error (corrected - dequant),
    and an all-zero leaf survives (epsilon-guarded scale, no NaNs)."""
    g = {"w": jnp.array(rng.randn(64), jnp.float32),
         "z": jnp.zeros(16, jnp.float32)}
    ef = init_error_feedback(g)
    q, s, ef2 = compress_grads(g, ef)
    deq = decompress_grads(q, s)
    for k in g:
        np.testing.assert_allclose(
            np.asarray(ef2.residual[k]),
            np.asarray(g[k]) - np.asarray(deq[k]), rtol=0, atol=1e-7)
        assert np.isfinite(np.asarray(deq[k])).all()
    np.testing.assert_array_equal(np.asarray(deq["z"]), np.zeros(16))
    # per-leaf half-step error bound holds through the tree path
    err = np.abs(np.asarray(deq["w"]) - np.asarray(g["w"])).max()
    assert err <= float(s["w"]) * 0.5 + 1e-9


def test_compressed_sgd_converges(rng):
    opt = SGD(lr=0.1)
    params = {"w": jnp.ones(8) * 3.0}
    state = opt.init(params)
    ef = init_error_feedback(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        q, s, ef = compress_grads(g, ef)
        params, state = opt.update(decompress_grads(q, s), state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert compression_ratio({'w': jnp.zeros(4096)}) > 3.5
