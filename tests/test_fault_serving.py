"""Fault-tolerant elastic serving: deterministic fault injection against
`DcnnServeEngine` — transient-failure retry, typed deadline/degraded
errors, drain queue preservation, straggler/heartbeat wiring, and the
acceptance scenario: losing half of an 8-fake-device mesh mid-stream
remeshes, re-plans (hash-asserted) and keeps serving bit-identically to
a healthy half-size engine.  Multi-device cases run in subprocesses via
`test_dist_multidevice.run_sub` (the XLA device-count flag must never
leak into this process)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from test_dist_multidevice import run_sub

from repro.dist.inject import (DeviceLoss, FaultInjector, SlowCall,
                               TransientFailure)
from repro.dist.pipeline import microbatch, pipeline_apply
from repro.models.dcnn import (DcnnConfig, DeconvLayerCfg, generator_apply,
                               generator_init)
from repro.serve import (DcnnServeEngine, DeadlineExceeded, EngineConfig,
                         EngineDegraded)

TINY = DcnnConfig(
    name="tiny-fault", z_dim=16, img_hw=16, img_c=1,
    layers=(DeconvLayerCfg(16, 32, 4, 1, 0, "relu"),
            DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),
            DeconvLayerCfg(16, 1, 4, 2, 1, "tanh")))

# the same geometry, inlined for the subprocess tests (run_sub dedents)
_TINY_SUB = """
        from repro.models.dcnn import DcnnConfig, DeconvLayerCfg
        TINY = DcnnConfig(
            name="tiny-fault", z_dim=16, img_hw=16, img_c=1,
            layers=(DeconvLayerCfg(16, 32, 4, 1, 0, "relu"),
                    DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),
                    DeconvLayerCfg(16, 1, 4, 2, 1, "tanh")))
"""


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setattr(autotune, "_cache", None)
    yield
    monkeypatch.setattr(autotune, "_cache", None)


@pytest.fixture(scope="module")
def tiny_setup():
    params, _ = generator_init(jax.random.PRNGKey(0), TINY)
    rng = np.random.RandomState(0)
    z = rng.randn(4, TINY.z_dim).astype(np.float32)
    ref = np.asarray(generator_apply(params, TINY, jnp.asarray(z),
                                     backend="reverse_loop"))
    return params, z, ref


def _engine(params, injector=None, **over):
    kw = dict(model=TINY, backend="pallas", buckets=(4,))
    kw.update(over)
    return DcnnServeEngine.from_config(EngineConfig(**kw), params,
                                       fault_injector=injector)


# ---------------------------------------------------------------------------
# retry / degraded semantics
# ---------------------------------------------------------------------------
def test_transient_failure_retried_transparently(tmp_cache, tiny_setup):
    """One injected transient failure: the retry succeeds and the output
    is bit-identical to an uninjected engine (same pinned plan)."""
    params, z, _ = tiny_setup
    inj = FaultInjector([TransientFailure(at_call=0)])
    eng = _engine(params, inj, max_retries=2, retry_backoff_s=0.01)
    ref = _engine(params)
    np.testing.assert_array_equal(eng.generate(z), ref.generate(z))
    assert eng.fault_stats["retries"] == 1
    assert eng.fault_stats["transient_failures"] == 1
    assert inj.calls == 2   # failed dispatch + successful retry


def test_retry_exhaustion_raises_typed(tmp_cache, tiny_setup):
    """max_retries+1 consecutive transient failures surface as
    `EngineDegraded` (typed), never an injector internal."""
    params, z, _ = tiny_setup
    inj = FaultInjector([TransientFailure(0), TransientFailure(1)])
    eng = _engine(params, inj, max_retries=1, retry_backoff_s=0.01)
    with pytest.raises(EngineDegraded, match="retries exhausted"):
        eng.generate(z)
    assert eng.fault_stats["transient_failures"] == 2


def test_retried_dispatch_tainted_not_in_healthy_cv(tmp_cache, tiny_setup):
    """CV-accounting audit: a dispatch that needed a transient retry is
    tagged ``tainted`` — its wall clock (which includes the failed
    attempt's backoff) must not mix into the healthy run-to-run mean/std/
    CV samples (Table II is a statement about the healthy path), nor seed
    the straggler EMA the SLO scheduler reads as capacity."""
    params, z, _ = tiny_setup
    inj = FaultInjector([TransientFailure(at_call=1)])
    eng = _engine(params, inj, max_retries=2, retry_backoff_s=0.01)
    eng.generate(z)                    # call 0: compiles, never sampled
    assert eng.bucket_stats == {}
    eng.generate(z)                    # call 1 fails -> retried success
    bs = eng.bucket_stats[4]
    assert bs["tainted_calls"] == 1 and bs["tainted_seconds"] > 0
    assert bs["calls"] == 0 and bs["seconds"] == 0.0
    assert eng.throughput() == {}      # no healthy sample yet
    assert eng.service_estimate(4) is None   # tainted never seeds capacity
    eng.generate(z)                    # healthy steady call
    row = eng.throughput()[4]
    assert row["calls"] == 1 and row["tainted_calls"] == 1
    assert row["mean_s"] == pytest.approx(bs["seconds"])
    assert eng.service_estimate(4) == pytest.approx(bs["seconds"])


def test_drain_restores_pending_on_failure(tmp_cache, tiny_setup):
    """Regression: a failure mid-drain used to silently drop every queued
    request (pending was popped before generate ran).  Now the tickets
    are restored and the next drain serves them."""
    params, z, ref = tiny_setup
    inj = FaultInjector([TransientFailure(at_call=0)])
    eng = _engine(params, inj, max_retries=0)
    r1, r2 = eng.submit(z[:2]), eng.submit(z[2:])
    with pytest.raises(EngineDegraded):
        eng.collect(r1)
    assert len(eng._pending) == 2      # nothing dropped
    # the injected fault is spent: the retried drain completes both
    out = np.concatenate([eng.collect(r1), eng.collect(r2)], axis=0)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_device_loss_without_mesh_is_degraded(tmp_cache, tiny_setup):
    """A single-process engine has nothing to shrink onto: device loss
    fails typed instead of retrying forever."""
    params, z, _ = tiny_setup
    inj = FaultInjector([DeviceLoss(at_call=0, keep=1)])
    eng = _engine(params, inj)
    with pytest.raises(EngineDegraded, match="elastic mesh"):
        eng.generate(z)


# ---------------------------------------------------------------------------
# deadlines + collect semantics
# ---------------------------------------------------------------------------
def test_deadline_exceeded_is_typed_and_queue_survives(tmp_cache,
                                                       tiny_setup):
    """An expired ticket fails with `DeadlineExceeded` at collect; later
    tickets on the same engine serve normally."""
    params, z, ref = tiny_setup
    eng = _engine(params)
    rid = eng.submit(z, deadline_s=0.0)
    time.sleep(0.02)
    with pytest.raises(DeadlineExceeded, match="missed its deadline"):
        eng.collect(rid)
    assert eng.fault_stats["deadline_expired"] == 1
    rid2 = eng.submit(z)               # no deadline: unaffected
    np.testing.assert_allclose(eng.collect(rid2), ref,
                               rtol=2e-3, atol=2e-3)


def test_default_deadline_from_config(tmp_cache, tiny_setup):
    params, z, _ = tiny_setup
    eng = _engine(params, default_deadline_s=0.0)
    rid = eng.submit(z)
    time.sleep(0.02)
    with pytest.raises(DeadlineExceeded):
        eng.collect(rid)
    # per-request deadline overrides the default
    rid2 = eng.submit(z, deadline_s=60.0)
    assert eng.collect(rid2).shape == (4, 16, 16, 1)


def test_collect_distinguishes_unknown_from_collected(tmp_cache,
                                                      tiny_setup):
    params, z, _ = tiny_setup
    eng = _engine(params)
    rid = eng.submit(z)
    eng.collect(rid)
    with pytest.raises(KeyError, match="already collected"):
        eng.collect(rid)
    with pytest.raises(KeyError, match="never issued"):
        eng.collect(rid + 999)


# ---------------------------------------------------------------------------
# straggler + heartbeat wiring
# ---------------------------------------------------------------------------
def test_straggler_flagged_and_heartbeat_fires_on_stall(tmp_cache,
                                                        tiny_setup):
    """An injected slow dispatch lands in the per-call timing window, so
    the per-bucket StragglerMonitor flags it and the armed heartbeat
    records the stall; an idle queue afterwards fires nothing (the
    engine disarms between calls)."""
    params, z, _ = tiny_setup
    inj = FaultInjector([SlowCall(at_call=3, delay_s=1.0)])
    eng = _engine(params, inj, straggler_warmup=1,
                  heartbeat_timeout_s=0.2)
    try:
        for _ in range(4):   # call 0 compiles; 1 seeds; 2 steady; 3 slow
            eng.generate(z)
        assert eng.fault_stats["stragglers"] == 1
        assert eng.fault_stats["heartbeat_fires"] >= 1
        fires = eng.fault_stats["heartbeat_fires"]
        time.sleep(0.5)      # idle != stalled
        assert eng.fault_stats["heartbeat_fires"] == fires
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# dist.pipeline coverage (satellite): bubble accounting without a mesh
# ---------------------------------------------------------------------------
def test_pipeline_apply_meshless_parity_and_bubble_drop():
    """pipeline_apply with mesh=None is the plain skewed schedule: every
    microbatch matches the sequential stage-by-stage oracle and exactly
    the n_stages-1 bubble outputs are dropped (n_micro outputs remain,
    also when n_micro != n_stages)."""
    rng = np.random.RandomState(0)
    ws = jnp.array(rng.randn(3, 8, 8) * 0.3, jnp.float32)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    for n_micro in (3, 6):
        x = jnp.array(rng.randn(2 * n_micro, 8), jnp.float32)
        xm = microbatch(x, n_micro)
        y = pipeline_apply(None, None, stage_fn, ws, xm)
        assert y.shape == xm.shape     # bubbles dropped, nothing else
        y_ref = x
        for i in range(3):
            y_ref = stage_fn(ws[i], y_ref)
        np.testing.assert_allclose(np.asarray(y).reshape(-1, 8),
                                   np.asarray(y_ref), rtol=1e-5, atol=1e-5)


def test_microbatch_rejects_ragged():
    with pytest.raises(ValueError, match="not divisible"):
        microbatch(jnp.zeros((7, 4)), 2)


# ---------------------------------------------------------------------------
# multi-device scenarios (subprocess: 8 forced host devices)
# ---------------------------------------------------------------------------
def test_device_loss_elastic_rebucketing_bit_identical():
    """ACCEPTANCE: on an 8-fake-device mesh, losing half the devices at
    the first dispatch completes the in-flight request and every
    subsequent one with outputs bit-identical to a healthy 4-device
    engine; the recovery re-plans buckets with plan hashes matching the
    pre-loss plans for the shared per-device batch."""
    out = run_sub(_TINY_SUB + """
        import os
        os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "/tmp/at_fault_a.json")
        import jax, numpy as np
        from repro.dist.fault import elastic_mesh
        from repro.dist.inject import DeviceLoss, FaultInjector
        from repro.launch.mesh import make_serving_mesh
        from repro.models.dcnn import generator_init
        from repro.serve import DcnnServeEngine, EngineConfig

        params, _ = generator_init(jax.random.PRNGKey(0), TINY)
        inj = FaultInjector([DeviceLoss(at_call=0, keep=4)])
        eng8 = DcnnServeEngine.from_config(
            EngineConfig(model=TINY, backend="pallas",
                         mesh=make_serving_mesh(),
                         buckets=(1, 2, 4, 8, 16)),
            params, fault_injector=inj)
        assert eng8.buckets == (8, 16), eng8.buckets
        eng4 = DcnnServeEngine.from_config(
            EngineConfig(model=TINY, backend="pallas",
                         mesh=elastic_mesh(jax.devices()[:4],
                                           model_parallel=1),
                         buckets=(1, 2, 4, 8, 16)), params)
        rng = np.random.RandomState(0)
        z = rng.randn(19, TINY.z_dim).astype(np.float32)
        y8 = eng8.generate(z)      # loss fires at call 0 -> remesh
        np.testing.assert_array_equal(y8, eng4.generate(z))
        assert eng8.n_devices == 4
        assert eng8.stats["device_count"] == 4
        assert eng8.buckets == eng4.buckets == (4, 8, 16), (
            eng8.buckets, eng4.buckets)
        ev = eng8.fault_stats["remesh_events"][0]
        assert ev["devices_before"] == 8 and ev["devices_after"] == 4
        assert ev["plan_hash_matches"], ev
        assert all(ev["plan_hash_matches"].values()), ev
        assert ev["seconds"] > 0
        # every shared per-device batch re-derived the same executable
        for b in eng8.buckets:
            assert (eng8.plans[b].stable_hash()
                    == eng4.plans[b].stable_hash() if b in eng4.plans
                    else True)
        # subsequent requests stay bit-identical on the shrunken mesh
        z2 = rng.randn(7, TINY.z_dim).astype(np.float32)
        np.testing.assert_array_equal(eng8.generate(z2), eng4.generate(z2))
        print("OK")
    """, timeout=900)
    assert "OK" in out


def test_device_loss_midstream_completes_in_flight():
    """Loss injected AFTER the first chunk already ran on 8 devices: the
    interrupted generate() still completes (the remaining chunks re-plan
    on the survivors) and matches the reference numerically; queued
    submit tickets drain to completion through the same recovery."""
    out = run_sub(_TINY_SUB + """
        import os
        os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "/tmp/at_fault_b.json")
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.dist.inject import DeviceLoss, FaultInjector
        from repro.launch.mesh import make_serving_mesh
        from repro.models.dcnn import generator_apply, generator_init
        from repro.serve import DcnnServeEngine, EngineConfig

        params, _ = generator_init(jax.random.PRNGKey(0), TINY)
        inj = FaultInjector([DeviceLoss(at_call=1, keep=4)])
        eng = DcnnServeEngine.from_config(
            EngineConfig(model=TINY, backend="pallas",
                         mesh=make_serving_mesh(),
                         buckets=(1, 2, 4, 8, 16)),
            params, fault_injector=inj)
        rng = np.random.RandomState(0)
        # three tickets; the coalesced 40-row drain runs 16+16+8: the
        # second 16-chunk hits the loss mid-stream
        zs = [rng.randn(n, TINY.z_dim).astype(np.float32)
              for n in (16, 16, 8)]
        rids = [eng.submit(z) for z in zs]
        outs = [eng.collect(r) for r in rids]
        assert eng.n_devices == 4
        assert len(eng.fault_stats["remesh_events"]) == 1
        for z, out in zip(zs, outs):
            ref = np.asarray(generator_apply(params, TINY, jnp.asarray(z),
                                             backend="reverse_loop"))
            np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
        print("OK")
    """, timeout=900)
    assert "OK" in out


def test_pipeline_apply_mesh_parity_with_tail_bubbles():
    """Satellite: pipeline parity vs the sequential oracle on a real
    4-device mesh with n_micro != n_stages (tail feed + bubble drop)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import microbatch, pipeline_apply

        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.RandomState(0)
        ws = jnp.array(rng.randn(4, 16, 16) * 0.3, jnp.float32)

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x = jnp.array(rng.randn(12, 16), jnp.float32)
        xm = microbatch(x, 6)          # 6 microbatches through 4 stages
        y = pipeline_apply(mesh, "pod", stage_fn, ws, xm)
        assert y.shape == xm.shape, y.shape   # 9 ticks, 3 bubbles dropped
        y_ref = x
        for i in range(4):
            y_ref = stage_fn(ws[i], y_ref)
        np.testing.assert_allclose(np.asarray(y).reshape(12, 16),
                                   np.asarray(y_ref), rtol=1e-5, atol=1e-5)
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_elastic_remesh_reshard_params_bit_equal():
    """Satellite: a replicated generator param tree survives an elastic
    remesh to half the devices bit-for-bit (reshard_tree round-trip)."""
    out = run_sub(_TINY_SUB + """
        import jax, numpy as np
        from repro.dist.fault import elastic_mesh, reshard_tree
        from repro.dist.sharding import (make_rules, replicated_specs,
                                         tree_shardings)
        from repro.launch.mesh import make_serving_mesh
        from repro.models.dcnn import generator_init

        params, _ = generator_init(jax.random.PRNGKey(0), TINY)
        host = jax.tree_util.tree_map(np.asarray, params)
        rules = make_rules("tp")
        m8 = make_serving_mesh()
        p8 = jax.device_put(params, tree_shardings(
            m8, rules, params, replicated_specs(params)))
        m4 = elastic_mesh(jax.devices()[:4], model_parallel=1)
        p4 = reshard_tree(p8, tree_shardings(
            m4, rules, p8, replicated_specs(p8)))
        for a, b in zip(jax.tree_util.tree_leaves(host),
                        jax.tree_util.tree_leaves(p4)):
            assert len(b.sharding.device_set) == 4
            np.testing.assert_array_equal(a, np.asarray(b))
        print("OK")
    """, timeout=900)
    assert "OK" in out
