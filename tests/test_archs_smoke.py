"""Per-assigned-architecture smoke tests: reduced family-faithful config,
one forward + one train step on CPU, output shapes + no NaNs; decode path
consistency against full recompute."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LM_CONFIGS, reduced_config
from repro.models.transformer import apply_lm, init_cache, init_lm
from repro.optim.optimizer import AdamW
from repro.train.lm import make_train_step

ARCHS = sorted(LM_CONFIGS)


def _batch(cfg, rng, b=2, s=24):
    s_tok = s - cfg.frontend_len if cfg.frontend else s
    out = {
        "tokens": jnp.array(rng.randint(0, cfg.vocab_size, (b, s_tok))),
        "labels": jnp.array(rng.randint(0, cfg.vocab_size, (b, s_tok))),
    }
    if cfg.frontend:
        out["frontend_embeds"] = jnp.array(
            rng.randn(b, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = reduced_config(arch)
    params, specs = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    logits, _, aux = apply_lm(params, cfg, batch["tokens"],
                              batch.get("frontend_embeds"), mode="train")
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.isnan(logits).any())

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    p2, o2, _, met = step(params, opt.init(params), None, batch)
    assert np.isfinite(float(met["loss"]))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(p2), jax.tree_util.tree_leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, rng):
    cfg = reduced_config(arch)
    if cfg.n_experts:  # capacity-drop-free for exact decode equivalence
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    if cfg.kv_quant:   # exact-math check; int8 KV covered by its own test
        cfg = dataclasses.replace(cfg, kv_quant=False)
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    batch = _batch(cfg, rng, b, s)
    toks = batch["tokens"]
    fe = batch.get("frontend_embeds")

    cache = init_cache(cfg, b, 32)
    lg_p, cache, _ = apply_lm(params, cfg, toks, fe, mode="prefill",
                              cache=cache)
    nxt = jnp.argmax(lg_p[:, -1], -1)[:, None].astype(jnp.int32)
    lg_d, cache, _ = apply_lm(params, cfg, nxt, None, mode="decode",
                              cache=cache)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    lg_full, _, _ = apply_lm(params, cfg, toks2, fe, mode="train")
    np.testing.assert_allclose(
        np.asarray(lg_d[:, -1]), np.asarray(lg_full[:, -1]),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "xlstm-1.3b"])
def test_long_context_state_is_bounded(arch):
    """The property that qualifies these archs for long_500k: serving state
    does not grow with context length."""
    cfg = reduced_config(arch)
    c1 = init_cache(cfg, 1, 1024)
    c2 = init_cache(cfg, 1, 65536)
    n1 = sum(x.size for x in jax.tree_util.tree_leaves(c1))
    n2 = sum(x.size for x in jax.tree_util.tree_leaves(c2))
    assert n2 == n1  # ring buffers bounded by window; recurrent state fixed


def test_full_attention_cache_grows():
    cfg = reduced_config("deepseek-7b")
    n1 = sum(x.size for x in jax.tree_util.tree_leaves(init_cache(cfg, 1, 64)))
    n2 = sum(x.size for x in jax.tree_util.tree_leaves(init_cache(cfg, 1, 128)))
    assert n2 > 1.5 * n1


def test_int8_kv_cache_decode(rng):
    """int8 KV cache (beyond-paper serving optimization): decode must track
    the full-recompute logits within quantization tolerance."""
    import dataclasses
    cfg = dataclasses.replace(reduced_config("deepseek-7b"), kv_quant=True)
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.array(rng.randint(0, cfg.vocab_size, (2, 12)))
    cache = init_cache(cfg, 2, 32)
    lg_p, cache, _ = apply_lm(params, cfg, toks, mode="prefill", cache=cache)
    nxt = jnp.argmax(lg_p[:, -1], -1)[:, None].astype(jnp.int32)
    lg_d, cache, _ = apply_lm(params, cfg, nxt, mode="decode", cache=cache)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    lg_full, _, _ = apply_lm(params, cfg, toks2, mode="train")
    rel = (float(jnp.abs(lg_d[:, -1] - lg_full[:, -1]).max())
           / float(jnp.abs(lg_full[:, -1]).max()))
    assert rel < 0.05
    # the cache really is int8
    assert cache["units"]["b0"]["k"].dtype == jnp.int8
