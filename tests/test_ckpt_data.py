"""Checkpointing (atomicity, retention, async, corruption) and the
deterministic data pipeline."""
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (
    AsyncCheckpointer, restore, retain, save, valid_steps,
)
from repro.data.pipeline import Prefetcher, image_source, lm_source
from repro.data.synthetic import digit_images, face_images, token_stream


def _tree(rng):
    return {"a": jnp.array(rng.randn(4, 3), jnp.float32),
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path, rng):
    t = _tree(rng)
    save(str(tmp_path), 7, t, extra={"note": "x"})
    r, step, extra = restore(str(tmp_path), t)
    assert step == 7 and extra == {"note": "x"}
    np.testing.assert_array_equal(r["a"], t["a"])
    np.testing.assert_array_equal(r["b"]["c"], t["b"]["c"])


def test_restore_ignores_uncommitted(tmp_path, rng):
    t = _tree(rng)
    save(str(tmp_path), 1, t)
    save(str(tmp_path), 2, t)
    # simulate crash mid-save of step 3: directory without .COMMITTED
    d = tmp_path / "step_00000003"
    d.mkdir()
    (d / "arrays.npz").write_bytes(b"garbage")
    assert valid_steps(str(tmp_path)) == [1, 2]
    _, step, _ = restore(str(tmp_path), t)
    assert step == 2


def test_retention(tmp_path, rng):
    t = _tree(rng)
    for s in range(6):
        save(str(tmp_path), s, t)
    retain(str(tmp_path), keep=2)
    assert valid_steps(str(tmp_path)) == [4, 5]


def test_async_checkpointer(tmp_path, rng):
    t = _tree(rng)
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(4):
        ck.save(s, t)
    ck.wait()
    assert valid_steps(str(tmp_path)) == [2, 3]


def test_restore_empty_dir(tmp_path, rng):
    r, step, extra = restore(str(tmp_path / "nothing"), _tree(rng))
    assert r is None and step == -1


# ---------------------------------------------------------------------------
def test_sources_deterministic():
    src = lm_source(seed=3, batch=4, seq_len=16, vocab=100)
    b1, b2 = src.batch(5), src.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # next-token alignment
    full = token_stream(3 + 5, 4 * 17, 100).reshape(4, 17)
    np.testing.assert_array_equal(b1["labels"], full[:, 1:])


def test_source_sharding_partitions():
    src = image_source("mnist", seed=0, batch=8)
    full = src.batch(0)["images"]
    parts = [src.shard(i, 4).batch(0)["images"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_synthetic_ranges():
    d = digit_images(0, 2)
    f = face_images(0, 2)
    for x in (d, f):
        assert x.min() >= -1.0 and x.max() <= 1.0
    t = token_stream(0, 1000, 50)
    assert t.min() >= 0 and t.max() < 50


def test_prefetcher_in_order():
    src = lm_source(seed=1, batch=2, seq_len=8, vocab=32)
    pf = Prefetcher(src, start_step=10, depth=2)
    try:
        for expect in (10, 11, 12):
            step, batch = pf.get()
            assert step == expect
            np.testing.assert_array_equal(batch["tokens"],
                                          src.batch(step)["tokens"])
    finally:
        pf.close()
