"""Batch-fused kernels + bucketed serving: numerical parity at batch > 1
across all four deconv backends on both network geometries, bucket padding
for non-power-of-two batches, and the no-per-request-recompilation
guarantee (at most one compile per bucket for a mixed-size stream)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.dcnn import (DcnnConfig, DeconvLayerCfg, generator_apply,
                               generator_init)
from repro.serve.engine import DcnnServeEngine, pow2_buckets

# the real MNIST / CelebA layer *geometries* (kernel/stride/padding and the
# spatial cascade) with channel counts cut down so the batch-64 interpret
# -mode sweep stays cheap — the tap/phase/halo structure under test is
# channel-count independent.
MNIST_SMALL = DcnnConfig(
    name="dcnn-mnist-small",
    z_dim=24,
    img_hw=28,
    img_c=1,
    layers=(
        DeconvLayerCfg(24, 32, 7, 1, 0, "relu"),   # 1x1 -> 7x7
        DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),   # 7x7 -> 14x14
        DeconvLayerCfg(16, 1, 4, 2, 1, "tanh"),    # 14x14 -> 28x28
    ),
)

CELEBA_SMALL = DcnnConfig(
    name="dcnn-celeba-small",
    z_dim=24,
    img_hw=64,
    img_c=3,
    layers=(
        DeconvLayerCfg(24, 32, 4, 1, 0, "relu"),   # 1x1 -> 4x4
        DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),   # 4x4 -> 8x8
        DeconvLayerCfg(16, 16, 4, 2, 1, "relu"),   # 8x8 -> 16x16
        DeconvLayerCfg(16, 8, 4, 2, 1, "relu"),    # 16x16 -> 32x32
        DeconvLayerCfg(8, 3, 4, 2, 1, "tanh"),     # 32x32 -> 64x64
    ),
)

BACKENDS = ("pallas", "pallas_sparse", "reverse_loop", "xla")


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setattr(autotune, "_cache", None)
    yield
    monkeypatch.setattr(autotune, "_cache", None)


# ---------------------------------------------------------------------------
# batch>1 numerical parity across backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [MNIST_SMALL, CELEBA_SMALL],
                         ids=lambda c: c.name)
@pytest.mark.parametrize("batch", [64, 6])  # 6: non-pow2, exercises padding
def test_backend_parity_batched(cfg, batch, tmp_cache, rng):
    """Acceptance: batch-64 (and a non-power-of-two batch) generator outputs
    agree across every backend pair on both network geometries.  All
    backends are compared to the XLA zero-insertion reference; pairwise
    agreement follows."""
    p, _ = generator_init(jax.random.PRNGKey(0), cfg)
    z = jnp.asarray(rng.randn(batch, cfg.z_dim).astype(np.float32))
    ref = np.asarray(generator_apply(p, cfg, z, backend="xla"))
    assert ref.shape == (batch, cfg.img_hw, cfg.img_hw, cfg.img_c)
    for backend in BACKENDS:
        if backend == "xla":
            continue
        y = np.asarray(generator_apply(p, cfg, z, backend=backend))
        np.testing.assert_allclose(
            y, ref, rtol=2e-3, atol=2e-3,
            err_msg=f"{backend} diverges from xla at batch={batch}")


def test_explicit_t_n_batched_layer_parity(rng):
    """Single layer, explicit batch tile, batch not a t_n multiple: the ops
    wrapper pads the batch to the tile and slices it back."""
    from repro.kernels.deconv2d import deconv2d, deconv2d_ref
    from repro.kernels.deconv2d_sparse import deconv2d_sparse

    x = jnp.array(rng.randn(10, 4, 4, 8), jnp.float32)   # 10 % 4 != 0
    w = jnp.array(rng.randn(4, 4, 8, 16) * 0.1, jnp.float32)
    b = jnp.array(rng.randn(16) * 0.1, jnp.float32)
    ref = np.asarray(deconv2d_ref(x, w, b, 2, 1))
    y = deconv2d(x, w, b, 2, 1, t_oh=4, t_ow=4, t_ci=8, t_co=8, t_n=4)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
    ys = deconv2d_sparse(x, w, b, 2, 1, t_oh=4, t_ow=4, t_ci=8, t_co=8,
                         t_n=4)
    np.testing.assert_allclose(np.asarray(ys), ref, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# bucketed serving engine
# ---------------------------------------------------------------------------
def test_pow2_buckets():
    assert pow2_buckets(64) == (1, 2, 4, 8, 16, 32, 64)
    assert pow2_buckets(6) == (1, 2, 4, 6)
    assert pow2_buckets(1) == (1,)
    with pytest.raises(ValueError):
        pow2_buckets(0)


def test_mixed_stream_compiles_at_most_len_buckets(tmp_cache, rng):
    """Acceptance: serving a mixed-size request stream compiles at most
    len(buckets) generator executables — bucketing, not per-shape jit."""
    p, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    eng = DcnnServeEngine(MNIST_SMALL, p, backend="pallas",
                          buckets=(1, 2, 4, 8))
    sizes = [3, 5, 1, 8, 2, 3, 7, 5, 1, 6]
    for n in sizes:
        imgs = eng.generate(rng.randn(n, MNIST_SMALL.z_dim)
                            .astype(np.float32))
        assert imgs.shape == (n, 28, 28, 1)
    assert eng.total_compiles <= len(eng.buckets), eng.trace_counts
    # repeating the whole stream compiles nothing new
    before = eng.total_compiles
    for n in sizes:
        eng.generate(rng.randn(n, MNIST_SMALL.z_dim).astype(np.float32))
    assert eng.total_compiles == before


def test_bucket_padding_non_pow2_parity(tmp_cache, rng):
    """A non-power-of-two request (6 -> one padded bucket-8 call: two pad
    rows beat an extra dispatch) returns exactly its own images — the pad
    rows never leak into the result."""
    p, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    eng = DcnnServeEngine(MNIST_SMALL, p, backend="pallas",
                          buckets=(1, 2, 4, 8))
    z = rng.randn(6, MNIST_SMALL.z_dim).astype(np.float32)
    assert eng.plan_chunks(6) == [(6, 8)]
    imgs = eng.generate(z)
    ref = np.asarray(generator_apply(p, MNIST_SMALL, jnp.asarray(z),
                                     backend="reverse_loop"))
    np.testing.assert_allclose(imgs, ref, rtol=2e-3, atol=2e-3)
    assert eng.stats["padded_images"] == 2
    assert eng.bucket_for(6) == 8


def test_tail_chunk_plan_minimizes_padding(tmp_cache, rng):
    """Regression: the old loop jumped to the smallest *covering* bucket
    for any remainder, so a 36-row tail at buckets 1..64 ran one 64-row
    call (28 padded rows) instead of exact 32+4 chunks.  The plan is
    cost-aware, not exact-at-any-price: a near-bucket tail (63) stays one
    padded call rather than fragmenting into six row-starved ones."""
    p, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    eng = DcnnServeEngine(MNIST_SMALL, p, backend="pallas",
                          buckets=(1, 2, 4, 8, 16, 32, 64))
    assert eng.plan_chunks(36) == [(32, 32), (4, 4)]
    assert eng.plan_chunks(65) == [(64, 64), (1, 1)]
    assert eng.plan_chunks(100) == [(64, 64), (32, 32), (4, 4)]
    assert eng.plan_chunks(63) == [(63, 64)]
    assert eng.plan_chunks(48) == [(32, 32), (16, 16)]
    # padding arises only below the smallest bucket
    eng8 = DcnnServeEngine(MNIST_SMALL, p, backend="pallas",
                           buckets=(8, 16))
    assert eng8.plan_chunks(21) == [(16, 16), (5, 8)]
    z = rng.randn(21, MNIST_SMALL.z_dim).astype(np.float32)
    imgs = eng8.generate(z)
    ref = np.asarray(generator_apply(p, MNIST_SMALL, jnp.asarray(z),
                                     backend="reverse_loop"))
    np.testing.assert_allclose(imgs, ref, rtol=2e-3, atol=2e-3)
    # stats accounting stays exact: 8 - 5 = 3 padded rows, no more
    assert eng8.stats["padded_images"] == 3
    assert eng8.stats["images"] == 21


def test_shard_aligned_buckets():
    from repro.serve.engine import shard_aligned_buckets

    assert shard_aligned_buckets((1, 2, 4, 8, 16), 8) == (8, 16)
    assert shard_aligned_buckets((1, 2, 4, 8, 16), 1) == (1, 2, 4, 8, 16)
    assert shard_aligned_buckets((4, 6), 4) == (4, 8)


def test_oversized_batch_chunks_at_largest_bucket(tmp_cache, rng):
    p, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    eng = DcnnServeEngine(MNIST_SMALL, p, backend="pallas", buckets=(1, 2, 4))
    z = rng.randn(11, MNIST_SMALL.z_dim).astype(np.float32)  # 4+4+2+1
    imgs = eng.generate(z)
    assert imgs.shape == (11, 28, 28, 1)
    ref = np.asarray(generator_apply(p, MNIST_SMALL, jnp.asarray(z),
                                     backend="reverse_loop"))
    np.testing.assert_allclose(imgs, ref, rtol=2e-3, atol=2e-3)
    assert eng.total_compiles <= 3


def test_submit_collect_microbatching(tmp_cache, rng):
    """The queue coalesces pending requests into one drained generate()
    and routes each ticket its own images."""
    p, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    eng = DcnnServeEngine(MNIST_SMALL, p, backend="pallas",
                          buckets=(1, 2, 4, 8))
    zs = [rng.randn(n, MNIST_SMALL.z_dim).astype(np.float32)
          for n in (2, 3, 1)]
    ids = [eng.submit(z) for z in zs]
    calls_before = eng.stats["generate_calls"]
    outs = [eng.collect(i) for i in ids]
    # one coalesced generate() served all three tickets
    assert eng.stats["generate_calls"] == calls_before + 1
    for z, out in zip(zs, outs):
        ref = np.asarray(generator_apply(p, MNIST_SMALL, jnp.asarray(z),
                                         backend="reverse_loop"))
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
    with pytest.raises(KeyError):
        eng.collect(ids[0])  # already collected


def test_single_row_submit_and_warmup(tmp_cache, rng):
    p, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    eng = DcnnServeEngine(MNIST_SMALL, p, backend="pallas", buckets=(1, 2),
                          warmup=True)
    # warmup compiled every bucket up front...
    assert sorted(eng.trace_counts) == [1, 2]
    rid = eng.submit(rng.randn(MNIST_SMALL.z_dim).astype(np.float32))
    out = eng.collect(rid)
    assert out.shape == (1, 28, 28, 1)
    # ...and serving traffic compiled nothing new
    assert eng.total_compiles == 2


def test_per_bucket_tiles_resolve_t_n(tmp_cache):
    """Each bucket's tile choices are fitted to that bucket's batch: the
    batch tile never exceeds the bucket, and large buckets batch-fuse the
    1x1 first layer (MXU row fill)."""
    p, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    eng = DcnnServeEngine(MNIST_SMALL, p, backend="pallas", buckets=(1, 16))
    eng._get_fn(1)
    eng._get_fn(16)
    for bucket in (1, 16):
        for choice in eng.tile_choices[bucket].values():
            assert choice.t_n <= bucket
    # L1 output is 7x7: 49 rows/image vs a 128x128 MXU -> fusion wins
    assert eng.tile_choices[16][0].t_n > 1
    assert eng.tile_choices[1][0].t_n == 1


def test_throughput_reports_run_to_run_cv(tmp_cache, rng):
    """Satellite: bucket stats carry running per-call wall-clock moments
    so `throughput()` reports mean/std/CV over repeated calls — the
    paper's Table II variation methodology (benchmarks.common.time_fn)
    applied to live serving, in O(1) state per bucket.  Compiling calls
    stay excluded from the timers."""
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    eng = DcnnServeEngine(MNIST_SMALL, params, backend="pallas",
                          buckets=(4,))
    z = rng.randn(4, MNIST_SMALL.z_dim).astype(np.float32)
    eng.generate(z)                      # compiling call: not sampled
    assert eng.throughput() == {}
    for _ in range(4):                   # steady state: 4 samples
        eng.generate(z)
    row = eng.throughput()[4]
    bs = eng.bucket_stats[4]
    assert row["calls"] == 4
    mean = bs["seconds"] / 4
    var = bs["sumsq_seconds"] / 4 - mean ** 2
    assert row["mean_s"] == pytest.approx(mean)
    assert row["std_s"] == pytest.approx(max(0.0, var) ** 0.5)
    assert row["cv"] == pytest.approx(row["std_s"] / row["mean_s"])
    assert row["std_s"] >= 0.0 and np.isfinite(row["cv"])
    assert row["img_per_s"] == pytest.approx(
        bs["images"] / bs["seconds"])
    # outcome tagging: a fault-free run has no tainted samples, and the
    # healthy counters alone fed the moments above
    assert row["tainted_calls"] == 0
    assert row["tainted_seconds"] == 0.0
    assert bs["tainted_calls"] == 0


def test_sparse_backend_buckets_share_plans(tmp_cache, rng):
    """pallas_sparse serving: the zero-skip schedule is bucket-independent,
    so buckets that agree on channel tiles reuse one plan, and results
    match the dense reference."""
    p, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    eng = DcnnServeEngine(MNIST_SMALL, p, backend="pallas_sparse",
                          buckets=(2, 4))
    z = rng.randn(3, MNIST_SMALL.z_dim).astype(np.float32)
    imgs = eng.generate(z)
    ref = np.asarray(generator_apply(p, MNIST_SMALL, jnp.asarray(z),
                                     backend="reverse_loop"))
    np.testing.assert_allclose(imgs, ref, rtol=2e-3, atol=2e-3)
    eng.generate(rng.randn(4, MNIST_SMALL.z_dim).astype(np.float32))
    # plans memoized per (layer, t_ci, t_co) — at most one per layer here
    # unless the autotuner picked different channel tiles per bucket
    n_layers = len(MNIST_SMALL.layers)
    assert len(eng._sparse_plan_memo) <= 2 * n_layers
