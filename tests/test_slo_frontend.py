"""SLO-aware async serving frontend: admission control (backpressure +
predictive SLO gate), EDF scheduling with graceful fp32->int8 precision
degradation, requeue-or-shed dispatch failure semantics, and the
overload acceptance scenario — at 2x estimated capacity every request
resolves *typed* (completed / downgraded / AdmissionRejected), never a
hang, never a post-dispatch DeadlineExceeded, with admitted p99 inside
each tenant's SLO.  Device-loss-under-load rides the elastic remesh in a
subprocess (`test_dist_multidevice.run_sub`)."""
import time
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from test_dist_multidevice import run_sub
from test_fault_serving import TINY, _TINY_SUB, tiny_setup, tmp_cache  # noqa: F401

from repro.dist.inject import FaultInjector, TransientFailure
from repro.plan import variant_fingerprints
from repro.serve import (AdmissionController, AdmissionRejected,
                         AsyncServeFrontend, DcnnServeEngine,
                         DeadlineExceeded, EdfScheduler, EngineConfig,
                         EngineDegraded, ServiceModel, TenantClass)


def _engines(params, precisions=("fp32",), buckets=(2, 4), injector=None,
             **cfg_over):
    engines = {}
    for p in precisions:
        engines[p] = DcnnServeEngine.from_config(
            EngineConfig(model=TINY, backend="pallas", buckets=buckets,
                         precision=p, **cfg_over),
            params, fault_injector=(injector if p == "fp32" else None))
    return engines


def _req(rid=0, priority=1, deadline=None, rows=1, allow_degrade=True):
    return types.SimpleNamespace(
        rid=rid, rows=rows, deadline=deadline,
        tenant=TenantClass("t", priority=priority,
                           allow_degrade=allow_degrade))


# ---------------------------------------------------------------------------
# scheduler / admission units (no engine, no threads)
# ---------------------------------------------------------------------------
def test_service_model_estimates_and_scaling():
    m = ServiceModel(decay=0.5)
    assert m.estimate("fp32", 4) is None
    m.observe("fp32", 4, 1.0)
    assert m.estimate("fp32", 4) == 1.0           # first sample seeds
    m.observe("fp32", 4, 2.0)
    assert m.estimate("fp32", 4) == pytest.approx(1.5)   # EMA
    m.override("fp32", 4, 0.4)
    assert m.estimate("fp32", 4) == 0.4           # override is exact
    m.scale(2.0)                                   # remesh: half capacity
    assert m.estimate("fp32", 4) == pytest.approx(0.8)
    assert m.snapshot() == {"fp32/b4": pytest.approx(0.8)}


def test_service_model_chunked_service_seconds():
    m = ServiceModel()
    m.override("fp32", 2, 0.2)
    m.override("fp32", 4, 0.3)
    # 6 rows over buckets (2, 4): one b4 chunk + one b2 chunk
    assert m.service_seconds("fp32", 6, (2, 4)) == pytest.approx(0.5)
    # 3 rows: smallest covering bucket (b4, one padded call)
    assert m.service_seconds("fp32", 3, (2, 4)) == pytest.approx(0.3)
    assert m.service_seconds("fp32", 0, (2, 4)) == 0.0
    # missing bucket estimate falls back to the best per-row rate
    m2 = ServiceModel()
    m2.override("int8", 4, 0.4)                    # 0.1 s/row
    assert m2.service_seconds("int8", 6, (2, 4)) == pytest.approx(
        0.4 + 0.1 * 2)
    # a precision the model knows nothing about: None (admit optimistic)
    assert m.service_seconds("int8", 4, (2, 4)) is None


def test_edf_order_priority_then_deadline_then_arrival():
    a = _req(rid=0, priority=1, deadline=9.0)
    b = _req(rid=1, priority=0, deadline=99.0)     # higher class wins
    c = _req(rid=2, priority=1, deadline=1.0)      # earliest deadline
    d = _req(rid=3, priority=1, deadline=None)     # batch work yields
    assert EdfScheduler.order([a, b, c, d]) == [b, c, a, d]


def test_feasible_precision_degrades_then_sheds():
    m = ServiceModel()
    m.override("fp32", 4, 10.0)
    m.override("int8", 4, 0.01)
    s = EdfScheduler(m, (4,), ("fp32", "int8"), safety=1.2)
    now = 100.0
    fast = _req(deadline=now + 0.5, rows=4)
    assert s.feasible_precision(fast, now) == "int8"      # fp32 busts SLO
    slow = _req(deadline=now + 60.0, rows=4)
    assert s.feasible_precision(slow, now) == "fp32"      # fp32 fits
    strict = _req(deadline=now + 0.5, rows=4, allow_degrade=False)
    assert s.feasible_precision(strict, now) is None      # shed
    none = _req(deadline=None, rows=4)
    assert s.feasible_precision(none, now) == "fp32"      # no deadline
    # backlog counts against the budget
    assert s.feasible_precision(slow, now, backlog_s=100.0) is None
    with pytest.raises(ValueError, match="lead with 'fp32'"):
        EdfScheduler(m, (4,), ("int8", "fp32"))


def test_admission_controller_typed_stages():
    m = ServiceModel()
    m.override("fp32", 4, 10.0)
    ctrl = AdmissionController(EdfScheduler(m, (4,), ("fp32",)),
                               max_queue_rows=8)
    now = 100.0
    with pytest.raises(AdmissionRejected, match="queue full") as ei:
        ctrl.admit(_req(rows=4), queued_rows=6, backlog_s=0.0, now=now)
    assert ei.value.stage == "queue_full"
    with pytest.raises(AdmissionRejected, match="cannot meet its SLO") as ei:
        ctrl.admit(_req(rows=4, deadline=now + 0.1), 0, 0.0, now)
    assert ei.value.stage == "predicted_slo"
    assert ctrl.admit(_req(rows=4, deadline=now + 60.0), 0, 0.0,
                      now) == "fp32"


def test_variant_fingerprints_precision_keyed():
    def plan(batch, precision, h):
        return types.SimpleNamespace(batch=batch, precision=precision,
                                     stable_hash=lambda: h)

    fps = variant_fingerprints([plan(4, "fp32", "aaa"),
                                plan(4, "int8", "bbb")])
    assert fps == {"b4/fp32": "aaa", "b4/int8": "bbb"}
    with pytest.raises(ValueError, match="b4/fp32 disagree"):
        variant_fingerprints([plan(4, "fp32", "aaa"),
                              plan(4, "fp32", "ccc")])


# ---------------------------------------------------------------------------
# frontend end-to-end (single device)
# ---------------------------------------------------------------------------
def test_frontend_parity_with_direct_engine(tmp_cache, tiny_setup):
    """An admitted fp32 request returns images bit-identical to calling
    the bucketed engine directly (the frontend adds scheduling, not
    numerics)."""
    params, z, _ = tiny_setup
    fe = AsyncServeFrontend(_engines(params),
                            [TenantClass("default", slo_ms=None)])
    try:
        ref = DcnnServeEngine.from_config(
            EngineConfig(model=TINY, backend="pallas", buckets=(2, 4)),
            params)
        rid = fe.submit(z, "default")
        np.testing.assert_array_equal(fe.result(rid, timeout_s=120),
                                      ref.generate(z))
        st = fe.stats()["tenants"]["default"]
        assert st["completed"] == 1 and st["shed"] == 0
        assert st["downgraded"] == 0
    finally:
        fe.close()


def test_downgrade_serves_pinned_int8_chain(tmp_cache, tiny_setup):
    """When fp32's predicted completion busts the SLO, a degrade-tolerant
    tenant is served through the pinned int8 plans — bit-identical to the
    int8 engine run directly, and tagged ``downgraded`` in stats."""
    params, z, _ = tiny_setup
    engines = _engines(params, ("fp32", "int8"))
    fe = AsyncServeFrontend(
        engines, [TenantClass("gold", slo_ms=500.0, priority=0)],
        start=False)
    try:
        fe._model.override("fp32", 2, 30.0)   # fp32 can never make 500ms
        fe._model.override("fp32", 4, 30.0)
        fe._model.override("int8", 2, 1e-4)
        fe._model.override("int8", 4, 1e-4)
        ref_int8 = DcnnServeEngine.from_config(
            EngineConfig(model=TINY, backend="pallas", buckets=(2, 4),
                         precision="int8"), params)
        expect = ref_int8.generate(z)         # compile outside the SLO
        fe.start()
        rid = fe.submit(z, "gold")
        np.testing.assert_array_equal(fe.result(rid, timeout_s=120),
                                      expect)
        st = fe.stats()["tenants"]["gold"]
        assert st["completed"] == 1 and st["downgraded"] == 1
        # the degraded chain's plan is pinned and fingerprinted by
        # (bucket, precision) — plans build lazily, so only dispatched
        # buckets appear until prime() touches the rest
        fps = fe.plan_fingerprints()
        assert "b4/int8" in fps
    finally:
        fe.close()


def test_admission_rejects_unmeetable_slo_typed(tmp_cache, tiny_setup):
    """A request that cannot meet its SLO even at the most degraded
    allowed precision is refused at submit (typed, counted) — it never
    occupies the queue."""
    params, z, _ = tiny_setup
    fe = AsyncServeFrontend(
        _engines(params),
        [TenantClass("strict", slo_ms=50.0, allow_degrade=False)],
        start=False)
    try:
        fe._model.override("fp32", 2, 30.0)
        fe._model.override("fp32", 4, 30.0)
        with pytest.raises(AdmissionRejected, match="cannot meet") as ei:
            fe.submit(z, "strict")
        assert ei.value.stage == "predicted_slo"
        st = fe.stats()["tenants"]["strict"]
        assert st["shed_admission"] == 1 and st["admitted"] == 0
    finally:
        fe.close(drain=False)


def test_backpressure_bounded_queue_rejects(tmp_cache, tiny_setup):
    """The request queue is bounded in rows: overflow rejects typed at
    submit (backpressure), and the queued work still completes once the
    worker runs."""
    params, z, ref = tiny_setup
    fe = AsyncServeFrontend(_engines(params),
                            [TenantClass("default", slo_ms=None)],
                            max_queue_rows=4, start=False)
    try:
        rid = fe.submit(z, "default")              # 4 rows: fills the bound
        with pytest.raises(AdmissionRejected, match="queue full") as ei:
            fe.submit(z[:1], "default")
        assert ei.value.stage == "queue_full"
        fe.start()
        out = fe.result(rid, timeout_s=120)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
        assert fe.stats()["queue_rows"] == 0       # bound released
        fe.submit(z[:1], "default")                # admits again
        fe.drain(timeout_s=120)
    finally:
        fe.close()


def test_late_request_shed_typed_before_dispatch(tmp_cache, tiny_setup):
    """A request whose deadline expires while queued is shed typed by the
    scheduler — never dispatched into a guaranteed miss, never a
    post-dispatch DeadlineExceeded."""
    params, z, _ = tiny_setup
    fe = AsyncServeFrontend(_engines(params),
                            [TenantClass("gold", slo_ms=20.0)],
                            start=False)
    try:
        rid = fe.submit(z[:2], "gold")     # no estimates: admits optimistic
        time.sleep(0.1)                    # deadline passes in queue
        fe.start()
        with pytest.raises(AdmissionRejected, match="no longer meet") as ei:
            fe.result(rid, timeout_s=60)
        assert ei.value.stage == "late"
        assert fe.stats()["tenants"]["gold"]["shed_late"] == 1
    finally:
        fe.close()


def test_dispatch_failure_requeues_then_completes(tmp_cache, tiny_setup):
    """A dispatch that fails typed (retries exhausted) requeues the wave's
    requests while their deadlines hold; the next wave serves them —
    callers see images, plus a ``requeued`` count, not an exception."""
    params, z, ref = tiny_setup
    inj = FaultInjector([TransientFailure(at_call=0)])
    fe = AsyncServeFrontend(_engines(params, injector=inj, max_retries=0),
                            [TenantClass("default", slo_ms=None)])
    try:
        rid = fe.submit(z, "default")      # first dispatch fails typed
        out = fe.result(rid, timeout_s=120)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
        st = fe.stats()["tenants"]["default"]
        assert st["requeued"] == 1 and st["completed"] == 1
    finally:
        fe.close()


def test_dispatch_failure_exhausted_resolves_typed(tmp_cache, tiny_setup):
    """With requeues exhausted the request resolves with the engine's
    typed error — a dispatch failure is never a silent drop or a hang."""
    params, z, _ = tiny_setup
    inj = FaultInjector([TransientFailure(0), TransientFailure(1)])
    fe = AsyncServeFrontend(_engines(params, injector=inj, max_retries=0),
                            [TenantClass("default", slo_ms=None)],
                            max_requeues=1)
    try:
        rid = fe.submit(z, "default")
        with pytest.raises(EngineDegraded, match="retries exhausted"):
            fe.result(rid, timeout_s=120)
        assert fe.stats()["tenants"]["default"]["shed_requeue"] == 1
    finally:
        fe.close()


def test_close_resolves_queued_requests_typed(tmp_cache, tiny_setup):
    """A non-draining shutdown fails every queued request typed
    (stage="shutdown") — a caller blocked in result() is released, not
    stranded."""
    params, z, _ = tiny_setup
    fe = AsyncServeFrontend(_engines(params),
                            [TenantClass("default", slo_ms=None)],
                            start=False)
    rid = fe.submit(z[:2], "default")
    fe.close(drain=False)
    with pytest.raises(AdmissionRejected, match="shutdown") as ei:
        fe.result(rid)
    assert ei.value.stage == "shutdown"
    with pytest.raises(RuntimeError, match="closed"):
        fe.submit(z[:1], "default")


def test_prime_seeds_every_bucket_precision(tmp_cache, tiny_setup):
    """`prime()` measures every bucket x precision so admission decisions
    are estimate-backed from the first request."""
    params, _, _ = tiny_setup
    fe = AsyncServeFrontend(_engines(params, ("fp32", "int8")),
                            [TenantClass("default")], start=False)
    try:
        fe.prime(reps=1)
        est = fe.stats()["estimates_s"]
        assert set(est) == {"fp32/b2", "fp32/b4", "int8/b2", "int8/b4"}
        assert all(v > 0 for v in est.values())
    finally:
        fe.close(drain=False)


# ---------------------------------------------------------------------------
# ACCEPTANCE: 2x overload with mixed tenant SLOs
# ---------------------------------------------------------------------------
def test_overload_2x_every_request_resolves_typed(tmp_cache, tiny_setup):
    """ACCEPTANCE: offered load at ~2x the queue's capacity with mixed
    tenant SLOs.  Every submission resolves typed — completed (possibly
    downgraded) or AdmissionRejected — with zero DeadlineExceeded after
    dispatch and zero hangs, and the admitted gold-tenant p99 stays
    inside its SLO."""
    params, z, _ = tiny_setup
    fe = AsyncServeFrontend(
        _engines(params, ("fp32", "int8")),
        [TenantClass("gold", slo_ms=30_000.0, priority=0),
         TenantClass("std", slo_ms=None, priority=1)],
        max_queue_rows=8, start=False)
    try:
        fe.prime(reps=1)
        fe.start()
        rng = np.random.RandomState(7)
        admitted, rejected = [], 0
        for i in range(40):                       # 80 rows vs an 8-row bound
            zi = rng.randn(2, TINY.z_dim).astype(np.float32)
            tenant = "gold" if i % 2 == 0 else "std"
            try:
                admitted.append(fe.submit(zi, tenant))
            except AdmissionRejected as e:
                assert e.stage in ("queue_full", "predicted_slo")
                rejected += 1
        resolved = 0
        for rid in admitted:
            out = fe.result(rid, timeout_s=120)   # a hang fails the test
            assert out.shape == (2, TINY.img_hw, TINY.img_hw, TINY.img_c)
            resolved += 1
        st = fe.stats()
        gold, std = st["tenants"]["gold"], st["tenants"]["std"]
        # typed resolution in both directions, nothing lost
        assert resolved == len(admitted)
        assert gold["admitted"] + std["admitted"] == len(admitted)
        assert (gold["shed_admission"] + std["shed_admission"]
                == rejected)
        assert rejected > 0                       # 2x load DID shed
        assert gold["completed"] + std["completed"] == resolved
        # admitted p99 within the gold SLO (degradation was available)
        assert gold["p99_ms"] <= 30_000.0
        assert st["queue_rows"] == 0 and st["inflight_rows"] == 0
    finally:
        fe.close()


# ---------------------------------------------------------------------------
# device loss under load (8 fake devices, subprocess)
# ---------------------------------------------------------------------------
def test_frontend_device_loss_midstream_resolves_all():
    """Mid-stream DeviceLoss rides the engine's elastic remesh: the
    interrupted wave completes on the shrunken mesh (plan-hash parity is
    asserted inside `_remesh`), every queued request resolves, the
    frontend scales its capacity estimates by the lost-device ratio, and
    the pre-loss throughput samples are snapshotted into the remesh
    event instead of polluting post-loss CV accounting."""
    out = run_sub(_TINY_SUB + """
        import os
        os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "/tmp/at_slo_dl.json")
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.dist.inject import DeviceLoss, FaultInjector
        from repro.launch.mesh import make_serving_mesh
        from repro.models.dcnn import generator_apply, generator_init
        from repro.serve import AsyncServeFrontend, EngineConfig, TenantClass

        params, _ = generator_init(jax.random.PRNGKey(0), TINY)
        inj = FaultInjector([DeviceLoss(at_call=1, keep=4)])
        fe = AsyncServeFrontend.from_config(
            EngineConfig(model=TINY, backend="pallas",
                         mesh=make_serving_mesh(),
                         buckets=(1, 2, 4, 8, 16)),
            params, [TenantClass("default", slo_ms=None)],
            precisions=("fp32",), fault_injector=inj)
        eng = fe._engines["fp32"]
        rng = np.random.RandomState(0)
        zs = [rng.randn(16, TINY.z_dim).astype(np.float32)
              for _ in range(3)]
        rids = [fe.submit(z, "default") for z in zs]
        outs = [fe.result(r, timeout_s=300) for r in rids]
        for z, out in zip(zs, outs):
            ref = np.asarray(generator_apply(params, TINY, jnp.asarray(z),
                                             backend="reverse_loop"))
            np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
        assert eng.n_devices == 4
        st = fe.stats()
        assert st["remeshes"] == 1
        assert st["tenants"]["default"]["completed"] == 3
        ev = eng.fault_stats["remesh_events"][0]
        assert ev["plan_hash_matches"] and all(
            ev["plan_hash_matches"].values())
        # CV audit: pre-loss samples live in the event snapshot, not in
        # the live accounting the post-loss CV is computed from
        assert "bucket_stats_before" in ev
        for bs in eng.bucket_stats.values():
            assert bs["calls"] + bs["tainted_calls"] <= 2
        fe.close()
        print("OK")
    """, timeout=900)
    assert "OK" in out
