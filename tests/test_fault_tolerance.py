"""Fault tolerance: failure-injected training recovery, stragglers,
heartbeats, elastic meshes."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import lm_source
from repro.dist.fault import Heartbeat, StragglerMonitor, elastic_mesh
from repro.train.loop import TrainDriver


def test_driver_recovers_from_injected_failure(tmp_path):
    """Kill the 'node' at step 7; driver must restore the step-5 checkpoint
    and converge to the same final state as an uninterrupted run
    (deterministic data => exact resume)."""
    src = lm_source(seed=0, batch=2, seq_len=8, vocab=64)

    def make_step():
        @jax.jit
        def f(state, tokens):
            # toy "training": state accumulates a function of (step data)
            return state + jnp.sum(tokens) % 97, {"loss": jnp.sum(tokens)}
        return lambda st, b: f(st, jnp.asarray(b["tokens"]))

    failed = {"done": False}

    def injector(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            return True
        return False

    d1 = TrainDriver(make_step(), src, ckpt_dir=str(tmp_path / "a"),
                     ckpt_every=5, failure_injector=injector)
    s_fail = d1.run(jnp.zeros((), jnp.int64), 10)
    assert d1.recoveries == 1

    d2 = TrainDriver(make_step(), src, ckpt_dir=str(tmp_path / "b"),
                     ckpt_every=5)
    s_clean = d2.run(jnp.zeros((), jnp.int64), 10)
    assert int(s_fail) == int(s_clean)


def test_straggler_monitor_flags_slow_steps():
    m = StragglerMonitor(factor=3.0, warmup_steps=2)
    for i in range(10):
        m.observe(i, 0.1)
    assert m.observe(10, 0.5) is True
    assert m.flagged == [10]
    # straggler must not poison the EMA
    assert m.ema < 0.12
    assert m.observe(11, 0.1) is False


def test_heartbeat_fires_on_silence():
    fired = []
    hb = Heartbeat(timeout_s=0.2, on_failure=lambda: fired.append(1))
    try:
        for _ in range(3):
            hb.tick()
            time.sleep(0.05)
        assert not fired
        time.sleep(0.5)
        assert fired == [1]
    finally:
        hb.close()


def test_elastic_mesh_scale_down():
    devs = jax.devices()  # single CPU device in tests
    m = elastic_mesh(devs, model_parallel=1)
    assert m.shape == {"data": 1, "model": 1}
