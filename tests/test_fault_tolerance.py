"""Fault tolerance: failure-injected training recovery, stragglers,
heartbeats, elastic meshes."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import lm_source
from repro.dist.fault import Heartbeat, StragglerMonitor, elastic_mesh
from repro.train.loop import TrainDriver


def test_driver_recovers_from_injected_failure(tmp_path):
    """Kill the 'node' at step 7; driver must restore the step-5 checkpoint
    and converge to the same final state as an uninterrupted run
    (deterministic data => exact resume)."""
    src = lm_source(seed=0, batch=2, seq_len=8, vocab=64)

    def make_step():
        @jax.jit
        def f(state, tokens):
            # toy "training": state accumulates a function of (step data)
            return state + jnp.sum(tokens) % 97, {"loss": jnp.sum(tokens)}
        return lambda st, b: f(st, jnp.asarray(b["tokens"]))

    failed = {"done": False}

    def injector(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            return True
        return False

    d1 = TrainDriver(make_step(), src, ckpt_dir=str(tmp_path / "a"),
                     ckpt_every=5, failure_injector=injector)
    s_fail = d1.run(jnp.zeros((), jnp.int64), 10)
    assert d1.recoveries == 1

    d2 = TrainDriver(make_step(), src, ckpt_dir=str(tmp_path / "b"),
                     ckpt_every=5)
    s_clean = d2.run(jnp.zeros((), jnp.int64), 10)
    assert int(s_fail) == int(s_clean)


def test_straggler_monitor_flags_slow_steps():
    m = StragglerMonitor(factor=3.0, warmup_steps=2)
    for i in range(10):
        m.observe(i, 0.1)
    assert m.observe(10, 0.5) is True
    assert m.flagged == [10]
    # straggler must not poison the EMA
    assert m.ema < 0.12
    assert m.observe(11, 0.1) is False


def test_heartbeat_fires_on_silence():
    fired = []
    hb = Heartbeat(timeout_s=0.2, on_failure=lambda: fired.append(1))
    try:
        for _ in range(3):
            hb.tick()
            time.sleep(0.05)
        assert not fired
        time.sleep(0.5)
        assert fired == [1]
    finally:
        hb.close()


def test_elastic_mesh_scale_down():
    devs = jax.devices()  # single CPU device in tests
    m = elastic_mesh(devs, model_parallel=1)
    assert m.shape == {"data": 1, "model": 1}


def test_straggler_warmup_seeds_with_mean():
    """The first warmup_steps observations ALL seed the EMA (their mean),
    matching the docstring — the pre-fix code seeded with only the first
    sample, so one noisy first call became the baseline forever."""
    m = StragglerMonitor(factor=3.0, warmup_steps=2, decay=0.9)
    assert m.observe(0, 0.1) is False
    assert m.observe(1, 0.3) is False   # seeds too (pre-fix: EMA-updated)
    assert abs(m.ema - 0.2) < 1e-12     # warmup mean, not 0.1-anchored EMA

    # boundary pin: with the 0.2 seed the 3x threshold sits at 0.6
    flag = StragglerMonitor(factor=3.0, warmup_steps=2)
    flag.observe(0, 0.1), flag.observe(1, 0.3)
    assert flag.observe(2, 0.61) is True
    assert flag.flagged == [2]
    ok = StragglerMonitor(factor=3.0, warmup_steps=2)
    ok.observe(0, 0.1), ok.observe(1, 0.3)
    assert ok.observe(2, 0.59) is False
    assert ok.flagged == []


def test_straggler_zero_warmup_still_seeds():
    m = StragglerMonitor(factor=3.0, warmup_steps=0)
    assert m.observe(0, 0.1) is False   # nothing to judge against yet
    assert m.observe(1, 0.5) is True


def test_heartbeat_callback_error_does_not_kill_watcher():
    """An exception raised by on_failure is recorded, and the watcher
    thread survives to fire again after the next tick+silence (the
    pre-fix watcher died silently on the first callback error)."""
    def boom():
        raise RuntimeError("callback boom")

    hb = Heartbeat(timeout_s=0.08, on_failure=boom, poll_s=0.01)
    try:
        time.sleep(0.3)
        assert hb.fire_count == 1            # fired once, not re-fired
        assert len(hb.callback_errors) == 1
        assert hb._thread.is_alive()
        hb.tick()                            # reset: silence fires again
        time.sleep(0.3)
        assert hb.fire_count == 2
        assert len(hb.callback_errors) == 2
    finally:
        hb.close()


def test_heartbeat_no_double_fire_without_tick():
    fired = []
    hb = Heartbeat(timeout_s=0.05, on_failure=lambda: fired.append(1),
                   poll_s=0.01)
    try:
        time.sleep(0.4)
        assert fired == [1]   # one silence window => exactly one fire
    finally:
        hb.close()


def test_heartbeat_disarm_gates_firing():
    """A disarmed heartbeat never fires through silence; re-arming opens
    a fresh window (the serving engine's idle-queue semantics)."""
    fired = []
    hb = Heartbeat(timeout_s=0.05, on_failure=lambda: fired.append(1),
                   poll_s=0.01)
    try:
        hb.disarm()
        time.sleep(0.3)
        assert fired == []
        hb.arm()
        time.sleep(0.3)
        assert fired == [1]
    finally:
        hb.close()


def test_heartbeat_concurrent_ticks_race_free():
    """Hammer tick() from several threads against a fast watcher: the
    locked check-and-set must never double-fire one silence window."""
    import threading

    fired = []
    hb = Heartbeat(timeout_s=0.04, on_failure=lambda: fired.append(1),
                   poll_s=0.002)
    stop = threading.Event()

    def ticker():
        while not stop.is_set():
            hb.tick()
            time.sleep(0.001)

    threads = [threading.Thread(target=ticker) for _ in range(4)]
    try:
        for t in threads:
            t.start()
        time.sleep(0.3)
        assert fired == []        # constant ticking: no fire
        stop.set()
        for t in threads:
            t.join()
        time.sleep(0.3)
        assert fired == [1]       # then one silence => exactly one fire
    finally:
        stop.set()
        hb.close()
