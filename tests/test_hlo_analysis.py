"""Trip-count-aware HLO analyzer: exactness on known programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import analyze
from repro.analysis.roofline import Roofline


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_flops_no_loop():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    res = analyze(_compile(lambda a, b, c: (a @ b) @ c, a, b, c).as_text())
    assert res.flops == 2 * 128 * 256 * 512 + 2 * 128 * 512 * 64


def test_flops_scan_multiplied():
    def g(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=37)
        return y
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    res = analyze(_compile(g, x, w).as_text())
    assert res.flops == 37 * 2 * 64 ** 3
    assert res.n_while >= 1


def test_flops_nested_scan():
    def h(x, w):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=7)
        return y
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    res = analyze(_compile(h, x, w).as_text())
    assert res.flops == 35 * 2 * 64 ** 3


def test_bytes_model_order_of_magnitude():
    """Traffic model within 3x of the obvious analytic value for a simple
    streaming op chain."""
    def f(x):
        return jnp.tanh(x) * 2.0 + 1.0
    x = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
    res = analyze(_compile(f, x).as_text())
    analytic = 2 * (1 << 20) * 4  # read + write once (fused)
    assert analytic / 3 <= res.bytes_accessed <= analytic * 3


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        arch="a", shape="s", mesh="pod", chips=256,
        flops_per_device=1.97e14, bytes_per_device=819e9 * 2,
        collective_bytes_per_device=50e9 * 0.5,
        collectives={}, peak_bytes_per_device=1e9,
        model_flops_global=1.97e14 * 256 * 0.5,
    )
    assert r.t_compute == 1.0
    assert r.t_memory == 2.0
    assert r.t_collective == 0.5
    assert r.bottleneck == "memory"
    assert r.useful_flops_ratio == 0.5
    assert r.roofline_fraction == 0.25  # 0.5 useful / 2.0 bound
