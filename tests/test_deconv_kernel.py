"""Pallas deconv2d kernel vs the pure-jnp oracle: shape/dtype/tiling sweep
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.deconv2d import deconv2d, deconv2d_ref

SWEEP = [
    # (ih, iw, ci, co, k, s, p, t_oh)
    (7, 7, 8, 16, 4, 2, 1, None),
    (7, 7, 8, 16, 4, 2, 1, 4),
    (1, 1, 4, 8, 7, 1, 0, None),
    (1, 1, 4, 8, 4, 1, 0, 2),
    (5, 6, 3, 5, 3, 2, 0, 4),
    (4, 4, 2, 3, 5, 3, 2, 6),
    (16, 16, 32, 64, 4, 2, 1, 8),
    (6, 5, 4, 4, 4, 1, 2, None),
    (8, 8, 16, 8, 3, 3, 1, 9),
]


@pytest.mark.parametrize("geom", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(geom, dtype, rng):
    ih, iw, ci, co, k, s, p, t = geom
    x = jnp.array(rng.randn(2, ih, iw, ci), dtype)
    w = jnp.array(rng.randn(k, k, ci, co) * 0.1, dtype)
    b = jnp.array(rng.randn(co) * 0.1, dtype)
    y = deconv2d(x, w, b, s, p, t_oh=t, t_ow=t)
    y_ref = deconv2d_ref(x, w, b, s, p)
    assert y.shape == y_ref.shape
    assert y.dtype == dtype
    tol = 1e-4 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=tol, atol=tol)


def test_kernel_channel_tiling(rng):
    """CI accumulation across grid steps (revisited output block)."""
    x = jnp.array(rng.randn(1, 6, 6, 24), jnp.float32)
    w = jnp.array(rng.randn(4, 4, 24, 40) * 0.1, jnp.float32)
    y = deconv2d(x, w, None, 2, 1, t_ci=8, t_co=16)
    y_ref = deconv2d_ref(x, w, None, 2, 1)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_kernel_bias_is_initial_value(rng):
    """Algorithm 1: y <- initializeToBias()."""
    x = jnp.zeros((1, 4, 4, 4), jnp.float32)
    w = jnp.zeros((4, 4, 4, 8), jnp.float32)
    b = jnp.array(rng.randn(8), jnp.float32)
    y = deconv2d(x, w, b, 2, 1)
    np.testing.assert_allclose(y, jnp.broadcast_to(b, y.shape), atol=1e-6)


def test_kernel_batch_independence(rng):
    x = jnp.array(rng.randn(3, 5, 5, 8), jnp.float32)
    w = jnp.array(rng.randn(4, 4, 8, 8) * 0.1, jnp.float32)
    y_all = deconv2d(x, w, None, 2, 1)
    y_one = deconv2d(x[1:2], w, None, 2, 1)
    np.testing.assert_allclose(y_all[1:2], y_one, rtol=1e-5, atol=1e-5)
