"""Plan DRC: clean plans verify, every mutation fixture fires its one
typed rule, and the serve engine rejects a corrupted pinned plan with a
typed error before anything compiles."""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.analysis.check import (PlanCheckError, check_network_plan,
                                  check_plan_json, registered_rules)
from repro.models.dcnn import DcnnConfig, DeconvLayerCfg, generator_init
from repro.plan import NetworkPlan, build_network_plan
from repro.serve import DcnnServeEngine, EngineConfig

MNIST_SMALL = DcnnConfig(
    name="dcnn-mnist-small",
    z_dim=24, img_hw=28, img_c=1,
    layers=(
        DeconvLayerCfg(24, 32, 7, 1, 0, "relu"),
        DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),
        DeconvLayerCfg(16, 1, 4, 2, 1, "tanh"),
    ),
)


@pytest.fixture(autouse=True)
def tmp_cache(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setattr(autotune, "_cache", None)


@pytest.fixture(scope="module")
def params():
    p, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    return p


@pytest.fixture(scope="module")
def pruned_params(params):
    out = {}
    for k, v in params.items():
        w = np.asarray(v["w"]).copy()
        thr = np.quantile(np.abs(w), 0.7)
        w[np.abs(w) < thr] = 0.0
        out[k] = {"w": w, "b": np.asarray(v["b"])}
    return out


def _fired(report):
    return sorted({v.rule_id for v in report.failures(strict=True)})


def _mutate_json(plan, edit, tmp_path, name="mutated.json"):
    """Corrupt a pinned plan document the way drift does: edit the JSON
    and drop the content hash (a tampered hash is caught at load, which
    is a different failure mode from a plan that *re-pinned* stale)."""
    doc = json.loads(plan.to_json())
    edit(doc)
    doc.pop("stable_hash", None)
    path = tmp_path / name
    path.write_text(json.dumps(doc))
    return str(path)


# ---------------------------------------------------------------------------
# clean plans verify
# ---------------------------------------------------------------------------
def test_clean_fp32_plan_is_drc_clean():
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")
    report = check_network_plan(plan)
    assert report.ok(strict=True), report.render(strict=True)
    # every DRC rule actually ran, not just passed vacuously
    assert {"drc.vmem_budget", "drc.tile_alignment", "drc.scale_chain",
            "drc.roofline"} <= set(report.rules_run)


def test_clean_int8_plan_is_drc_clean(params):
    plan = build_network_plan(MNIST_SMALL, batch=4, precision="int8",
                              params=params, calib_batch=8)
    report = check_network_plan(plan)
    assert report.ok(strict=True), report.render(strict=True)


def test_clean_sparse_plan_is_drc_clean(pruned_params):
    plan = build_network_plan(MNIST_SMALL, batch=2,
                              backend="pallas_sparse", params=pruned_params)
    report = check_network_plan(plan, params=pruned_params)
    assert report.ok(strict=True), report.render(strict=True)


def test_clean_plan_json_roundtrip_is_drc_clean(tmp_path):
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")
    path = tmp_path / "plan.json"
    plan.to_json(str(path))
    report = check_plan_json(str(path))
    assert report.ok(strict=True), report.render(strict=True)


# ---------------------------------------------------------------------------
# mutation fixtures: each corruption fires its specific typed rule
# ---------------------------------------------------------------------------
def test_oversized_tile_fires_vmem_budget(tmp_path):
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")

    def edit(doc):
        # stride-aligned but grotesquely over VMEM: only the budget rule
        # has grounds to complain
        t = doc["layers"][1]["tiles"]
        t["t_oh"] = t["t_ow"] = 512
        t["t_ci"] = t["t_co"] = 2048

    report = check_plan_json(_mutate_json(plan, edit, tmp_path))
    assert "drc.vmem_budget" in _fired(report), report.render()
    v = report.by_rule()["drc.vmem_budget"][0]
    assert v.layer == 1 and v.fix_hint


def test_stride_misaligned_tile_fires_tile_alignment(tmp_path):
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")

    def edit(doc):
        doc["layers"][1]["tiles"]["t_oh"] = 7   # layer 1 has stride 2

    report = check_plan_json(_mutate_json(plan, edit, tmp_path))
    assert "drc.tile_alignment" in _fired(report), report.render()
    assert report.by_rule()["drc.tile_alignment"][0].layer == 1


def test_broken_scale_chain_fires_scale_chain(params, tmp_path):
    plan = build_network_plan(MNIST_SMALL, batch=4, precision="int8",
                              params=params, calib_batch=8)

    def edit(doc):
        doc["layers"][0]["out_scale"] = 123.0   # != layer 1's x_scale

    report = check_plan_json(_mutate_json(plan, edit, tmp_path))
    assert "drc.scale_chain" in _fired(report), report.render()
    assert report.by_rule()["drc.scale_chain"][0].layer == 0


def test_stale_sparse_digest_fires_sparse_digest(pruned_params, tmp_path):
    plan = build_network_plan(MNIST_SMALL, batch=2,
                              backend="pallas_sparse", params=pruned_params)

    def edit(doc):
        # the drift scenario: a pinned digest that no longer matches the
        # served weights.  Tables are dropped (re-derived at serve time);
        # the digest is the only record of what was validated.
        for layer in doc["layers"]:
            layer["sparse_digest"] = "0badc0de0badc0de"
            layer.pop("sparse_tables", None)

    path = _mutate_json(plan, edit, tmp_path)
    loaded = NetworkPlan.load(path)
    report = check_network_plan(loaded, params=pruned_params)
    assert "drc.sparse_digest" in _fired(report), report.render()


def test_misaligned_bucket_fires_bucket_mesh():
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")
    # per-device batch 4 on 2 devices needs global bucket 8 — absent
    report = check_network_plan(plan, n_devices=2, buckets=(4, 16))
    assert "drc.bucket_mesh" in _fired(report), report.render()
    # and the aligned mesh is clean
    assert check_network_plan(plan, n_devices=2,
                              buckets=(8, 16)).ok(strict=True)


def test_unknown_activation_fires_epilogue():
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")
    bad = dataclasses.replace(
        plan, layers=(dataclasses.replace(plan.layers[0],
                                          activation="swish"),)
        + plan.layers[1:])
    report = check_network_plan(bad)
    assert "drc.epilogue" in _fired(report), report.render()


def test_unloadable_plan_fires_schema(tmp_path):
    path = tmp_path / "garbage.json"
    path.write_text("{not json")
    report = check_plan_json(str(path))
    assert _fired(report) == ["drc.schema"]
    # tampered content hash is also a load-time (schema) failure
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")
    doc = json.loads(plan.to_json())
    doc["layers"][1]["tiles"]["t_oh"] = 512
    tampered = tmp_path / "tampered.json"
    tampered.write_text(json.dumps(doc))
    report = check_plan_json(str(tampered))
    assert _fired(report) == ["drc.schema"]


# ---------------------------------------------------------------------------
# image-rooted (workload zoo) towers: drc.input_root
# ---------------------------------------------------------------------------
def test_clean_image_rooted_plans_are_drc_clean():
    from repro.workloads import DAE_DENOISE, SR_X2

    for cfg in (SR_X2, DAE_DENOISE):
        plan = build_network_plan(cfg, batch=4, backend="pallas")
        report = check_network_plan(plan)
        assert report.ok(strict=True), report.render(strict=True)
        assert "drc.input_root" in report.rules_run


def test_latent_root_spliced_into_sr_fires_input_root(tmp_path):
    from repro.workloads import SR_X2

    plan = build_network_plan(SR_X2, batch=4, backend="pallas")

    def edit(doc):
        # the mix-up this rule exists for: a 1x1 latent root smuggled
        # into a pinned SR plan (first layer no longer consumes images)
        g = doc["layers"][0]["geometry"]
        g["in_h"] = g["in_w"] = 1

    report = check_plan_json(_mutate_json(plan, edit, tmp_path))
    assert "drc.input_root" in _fired(report), report.render()
    v = report.by_rule()["drc.input_root"][0]
    assert v.layer == 0 and "14x14x1" in v.message


def test_bad_sr_geometry_chain_fires(tmp_path):
    from repro.workloads import SR_X2

    plan = build_network_plan(SR_X2, batch=4, backend="pallas")

    def edit(doc):
        doc["layers"][1]["geometry"]["in_h"] = 28   # layer 0 emits 14

    report = check_plan_json(_mutate_json(plan, edit, tmp_path))
    fired = _fired(report)
    assert "drc.geometry_chain" in fired, report.render()
    # the mutated middle layer also breaks squareness of nothing at the
    # root — input_root must NOT misfire on an interior edit
    assert "drc.input_root" not in fired


def test_relabeled_workload_fires_input_root(tmp_path):
    from repro.workloads import SR_X2

    plan = build_network_plan(SR_X2, batch=4, backend="pallas")

    def edit(doc):
        doc["workload"] = "denoise"     # denoise declares a 28x28x1 root

    report = check_plan_json(_mutate_json(plan, edit, tmp_path))
    assert "drc.input_root" in _fired(report), report.render()


def test_unregistered_workload_id_skips_input_root(tmp_path):
    """The registry is open: a plan pinned by a process that registered
    a third-party tower must not fail DRC in a process that didn't."""
    from repro.workloads import SR_X2

    plan = build_network_plan(SR_X2, batch=4, backend="pallas")

    def edit(doc):
        doc["workload"] = "some-third-party-tower"

    report = check_plan_json(_mutate_json(plan, edit, tmp_path))
    assert report.ok(strict=True), report.render(strict=True)


# ---------------------------------------------------------------------------
# engine integration: typed rejection before any compile
# ---------------------------------------------------------------------------
def test_from_config_rejects_corrupt_plan_before_compile(monkeypatch):
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")
    bad_tiles = dataclasses.replace(plan.layers[1].tiles,
                                    t_oh=512, t_ow=512,
                                    t_ci=2048, t_co=2048)
    bad = dataclasses.replace(
        plan, layers=plan.layers[:1]
        + (dataclasses.replace(plan.layers[1], tiles=bad_tiles),)
        + plan.layers[2:])

    def boom(*a, **k):
        raise AssertionError("engine compiled/planned before DRC verdict")

    monkeypatch.setattr(DcnnServeEngine, "_warmup_bucket", boom)
    monkeypatch.setattr(DcnnServeEngine, "_plan_for", boom)
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    cfg = EngineConfig(model=MNIST_SMALL, backend="pallas",
                       max_batch=4, warmup=True)
    with pytest.raises(PlanCheckError) as ei:
        DcnnServeEngine.from_config(cfg, params, plan=bad)
    err = ei.value
    assert isinstance(err, ValueError)          # typed, catchable as both
    assert any(v.rule_id == "drc.vmem_budget" for v in err.violations)
    assert "drc.vmem_budget" in err.report()


def test_from_config_accepts_clean_pinned_plan():
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    cfg = EngineConfig(model=MNIST_SMALL, backend="pallas",
                       max_batch=4, warmup=False)
    eng = DcnnServeEngine.from_config(cfg, params, plan=plan)
    assert eng.plans[eng.max_bucket] is plan


# ---------------------------------------------------------------------------
# registry + CLI plumbing
# ---------------------------------------------------------------------------
def test_rule_registry_covers_both_passes():
    rules = registered_rules()
    assert {"drc.vmem_budget", "drc.tile_alignment", "drc.scale_chain",
            "drc.sparse_digest", "drc.bucket_mesh", "drc.epilogue",
            "drc.roofline", "drc.geometry_chain", "drc.input_root",
            "drc.backend", "drc.schema", "lint.unguarded_write",
            "lint.unguarded_read", "lint.lock_order",
            "lint.callback_in_lock", "lint.check_then_act",
            "bench.sections", "bench.keys", "bench.nan",
            "bench.workloads_rows"} <= set(rules)


def test_cli_gates_on_mutated_plan(tmp_path, capsys):
    from repro.analysis.check.__main__ import main

    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")

    def edit(doc):
        doc["layers"][1]["tiles"]["t_oh"] = 7

    bad = _mutate_json(plan, edit, tmp_path)
    good = tmp_path / "good.json"
    plan.to_json(str(good))
    # --lint with no files skips the lint pass: plan DRC only
    assert main(["--plan-json", str(good), "--lint"]) == 0
    assert main(["--plan-json", bad, "--lint"]) == 1
    out = capsys.readouterr().out
    assert "drc.tile_alignment" in out
