"""Randomized interleaving stress for the engine's submit/collect/drain
micro-batching queue: concurrent submitters, out-of-order collects and
mid-stream drains must deliver every ticket exactly once — no ticket
dropped, none double-delivered, no unbounded wait.  Seeded (the
interleaving pressure comes from real threads, the *workload* from a
fixed RandomState) so a failure reproduces."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from test_fault_serving import TINY, _engine, tiny_setup, tmp_cache  # noqa: F401

from repro.models.dcnn import generator_apply
from repro.serve import AdmissionRejected, DeadlineExceeded


def test_randomized_interleaving_exactly_once(tmp_cache, tiny_setup):
    """4 submitter threads x 12 requests of random size, each collecting
    its own tickets out of submission order, against a drainer thread
    firing mid-stream drains: every ticket resolves exactly once with
    the right rows, and a second collect is a typed KeyError."""
    params, _, _ = tiny_setup
    eng = _engine(params, buckets=(2, 4))
    eng.generate(np.zeros((4, TINY.z_dim), np.float32))   # compile b4
    eng.generate(np.zeros((2, TINY.z_dim), np.float32))   # compile b2
    images_before = eng.stats["images"]
    rng = np.random.RandomState(42)
    payloads = {}                      # rid -> z  (written under lock)
    results = {}                       # rid -> images
    errors = []
    reg = threading.Lock()
    n_threads, n_reqs = 4, 12
    # pre-draw every thread's workload from the one seeded stream
    work = [[rng.randn(int(rng.randint(1, 4)), TINY.z_dim)
             .astype(np.float32) for _ in range(n_reqs)]
            for _ in range(n_threads)]

    def submitter(tid):
        try:
            mine = []
            for z in work[tid]:
                rid = eng.submit(z)
                with reg:
                    payloads[rid] = z
                mine.append(rid)
            for rid in reversed(mine):             # out-of-order collect
                out = eng.collect(rid, timeout_s=120)
                with reg:
                    results[rid] = out
        except Exception as e:                      # pragma: no cover
            errors.append((tid, e))

    def drainer():
        try:
            for _ in range(20):                     # mid-stream drains
                eng.drain()
                time.sleep(0.001)
        except Exception as e:                      # pragma: no cover
            errors.append(("drain", e))

    threads = ([threading.Thread(target=submitter, args=(t,))
                for t in range(n_threads)]
               + [threading.Thread(target=drainer)])
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
        assert not t.is_alive(), "stress thread hung"
    assert not errors, errors

    # exactly once: every ticket delivered, with its own rows
    assert len(results) == n_threads * n_reqs
    assert sorted(results) == sorted(payloads)
    for rid, out in results.items():
        assert out.shape[0] == payloads[rid].shape[0]
    # nothing left behind in any queue state
    assert eng._pending == [] and not eng._inflight
    assert eng._results == {} and eng._failures == {}
    assert (eng.stats["images"] - images_before
            == sum(z.shape[0] for z in payloads.values()))
    # double-collect is typed, not a hang or a silent None
    some_rid = next(iter(results))
    with pytest.raises(KeyError, match="already collected"):
        eng.collect(some_rid)
    # spot-check numerics: the coalesced, interleaved path served real
    # images (vs the reverse_loop oracle), not just the right shapes
    for rid in sorted(results)[:3]:
        ref = np.asarray(generator_apply(
            params, TINY, jnp.asarray(payloads[rid]),
            backend="reverse_loop"))
        np.testing.assert_allclose(results[rid], ref, rtol=2e-3, atol=2e-3)


def test_shed_resolves_ticket_typed(tmp_cache, tiny_setup):
    """Load-shedding a pending ticket resolves it (`AdmissionRejected`),
    never silently drops it; other tickets are untouched and shedding a
    non-pending ticket reports False."""
    params, z, ref = tiny_setup
    eng = _engine(params)
    r1, r2 = eng.submit(z[:2]), eng.submit(z[2:])
    assert eng.shed(r1, "overload drill")
    assert eng.fault_stats["shed"] == 1
    with pytest.raises(AdmissionRejected, match="overload drill") as ei:
        eng.collect(r1)
    assert ei.value.stage == "shed"
    np.testing.assert_allclose(eng.collect(r2), ref[2:],
                               rtol=2e-3, atol=2e-3)
    assert not eng.shed(r2)            # already resolved
    assert not eng.shed(10_000)        # never issued


def test_collect_timeout_on_lost_ticket(tmp_cache, tiny_setup):
    """A ticket that vanished without a result (dispatch lost, e.g. a
    remesh dropped it) raises `DeadlineExceeded` at ``timeout_s`` instead
    of the pre-fix unbounded block; without a timeout the caller gets the
    already-collected KeyError diagnosis immediately."""
    params, z, _ = tiny_setup
    eng = _engine(params)
    rid = eng.submit(z[:1])
    with eng._qlock:                   # simulate a lost dispatch
        eng._pending.clear()
    t0 = time.monotonic()
    with pytest.raises(DeadlineExceeded, match="did not resolve"):
        eng.collect(rid, timeout_s=0.2)
    assert 0.15 < time.monotonic() - t0 < 5.0
    with pytest.raises(KeyError, match="already collected"):
        eng.collect(rid)


def test_collect_timeout_while_queue_busy(tmp_cache, tiny_setup):
    """`collect(timeout_s=)` honors the bound even when another thread's
    drain holds the queue: it fails typed at expiry rather than queueing
    behind an arbitrarily long drain."""
    params, z, _ = tiny_setup
    eng = _engine(params)
    rid = eng.submit(z[:1])
    assert eng._drain_lock.acquire(timeout=1.0)    # a "busy" drain
    try:
        t0 = time.monotonic()
        with pytest.raises(DeadlineExceeded, match="queue busy"):
            eng.collect(rid, timeout_s=0.15)
        assert time.monotonic() - t0 < 5.0
    finally:
        eng._drain_lock.release()
    # once the long drain releases, the ticket still serves
    out = eng.collect(rid, timeout_s=120)
    assert out.shape[0] == 1
