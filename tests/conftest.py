import os

# Tests and benches must see exactly ONE device — the 512-device flag belongs
# to launch/dryrun.py only (and to explicit subprocess tests).
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
