"""Multi-device distribution tests.

These run REAL multi-device SPMD programs on forced host devices; each test
spawns a subprocess so the 8-device XLA flag never leaks into the main
test process (smoke tests and benches must see 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 600) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        os.environ["JAX_PLATFORMS"] = "cpu"
    """) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    """The same reduced-arch train step on a 4x2 mesh and on 1 device must
    produce identical losses (SPMD correctness)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import (abstract_params, build_train_step,
                                        opt_shardings, batch_shardings,
                                        make_optimizer)
        from repro.dist.sharding import make_rules, tree_shardings
        from repro.models.transformer import init_lm

        cfg = reduced_config("deepseek-7b")
        rng = np.random.RandomState(0)
        batch = {
            "tokens": jnp.array(rng.randint(0, cfg.vocab_size, (8, 16))),
            "labels": jnp.array(rng.randint(0, cfg.vocab_size, (8, 16))),
        }
        params, specs = init_lm(jax.random.PRNGKey(0), cfg)
        opt = make_optimizer(cfg)
        opt_state = opt.init(params)

        # single-device reference
        step1 = build_train_step(cfg, None, None) if False else None
        from repro.train.lm import make_train_step
        ref_step = jax.jit(make_train_step(cfg, opt))
        _, _, _, met_ref = ref_step(params, opt_state, None, batch)

        mesh = make_test_mesh(4, 2)
        rules = make_rules("fsdp_tp")
        p_sh = tree_shardings(mesh, rules, params, specs)
        step = build_train_step(cfg, mesh, rules)
        jitted = jax.jit(step, in_shardings=(p_sh, None, None))
        p2, o2, met = jitted(params, opt_state, batch)
        print("ref", float(met_ref["loss"]), "sharded", float(met["loss"]))
        assert abs(float(met_ref["loss"]) - float(met["loss"])) < 1e-3
        # params visibly sharded
        embed_shard = p2["embed"]["table"].sharding
        assert len(embed_shard.device_set) == 8
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.dist.pipeline import microbatch, pipeline_apply

        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.RandomState(0)
        ws = jnp.array(rng.randn(4, 16, 16) * 0.3, jnp.float32)

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        x = jnp.array(rng.randn(8, 16), jnp.float32)
        xm = microbatch(x, 4)
        y_pp = pipeline_apply(mesh, "pod", stage_fn, ws, xm)
        # sequential oracle
        y_ref = x
        for i in range(4):
            y_ref = stage_fn(ws[i], y_ref)
        np.testing.assert_allclose(
            np.asarray(y_pp).reshape(8, 16), np.asarray(y_ref),
            rtol=1e-5, atol=1e-5)
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_elastic_remesh_and_reshard():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.fault import elastic_mesh, reshard_tree
        from jax.sharding import NamedSharding, PartitionSpec as P

        devs = jax.devices()
        m8 = elastic_mesh(devs, model_parallel=2)
        assert m8.shape == {"data": 4, "model": 2}
        x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
        xs = jax.device_put(x, NamedSharding(m8, P("data", "model")))
        # lose 3 devices -> scale down to 2x2
        m4 = elastic_mesh(devs[:5], model_parallel=2)
        assert m4.shape == {"data": 2, "model": 2}
        xr = reshard_tree(xs, NamedSharding(m4, P("data", "model")))
        np.testing.assert_array_equal(np.asarray(xr), np.asarray(x))
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_cell_small_mesh_all_kinds():
    """lower+compile one train, one prefill, one decode cell on a 2x2 mesh
    through the SAME code path the production dry-run uses."""
    out = run_sub("""
        import dataclasses, jax
        from repro.configs import reduced_config, SHAPES
        from repro.launch.mesh import make_test_mesh
        from repro.launch.steps import lower_cell

        mesh = make_test_mesh(2, 2)
        cfg = reduced_config("gemma2-27b")
        for name, seq, gb in (("train_4k", 64, 8), ("prefill_32k", 64, 4),
                              ("decode_32k", 64, 8)):
            suite = dataclasses.replace(SHAPES[name], seq_len=seq,
                                        global_batch=gb)
            compiled = lower_cell(cfg, suite, mesh).compile()
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):  # pre-0.5 jax: one dict/device
                ca = ca[0]
            assert ca.get("flops", 0) > 0
            print(name, "ok")
        print("OK")
    """, devices=4)
    assert "OK" in out
