"""End-to-end behaviour: public API surface + a miniature full pipeline
(data -> train LM -> checkpoint -> serve) exercising every subsystem once."""
import jax
import jax.numpy as jnp
import numpy as np


def test_public_api_imports():
    import repro
    from repro import core
    from repro.configs import get_config, list_configs
    from repro.kernels.deconv2d import deconv2d, deconv2d_ref
    from repro.kernels.deconv2d_sparse import deconv2d_sparse

    assert len(list_configs()) == 12  # 10 assigned LM archs + 2 paper DCNNs
    cfg = get_config("gemma2-27b")
    assert cfg.n_layers == 46 and cfg.d_model == 4608


def test_miniature_end_to_end(tmp_path):
    from repro.configs import reduced_config
    from repro.data.pipeline import lm_source
    from repro.models.transformer import init_lm
    from repro.optim.optimizer import AdamW
    from repro.serve.engine import ServeEngine
    from repro.train.lm import make_train_step
    from repro.train.loop import TrainDriver

    cfg = reduced_config("qwen2-moe-a2.7b")  # exercises the MoE path
    src = lm_source(seed=0, batch=2, seq_len=16, vocab=cfg.vocab_size)
    opt = AdamW(lr=1e-3)
    inner = jax.jit(make_train_step(cfg, opt))

    def step_fn(state, batch):
        p, o = state
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, _, met = inner(p, o, None, b)
        return (p, o), met

    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    driver = TrainDriver(step_fn, src, ckpt_dir=str(tmp_path), ckpt_every=2)
    (params, _) = driver.run((params, opt.init(params)), 4)
    losses = [m["loss"] for m in driver.metrics_log]
    assert all(np.isfinite(l) for l in losses)

    eng = ServeEngine(cfg, params, batch_size=2, max_len=24)
    out = eng.generate(np.ones((2, 4), np.int32), max_new_tokens=3)
    assert out.shape == (2, 3)
