"""Statistical-calibration int8 quantization subsystem.

Covers: shared quantization math, activation observers, per-channel
weight calibration, int8-kernel-vs-integer-reference parity (exhaustive
small shapes incl. non-square and the S=2/K=5 Algorithm-1 case), the
chained quantized generator, dtype-aware autotuning, the int8 serving
engine on both paper networks, and MMD-vs-fp32 quality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dse import TPU_V5E, tile_attainable
from repro.core.tiling import DeconvGeometry, kernel_vmem_bytes
from repro.kernels.deconv2d import deconv2d_int8, deconv2d_int8_ref
from repro.models.dcnn import (CELEBA_DCNN, DcnnConfig, DeconvLayerCfg,
                               MNIST_DCNN, generator_apply, generator_init)
from repro.quant import (QMAX, LayerQuant, QuantConfig, calibrate,
                         dequantize_symmetric, fake_quant, observe_amax,
                         quantize_params, quantize_symmetric,
                         quantized_generator_apply, quantized_generator_ref,
                         symmetric_scale)


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setattr(autotune, "_cache", None)
    yield tmp_path / "at.json"
    monkeypatch.setattr(autotune, "_cache", None)


# ---------------------------------------------------------------------------
# shared quantization math
# ---------------------------------------------------------------------------
def test_qmath_roundtrip_half_step_error(rng):
    x = jnp.array(rng.randn(512), jnp.float32)
    scale = symmetric_scale(jnp.max(jnp.abs(x)))
    q = quantize_symmetric(x, scale)
    assert q.dtype == jnp.int8
    assert int(jnp.abs(q).max()) <= QMAX
    err = jnp.abs(dequantize_symmetric(q, scale) - x).max()
    assert float(err) <= float(scale) * 0.5 + 1e-9
    # fake_quant is exactly quantize-then-dequantize
    np.testing.assert_array_equal(np.asarray(fake_quant(x, scale)),
                                  np.asarray(dequantize_symmetric(q, scale)))


def test_qmath_saturates_and_keeps_zero_exact(rng):
    x = jnp.array([0.0, 1e6, -1e6, 0.5], jnp.float32)
    q = quantize_symmetric(x, 1.0)
    assert q[0] == 0          # symmetric: zero maps to zero (pad-safe)
    assert q[1] == QMAX and q[2] == -QMAX


# ---------------------------------------------------------------------------
# activation observers
# ---------------------------------------------------------------------------
def test_observers_order_on_heavy_tail(rng):
    """Statistical clipping tightens the range: on long-tailed data both
    percentile and mean+k-sigma clip below the raw absmax, and the
    percentile clip tightens as p drops."""
    x = rng.standard_cauchy(20000).astype(np.float32)
    amax = observe_amax(x, "minmax")
    p999 = observe_amax(x, "percentile", percentile=99.9)
    p99 = observe_amax(x, "percentile", percentile=99.0)
    ks = observe_amax(x, "mean_ksigma", k=3.0)
    assert amax == pytest.approx(np.abs(x).max())
    assert p99 < p999 < amax
    assert ks < amax


def test_observer_mean_ksigma_never_exceeds_minmax(rng):
    """On short-tailed data mean + k*sigma could overshoot the true max;
    the observer clamps at it (a clip beyond the data range only wastes
    integer steps)."""
    x = np.ones(100, np.float32)  # std 0, mean 1
    assert observe_amax(x, "mean_ksigma", k=6.0) == pytest.approx(1.0)


def test_observer_unknown_strategy_raises():
    with pytest.raises(ValueError, match="unknown observer"):
        observe_amax(np.ones(4), "entropy")
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    with pytest.raises(ValueError, match="unknown observer"):
        calibrate(params, MNIST_DCNN,
                  jnp.zeros((4, MNIST_DCNN.z_dim)), strategy="entropy")


def test_calibrate_shapes_and_chaining():
    """One LayerQuant per layer; per-channel weight scales; out_scale(i)
    chains to layer i+1's input scale and is None for the last layer."""
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    z = jax.random.normal(jax.random.PRNGKey(1), (8, MNIST_DCNN.z_dim))
    qcfg = calibrate(params, MNIST_DCNN, z)
    assert len(qcfg.layers) == len(MNIST_DCNN.layers)
    for i, (lq, l) in enumerate(zip(qcfg.layers, MNIST_DCNN.layers)):
        assert lq.x_scale > 0
        assert len(lq.w_scale) == l.c_out
        assert all(s > 0 for s in lq.w_scale)
        if i + 1 < len(qcfg.layers):
            assert qcfg.out_scale(i) == qcfg.layers[i + 1].x_scale
    assert qcfg.out_scale(len(qcfg.layers) - 1) is None


def test_generator_apply_intermediates_are_layer_inputs():
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    z = jax.random.normal(jax.random.PRNGKey(1), (4, MNIST_DCNN.z_dim))
    imgs, inters = generator_apply(params, MNIST_DCNN, z,
                                   backend="reverse_loop",
                                   return_intermediates=True)
    assert len(inters) == len(MNIST_DCNN.layers)
    assert inters[0].shape == (4, 1, 1, MNIST_DCNN.z_dim)
    geoms = MNIST_DCNN.geometries()
    for x_in, g in zip(inters, geoms):
        assert x_in.shape == (4, g.in_h, g.in_w, g.c_in)
    assert imgs.shape == (4, 28, 28, 1)


def test_quantize_params_per_channel_int8():
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    z = jax.random.normal(jax.random.PRNGKey(1), (8, MNIST_DCNN.z_dim))
    qcfg = calibrate(params, MNIST_DCNN, z)
    qp = quantize_params(params, MNIST_DCNN, qcfg)
    for i, l in enumerate(MNIST_DCNN.layers):
        lq = qp[f"l{i}"]
        assert lq["w_q"].dtype == np.int8
        assert lq["scale"].shape == (l.c_out,)
        # per-channel: each channel's max |q| saturates its own range
        # (the channel absmax quantizes to exactly +-127)
        q_amax = np.abs(lq["w_q"].reshape(-1, l.c_out)).max(axis=0)
        assert (q_amax == QMAX).all()


# ---------------------------------------------------------------------------
# int8 kernel vs integer-exact reference
# ---------------------------------------------------------------------------
# (ih, iw, ci, co, k, s, p, t) — the Algorithm-1 parity shapes of
# test_halo_kernel, incl. the OH=7/S=2/K=5 case and non-square images
INT8_GEOMS = [
    (4, 4, 6, 5, 5, 2, 2, 4),
    (4, 6, 3, 4, 5, 2, 2, 4),
    (7, 7, 8, 8, 4, 2, 1, 4),
    (3, 5, 4, 3, 3, 1, 1, 3),
    (4, 5, 2, 3, 5, 3, 1, 6),
]


@pytest.mark.parametrize("geom", INT8_GEOMS)
@pytest.mark.parametrize("out_scale", [None, 0.04])
def test_int8_kernel_matches_integer_reference(geom, out_scale, rng):
    """The kernel's int32 accumulation is integer-exact, so parity with
    the int32 zero-insertion oracle is near-ulp for the f32 epilogue and
    within one LSB for the re-quantized int8 output."""
    ih, iw, ci, co, k, s, p, t = geom
    xq = jnp.asarray(rng.randint(-QMAX, QMAX + 1, (3, ih, iw, ci)), jnp.int8)
    wq = jnp.asarray(rng.randint(-QMAX, QMAX + 1, (k, k, ci, co)), jnp.int8)
    scale = jnp.asarray(rng.rand(co).astype(np.float32) * 1e-3 + 1e-5)
    b = jnp.asarray(rng.randn(co).astype(np.float32) * 0.1)
    for act in (None, "relu", "tanh"):
        y = deconv2d_int8(xq, wq, scale, b, s, p, t_oh=t, t_ow=t,
                          t_ci=8, t_co=8, t_n=2, activation=act,
                          out_scale=out_scale)
        y_ref = deconv2d_int8_ref(xq, wq, scale, b, s, p, activation=act,
                                  out_scale=out_scale)
        assert y.shape == y_ref.shape
        if out_scale is None:
            assert y.dtype == jnp.float32
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=1e-5, atol=1e-5)
        else:
            assert y.dtype == jnp.int8
            # FMA/rounding at exact .5 ties may flip one LSB
            assert np.abs(np.asarray(y, np.int32)
                          - np.asarray(y_ref, np.int32)).max() <= 1


def test_int8_kernel_ragged_batch_and_channels(rng):
    """Batch not a t_n multiple + channels not tile multiples: the int8
    zero padding (symmetric quantization: 0 is exact) must not leak."""
    xq = jnp.asarray(rng.randint(-QMAX, QMAX + 1, (5, 7, 7, 10)), jnp.int8)
    wq = jnp.asarray(rng.randint(-QMAX, QMAX + 1, (4, 4, 10, 12)), jnp.int8)
    scale = jnp.asarray(rng.rand(12).astype(np.float32) * 1e-3)
    y = deconv2d_int8(xq, wq, scale, None, 2, 1, t_oh=4, t_ow=4,
                      t_ci=8, t_co=8, t_n=2)
    y_ref = deconv2d_int8_ref(xq, wq, scale, None, 2, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# chained quantized generator
# ---------------------------------------------------------------------------
TINY = DcnnConfig(
    name="dcnn-tiny-quant", z_dim=12, img_hw=8, img_c=1,
    layers=(
        DeconvLayerCfg(12, 16, 4, 1, 0, "relu"),   # 1x1 -> 4x4
        DeconvLayerCfg(16, 1, 4, 2, 1, "tanh"),    # 4x4 -> 8x8
    ),
)


def test_quantized_chain_matches_reference_chain(tmp_cache):
    params, _ = generator_init(jax.random.PRNGKey(0), TINY)
    z = jax.random.normal(jax.random.PRNGKey(1), (6, TINY.z_dim))
    qcfg = calibrate(params, TINY, z)
    qp = quantize_params(params, TINY, qcfg)
    y = quantized_generator_apply(qp, TINY, qcfg, z)
    y_ref = quantized_generator_ref(qp, TINY, qcfg, z)
    assert y.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_quantized_chain_close_to_fp32(tmp_cache):
    """End-to-end quality: int8 images track the fp32 generator closely
    on a freshly-initialized MNIST net (tanh output range [-1, 1])."""
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    z = jax.random.normal(jax.random.PRNGKey(1), (8, MNIST_DCNN.z_dim))
    qcfg = calibrate(params, MNIST_DCNN, z)
    qp = quantize_params(params, MNIST_DCNN, qcfg)
    y = quantized_generator_apply(qp, MNIST_DCNN, qcfg, z)
    base = generator_apply(params, MNIST_DCNN, z, backend="reverse_loop")
    assert float(jnp.abs(y - base).max()) < 0.05
    assert float(jnp.abs(y - base).mean()) < 0.005


def test_quant_config_layer_count_mismatch_rejected():
    params, _ = generator_init(jax.random.PRNGKey(0), TINY)
    z = jax.random.normal(jax.random.PRNGKey(1), (4, TINY.z_dim))
    qcfg = calibrate(params, TINY, z)
    qp = quantize_params(params, TINY, qcfg)
    bad = QuantConfig(name="bad", strategy="minmax",
                      layers=(LayerQuant(1.0, (1.0,)),))
    with pytest.raises(ValueError, match="layers"):
        quantized_generator_apply(qp, TINY, bad, z)


# ---------------------------------------------------------------------------
# dtype-aware autotuning / DSE
# ---------------------------------------------------------------------------
def test_int8_candidates_fit_vmem_at_one_byte(tmp_cache):
    from repro.kernels.autotune import choose_tiles

    l1 = DeconvGeometry(1, 1, 100, 1024, 4, 1, 0)
    c = choose_tiles(l1, jnp.int8, backend="pallas", batch=64)
    assert kernel_vmem_bytes(l1, c.t_oh, c.t_ow, c.t_ci, c.t_co, 1,
                             t_n=c.t_n) <= TPU_V5E.onchip_bytes
    # distinct cache entry from the fp32 choice at the same geometry/batch
    assert choose_tiles(l1, jnp.int8, backend="pallas",
                        batch=64).source == "cache"
    assert choose_tiles(l1, jnp.float32, backend="pallas",
                        batch=64).source != "cache"


def test_int8_attainable_beats_fp32(tmp_cache):
    """The acceptance roofline: at batch 64 the modeled int8 throughput
    (quarter traffic, doubled MXU peak) is >= 1.5x fp32 on the paper's
    generator layers."""
    for g in (CELEBA_DCNN.geometries()[0], MNIST_DCNN.geometries()[0],
              CELEBA_DCNN.geometries()[1]):
        from repro.kernels.autotune import choose_tiles

        c8 = choose_tiles(g, jnp.int8, backend="pallas", batch=64)
        c32 = choose_tiles(g, jnp.float32, backend="pallas", batch=64)
        a8 = tile_attainable(g, c8.t_oh, c8.t_ow, c8.t_ci, c8.t_co,
                             TPU_V5E, t_n=c8.t_n, batch=64, dtype_bytes=1)
        a32 = tile_attainable(g, c32.t_oh, c32.t_ow, c32.t_ci, c32.t_co,
                              TPU_V5E, t_n=c32.t_n, batch=64, dtype_bytes=4)
        assert a8.attainable_ops >= 1.5 * a32.attainable_ops, g


def test_device_int8_peak_selection():
    assert TPU_V5E.peak_for(1) == TPU_V5E.int8_peak_ops > TPU_V5E.peak_ops
    assert TPU_V5E.peak_for(4) == TPU_V5E.peak_ops
    assert TPU_V5E.peak_for(None) == TPU_V5E.peak_ops


# ---------------------------------------------------------------------------
# int8 serving engine (calibrate -> autotune -> serve)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg,buckets,n", [(MNIST_DCNN, (1, 2, 4), 7),
                                           (CELEBA_DCNN, (2,), 2)])
def test_serve_engine_int8_end_to_end(cfg, buckets, n, tmp_cache, rng):
    from repro.serve.engine import DcnnServeEngine

    params, _ = generator_init(jax.random.PRNGKey(0), cfg)
    eng = DcnnServeEngine(cfg, params, backend="pallas", precision="int8",
                          buckets=buckets, calib_batch=16)
    z = rng.randn(n, cfg.z_dim).astype(np.float32)
    imgs = eng.generate(z)
    assert imgs.shape == (n, cfg.img_hw, cfg.img_hw, cfg.img_c)
    assert imgs.dtype == np.float32
    base = np.asarray(generator_apply(params, cfg, jnp.asarray(z),
                                      backend="reverse_loop"))
    assert np.abs(imgs - base).max() < 0.1
    assert eng.total_compiles <= len(buckets)
    # per-bucket tiles were resolved for int8 (cache hit at int8 dtype)
    from repro.kernels.autotune import choose_tiles
    g0 = cfg.geometries()[0]
    hit = choose_tiles(g0, jnp.int8, backend="pallas",
                       batch=eng.shard_batch(eng.buckets[-1]))
    assert hit.source == "cache"


def test_serve_engine_int8_rejects_non_pallas():
    from repro.serve.engine import DcnnServeEngine

    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    with pytest.raises(ValueError, match="quantized"):
        DcnnServeEngine(MNIST_DCNN, params, backend="xla",
                        precision="int8")
    with pytest.raises(ValueError, match="precision"):
        DcnnServeEngine(MNIST_DCNN, params, precision="int4")


def test_serve_engine_int8_explicit_quant_cfg(tmp_cache, rng):
    """A pre-computed QuantConfig bypasses self-calibration and is served
    verbatim (the production path: calibrate offline, deploy the config)."""
    from repro.serve.engine import DcnnServeEngine

    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    z_cal = jax.random.normal(jax.random.PRNGKey(5), (16, MNIST_DCNN.z_dim))
    qcfg = calibrate(params, MNIST_DCNN, z_cal, strategy="percentile")
    eng = DcnnServeEngine(MNIST_DCNN, params, backend="pallas",
                          precision="int8", quant_cfg=qcfg, buckets=(4,))
    assert eng.quant_cfg is qcfg
    imgs = eng.generate(rng.randn(4, MNIST_DCNN.z_dim).astype(np.float32))
    assert np.isfinite(imgs).all()


# ---------------------------------------------------------------------------
# quality harness
# ---------------------------------------------------------------------------
def test_mmd_degradation_report(tmp_cache):
    from repro.quant.evaluate import mmd_degradation

    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_DCNN)
    rows = mmd_degradation(params, MNIST_DCNN, jax.random.PRNGKey(2),
                           n=8, calib_n=8, use_kernel=False)
    assert [r["strategy"] for r in rows] == list(
        ("minmax", "percentile", "mean_ksigma"))
    for r in rows:
        assert np.isfinite(r["mmd_vs_fp32"])
        assert r["mmd_vs_fp32"] < 0.5       # int8 tracks fp32's distribution
        assert r["max_abs_err"] < 0.1       # tanh range [-1, 1]
