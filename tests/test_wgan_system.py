"""End-to-end system tests: WGAN-GP training (the paper's framework), LM
training with exact checkpoint resume, the full quality/speed sparsity loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metric import optimal_sparsity
from repro.core.mmd import mmd
from repro.core.sparsity import prune_tree
from repro.data.pipeline import image_source, lm_source
from repro.models.dcnn import DcnnConfig, DeconvLayerCfg, generator_apply
from repro.optim.optimizer import AdamW
from repro.train.wgan import train_wgan

# tiny but structurally-faithful WGAN config (3 deconv layers like MNIST)
TINY = DcnnConfig(
    name="tiny", z_dim=16, img_hw=16, img_c=1,
    layers=(
        DeconvLayerCfg(16, 32, 4, 1, 0, "relu"),   # 1 -> 4
        DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),   # 4 -> 8
        DeconvLayerCfg(16, 1, 4, 2, 1, "tanh"),    # 8 -> 16
    ),
)


class _TinySource:
    def batch(self, step):
        rng = np.random.RandomState(step)
        x = rng.randn(8, 16, 16, 1).astype(np.float32) * 0.2
        x[:, 4:12, 4:12, :] += 0.5  # learnable structure
        return {"images": np.clip(x, -1, 1)}


def test_wgan_gp_trains():
    gp, dp, hist = train_wgan(
        TINY, _TinySource(), steps=6, key=jax.random.PRNGKey(0),
        g_opt=AdamW(lr=1e-4, b1=0.5, b2=0.9),
        d_opt=AdamW(lr=1e-4, b1=0.5, b2=0.9),
        n_critic=2, log_every=1)
    assert len(hist) >= 2
    for h in hist:
        assert np.isfinite(h["d_loss"]) and np.isfinite(h["g_loss"])
        assert np.isfinite(h["gp"])
    imgs = generator_apply(gp, TINY, jnp.zeros((2, TINY.z_dim)))
    assert imgs.shape == (2, 16, 16, 1)


def test_wgan_fit_streaming_iterator_drains_exactly():
    """Satellite (ROADMAP open item): `fit` accepts a streaming batch
    iterator.  A finite iterator is consumed one batch per critic
    sub-step and training stops the moment it drains — no synthetic
    batches are invented past its end, and the iterator is left fully
    exhausted."""
    from repro.data.pipeline import finite_batches
    from repro.train.wgan import WganTrainer

    src = _TinySource()
    stream = finite_batches(src, 3)     # 3 batches, n_critic=1 -> 3 steps
    t = WganTrainer(TINY, AdamW(lr=1e-4, b1=0.5, b2=0.9),
                    AdamW(lr=1e-4, b1=0.5, b2=0.9), n_critic=1)
    gp, dp, hist = t.fit(stream, 10, jax.random.PRNGKey(0), log_every=1)
    assert [h["step"] for h in hist] == [0, 1, 2]
    assert next(stream, None) is None   # drained exactly
    assert all(np.isfinite(v) for h in hist for v in h.values())

    # n_critic=2 over 5 batches: 2 full steps; the dangling 5th batch must
    # not produce an unpaired generator update (history stops at step 1)
    t2 = WganTrainer(TINY, AdamW(lr=1e-4, b1=0.5, b2=0.9),
                     AdamW(lr=1e-4, b1=0.5, b2=0.9), n_critic=2)
    _, _, hist2 = t2.fit(finite_batches(src, 5), 10, jax.random.PRNGKey(0),
                         log_every=1)
    assert [h["step"] for h in hist2] == [0, 1]
    # bare-array streams (no dict wrapper) work too
    t3 = WganTrainer(TINY, AdamW(lr=1e-4, b1=0.5, b2=0.9),
                     AdamW(lr=1e-4, b1=0.5, b2=0.9), n_critic=1)
    _, _, hist3 = t3.fit(iter([src.batch(0)["images"]] * 2), 10,
                         jax.random.PRNGKey(0), log_every=1)
    assert [h["step"] for h in hist3] == [0, 1]


def test_wgan_n_critic_zero_raises():
    """Regression: n_critic=0 used to crash with an unbound `real` at the
    gen_step call; it is now rejected up front."""
    import pytest

    from repro.train.wgan import WganTrainer

    with pytest.raises(ValueError, match="n_critic"):
        WganTrainer(TINY, AdamW(lr=1e-4), AdamW(lr=1e-4), n_critic=0)
    with pytest.raises(ValueError, match="n_critic"):
        train_wgan(TINY, _TinySource(), steps=1, key=jax.random.PRNGKey(0),
                   g_opt=AdamW(lr=1e-4), d_opt=AdamW(lr=1e-4), n_critic=0)
    # inference-only backend rejected up front, not at the first step
    # (after the autotune DSE has already run)
    with pytest.raises(ValueError, match="inference-only"):
        WganTrainer(TINY, AdamW(lr=1e-4), AdamW(lr=1e-4),
                    backend="pallas_sparse")


class _RaggedSource:
    """Batch size varies per step (e.g. a final partial epoch batch)."""
    sizes = (5, 6, 7, 8)

    def batch(self, step):
        rng = np.random.RandomState(step)
        n = self.sizes[step % len(self.sizes)]
        return {"images": rng.randn(n, 16, 16, 1).astype(np.float32) * 0.2}


def test_wgan_ragged_batches_hit_buckets_not_fresh_traces():
    """Regression: `batch` was a static jit argument, so every distinct
    ragged batch size compiled a new gen_step executable (and the critic
    retraced per shape).  Both steps now round through power-of-two
    buckets: four distinct sizes -> one compile each."""
    from repro.train.wgan import WganTrainer

    t = WganTrainer(TINY, AdamW(lr=1e-4, b1=0.5, b2=0.9),
                    AdamW(lr=1e-4, b1=0.5, b2=0.9), n_critic=1)
    gp, dp, hist = t.fit(_RaggedSource(), 4, jax.random.PRNGKey(0),
                         log_every=1)
    assert all(np.isfinite(v) for h in hist for v in h.values())
    assert t.trace_counts["critic"] == {8: 1}, t.trace_counts
    assert t.trace_counts["gen"] == {8: 1}, t.trace_counts
    # masked bucket padding is exact: a padded step equals the same step
    # on the unpadded batch only through the mask, which the finite
    # metrics + parity tests in tests/test_dist_dcnn.py pin further


def test_wgan_checkpoint_resume_exact(tmp_path):
    """Regression: checkpoints used to drop the optimizer states and skip
    step 0.  Now {g, d, gs, ds} + step are persisted (step 0 included) and
    `resume_from=` reproduces the uninterrupted run bitwise."""
    from repro.ckpt.checkpoint import AsyncCheckpointer, valid_steps

    d = str(tmp_path / "run")
    opt = lambda: AdamW(lr=1e-4, b1=0.5, b2=0.9)
    ck = AsyncCheckpointer(d, keep=5)
    train_wgan(TINY, _TinySource(), steps=4, key=jax.random.PRNGKey(0),
               g_opt=opt(), d_opt=opt(), n_critic=2, ckpt=ck, ckpt_every=2)
    ck.wait()
    assert valid_steps(d) == [0, 2]   # step 0 no longer skipped
    g2, d2, _ = train_wgan(TINY, _TinySource(), steps=6,
                           key=jax.random.PRNGKey(0), g_opt=opt(),
                           d_opt=opt(), n_critic=2, resume_from=d)
    g3, d3, _ = train_wgan(TINY, _TinySource(), steps=6,
                           key=jax.random.PRNGKey(0), g_opt=opt(),
                           d_opt=opt(), n_critic=2)
    for a, b in zip(jax.tree_util.tree_leaves((g2, d2)),
                    jax.tree_util.tree_leaves((g3, d3))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sparsity_quality_loop():
    """The paper's §V-C loop end-to-end: prune -> measure latency model +
    MMD -> Eq. 6 metric."""
    key = jax.random.PRNGKey(0)
    from repro.models.dcnn import generator_init
    gp, _ = generator_init(key, TINY)
    z = jax.random.normal(key, (32, TINY.z_dim))
    ref = generator_apply(gp, TINY, z)

    from repro.core.sparsity import zero_skip_stats
    sparsities = [0.0, 0.5, 0.8, 0.95]
    tp, dp_ = [], []
    for s in sparsities:
        pruned = prune_tree(gp, s)
        imgs = generator_apply(pruned, TINY, z)
        d = float(mmd(ref.reshape(32, -1), imgs.reshape(32, -1))) + 1e-4
        t = 0.0
        for i, l in enumerate(TINY.layers):
            st = zero_skip_stats(np.asarray(pruned[f"l{i}"]["w"]))
            t += 1.0 / st.element_speedup
        tp.append(t)
        dp_.append(d)
    best, curve = optimal_sparsity(sparsities, tp[0], dp_[0], tp, dp_)
    assert np.isfinite(curve).all()
    assert (np.diff(tp) <= 1e-9).all()          # latency model monotone down
    assert dp_[-1] >= dp_[0]                    # quality degrades


def test_lm_checkpoint_exact_resume(tmp_path):
    """Train 8 steps with ckpt every 3; crash-free rerun from scratch and a
    resumed run must produce identical final params (deterministic data)."""
    from repro.configs import reduced_config
    from repro.models.transformer import init_lm
    from repro.train.lm import make_train_step
    from repro.train.loop import TrainDriver

    cfg = reduced_config("minitron-4b")
    src = lm_source(seed=0, batch=2, seq_len=12, vocab=cfg.vocab_size)
    opt = AdamW(lr=1e-3)
    inner = jax.jit(make_train_step(cfg, opt))

    def step_fn(state, batch):
        p, o = state
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, _, met = inner(p, o, None, b)
        return (p, o), met

    def fresh():
        p, _ = init_lm(jax.random.PRNGKey(0), cfg)
        return (p, opt.init(p))

    d1 = TrainDriver(step_fn, src, ckpt_dir=str(tmp_path / "run"), ckpt_every=3)
    s1 = d1.run(fresh(), 5)
    # "restart": new driver restores from the run dir and continues to 8
    d2 = TrainDriver(step_fn, src, ckpt_dir=str(tmp_path / "run"), ckpt_every=3)
    s2 = d2.run(fresh(), 8)
    # straight-through oracle
    d3 = TrainDriver(step_fn, src, ckpt_dir=None)
    s3 = d3.run(fresh(), 8)
    for a, b in zip(jax.tree_util.tree_leaves(s2[0]),
                    jax.tree_util.tree_leaves(s3[0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
