"""Bench-artifact schema pass: a well-formed BENCH_deconv.json is
clean; dropped sections, renamed row keys, and NaN leaks each fire
their rule."""
import json
import math

import pytest

from repro.analysis.check import check_bench_doc, check_bench_json
from repro.analysis.check.bench_schema import ROW_KEYS, SECTIONS


def _doc():
    doc = {name: typ() for name, typ in SECTIONS.items()}
    doc["table2"] = [{
        "net": "dcnn-mnist", "precision": "fp32", "bucket": 4, "calls": 5,
        "mean_s": 0.01, "std_s": 0.001, "cv": 0.1, "tainted_calls": 0}]
    doc["traffic"] = [{
        "net": "dcnn-mnist", "layer": "L1", "in_bytes_per_tile": 4096,
        "halo_total_bytes": 65536, "full_image_total_bytes": 262144,
        "traffic_reduction": 4.0}]
    doc["autotune"] = [{
        "net": "dcnn-mnist", "layer": "L1", "fixed_tiles": {"t_oh": 32},
        "tuned_tiles": {"t_oh": 16}, "fixed_us": 10.0, "tuned_us": 8.0}]
    doc["scaling"] = [{
        "in_hw": 16, "out_hw": 32, "halo_in_bytes_per_tile": 4096,
        "full_in_bytes_per_tile": 16384, "n_tiles": 4}]
    doc["workloads"] = [{
        "workload": "sr", "net": "sr-espcn-x2", "precision": "fp32",
        "bucket": 4, "calls": 3, "mean_s": 0.01, "cv": 0.1}]
    return doc


def _fired(report):
    return sorted({v.rule_id for v in report.failures(strict=True)})


def test_wellformed_doc_is_clean():
    report = check_bench_doc(_doc())
    assert report.ok(strict=True), report.render(strict=True)


def test_empty_table2_fires_rows_rule():
    # pre-obs behavior (smoke mode skipping the timing sweep entirely) is
    # exactly the regression bench.table2_rows exists to catch
    doc = _doc()
    doc["table2"] = []
    assert _fired(check_bench_doc(doc)) == ["bench.table2_rows"]


def test_legacy_sweep_table2_row_is_clean():
    doc = _doc()
    doc["table2"] = [{
        "net": "dcnn-mnist", "layer": "L1", "rl_gops": 1.0, "rl_cv": 0.1,
        "zi_gops": 0.5, "zi_cv": 0.2, "useful_mac_ratio_zi": 0.25,
        "rl_us": 10.0, "zi_us": 20.0}]
    assert check_bench_doc(doc).ok(strict=True)


def test_table2_row_matching_neither_schema_fires():
    doc = _doc()
    doc["table2"] = [{"net": "dcnn-mnist", "mean_s": 0.01}]
    assert _fired(check_bench_doc(doc)) == ["bench.table2_rows"]


def test_table2_cv_over_ceiling_fires():
    doc = _doc()
    doc["table2"][0]["cv"] = 2.5
    report = check_bench_doc(doc)
    assert _fired(report) == ["bench.table2_cv"]
    v, = report.errors()
    assert v.location == "table2[0]"


def test_empty_workloads_fires_rows_rule():
    # dropping the zoo from the smoke run is the regression
    # bench.workloads_rows exists to catch
    doc = _doc()
    doc["workloads"] = []
    assert _fired(check_bench_doc(doc)) == ["bench.workloads_rows"]


def test_workloads_row_missing_key_fires():
    doc = _doc()
    del doc["workloads"][0]["workload"]
    report = check_bench_doc(doc)
    assert _fired(report) == ["bench.workloads_rows"]
    v, = report.errors()
    assert "workload" in v.message and v.location == "workloads[0]"


def test_missing_section_fires_sections():
    doc = _doc()
    del doc["serving"]
    report = check_bench_doc(doc)
    assert _fired(report) == ["bench.sections"]


def test_wrong_section_shape_fires_sections():
    doc = _doc()
    doc["traffic"] = {"not": "a list"}
    assert "bench.sections" in _fired(check_bench_doc(doc))


def test_unknown_section_warns_only():
    doc = _doc()
    doc["mystery"] = []
    report = check_bench_doc(doc)
    assert report.ok(strict=False)
    assert _fired(report) == ["bench.sections"]


def test_missing_row_key_fires_keys():
    doc = _doc()
    del doc["traffic"][0]["halo_total_bytes"]
    report = check_bench_doc(doc)
    assert _fired(report) == ["bench.keys"]
    v, = report.errors()
    assert "halo_total_bytes" in v.message and v.location == "traffic[0]"


@pytest.mark.parametrize("bad", [float("nan"), float("inf"),
                                 -float("inf")])
def test_nonfinite_value_fires_nan(bad):
    doc = _doc()
    doc["serving"]["p99_ms"] = bad
    report = check_bench_doc(doc)
    assert _fired(report) == ["bench.nan"]
    v, = report.errors()
    assert v.location == "$.serving.p99_ms"


def test_nan_found_in_nested_rows():
    doc = _doc()
    doc["traffic"][0]["traffic_reduction"] = float("nan")
    assert _fired(check_bench_doc(doc)) == ["bench.nan"]


def test_check_bench_json_roundtrip(tmp_path):
    path = tmp_path / "BENCH_deconv.json"
    path.write_text(json.dumps(_doc()))
    assert check_bench_json(str(path)).ok(strict=True)
    # json.dump writes bare NaN tokens; json.load parses them to nan —
    # the scan must catch what actually lands on disk
    doc = _doc()
    doc["degraded"]["gops"] = math.nan
    path.write_text(json.dumps(doc))
    report = check_bench_json(str(path))
    assert _fired(report) == ["bench.nan"]


def test_unreadable_artifact_reports_not_raises(tmp_path):
    report = check_bench_json(str(tmp_path / "missing.json"))
    assert _fired(report) == ["bench.sections"]
    bad = tmp_path / "broken.json"
    bad.write_text("{nope")
    assert _fired(check_bench_json(str(bad))) == ["bench.sections"]


def test_row_keys_match_bench_writer():
    # ROW_KEYS must stay a subset of what bench_deconv actually writes —
    # validated end-to-end by the smoke gate; here we at least pin the
    # contract the smoke artifact was checked against
    assert set(ROW_KEYS) <= set(SECTIONS)
    for keys in ROW_KEYS.values():
        assert len(keys) == len(set(keys))
