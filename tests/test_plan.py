"""Plan/execute API: DeconvPlan/NetworkPlan round-tripping, the v4
plan-hash autotune cache, plan-path vs legacy-path bit-identity on all
four execution paths (dense fp32, sparse, int8, fused-chain), and the
EngineConfig-driven serve engine."""
import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tiling import DeconvGeometry
from repro.models.dcnn import (DcnnConfig, DeconvLayerCfg, generator_apply,
                               generator_init, make_fused_generator)
from repro.plan import (PLAN_SCHEMA_VERSION, DeconvPlan, NetworkPlan,
                        PlanSchemaError, build_layer_plan,
                        build_network_plan)
from repro.serve import DcnnServeEngine, EngineConfig

# the real MNIST / CelebA layer cascades with channel counts cut down so
# interpret-mode execution stays cheap (matches test_batch_serving.py)
MNIST_SMALL = DcnnConfig(
    name="dcnn-mnist-small",
    z_dim=24, img_hw=28, img_c=1,
    layers=(
        DeconvLayerCfg(24, 32, 7, 1, 0, "relu"),
        DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),
        DeconvLayerCfg(16, 1, 4, 2, 1, "tanh"),
    ),
)

CELEBA_SMALL = DcnnConfig(
    name="dcnn-celeba-small",
    z_dim=24, img_hw=64, img_c=3,
    layers=(
        DeconvLayerCfg(24, 32, 4, 1, 0, "relu"),
        DeconvLayerCfg(32, 16, 4, 2, 1, "relu"),
        DeconvLayerCfg(16, 16, 4, 2, 1, "relu"),
        DeconvLayerCfg(16, 8, 4, 2, 1, "relu"),
        DeconvLayerCfg(8, 3, 4, 2, 1, "tanh"),
    ),
)

# the Algorithm-1 OH=7/S=2/K=5 parity geometry (CelebA layer type whose
# phase structure exercises every tap path) + a non-square variant
ALGO1_GEOMS = [
    DeconvGeometry(4, 4, 6, 5, 5, 2, 2),
    DeconvGeometry(4, 6, 3, 4, 5, 2, 2),
]


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setattr(autotune, "_cache", None)
    yield tmp_path / "at.json"
    monkeypatch.setattr(autotune, "_cache", None)


def _prune(params, frac=0.6, seed=0):
    """Magnitude-prune the weight tree so sparse plans have zero blocks."""
    rng = np.random.RandomState(seed)
    out = {}
    for k, leaf in params.items():
        w = np.asarray(leaf["w"])
        mask = rng.rand(*w.shape[2:]) < frac  # prune whole (ci, co) fibers
        out[k] = {"w": jnp.asarray(np.where(mask, 0.0, w)), "b": leaf["b"]}
    return out


# ---------------------------------------------------------------------------
# DeconvPlan basics
# ---------------------------------------------------------------------------
def test_layer_plan_is_frozen_and_hashable(tmp_cache):
    g = ALGO1_GEOMS[0]
    p1 = build_layer_plan(g, batch=4, activation="relu")
    p2 = build_layer_plan(g, batch=4, activation="relu")
    assert p1 == p2 and hash(p1) == hash(p2)
    assert p1.stable_hash() == p2.stable_hash()
    with pytest.raises(dataclasses.FrozenInstanceError):
        p1.batch = 8
    # tiles resolved (the plan is executable as-is)
    assert p1.tiles is not None and p1.tiles.t_oh % g.stride == 0


def test_layer_plan_padded_geometry(tmp_cache):
    """The plan exposes the halo_pad_geometry the kernel runs at: output
    extents, tile-multiple grid, halo padding, padded channels/batch."""
    g = ALGO1_GEOMS[0]
    p = build_layer_plan(g, batch=3)
    (oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop, t_n,
     np_) = p.padded_geometry()
    assert (oh, ow) == (g.out_h, g.out_w)
    assert ohp % p.tiles.t_oh == 0 and owp % p.tiles.t_ow == 0
    assert cip % p.tiles.t_ci == 0 and cop % p.tiles.t_co == 0
    assert t_n <= 3 and np_ % t_n == 0 and np_ >= 3
    assert pad_l >= 0 and pad_rh >= 0 and pad_rw >= 0


def test_stable_hash_scopes_and_aliasing(tmp_cache):
    """Tile-scope hashes split on every tile-planning input and nothing
    else; full-scope hashes additionally pin the epilogue + tiles."""
    g = ALGO1_GEOMS[0]
    base = DeconvPlan(geometry=g, batch=4, dtype="float32")
    assert base.stable_hash("tiles") == dataclasses.replace(
        base, activation="relu").stable_hash("tiles")
    assert base.stable_hash() != dataclasses.replace(
        base, activation="relu").stable_hash()
    for other in (dataclasses.replace(base, dtype="int8"),
                  dataclasses.replace(base, batch=8),
                  dataclasses.replace(base, backend="pallas_sparse"),
                  dataclasses.replace(base, out_dtype_bytes=4)):
        assert base.stable_hash("tiles") != other.stable_hash("tiles")


# ---------------------------------------------------------------------------
# satellite: plan round-tripping
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [MNIST_SMALL, CELEBA_SMALL],
                         ids=lambda c: c.name)
def test_network_plan_roundtrip_fp32(cfg, tmp_cache):
    plan = build_network_plan(cfg, batch=4, backend="pallas")
    back = NetworkPlan.from_json(plan.to_json())
    assert back == plan
    assert back.stable_hash() == plan.stable_hash()
    assert back.tile_overrides() == plan.tile_overrides()


def test_network_plan_roundtrip_int8(tmp_cache):
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    plan = build_network_plan(MNIST_SMALL, batch=4, precision="int8",
                              params=params, calib_batch=8)
    back = NetworkPlan.from_json(plan.to_json())
    assert back == plan and back.stable_hash() == plan.stable_hash()
    # the calibrated scales survive exactly (the requant chain is pinned)
    assert back.quant_config() == plan.quant_config()
    assert [l.out_scale for l in back.layers] == \
        [l.out_scale for l in plan.layers]


def test_network_plan_roundtrip_sparse(tmp_cache):
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    pruned = _prune(params)
    plan = build_network_plan(MNIST_SMALL, batch=2,
                              backend="pallas_sparse", params=pruned)
    assert plan.sparse_plans() is not None
    back = NetworkPlan.from_json(plan.to_json())
    assert back == plan and back.stable_hash() == plan.stable_hash()
    # the zero-skip tables round-trip bit-exactly
    for i, tabs in plan.sparse_plans().items():
        for a, b in zip(tabs, back.sparse_plans()[i]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stale_schema_json_rejected(tmp_cache):
    plan = build_network_plan(MNIST_SMALL, batch=2)
    doc = json.loads(plan.to_json())
    doc["schema"] = PLAN_SCHEMA_VERSION + 1
    with pytest.raises(PlanSchemaError, match="schema"):
        NetworkPlan.from_json(json.dumps(doc))
    with pytest.raises(PlanSchemaError, match="kind"):
        NetworkPlan.from_json("{}")
    with pytest.raises(PlanSchemaError):
        NetworkPlan.from_json("not json at all")
    # a tampered document (edited after pinning) is rejected too
    doc = json.loads(plan.to_json())
    doc["layers"][0]["tiles"]["t_oh"] *= 2
    with pytest.raises(PlanSchemaError, match="hash"):
        NetworkPlan.from_json(json.dumps(doc))


def test_plan_for_wrong_network_rejected(tmp_cache):
    plan = build_network_plan(MNIST_SMALL, batch=2)
    with pytest.raises(ValueError, match="layers"):
        plan.validate_for(CELEBA_SMALL)
    params, _ = generator_init(jax.random.PRNGKey(0), CELEBA_SMALL)
    with pytest.raises(ValueError):
        generator_apply(params, CELEBA_SMALL,
                        jnp.zeros((2, CELEBA_SMALL.z_dim)), plan=plan)


# ---------------------------------------------------------------------------
# all four execution paths: plan path vs pre-refactor wrappers,
# bit-identical on the Algorithm-1 S=2/K=5 parity geometries
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("geom", ALGO1_GEOMS, ids=str)
def test_dense_plan_path_bit_identical(geom, tmp_cache, rng):
    from repro.kernels.deconv2d import deconv2d

    x = jnp.asarray(rng.randn(3, geom.in_h, geom.in_w, geom.c_in),
                    jnp.float32)
    w = jnp.asarray(rng.randn(geom.kernel, geom.kernel, geom.c_in,
                              geom.c_out) * 0.1, jnp.float32)
    b = jnp.asarray(rng.randn(geom.c_out), jnp.float32)
    plan = build_layer_plan(geom, batch=3, activation="relu")
    y_plan = np.asarray(deconv2d(x, w, b, plan=plan))
    t = plan.tiles
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        y_leg = np.asarray(deconv2d(x, w, b, geom.stride, geom.padding,
                                    activation="relu", **t.as_kwargs()))
    np.testing.assert_array_equal(y_plan, y_leg)


@pytest.mark.parametrize("geom", ALGO1_GEOMS, ids=str)
def test_sparse_plan_path_bit_identical(geom, tmp_cache, rng):
    from repro.kernels.deconv2d_sparse import (deconv2d_sparse,
                                               make_sparse_plan)

    x = jnp.asarray(rng.randn(2, geom.in_h, geom.in_w, geom.c_in),
                    jnp.float32)
    w = np.asarray(rng.randn(geom.kernel, geom.kernel, geom.c_in,
                             geom.c_out) * 0.1, np.float32)
    w[:, :, :, :: 2] = 0.0  # prune alternating C_out fibers
    w = jnp.asarray(w)
    plan = build_layer_plan(geom, batch=2, backend="pallas_sparse",
                            activation="relu", weights=np.asarray(w))
    assert plan.sparse_tables is not None and plan.sparse_digest
    y_plan = np.asarray(deconv2d_sparse(x, w, None, plan=plan))
    t = plan.tiles
    legacy_tables = make_sparse_plan(np.asarray(w), geom.stride,
                                     geom.padding, t.t_ci, t.t_co)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        y_leg = np.asarray(deconv2d_sparse(
            x, w, None, geom.stride, geom.padding, activation="relu",
            plan=legacy_tables, **t.as_kwargs()))
    np.testing.assert_array_equal(y_plan, y_leg)


def test_int8_plan_path_bit_identical(tmp_cache, rng):
    from repro.quant.infer import quantized_generator_apply
    from repro.quant.calibrate import calibrate, quantize_params

    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    z = jnp.asarray(rng.randn(4, MNIST_SMALL.z_dim), jnp.float32)
    qcfg = calibrate(params, MNIST_SMALL, z)
    qp = quantize_params(params, MNIST_SMALL, qcfg)
    plan = build_network_plan(MNIST_SMALL, batch=4, precision="int8",
                              quant_cfg=qcfg)
    y_plan = np.asarray(quantized_generator_apply(qp, MNIST_SMALL, None, z,
                                                  plan=plan))
    y_leg = np.asarray(quantized_generator_apply(
        qp, MNIST_SMALL, qcfg, z, tile_overrides=plan.tile_overrides()))
    np.testing.assert_array_equal(y_plan, y_leg)


def test_fused_chain_plan_path_bit_identical(tmp_cache, rng):
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    z = jnp.asarray(rng.randn(4, MNIST_SMALL.z_dim), jnp.float32)
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")
    gen_plan = make_fused_generator(MNIST_SMALL, plan=plan)
    gen_leg = make_fused_generator(MNIST_SMALL,
                                   tiles=plan.tile_overrides())
    np.testing.assert_array_equal(np.asarray(gen_plan(params, z)),
                                  np.asarray(gen_leg(params, z)))
    # and the fused chain stays differentiable through the plan path
    g = jax.grad(lambda p: jnp.sum(gen_plan(p, z)))(params)
    assert np.isfinite(np.asarray(g["l0"]["w"])).all()


# ---------------------------------------------------------------------------
# satellite: deprecation shims route old calls through the plan path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [MNIST_SMALL, CELEBA_SMALL],
                         ids=lambda c: c.name)
def test_engine_old_kwargs_equal_new_config(cfg, tmp_cache, rng):
    """Regression: the deprecated kwarg constructor and the EngineConfig
    path serve bit-identical images on both network configs."""
    params, _ = generator_init(jax.random.PRNGKey(0), cfg)
    z = rng.randn(5, cfg.z_dim).astype(np.float32)
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        warnings.simplefilter("always")
        old = DcnnServeEngine(cfg, params, backend="pallas",
                              buckets=(1, 2, 4))
    new = DcnnServeEngine.from_config(
        EngineConfig(model=cfg, backend="pallas", buckets=(1, 2, 4)),
        params)
    np.testing.assert_array_equal(old.generate(z), new.generate(z))
    assert old.trace_counts == new.trace_counts


def test_tile_kwargs_deprecation_warning(tmp_cache, rng):
    from repro.kernels.deconv2d import ops
    from repro.kernels.deconv2d import deconv2d

    x = jnp.asarray(rng.randn(1, 4, 4, 8), jnp.float32)
    w = jnp.asarray(rng.randn(4, 4, 8, 8) * 0.1, jnp.float32)
    ops._warned_tile_kwargs.discard("deconv2d")
    with pytest.warns(DeprecationWarning, match="DeconvPlan"):
        warnings.simplefilter("always")
        deconv2d(x, w, None, 2, 1, t_oh=2, t_ow=2)
    # plain geometry-only calls (auto-resolved tiles) stay warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        deconv2d(x, w, None, 2, 1)


def test_plan_geometry_mismatch_rejected(tmp_cache, rng):
    from repro.kernels.deconv2d import deconv2d

    plan = build_layer_plan(ALGO1_GEOMS[0], batch=2)
    x = jnp.zeros((2, 9, 9, ALGO1_GEOMS[0].c_in), jnp.float32)
    w = jnp.zeros((5, 5, ALGO1_GEOMS[0].c_in, ALGO1_GEOMS[0].c_out),
                  jnp.float32)
    with pytest.raises(ValueError, match="geometry"):
        deconv2d(x, w, None, plan=plan)


# ---------------------------------------------------------------------------
# EngineConfig-driven serving: both generators x both precisions through
# the bucket machinery with unchanged per-bucket compile counts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg,precision", [
    (MNIST_SMALL, "fp32"), (MNIST_SMALL, "int8"),
    (CELEBA_SMALL, "fp32"), (CELEBA_SMALL, "int8"),
], ids=lambda v: getattr(v, "name", v))
def test_from_config_serves_both_precisions(cfg, precision, tmp_cache, rng):
    params, _ = generator_init(jax.random.PRNGKey(0), cfg)
    eng = DcnnServeEngine.from_config(
        EngineConfig(model=cfg, precision=precision, buckets=(1, 2, 4),
                     calib_batch=8),
        params)
    for n in (3, 4, 1):
        imgs = eng.generate(rng.randn(n, cfg.z_dim).astype(np.float32))
        assert imgs.shape == (n, cfg.img_hw, cfg.img_hw, cfg.img_c)
        assert np.isfinite(imgs).all()
    # compile-once per touched bucket, plan-once per touched bucket
    assert all(v == 1 for v in eng.trace_counts.values())
    assert eng.plan_stats["builds"] == len(eng.trace_counts)
    for b in eng.trace_counts:
        assert eng.plans[b].precision == precision
        assert eng.plans[b].batch == eng.shard_batch(b)


def test_from_config_pinned_plan_no_rebuild(tmp_cache, rng):
    """A deserialized plan is served verbatim: no plan build, no
    recalibration, same images as the self-planning engine."""
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    plan = build_network_plan(MNIST_SMALL, batch=4, precision="int8",
                              params=params, calib_batch=8)
    pinned = NetworkPlan.from_json(plan.to_json())
    cfgE = EngineConfig(model=MNIST_SMALL, precision="int8", buckets=(4,),
                        calib_batch=8)
    eng = DcnnServeEngine.from_config(cfgE, params, plan=pinned)
    auto = DcnnServeEngine.from_config(cfgE, params)
    z = rng.randn(4, MNIST_SMALL.z_dim).astype(np.float32)
    np.testing.assert_array_equal(eng.generate(z), auto.generate(z))
    assert eng.plan_stats["builds"] == 0
    assert auto.plan_stats["builds"] == 1
    # pinned calibration == self-calibration (same seed/batch/strategy)
    assert eng.quant_cfg == auto.quant_cfg


def test_from_config_plan_mismatch_rejected(tmp_cache):
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    plan = build_network_plan(MNIST_SMALL, batch=4, backend="pallas")
    with pytest.raises(ValueError, match="precision"):
        DcnnServeEngine.from_config(
            EngineConfig(model=MNIST_SMALL, precision="int8",
                         buckets=(4,)), params, plan=plan)
    with pytest.raises(ValueError, match="bucket"):
        DcnnServeEngine.from_config(
            EngineConfig(model=MNIST_SMALL, buckets=(8, 16)), params,
            plan=plan)


def test_sparse_engine_via_config_shares_tables(tmp_cache, rng):
    """pallas_sparse through from_config: zero-skip schedules come from
    the per-bucket plans, memoized across buckets sharing channel tiles
    (the table cache never rebuilds per bucket needlessly)."""
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    pruned = _prune(params)
    eng = DcnnServeEngine.from_config(
        EngineConfig(model=MNIST_SMALL, backend="pallas_sparse",
                     buckets=(1, 2)), pruned)
    z = rng.randn(3, MNIST_SMALL.z_dim).astype(np.float32)
    imgs = eng.generate(z)
    ref = np.asarray(generator_apply(pruned, MNIST_SMALL, jnp.asarray(z),
                                     backend="reverse_loop"))
    np.testing.assert_allclose(imgs, ref, rtol=1e-4, atol=1e-4)
    n_layers = len(MNIST_SMALL.layers)
    # both buckets planned; the memo holds at most one entry per distinct
    # (layer, t_ci, t_co) — not one per (bucket, layer)
    assert eng.plan_stats["builds"] == 2
    assert len(eng._sparse_plan_memo) <= 2 * n_layers
    shared = [k for k in eng._sparse_plan_memo]
    assert len(set(shared)) == len(shared)


def test_stale_sparse_plan_rejected_at_engine_load(tmp_cache, rng):
    """Review regression: a pinned pallas_sparse plan whose zero-skip
    schedule no longer matches the served weights (checkpoint re-pruned
    after pinning) must fail loudly at engine construction, not silently
    skip now-nonzero blocks."""
    def tap_prune(params, taps):
        """Zero whole kernel taps of layer 1 (block-level sparsity the
        schedule actually encodes)."""
        out = {k: dict(v) for k, v in params.items()}
        w = np.asarray(out["l1"]["w"]).copy()
        w[list(taps)] = 0.0
        out["l1"]["w"] = jnp.asarray(w)
        return out

    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    pruned_a = tap_prune(params, (0, 1))
    pruned_b = tap_prune(params, (2, 3))   # different sparsity pattern
    plan = build_network_plan(MNIST_SMALL, batch=2,
                              backend="pallas_sparse", params=pruned_a)
    cfgE = EngineConfig(model=MNIST_SMALL, backend="pallas_sparse",
                        buckets=(2,))
    # matching weights load fine...
    DcnnServeEngine.from_config(cfgE, pruned_a, plan=plan)
    # ...re-pruned weights are rejected
    with pytest.raises(ValueError, match="stale"):
        DcnnServeEngine.from_config(cfgE, pruned_b, plan=plan)


def test_conflicting_calibrations_rejected(tmp_cache):
    """Review regression: quant_cfg in the EngineConfig AND a pinned int8
    plan with a different calibration would quantize params with one
    scale set and requant with another — rejected up front."""
    from repro.quant.calibrate import calibrate

    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    plan = build_network_plan(MNIST_SMALL, batch=4, precision="int8",
                              params=params, calib_batch=8)
    other = calibrate(params, MNIST_SMALL,
                      jax.random.normal(jax.random.PRNGKey(9),
                                        (8, MNIST_SMALL.z_dim)),
                      strategy="minmax")
    with pytest.raises(ValueError, match="calibrations"):
        DcnnServeEngine.from_config(
            EngineConfig(model=MNIST_SMALL, precision="int8",
                         quant_cfg=other, buckets=(4,)),
            params, plan=plan)
    # the same calibration object is accepted
    eng = DcnnServeEngine.from_config(
        EngineConfig(model=MNIST_SMALL, precision="int8",
                     quant_cfg=plan.quant_config(), buckets=(4,)),
        params, plan=plan)
    assert eng.quant_cfg == plan.quant_config()


def test_sparse_network_plan_requires_params(tmp_cache):
    """Review regression: a weightless sparse plan would re-derive the
    zero-skip schedule per call (and crash under jit) — refused."""
    with pytest.raises(ValueError, match="pruned weights"):
        build_network_plan(MNIST_SMALL, batch=2, backend="pallas_sparse")


def test_tile_overrides_surface_does_not_warn(tmp_cache, rng):
    """Review regression: the supported legacy override surface
    (generator_apply(tile_overrides=...), the WganTrainer path) expands
    tile kwargs internally and must not nag the user."""
    from repro.kernels.autotune import choose_tiles

    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    z = jnp.asarray(rng.randn(2, MNIST_SMALL.z_dim), jnp.float32)
    tiles = {i: choose_tiles(g, jnp.float32, backend="pallas", batch=2)
             for i, g in enumerate(MNIST_SMALL.geometries())}
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        generator_apply(params, MNIST_SMALL, z, backend="pallas",
                        tile_overrides=tiles)
        make_fused_generator(MNIST_SMALL, tiles=tiles)(params, z)


def test_plan_roofline_estimates(tmp_cache):
    """NetworkPlan owns the traffic/roofline numbers the benches report:
    int8 plans model faster-than-fp32 network throughput at batch 64."""
    p32 = build_network_plan(MNIST_SMALL, batch=64, backend="pallas")
    params, _ = generator_init(jax.random.PRNGKey(0), MNIST_SMALL)
    p8 = build_network_plan(MNIST_SMALL, batch=64, precision="int8",
                            params=params, calib_batch=8)
    t32 = p32.traffic_report()
    t8 = p8.traffic_report()
    assert set(t32) == set(t8) == set(range(len(MNIST_SMALL.layers)))
    # int8 streams fewer bytes on every intermediate layer
    for i in range(len(MNIST_SMALL.layers) - 1):
        assert t8[i].total_bytes < t32[i].total_bytes
    a32 = p32.modeled_network_ops()
    a8 = p8.modeled_network_ops()
    assert a8 > a32 > 0
