"""Block-sparse zero-skipping kernel vs dense oracle on pruned weights."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparsity import block_mask, magnitude_prune, zero_skip_stats
from repro.kernels.deconv2d import deconv2d_ref
from repro.kernels.deconv2d_sparse import deconv2d_sparse
from repro.kernels.deconv2d_sparse.kernel import build_schedule


@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9, 0.97])
def test_sparse_kernel_matches_oracle(sparsity, rng):
    x = jnp.array(rng.randn(2, 7, 7, 16), jnp.float32)
    w = jnp.array(rng.randn(4, 4, 16, 16), jnp.float32)
    b = jnp.array(rng.randn(16), jnp.float32)
    wp, _ = magnitude_prune(w, sparsity)
    y = deconv2d_sparse(x, wp, b, 2, 1, t_ci=8, t_co=8)
    y_ref = deconv2d_ref(x, wp, b, 2, 1)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


def test_schedule_compression(rng):
    """Structured sparsity (whole CI slabs zero) shrinks the schedule — the
    DMA-level zero-skip of the TPU adaptation."""
    w = rng.randn(4, 4, 32, 16).astype(np.float32)
    w[:, :, 8:, :] = 0.0  # channels 8.. entirely zero
    mask = block_mask(w, 8, 16)
    ci_idx, valid, taps, max_len = build_schedule(mask)
    assert max_len == 1            # only 1 of 4 CI slabs survives
    assert valid.sum() == 1
    s = zero_skip_stats(w, block_ci=8, block_co=16)
    assert s.block_macs == s.total_macs // 4
    assert s.block_speedup == pytest.approx(4.0)


def test_element_vs_block_speedup(rng):
    """Unstructured pruning: element skip (FPGA) >= block skip (TPU)."""
    w = jnp.array(rng.randn(4, 4, 32, 32), jnp.float32)
    wp, _ = magnitude_prune(w, 0.8)
    s = zero_skip_stats(np.asarray(wp), block_ci=8, block_co=8)
    assert s.element_speedup == pytest.approx(5.0, rel=0.05)
    assert 1.0 <= s.block_speedup <= s.element_speedup
