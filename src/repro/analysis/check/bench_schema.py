"""Benchmark-artifact validation: `BENCH_deconv.json` schema + NaN scan.

`benchmarks/run.py --smoke` regenerates `BENCH_deconv.json` on every CI
run; this pass (same rule-engine plumbing as the plan DRC) makes the
smoke gate fail loudly when a refactor drops a section, renames a row
key, or lets a divide-by-zero leak a NaN into the artifact — all of
which previously surfaced only when a human read the report."""
from __future__ import annotations

import json
import math
import os
from typing import Any, List

from .rules import CheckReport, Severity, rule

#: {section: container type}.  ``table2`` may legitimately be an empty
#: list (smoke mode skips the paper-table timing sweep).
SECTIONS = {
    "table2": list, "traffic": list, "autotune": list, "scaling": list,
    "batch_sweep": list, "serving": dict, "sharded": dict, "quant": list,
    "plan": list, "degraded": dict, "slo": dict,
}

#: per-row required keys for the sections the smoke run always fills
ROW_KEYS = {
    "traffic": ("net", "layer", "in_bytes_per_tile", "halo_total_bytes",
                "full_image_total_bytes", "traffic_reduction"),
    "autotune": ("net", "layer", "fixed_tiles", "tuned_tiles",
                 "fixed_us", "tuned_us"),
    "scaling": ("in_hw", "out_hw", "halo_in_bytes_per_tile",
                "full_in_bytes_per_tile", "n_tiles"),
}


@rule("bench.sections",
      "BENCH_deconv.json is missing a section or has the wrong shape")
def check_sections(r, doc):
    out = []
    if not isinstance(doc, dict):
        return [r.violation(
            f"top level must be an object, got {type(doc).__name__}",
            fix_hint="regenerate with benchmarks/run.py")]
    for name, typ in SECTIONS.items():
        if name not in doc:
            out.append(r.violation(
                f"section {name!r} missing",
                location=name,
                fix_hint="regenerate with benchmarks/run.py (write_json "
                         "emits every section, empty or not)"))
        elif not isinstance(doc[name], typ):
            out.append(r.violation(
                f"section {name!r} should be a {typ.__name__}, got "
                f"{type(doc[name]).__name__}", location=name))
    for name in doc:
        if name not in SECTIONS:
            out.append(r.violation(
                f"unknown section {name!r}", location=name,
                severity=Severity.WARNING,
                fix_hint="add it to SECTIONS in bench_schema.py if it is "
                         "a new deliberate artifact"))
    return out


@rule("bench.keys", "a benchmark row is missing a required key")
def check_row_keys(r, doc):
    out = []
    if not isinstance(doc, dict):
        return out
    for section, keys in ROW_KEYS.items():
        rows = doc.get(section)
        if not isinstance(rows, list):
            continue
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                out.append(r.violation(
                    f"row {i} is not an object",
                    location=f"{section}[{i}]"))
                continue
            missing = [k for k in keys if k not in row]
            if missing:
                out.append(r.violation(
                    f"row {i} missing key(s) {', '.join(missing)}",
                    location=f"{section}[{i}]",
                    fix_hint="a rename in bench_deconv.py must update "
                             "ROW_KEYS (and the README tables) with it"))
    return out


@rule("bench.nan", "a benchmark value is NaN or infinite")
def check_finite(r, doc):
    out = []

    def scan(node: Any, path: str) -> None:
        if isinstance(node, float) and not math.isfinite(node):
            out.append(r.violation(
                f"non-finite value {node!r}", location=path,
                fix_hint="guard the producing division (bench rows use "
                         "max(denom, eps)) or drop the row"))
        elif isinstance(node, dict):
            for k, v in node.items():
                scan(v, f"{path}.{k}")
        elif isinstance(node, list):
            for i, v in enumerate(node):
                scan(v, f"{path}[{i}]")

    scan(doc, "$")
    return out


BENCH_RULES = ("bench.sections", "bench.keys", "bench.nan")


def check_bench_doc(doc, name: str = "BENCH_deconv.json") -> CheckReport:
    report = CheckReport(f"bench-schema:{name}")
    report.rules_run += list(BENCH_RULES)
    report.extend(check_sections(doc))
    report.extend(check_row_keys(doc))
    report.extend(check_finite(doc))
    return report


def check_bench_json(path: str) -> CheckReport:
    """Validate a benchmark artifact on disk.  Unreadable/unparsable
    files report through ``bench.sections`` rather than raising — the
    smoke gate wants a report either way."""
    name = os.path.basename(path)
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        report = CheckReport(f"bench-schema:{name}")
        report.rules_run += list(BENCH_RULES)
        report.extend([check_sections.rule.violation(
            f"cannot load {path}: {e}",
            fix_hint="regenerate with benchmarks/run.py --smoke")])
        return report
    return check_bench_doc(doc, name)
