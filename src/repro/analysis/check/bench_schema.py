"""Benchmark-artifact validation: `BENCH_deconv.json` schema + NaN scan.

`benchmarks/run.py --smoke` regenerates `BENCH_deconv.json` on every CI
run; this pass (same rule-engine plumbing as the plan DRC) makes the
smoke gate fail loudly when a refactor drops a section, renames a row
key, or lets a divide-by-zero leak a NaN into the artifact — all of
which previously surfaced only when a human read the report."""
from __future__ import annotations

import json
import math
import os
from typing import Any, List

from .rules import CheckReport, Severity, rule

#: {section: container type}.  Since the obs layer landed, ``table2`` is
#: populated in every mode (smoke emits interpret-mode rows via
#: `repro.obs.report`); `bench.table2_rows` rejects an empty section.
SECTIONS = {
    "table2": list, "traffic": list, "autotune": list, "scaling": list,
    "batch_sweep": list, "serving": dict, "sharded": dict, "quant": list,
    "plan": list, "degraded": dict, "slo": dict, "workloads": list,
}

#: obs-produced Table II rows (`repro.obs.report.table2_rows`) carry the
#: run-to-run statistics; legacy full-sweep rows carry the GOPS columns.
#: Either shape is a valid table2 row — `bench.table2_rows` requires one
#: of the two key sets to be complete.
TABLE2_STAT_KEYS = ("net", "precision", "bucket", "calls", "mean_s",
                    "std_s", "cv", "tainted_calls")
TABLE2_LEGACY_KEYS = ("net", "layer", "rl_gops", "rl_cv", "zi_gops",
                      "zi_cv")
#: generous healthy-run CV ceiling: interpret-mode CPU timing jitters,
#: but a healthy dispatch population whose std exceeds 1.5x its mean
#: means the "healthy" tagging broke (compiles or retries leaked in)
TABLE2_CV_MAX = 1.5

#: per-row required keys for the sections the smoke run always fills
ROW_KEYS = {
    "traffic": ("net", "layer", "in_bytes_per_tile", "halo_total_bytes",
                "full_image_total_bytes", "traffic_reduction"),
    "autotune": ("net", "layer", "fixed_tiles", "tuned_tiles",
                 "fixed_us", "tuned_us"),
    "scaling": ("in_hw", "out_hw", "halo_in_bytes_per_tile",
                "full_in_bytes_per_tile", "n_tiles"),
}


@rule("bench.sections",
      "BENCH_deconv.json is missing a section or has the wrong shape")
def check_sections(r, doc):
    out = []
    if not isinstance(doc, dict):
        return [r.violation(
            f"top level must be an object, got {type(doc).__name__}",
            fix_hint="regenerate with benchmarks/run.py")]
    for name, typ in SECTIONS.items():
        if name not in doc:
            out.append(r.violation(
                f"section {name!r} missing",
                location=name,
                fix_hint="regenerate with benchmarks/run.py (write_json "
                         "emits every section, empty or not)"))
        elif not isinstance(doc[name], typ):
            out.append(r.violation(
                f"section {name!r} should be a {typ.__name__}, got "
                f"{type(doc[name]).__name__}", location=name))
    for name in doc:
        if name not in SECTIONS:
            out.append(r.violation(
                f"unknown section {name!r}", location=name,
                severity=Severity.WARNING,
                fix_hint="add it to SECTIONS in bench_schema.py if it is "
                         "a new deliberate artifact"))
    return out


@rule("bench.keys", "a benchmark row is missing a required key")
def check_row_keys(r, doc):
    out = []
    if not isinstance(doc, dict):
        return out
    for section, keys in ROW_KEYS.items():
        rows = doc.get(section)
        if not isinstance(rows, list):
            continue
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                out.append(r.violation(
                    f"row {i} is not an object",
                    location=f"{section}[{i}]"))
                continue
            missing = [k for k in keys if k not in row]
            if missing:
                out.append(r.violation(
                    f"row {i} missing key(s) {', '.join(missing)}",
                    location=f"{section}[{i}]",
                    fix_hint="a rename in bench_deconv.py must update "
                             "ROW_KEYS (and the README tables) with it"))
    return out


@rule("bench.nan", "a benchmark value is NaN or infinite")
def check_finite(r, doc):
    out = []

    def scan(node: Any, path: str) -> None:
        if isinstance(node, float) and not math.isfinite(node):
            out.append(r.violation(
                f"non-finite value {node!r}", location=path,
                fix_hint="guard the producing division (bench rows use "
                         "max(denom, eps)) or drop the row"))
        elif isinstance(node, dict):
            for k, v in node.items():
                scan(v, f"{path}.{k}")
        elif isinstance(node, list):
            for i, v in enumerate(node):
                scan(v, f"{path}[{i}]")

    scan(doc, "$")
    return out


@rule("bench.table2_rows",
      "the table2 section is empty or a row matches neither schema")
def check_table2_rows(r, doc):
    out = []
    if not isinstance(doc, dict) or not isinstance(doc.get("table2"), list):
        return out          # shape problems are bench.sections' findings
    rows = doc["table2"]
    if not rows:
        return [r.violation(
            "table2 is empty: the bench no longer reports the paper's "
            "run-to-run variation statistics",
            location="table2",
            fix_hint="smoke mode must emit obs rows (bench_deconv."
                     "table2_obs_rows via repro.obs.report)")]
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            out.append(r.violation(f"row {i} is not an object",
                                   location=f"table2[{i}]"))
            continue
        if (any(k not in row for k in TABLE2_STAT_KEYS)
                and any(k not in row for k in TABLE2_LEGACY_KEYS)):
            out.append(r.violation(
                f"row {i} is neither an obs statistics row "
                f"({', '.join(TABLE2_STAT_KEYS)}) nor a legacy sweep row "
                f"({', '.join(TABLE2_LEGACY_KEYS)})",
                location=f"table2[{i}]",
                fix_hint="a key rename in obs/report.py or "
                         "bench_deconv.py must update TABLE2_*_KEYS"))
    return out


@rule("bench.table2_cv",
      "a healthy-run CV in table2 exceeds the pinned ceiling")
def check_table2_cv(r, doc):
    out = []
    if not isinstance(doc, dict) or not isinstance(doc.get("table2"), list):
        return out
    for i, row in enumerate(doc["table2"]):
        if not isinstance(row, dict) or "cv" not in row:
            continue        # legacy sweep rows carry rl_cv/zi_cv instead
        cv = row["cv"]
        if isinstance(cv, float) and not math.isfinite(cv):
            continue        # bench.nan's finding
        if cv > TABLE2_CV_MAX:
            out.append(r.violation(
                f"row {i} ({row.get('net')}/{row.get('precision')}/"
                f"b{row.get('bucket')}): healthy-run cv={cv:.3f} exceeds "
                f"{TABLE2_CV_MAX} — run-to-run variation regressed, or "
                "unhealthy samples (compiles, retries) leaked into the "
                "healthy population",
                location=f"table2[{i}]",
                fix_hint="check the engine's steady/tainted outcome "
                         "tagging before raising TABLE2_CV_MAX"))
    return out


#: workload-zoo serving rows: Table II statistics labeled by registry
#: workload (the zoo's proof that new towers serve with the same
#: run-to-run stability as the paper's generators)
WORKLOADS_ROW_KEYS = ("workload", "net", "precision", "bucket", "calls",
                      "mean_s", "cv")


@rule("bench.workloads_rows",
      "the workloads section is empty or a row is malformed")
def check_workloads_rows(r, doc):
    out = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("workloads"), list):
        return out          # shape problems are bench.sections' findings
    rows = doc["workloads"]
    if not rows:
        return [r.violation(
            "workloads is empty: the bench no longer serves the workload "
            "zoo (SR / denoising heads) through the engine",
            location="workloads",
            fix_hint="smoke mode must emit bench_deconv.workloads_rows")]
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            out.append(r.violation(f"row {i} is not an object",
                                   location=f"workloads[{i}]"))
            continue
        missing = [k for k in WORKLOADS_ROW_KEYS if k not in row]
        if missing:
            out.append(r.violation(
                f"row {i} missing key(s) {', '.join(missing)}",
                location=f"workloads[{i}]",
                fix_hint="a key rename in obs/report.py or "
                         "bench_deconv.py must update WORKLOADS_ROW_KEYS"))
    return out


BENCH_RULES = ("bench.sections", "bench.keys", "bench.nan",
               "bench.table2_rows", "bench.table2_cv",
               "bench.workloads_rows")


def check_bench_doc(doc, name: str = "BENCH_deconv.json") -> CheckReport:
    report = CheckReport(f"bench-schema:{name}")
    report.rules_run += list(BENCH_RULES)
    report.extend(check_sections(doc))
    report.extend(check_row_keys(doc))
    report.extend(check_finite(doc))
    report.extend(check_table2_rows(doc))
    report.extend(check_table2_cv(doc))
    report.extend(check_workloads_rows(doc))
    return report


def check_bench_json(path: str) -> CheckReport:
    """Validate a benchmark artifact on disk.  Unreadable/unparsable
    files report through ``bench.sections`` rather than raising — the
    smoke gate wants a report either way."""
    name = os.path.basename(path)
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        report = CheckReport(f"bench-schema:{name}")
        report.rules_run += list(BENCH_RULES)
        report.extend([check_sections.rule.violation(
            f"cannot load {path}: {e}",
            fix_hint="regenerate with benchmarks/run.py --smoke")])
        return report
    return check_bench_doc(doc, name)
