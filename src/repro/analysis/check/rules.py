"""Rule-engine core shared by every static verification pass.

The FPGA flow the paper builds on never runs an unverified bitstream:
the toolchain *statically* proves resource budgets and timing before
synthesis signs off.  This module is the TPU-stack analogue's chassis —
a typed violation record, severity levels, a registry of named rules,
and a report that renders rule-by-rule for humans or machines.  The
actual rules live in `plan_drc` (plan design-rule check),
`concurrency` (lock-discipline lint) and `bench_schema` (benchmark
artifact validation); all three emit `PlanRuleViolation`s through this
one chassis so CLIs, CI gates and the serving engine agree on what
"clean" means.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional, Sequence


class Severity(enum.IntEnum):
    """Violation severity.  ERROR always fails a check run; WARNING
    fails only under ``--strict`` (the CI gate); INFO never fails."""

    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:  # pragma: no cover
        return self.name


@dataclasses.dataclass(frozen=True)
class PlanRuleViolation:
    """One design-rule violation, carrying everything a human needs to
    fix it offline: the rule id (stable, testable), where it fired
    (layer index for plan rules, file:line for lint rules), what is
    wrong, and the fix hint."""

    rule_id: str
    severity: Severity
    message: str
    fix_hint: str = ""
    layer: Optional[int] = None
    location: Optional[str] = None

    def render(self) -> str:
        where = ""
        if self.location is not None:
            where = f" [{self.location}]"
        elif self.layer is not None:
            where = f" [layer {self.layer}]"
        out = f"{self.severity.name:7s} {self.rule_id}{where}: {self.message}"
        if self.fix_hint:
            out += f"\n        fix: {self.fix_hint}"
        return out


class PlanCheckError(ValueError):
    """A check pass found ERROR-level violations.

    The typed rejection the serving engine raises when a pinned plan
    fails DRC at load — the caller gets the full violation list instead
    of a mid-serve crash (or a traceback pointing into kernel guts)."""

    def __init__(self, message: str,
                 violations: Sequence[PlanRuleViolation] = ()):
        super().__init__(message)
        self.violations = tuple(violations)

    def report(self) -> str:
        lines = [str(self)]
        lines += [v.render() for v in self.violations]
        return "\n".join(lines)


@dataclasses.dataclass
class CheckReport:
    """Accumulated violations of one check run (possibly many passes)."""

    name: str
    violations: List[PlanRuleViolation] = dataclasses.field(
        default_factory=list)
    rules_run: List[str] = dataclasses.field(default_factory=list)

    def extend(self, violations: Sequence[PlanRuleViolation]) -> None:
        self.violations.extend(violations)

    def merge(self, other: "CheckReport") -> None:
        self.violations.extend(other.violations)
        self.rules_run.extend(r for r in other.rules_run
                              if r not in self.rules_run)

    def by_rule(self) -> Dict[str, List[PlanRuleViolation]]:
        out: Dict[str, List[PlanRuleViolation]] = {}
        for v in self.violations:
            out.setdefault(v.rule_id, []).append(v)
        return out

    def errors(self) -> List[PlanRuleViolation]:
        return [v for v in self.violations if v.severity >= Severity.ERROR]

    def failures(self, strict: bool = False) -> List[PlanRuleViolation]:
        """What gates: ERRORs always, WARNINGs too under strict."""
        bar = Severity.WARNING if strict else Severity.ERROR
        return [v for v in self.violations if v.severity >= bar]

    def ok(self, strict: bool = False) -> bool:
        return not self.failures(strict)

    def render(self, strict: bool = False) -> str:
        """Rule-by-rule human report (the `--plan-json` failure output)."""
        lines = [f"== {self.name}: "
                 f"{len(self.violations)} violation(s), "
                 f"{len(self.failures(strict))} gating"
                 f"{' (strict)' if strict else ''} =="]
        for rule_id in sorted(self.by_rule()):
            lines.append(f"-- {rule_id} --")
            lines += [v.render() for v in self.by_rule()[rule_id]]
        if not self.violations:
            lines.append("clean: no violations")
        return "\n".join(lines)

    def raise_if_failed(self, strict: bool = False) -> None:
        bad = self.failures(strict)
        if bad:
            raise PlanCheckError(
                f"{self.name}: {len(bad)} gating violation(s)", bad)


# -- registry ----------------------------------------------------------
# Rules register under a stable id so tests can assert "this mutation
# fires exactly that rule" and the README's rule table can be generated
# instead of hand-maintained.
_RULES: Dict[str, "Rule"] = {}


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    description: str
    default_severity: Severity
    fn: Callable

    def violation(self, message: str, *, fix_hint: str = "",
                  layer: Optional[int] = None,
                  location: Optional[str] = None,
                  severity: Optional[Severity] = None) -> PlanRuleViolation:
        return PlanRuleViolation(
            rule_id=self.rule_id,
            severity=(self.default_severity if severity is None
                      else severity),
            message=message, fix_hint=fix_hint, layer=layer,
            location=location)


def rule(rule_id: str, description: str,
         severity: Severity = Severity.ERROR):
    """Decorator: register a check function under a stable rule id.

    The decorated function receives the `Rule` as its first argument
    (so it mints violations with the right id/severity) and returns a
    list of `PlanRuleViolation`s."""
    def deco(fn: Callable) -> Rule:
        r = Rule(rule_id=rule_id, description=description,
                 default_severity=severity, fn=fn)
        if rule_id in _RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        _RULES[rule_id] = r

        def run(*args, **kwargs) -> List[PlanRuleViolation]:
            return fn(r, *args, **kwargs)

        run.rule = r                      # type: ignore[attr-defined]
        run.rule_id = rule_id             # type: ignore[attr-defined]
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run
    return deco


def registered_rules() -> Dict[str, Rule]:
    """{rule_id: Rule} over everything imported so far (the README rule
    table and the CLI's --list-rules render from this)."""
    # import the passes for their registration side effects
    from . import bench_schema, concurrency, plan_drc  # noqa: F401
    return dict(_RULES)
