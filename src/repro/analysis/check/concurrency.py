"""Pass 2 — AST lock-discipline lint over the threaded serve stack.

The serving engine, the async frontend, and the fault primitives are the
only genuinely concurrent code in the repo, and their discipline is
conventions in comments ("_qlock guards the queue state", "lock order:
_cond before _slock").  This pass turns those comments into checked
rules — statically, the way the plan DRC checks VMEM budgets without
running a kernel:

* **guarded-attribute learning** — a class's lock attributes are the
  ``self.X = threading.Lock()/RLock()/Condition()`` assignments; an
  instance attribute is *guarded* by the locks held at every one of its
  non-constructor assignments.  A write to a guarded attribute outside
  its guard is ``lint.unguarded_write`` (ERROR); a read outside it is
  ``lint.unguarded_read`` (WARNING — some stats reads are intentionally
  lock-free, which is what the allowlist is for).
* **call-site lock propagation** — a ``*_locked`` helper inherits the
  locks held at every one of its call sites (the repo convention:
  `_drain_locked`, `_pick_wave_locked`, ...), so accesses inside it are
  not falsely flagged.  Explicit ``self.X.acquire()`` / ``release()``
  pairs are tracked through the enclosing statement list.
* **lock-order inversion** — every "acquire L while holding H" pair is
  collected (one level of transitivity through self-calls); seeing both
  H->L and L->H is ``lint.lock_order`` (ERROR): two threads taking the
  locks in opposite orders is a deadlock waiting for load.
* **callback under lock** — invoking a configurable callback name
  (``on_failure``, ``before_call``, ...) while holding any lock is
  ``lint.callback_in_lock`` (WARNING): a callback that re-enters the
  lock owner deadlocks (the Heartbeat deliberately fires OUTSIDE its
  lock for exactly this reason).
* **check-then-act** — ``if self.flag: ... self.flag = ...`` on a bare
  boolean/None flag with no lock held, in a class that owns locks, is
  ``lint.check_then_act`` (ERROR): the window between the check and the
  set admits two winners (the frontend's double-`start()` race).

The linter is intentionally conservative: attributes never assigned
under a lock are presumed single-threaded by design and not reported
(the engine's lazy `_fns`/`plans` caches are that, documented); only
attributes the code *itself* treats as lock-guarded somewhere are held
to that discipline everywhere.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .rules import CheckReport, PlanRuleViolation, Severity, rule

LOCK_FACTORIES = ("Lock", "RLock", "Condition")
CONSTRUCTOR_METHODS = ("__init__", "__new__", "_setup")
CALLBACK_NAMES = ("on_failure", "on_stall", "on_error", "on_complete",
                  "before_call", "callback")
LOCKED_HELPER_SUFFIX = "_locked"


# ---------------------------------------------------------------------------
# allowlist
# ---------------------------------------------------------------------------
class Allowlist:
    """Suppressions for intentionally lock-free accesses.

    Entries are ``ClassName.attr`` (suppresses reads and writes) or
    ``ClassName.attr:read`` (reads only); ``#`` starts a comment.  The
    default allowlist documents the serve stack's deliberate lock-free
    surfaces (single-threaded dispatch caches, stats snapshots)."""

    def __init__(self, entries: Sequence[str] = ()):
        self._all: Set[Tuple[str, str]] = set()
        self._read: Set[Tuple[str, str]] = set()
        for line in entries:
            line = line.split("#", 1)[0].strip()
            if not line:
                continue
            kind = "all"
            if ":" in line:
                line, kind = line.rsplit(":", 1)
                kind = kind.strip()
                if kind not in ("read", "all"):
                    raise ValueError(
                        f"allowlist entry {line!r}: kind must be 'read' "
                        f"or 'all', got {kind!r}")
            if "." not in line:
                raise ValueError(
                    f"allowlist entry {line!r}: expected ClassName.attr")
            cls, attr = line.rsplit(".", 1)
            (self._all if kind == "all" else self._read).add(
                (cls.strip(), attr.strip()))

    def allows(self, cls: str, attr: str, kind: str) -> bool:
        if (cls, attr) in self._all:
            return True
        return kind == "read" and (cls, attr) in self._read

    @classmethod
    def load(cls, path: str) -> "Allowlist":
        with open(path) as f:
            return cls(f.read().splitlines())


#: deliberate lock-free surfaces in the serve stack (see class docstring)
DEFAULT_ALLOWLIST = Allowlist([
    "DcnnServeEngine.stats",          # dispatch is single-threaded
    "DcnnServeEngine.bucket_stats",   # idem (timing accounting)
    "DcnnServeEngine.trace_counts",   # written inside jit trace
    "DcnnServeEngine.plan_stats",
    "DcnnServeEngine.fault_stats:read",   # snapshot reads are lock-free
    "AsyncServeFrontend._worker_errors:read",
])


# ---------------------------------------------------------------------------
# per-method facts collected by the AST walk
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Access:
    attr: str
    kind: str                      # "read" | "write"
    held: FrozenSet[str]
    lineno: int
    method: str


@dataclasses.dataclass
class _MethodFacts:
    name: str
    accesses: List[_Access] = dataclasses.field(default_factory=list)
    # self-calls: (callee, held, lineno)
    calls: List[Tuple[str, FrozenSet[str], int]] = dataclasses.field(
        default_factory=list)
    # lock acquisitions: (held_before, lock, lineno)
    acquires: List[Tuple[FrozenSet[str], str, int]] = dataclasses.field(
        default_factory=list)
    # callback invocations: (callback_name, held, lineno)
    callbacks: List[Tuple[str, FrozenSet[str], int]] = dataclasses.field(
        default_factory=list)
    # bare-flag check-then-act candidates: (attr, lineno)
    flag_races: List[Tuple[str, int]] = dataclasses.field(
        default_factory=list)


@dataclasses.dataclass
class _ClassFacts:
    name: str
    locks: Set[str]
    methods: Dict[str, _MethodFacts]


def _self_attr(node: ast.AST) -> Optional[str]:
    """'attr' for an ``self.attr`` Attribute node, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _subscript_base_attr(node: ast.AST) -> Optional[str]:
    """Base ``self.attr`` of a (possibly nested) subscript chain:
    ``self.a[k]``, ``self.a[k][j]`` -> "a"."""
    while isinstance(node, ast.Subscript):
        node = node.value
    return _self_attr(node)


def _is_lock_factory(node: ast.AST) -> bool:
    """True for ``threading.Lock()`` / ``Lock()`` / RLock / Condition."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    name = (f.attr if isinstance(f, ast.Attribute)
            else f.id if isinstance(f, ast.Name) else None)
    return name in LOCK_FACTORIES


class _MethodWalker:
    """Walks one method body tracking the set of held locks."""

    def __init__(self, cls: "_ClassFacts", method: str):
        self.cls = cls
        self.facts = _MethodFacts(method)
        self.method_names: Set[str] = set()   # filled by caller

    # -- expression-level recording ------------------------------------
    def _record_expr(self, node: ast.AST, held: FrozenSet[str],
                     skip: Tuple[ast.AST, ...] = ()) -> None:
        """Record reads / self-calls / acquires / callbacks in an
        expression subtree.  ``skip`` holds Attribute nodes already
        counted as write targets."""
        for sub in ast.walk(node):
            if sub in skip:
                continue
            if isinstance(sub, ast.Call):
                f = sub.func
                if isinstance(f, ast.Attribute):
                    base = _self_attr(f.value)    # self.X.m() -> X
                    if base in self.cls.locks:
                        if f.attr == "acquire":
                            self.facts.acquires.append(
                                (held, base, sub.lineno))
                        continue  # lock-method call; not a data access
                    if (_self_attr(f) in self.method_names):
                        self.facts.calls.append((f.attr, held, sub.lineno))
                    if f.attr in CALLBACK_NAMES:
                        self.facts.callbacks.append(
                            (f.attr, held, sub.lineno))
            attr = _self_attr(sub)
            if attr is None:
                continue
            if attr in self.cls.locks or attr in self.method_names:
                continue
            if isinstance(sub.ctx, ast.Load):
                self.facts.accesses.append(_Access(
                    attr, "read", held, sub.lineno, self.facts.name))

    def _record_write_target(self, target: ast.AST,
                             held: FrozenSet[str]) -> List[ast.AST]:
        """Record writes for an assignment target; returns the Attribute
        nodes consumed as write bases (so they are not double-counted as
        reads)."""
        consumed: List[ast.AST] = []
        if isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                consumed += self._record_write_target(el, held)
            return consumed
        if isinstance(target, ast.Starred):
            return self._record_write_target(target.value, held)
        attr = _self_attr(target)
        if attr is not None:
            if attr not in self.cls.locks:
                self.facts.accesses.append(_Access(
                    attr, "write", held, target.lineno, self.facts.name))
            consumed.append(target)
            return consumed
        if isinstance(target, ast.Subscript):
            base = target
            while isinstance(base, ast.Subscript):
                # slice expressions are ordinary reads
                self._record_expr(base.slice, held)
                base = base.value
            battr = _self_attr(base)
            if battr is not None and battr not in self.cls.locks:
                self.facts.accesses.append(_Access(
                    battr, "write", held, target.lineno, self.facts.name))
                consumed.append(base)
            else:
                self._record_expr(base, held)
            return consumed
        # non-self target (local, req.field, ...): its value expr may
        # still contain reads
        self._record_expr(target, held)
        return consumed

    # -- statement walking ----------------------------------------------
    def _lock_events(self, stmt: ast.stmt) -> Tuple[Set[str], Set[str]]:
        """Locks explicitly acquire()d / release()d anywhere in ``stmt``
        (for tracking held state through the enclosing statement list)."""
        acq: Set[str] = set()
        rel: Set[str] = set()
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.Call) and isinstance(
                    sub.func, ast.Attribute):
                base = _self_attr(sub.func.value)
                if base in self.cls.locks:
                    if sub.func.attr == "acquire":
                        acq.add(base)
                    elif sub.func.attr == "release":
                        rel.add(base)
        return acq, rel

    def walk_body(self, body: Sequence[ast.stmt],
                  held: FrozenSet[str]) -> None:
        tracked: Set[str] = set()
        for stmt in body:
            self._walk_stmt(stmt, held | frozenset(tracked))
            acq, rel = self._lock_events(stmt)
            tracked |= acq
            tracked -= rel

    def _walk_stmt(self, stmt: ast.stmt, held: FrozenSet[str]) -> None:
        if isinstance(stmt, ast.With):
            inner = set(held)
            for item in stmt.items:
                attr = _self_attr(item.context_expr)
                if attr in self.cls.locks:
                    self.facts.acquires.append(
                        (held, attr, stmt.lineno))
                    inner.add(attr)
                else:
                    self._record_expr(item.context_expr, held)
            self.walk_body(stmt.body, frozenset(inner))
        elif isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            consumed: List[ast.AST] = []
            for t in targets:
                consumed += self._record_write_target(t, held)
            if getattr(stmt, "value", None) is not None:
                self._record_expr(stmt.value, held, skip=tuple(consumed))
        elif isinstance(stmt, ast.Delete):
            for t in stmt.targets:
                attr = _self_attr(t) or _subscript_base_attr(t)
                if attr is not None and attr not in self.cls.locks:
                    self.facts.accesses.append(_Access(
                        attr, "write", held, stmt.lineno, self.facts.name))
                else:
                    self._record_expr(t, held)
        elif isinstance(stmt, ast.If):
            self._record_expr(stmt.test, held)
            self._flag_race_check(stmt, held)
            self.walk_body(stmt.body, held)
            self.walk_body(stmt.orelse, held)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._record_expr(stmt.iter, held)
            self._record_write_target(stmt.target, held)
            self.walk_body(stmt.body, held)
            self.walk_body(stmt.orelse, held)
        elif isinstance(stmt, ast.While):
            self._record_expr(stmt.test, held)
            self.walk_body(stmt.body, held)
            self.walk_body(stmt.orelse, held)
        elif isinstance(stmt, ast.Try):
            self.walk_body(stmt.body, held)
            for h in stmt.handlers:
                self.walk_body(h.body, held)
            self.walk_body(stmt.orelse, held)
            self.walk_body(stmt.finalbody, held)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested closure runs at an unknown later time: the locks
            # held at definition say nothing about the locks held at call
            self.walk_body(stmt.body, frozenset())
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self._record_expr(stmt.value, held)
        elif isinstance(stmt, ast.Expr):
            self._record_expr(stmt.value, held)
        elif isinstance(stmt, (ast.Raise, ast.Assert)):
            self._record_expr(stmt, held)
        elif isinstance(stmt, ast.ClassDef):
            pass
        else:
            self._record_expr(stmt, held)

    def _flag_race_check(self, stmt: ast.If,
                         held: FrozenSet[str]) -> None:
        """``if self.flag: ... self.flag = ...`` with no lock held."""
        if held & self.cls.locks:
            return
        flags = self._bare_flag_attrs(stmt.test)
        if not flags:
            return
        written: Set[str] = set()
        for sub in ast.walk(stmt):
            if isinstance(sub, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (sub.targets if isinstance(sub, ast.Assign)
                           else [sub.target])
                for t in targets:
                    attr = _self_attr(t)
                    if attr is not None:
                        written.add(attr)
        for attr in flags & written:
            self.facts.flag_races.append((attr, stmt.lineno))

    def _bare_flag_attrs(self, test: ast.expr) -> Set[str]:
        """self-attributes used as bare boolean/None flags in a test:
        ``self.a``, ``not self.a``, ``self.a is (not) None``.  Membership
        or comparison tests are excluded — flagging every lazy-cache
        ``if key not in self.cache`` would drown the one real race."""
        out: Set[str] = set()
        nodes = [test]
        while nodes:
            n = nodes.pop()
            if isinstance(n, ast.BoolOp):
                nodes += n.values
                continue
            if isinstance(n, ast.UnaryOp) and isinstance(n.op, ast.Not):
                nodes.append(n.operand)
                continue
            attr = _self_attr(n)
            if attr is not None and attr not in self.cls.locks:
                out.add(attr)
                continue
            if (isinstance(n, ast.Compare) and len(n.ops) == 1
                    and isinstance(n.ops[0], (ast.Is, ast.IsNot))
                    and isinstance(n.comparators[0], ast.Constant)
                    and n.comparators[0].value is None):
                a = _self_attr(n.left)
                if a is not None and a not in self.cls.locks:
                    out.add(a)
        return out


# ---------------------------------------------------------------------------
# class-level analysis
# ---------------------------------------------------------------------------
def _collect_class(node: ast.ClassDef) -> _ClassFacts:
    method_defs = [n for n in node.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    method_names = {m.name for m in method_defs}
    locks: Set[str] = set()
    for m in method_defs:
        for sub in ast.walk(m):
            if isinstance(sub, ast.Assign) and _is_lock_factory(sub.value):
                for t in sub.targets:
                    attr = _self_attr(t)
                    if attr is not None:
                        locks.add(attr)
    cls = _ClassFacts(node.name, locks, {})
    for m in method_defs:
        w = _MethodWalker(cls, m.name)
        w.method_names = method_names
        w.walk_body(m.body, frozenset())
        cls.methods[m.name] = w.facts
    return cls


def _propagated_held(cls: _ClassFacts) -> Dict[str, FrozenSet[str]]:
    """Locks a ``*_locked`` helper inherits: the intersection of the
    locks held at every one of its in-class call sites."""
    sites: Dict[str, List[FrozenSet[str]]] = {}
    for mf in cls.methods.values():
        for callee, held, _ in mf.calls:
            sites.setdefault(callee, []).append(held)
    out: Dict[str, FrozenSet[str]] = {}
    for name, helds in sites.items():
        if not name.endswith(LOCKED_HELPER_SUFFIX):
            continue
        common = frozenset.intersection(*helds) if helds else frozenset()
        if common:
            out[name] = common
    return out


def _effective_accesses(cls: _ClassFacts) -> List[_Access]:
    extra = _propagated_held(cls)
    out: List[_Access] = []
    for mf in cls.methods.values():
        add = extra.get(mf.name, frozenset())
        for a in mf.accesses:
            out.append(dataclasses.replace(a, held=a.held | add)
                       if add else a)
    return out


def _lock_order_pairs(cls: _ClassFacts
                      ) -> Dict[Tuple[str, str], List[Tuple[str, int]]]:
    """(outer, inner) -> [(method, line)] over every acquire made while
    holding another lock, with call-site propagation and one level of
    transitivity through self-calls."""
    extra = _propagated_held(cls)
    pairs: Dict[Tuple[str, str], List[Tuple[str, int]]] = {}

    def note(held: FrozenSet[str], lock: str, method: str,
             line: int) -> None:
        for h in held:
            if h != lock:
                pairs.setdefault((h, lock), []).append((method, line))

    for mf in cls.methods.values():
        add = extra.get(mf.name, frozenset())
        for held, lock, line in mf.acquires:
            note(held | add, lock, mf.name, line)
        # one level through self-calls: m holds H and calls c; c's own
        # acquires happen with H additionally held
        for callee, held, line in mf.calls:
            held = held | add
            if not held:
                continue
            cf = cls.methods.get(callee)
            if cf is None:
                continue
            for inner_held, lock, _ in cf.acquires:
                note(held | inner_held, lock,
                     f"{mf.name}->{callee}", line)
    return pairs


# ---------------------------------------------------------------------------
# rules (ids are what the tests and the allowlist hang off)
# ---------------------------------------------------------------------------
@rule("lint.unguarded_write",
      "write to a lock-guarded attribute without holding its guard")
def _r_unguarded_write(r, findings):
    return [r.violation(**f) for f in findings]


@rule("lint.unguarded_read",
      "read of a lock-guarded attribute without holding its guard",
      severity=Severity.WARNING)
def _r_unguarded_read(r, findings):
    return [r.violation(**f) for f in findings]


@rule("lint.lock_order",
      "two locks acquired in opposite orders on different paths")
def _r_lock_order(r, findings):
    return [r.violation(**f) for f in findings]


@rule("lint.callback_in_lock",
      "callback invoked while holding a lock",
      severity=Severity.WARNING)
def _r_callback_in_lock(r, findings):
    return [r.violation(**f) for f in findings]


@rule("lint.check_then_act",
      "unlocked check-then-act on a shared flag")
def _r_check_then_act(r, findings):
    return [r.violation(**f) for f in findings]


LINT_RULES = ("lint.unguarded_write", "lint.unguarded_read",
              "lint.lock_order", "lint.callback_in_lock",
              "lint.check_then_act")


def _lint_class(cls: _ClassFacts, relpath: str,
                allowlist: Allowlist) -> CheckReport:
    report = CheckReport(f"lint:{relpath}:{cls.name}")
    if not cls.locks:
        return report
    loc = lambda a: f"{relpath}:{a.lineno} ({cls.name}.{a.method})"
    accesses = _effective_accesses(cls)

    # learn which attributes the class itself treats as guarded
    guards: Dict[str, FrozenSet[str]] = {}
    for a in accesses:
        if a.kind != "write" or a.method in CONSTRUCTOR_METHODS:
            continue
        locked = frozenset(a.held & cls.locks)
        if not locked:
            continue
        prev = guards.get(a.attr)
        guards[a.attr] = locked if prev is None else (prev & locked
                                                      or prev | locked)

    uw, ur = [], []
    for a in accesses:
        if a.method in CONSTRUCTOR_METHODS or a.attr not in guards:
            continue
        guard = guards[a.attr]
        if a.held & guard:
            continue
        if allowlist.allows(cls.name, a.attr, a.kind):
            continue
        pretty = "/".join(sorted(guard))
        if a.kind == "write":
            uw.append(dict(
                message=f"self.{a.attr} is written under {pretty} "
                        f"elsewhere but written here with no lock held",
                location=loc(a),
                fix_hint=f"take {pretty} around this write (or allowlist "
                         f"{cls.name}.{a.attr} if it is deliberately "
                         "lock-free)"))
        else:
            ur.append(dict(
                message=f"self.{a.attr} is guarded by {pretty} but read "
                        "here with no lock held",
                location=loc(a),
                fix_hint=f"take {pretty}, or allowlist "
                         f"{cls.name}.{a.attr}:read for an intentionally "
                         "lock-free snapshot"))

    pairs = _lock_order_pairs(cls)
    lo = []
    for (a_, b_), sites in sorted(pairs.items()):
        if (b_, a_) in pairs and a_ < b_:
            here = ", ".join(f"{m}:{ln}" for m, ln in sites[:3])
            there = ", ".join(f"{m}:{ln}"
                              for m, ln in pairs[(b_, a_)][:3])
            lo.append(dict(
                message=f"lock order inversion: {a_} -> {b_} ({here}) "
                        f"but also {b_} -> {a_} ({there})",
                location=f"{relpath} ({cls.name})",
                fix_hint="pick one order and restructure the minority "
                         "path (release before re-acquiring)"))

    cb = []
    for mf in cls.methods.values():
        for name, held, line in mf.callbacks:
            if held & cls.locks and mf.name not in CONSTRUCTOR_METHODS:
                cb.append(dict(
                    message=f"callback {name}() invoked while holding "
                            f"{'/'.join(sorted(held & cls.locks))}: a "
                            "callback that re-enters this object "
                            "deadlocks",
                    location=f"{relpath}:{line} ({cls.name}.{mf.name})",
                    fix_hint="snapshot under the lock, invoke the "
                             "callback after releasing it"))

    cta = []
    for mf in cls.methods.values():
        if mf.name in CONSTRUCTOR_METHODS:
            continue
        for attr, line in mf.flag_races:
            if allowlist.allows(cls.name, attr, "write"):
                continue
            cta.append(dict(
                message=f"check-then-act on self.{attr} with no lock "
                        "held: two threads can both pass the check "
                        "before either writes",
                location=f"{relpath}:{line} ({cls.name}.{mf.name})",
                fix_hint="perform the check and the set under one lock"))

    report.extend(_r_unguarded_write(uw))
    report.extend(_r_unguarded_read(ur))
    report.extend(_r_lock_order(lo))
    report.extend(_r_callback_in_lock(cb))
    report.extend(_r_check_then_act(cta))
    return report


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def lint_file(path: str,
              allowlist: Optional[Allowlist] = None) -> CheckReport:
    """Concurrency-lint every class in one Python source file."""
    allowlist = DEFAULT_ALLOWLIST if allowlist is None else allowlist
    relpath = os.path.basename(path)
    report = CheckReport(f"concurrency-lint:{relpath}")
    report.rules_run += list(LINT_RULES)
    with open(path) as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        report.extend([PlanRuleViolation(
            "lint.unguarded_write", Severity.ERROR,
            f"file does not parse: {e}", location=relpath)])
        return report
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            report.merge(_lint_class(_collect_class(node), relpath,
                                     allowlist))
    report.name = f"concurrency-lint:{relpath}"
    return report


def default_target_files() -> List[str]:
    """The threaded serve stack, located via the modules themselves (so
    the CLI works from any cwd)."""
    from ... import dist, obs, serve

    sdir = os.path.dirname(os.path.abspath(serve.__file__))
    ddir = os.path.dirname(os.path.abspath(dist.__file__))
    odir = os.path.dirname(os.path.abspath(obs.__file__))
    return [os.path.join(sdir, "engine.py"),
            os.path.join(sdir, "frontend.py"),
            os.path.join(ddir, "fault.py"),
            os.path.join(odir, "metrics.py"),
            os.path.join(odir, "trace.py")]


def lint_files(paths: Optional[Sequence[str]] = None,
               allowlist: Optional[Allowlist] = None) -> CheckReport:
    """Lint ``paths`` (default: engine.py, frontend.py, fault.py, plus
    the obs layer's metrics.py and trace.py)."""
    paths = default_target_files() if paths is None else list(paths)
    report = CheckReport("concurrency-lint")
    report.rules_run += list(LINT_RULES)
    for p in paths:
        report.merge(lint_file(p, allowlist))
    report.name = f"concurrency-lint[{len(paths)} file(s)]"
    return report
