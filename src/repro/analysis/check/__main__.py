"""CLI for the static verification subsystem.

    python -m repro.analysis.check                     # lint serve stack
    python -m repro.analysis.check --strict            # CI gate
    python -m repro.analysis.check --plan-json p.json  # + plan DRC
    python -m repro.analysis.check --bench BENCH_deconv.json
    python -m repro.analysis.check --list-rules

Exit status 0 iff every requested pass is clean (WARNINGs gate too
under ``--strict``)."""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional

from .bench_schema import check_bench_json
from .concurrency import Allowlist, lint_files
from .plan_drc import check_plan_json
from .rules import CheckReport, registered_rules


def _json_report(report: CheckReport, strict: bool) -> str:
    return json.dumps({
        "name": report.name,
        "ok": report.ok(strict),
        "rules_run": report.rules_run,
        "violations": [
            {**dataclasses.asdict(v), "severity": v.severity.name}
            for v in report.violations],
    }, indent=1)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check",
        description="Static verification: plan DRC, concurrency lint, "
                    "bench-artifact schema.")
    ap.add_argument("--strict", action="store_true",
                    help="WARNING-level violations also fail the run")
    ap.add_argument("--plan-json", nargs="*", default=[], metavar="PATH",
                    help="pinned NetworkPlan JSON(s) to design-rule check")
    ap.add_argument("--bench", nargs="*", default=[], metavar="PATH",
                    help="BENCH_deconv.json artifact(s) to validate")
    ap.add_argument("--lint", nargs="*", default=None, metavar="FILE",
                    help="Python files to concurrency-lint (default: the "
                         "threaded serve stack; pass with no files to "
                         "skip the lint pass)")
    ap.add_argument("--allowlist", metavar="FILE",
                    help="allowlist file (ClassName.attr[:read] lines) "
                         "replacing the built-in one")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print every registered rule id and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule_id, r in sorted(registered_rules().items()):
            print(f"{rule_id:24s} [{r.default_severity.name:7s}] "
                  f"{r.description}")
        return 0

    report = CheckReport("repro.analysis.check")
    for path in args.plan_json:
        report.merge(check_plan_json(path))
    for path in args.bench:
        report.merge(check_bench_json(path))
    run_lint = args.lint is None or len(args.lint) > 0
    if run_lint:
        allow = (Allowlist.load(args.allowlist)
                 if args.allowlist else None)
        report.merge(lint_files(args.lint, allowlist=allow))

    if args.format == "json":
        print(_json_report(report, args.strict))
    else:
        print(report.render(args.strict))
    return 0 if report.ok(args.strict) else 1


if __name__ == "__main__":
    sys.exit(main())
