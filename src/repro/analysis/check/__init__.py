"""Static verification subsystem: plan DRC + concurrency lint.

The FPGA flow in the source paper signs off resource budgets and timing
*before* synthesis; this package is the same discipline for the TPU
stack.  Two passes, one chassis:

* ``plan_drc`` — design-rule check over ``NetworkPlan``/``DeconvPlan``
  (VMEM budgets, tile/halo alignment, int8 scale chaining, sparse
  digests, bucket/mesh alignment, epilogue legality, roofline sanity)
  without executing a single kernel.
* ``concurrency`` — AST lock-discipline lint over the threaded serve
  stack (guarded-attribute learning, lock-order inversions, callbacks
  under locks, check-then-act races).
* ``bench_schema`` — schema + NaN validation for ``BENCH_deconv.json``.

CLI: ``python -m repro.analysis.check`` (see ``--help``); the serving
engine runs the plan DRC on every pinned plan at load and rejects bad
ones with a typed :class:`PlanCheckError` before any compile.
"""
from .bench_schema import check_bench_doc, check_bench_json
from .concurrency import (Allowlist, DEFAULT_ALLOWLIST,
                          default_target_files, lint_file, lint_files)
from .plan_drc import check_network_plan, check_plan_json
from .rules import (CheckReport, PlanCheckError, PlanRuleViolation,
                    Severity, registered_rules)

__all__ = [
    "Allowlist", "CheckReport", "DEFAULT_ALLOWLIST", "PlanCheckError",
    "PlanRuleViolation", "Severity", "check_bench_doc",
    "check_bench_json", "check_network_plan", "check_plan_json",
    "default_target_files", "lint_file", "lint_files",
    "registered_rules",
]
