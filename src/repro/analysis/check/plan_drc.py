"""Pass 1 — plan design-rule check (DRC) over pinned execution plans.

The paper's FPGA toolchain proves DSP/BRAM/LUT budgets and timing
*before* a bitstream exists; a `plan.NetworkPlan` is this repo's
bitstream analogue, and until now its invariants were only checked by
executing kernels.  This pass verifies a plan (in memory or pinned as
JSON) **without executing anything**:

* ``drc.vmem_budget``    — every resolved `TileChoice`'s
  `kernel_vmem_bytes` fits the device VMEM budget (BRAM fit);
* ``drc.tile_alignment`` — stride-aligned spatial tiles, positive tile
  factors, `padded_geometry()` / Eq. 5 halo geometry resolvable and
  internally consistent;
* ``drc.geometry_chain`` — layer i's output extents/channels feed
  layer i+1's input exactly;
* ``drc.input_root``     — the tower's first-layer input (1x1 latent
  root or H×W×C image root) and last-layer output match what the plan's
  declared `repro.workloads` entry expects;
* ``drc.scale_chain``    — the int8 requant chain: layer i's
  ``out_scale`` must equal layer i+1's input quant scale, epilogue
  widths must follow the int8-in-HBM convention (intermediates int8,
  the last layer emits f32);
* ``drc.sparse_digest``  — zero-skip schedule content hashes match the
  serialized tables and (when params are supplied) the weights that
  will actually be served;
* ``drc.bucket_mesh``    — per-layer batches agree with the network
  batch, batch tiles fit the batch, and the implied global bucket
  aligns to the mesh device count / engine bucket set;
* ``drc.epilogue``       — fused activation / output-width legality;
* ``drc.roofline``       — modeled attainable throughput positive and
  traffic estimates internally consistent;
* ``drc.backend``        — backend/precision/dtype combinations the
  executors actually implement;
* ``drc.schema``         — a JSON document that cannot even be loaded
  (stale schema, tampered content hash) reports as a violation instead
  of a traceback.

Entry points: `check_network_plan` (in-memory), `check_plan_json`
(pinned artifact).  `DcnnServeEngine.from_config` runs
`check_network_plan` at load and rejects on ERROR with a typed
`PlanCheckError` — the load-time gate that turns a mid-serve crash into
an offline report.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from ...core.dse import TPU_V5E, Device
from ...core.tiling import kernel_vmem_bytes
from .rules import CheckReport, PlanRuleViolation, Severity, rule

KNOWN_BACKENDS = ("pallas", "pallas_sparse", "reverse_loop", "xla")
TILED_BACKENDS = ("pallas", "pallas_sparse")
KNOWN_ACTIVATIONS = (None, "relu", "tanh")
_REL_TOL = 1e-9


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=_REL_TOL, abs_tol=0.0)


# ---------------------------------------------------------------------------
# per-rule checks (each returns a violation list; the registry gives them
# stable ids the mutation-fixture tests assert on)
# ---------------------------------------------------------------------------
@rule("drc.backend", "backend/precision/dtype combination is executable")
def check_backend(r, plan) -> List[PlanRuleViolation]:
    out: List[PlanRuleViolation] = []
    if plan.backend not in KNOWN_BACKENDS:
        out.append(r.violation(
            f"unknown backend {plan.backend!r}",
            fix_hint=f"one of {KNOWN_BACKENDS}"))
    if plan.precision == "int8" and plan.backend != "pallas":
        out.append(r.violation(
            f"precision='int8' with backend={plan.backend!r}: only the "
            "dense Pallas kernel has a quantized variant",
            fix_hint="re-plan with backend='pallas' or precision='fp32'"))
    want_dtype = "int8" if plan.precision == "int8" else None
    for i, l in enumerate(plan.layers):
        if l.backend != plan.backend:
            out.append(r.violation(
                f"layer backend {l.backend!r} != network backend "
                f"{plan.backend!r}", layer=i,
                fix_hint="re-plan; layers cannot mix backends"))
        if want_dtype is not None and l.dtype != want_dtype:
            out.append(r.violation(
                f"int8 plan streams dtype {l.dtype!r}", layer=i,
                fix_hint="int8 chains stream int8 between layers"))
        if plan.backend in TILED_BACKENDS and l.tiles is None:
            out.append(r.violation(
                "tiled backend but no resolved TileChoice", layer=i,
                fix_hint="re-plan with autotune (or fallback) tiles"))
    return out


@rule("drc.vmem_budget",
      "every resolved TileChoice fits the device VMEM budget")
def check_vmem_budget(r, plan, device: Device = TPU_V5E
                      ) -> List[PlanRuleViolation]:
    out: List[PlanRuleViolation] = []
    for i, l in enumerate(plan.layers):
        t = l.tiles
        if t is None:
            continue
        try:
            need = kernel_vmem_bytes(
                l.geometry, t.t_oh, t.t_ow, t.t_ci, t.t_co, l.dtype_bytes,
                t_n=t.t_n, out_dtype_bytes=l.out_dtype_bytes)
        except Exception:
            continue  # unresolvable tiling: drc.tile_alignment reports it
        if need > device.onchip_bytes:
            out.append(r.violation(
                f"tile ({t.t_oh}x{t.t_ow}/{t.t_ci}/{t.t_co}/n{t.t_n}) "
                f"needs {need} B of VMEM against the {device.name} "
                f"budget of {device.onchip_bytes} B",
                layer=i,
                fix_hint="re-run the autotuner for this device; a plan "
                         "pinned for a larger-VMEM part cannot run here"))
    return out


@rule("drc.tile_alignment",
      "tile factors stride-aligned, positive, and halo geometry resolvable")
def check_tile_alignment(r, plan) -> List[PlanRuleViolation]:
    out: List[PlanRuleViolation] = []
    for i, l in enumerate(plan.layers):
        t = l.tiles
        if t is None:
            continue
        g = l.geometry
        for name in ("t_oh", "t_ow", "t_ci", "t_co", "t_n"):
            v = getattr(t, name)
            if not isinstance(v, int) or v < 1:
                out.append(r.violation(
                    f"{name}={v!r} is not a positive integer", layer=i,
                    fix_hint="re-plan; tile factors are positive ints"))
        if t.t_oh % g.stride or t.t_ow % g.stride:
            out.append(r.violation(
                f"spatial tile {t.t_oh}x{t.t_ow} is not stride-aligned "
                f"(S={g.stride}): the Eq. 5 constant-extent window (and "
                "uniform per-tile phase structure) requires S | T_OH",
                layer=i,
                fix_hint="round the spatial tile to a stride multiple"))
            continue  # padded_geometry asserts on misaligned tiles
        try:
            (oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop, t_n, np_
             ) = l.padded_geometry()
        except Exception as e:
            out.append(r.violation(
                f"padded_geometry() unresolvable: {e}", layer=i,
                fix_hint="the pinned tiles do not form a legal halo "
                         "grid for this geometry; re-plan"))
            continue
        if (oh, ow) != (g.out_h, g.out_w):
            out.append(r.violation(
                f"halo geometry disagrees with the layer geometry: "
                f"padded grid solves {oh}x{ow}, layer says "
                f"{g.out_h}x{g.out_w}", layer=i,
                fix_hint="geometry and tiles were pinned from different "
                         "configs; re-plan"))
        if ohp % t.t_oh or owp % t.t_ow:
            out.append(r.violation(
                f"padded output {ohp}x{owp} is not tiled exactly by "
                f"{t.t_oh}x{t.t_ow}", layer=i,
                fix_hint="re-plan; the grid must cover the padded output "
                         "in whole tiles"))
        if cip % t.t_ci or cop % t.t_co:
            out.append(r.violation(
                f"padded channels ({cip}, {cop}) not divisible by the "
                f"channel tiles ({t.t_ci}, {t.t_co})", layer=i,
                fix_hint="re-plan; channel padding must be tile-exact"))
        if pad_l < 0 or pad_rh < 0 or pad_rw < 0:
            out.append(r.violation(
                f"negative halo padding ({pad_l}, {pad_rh}, {pad_rw})",
                layer=i, fix_hint="re-plan against this geometry"))
    return out


@rule("drc.geometry_chain",
      "layer i's output feeds layer i+1's input exactly")
def check_geometry_chain(r, plan) -> List[PlanRuleViolation]:
    out: List[PlanRuleViolation] = []
    for i in range(len(plan.layers) - 1):
        g, nxt = plan.layers[i].geometry, plan.layers[i + 1].geometry
        if (g.out_h, g.out_w, g.c_out) != (nxt.in_h, nxt.in_w, nxt.c_in):
            out.append(r.violation(
                f"layer {i} emits {g.out_h}x{g.out_w}x{g.c_out} but "
                f"layer {i + 1} expects {nxt.in_h}x{nxt.in_w}x{nxt.c_in}",
                layer=i + 1,
                fix_hint="the layer list was edited after pinning; "
                         "re-plan from the network config"))
    return out


@rule("drc.input_root",
      "tower root/head geometry matches the plan's declared workload")
def check_input_root(r, plan) -> List[PlanRuleViolation]:
    """Image-rooted towers (SR heads, denoising decoders) enter at
    in_hw x in_hw x in_c rather than the WGAN 1x1 latent root; this rule
    pins the first layer's input and the last layer's output to whatever
    the plan's registered workload declares, so a plan relabeled or
    spliced across workloads fails offline instead of reshaping wrong."""
    out: List[PlanRuleViolation] = []
    if not plan.layers:
        return [r.violation("plan has no layers",
                            fix_hint="re-plan from the network config")]
    g0 = plan.layers[0].geometry
    if g0.in_h != g0.in_w or g0.in_h < 1:
        out.append(r.violation(
            f"tower root is {g0.in_h}x{g0.in_w}: roots are square "
            "(1x1 latent or in_hw x in_hw image)", layer=0,
            fix_hint="re-plan from the network config"))
    wname = getattr(plan, "workload", None)
    if wname is None:
        return out  # legacy plan: no declared workload to check against
    try:
        from ...workloads import get as get_workload
        cfg = get_workload(wname).cfg
    except Exception:
        # the registry is open (third-party towers register at runtime);
        # an id this process doesn't know is not provably wrong
        return out
    root = (cfg.in_hw, cfg.in_hw, cfg.in_c)
    if (g0.in_h, g0.in_w, g0.c_in) != root:
        out.append(r.violation(
            f"first layer consumes {g0.in_h}x{g0.in_w}x{g0.c_in} but "
            f"workload {wname!r} declares the input root "
            f"{root[0]}x{root[1]}x{root[2]}", layer=0,
            fix_hint="the plan was edited or relabeled after pinning; "
                     "re-plan from the workload's config"))
    gl = plan.layers[-1].geometry
    head = (cfg.img_hw, cfg.img_hw, cfg.img_c)
    if (gl.out_h, gl.out_w, gl.c_out) != head:
        out.append(r.violation(
            f"last layer emits {gl.out_h}x{gl.out_w}x{gl.c_out} but "
            f"workload {wname!r} declares the output head "
            f"{head[0]}x{head[1]}x{head[2]}",
            layer=len(plan.layers) - 1,
            fix_hint="the plan was edited or relabeled after pinning; "
                     "re-plan from the workload's config"))
    return out


@rule("drc.scale_chain",
      "int8 requant chain: out_scale[i] == input scale of layer i+1")
def check_scale_chain(r, plan) -> List[PlanRuleViolation]:
    out: List[PlanRuleViolation] = []
    layers = plan.layers
    if plan.precision != "int8":
        for i, l in enumerate(layers):
            if l.quant is not None or l.out_scale is not None:
                out.append(r.violation(
                    f"fp32 plan carries quantization state "
                    f"(quant={l.quant is not None}, "
                    f"out_scale={l.out_scale})", layer=i,
                    fix_hint="re-plan at precision='int8' or drop the "
                             "stale scales"))
        return out
    last = len(layers) - 1
    for i, l in enumerate(layers):
        if l.quant is None:
            out.append(r.violation(
                "int8 layer has no calibrated LayerQuant scales",
                layer=i, fix_hint="re-calibrate and re-plan"))
            continue
        if i < last:
            nxt = layers[i + 1].quant
            if l.out_scale is None:
                out.append(r.violation(
                    "intermediate int8 layer has no requant out_scale: "
                    "its epilogue could not re-quantize into the next "
                    "layer's range", layer=i,
                    fix_hint="re-plan; out_scale must be layer "
                             f"{i + 1}'s input scale"))
            elif nxt is not None and not _close(l.out_scale, nxt.x_scale):
                out.append(r.violation(
                    f"requant chain broken: layer {i} re-quantizes at "
                    f"out_scale={l.out_scale!r} but layer {i + 1} was "
                    f"calibrated for x_scale={nxt.x_scale!r} — the "
                    "served images would be silently wrong", layer=i,
                    fix_hint="the plan mixes two calibrations; re-plan "
                             "from one QuantConfig"))
            if l.out_dtype_bytes is not None:
                out.append(r.violation(
                    f"intermediate int8 layer widens its output to "
                    f"{l.out_dtype_bytes} B: activations must stay int8 "
                    "in HBM between layers", layer=i,
                    fix_hint="only the last layer emits f32 "
                             "(out_dtype_bytes=4)"))
        else:
            if l.out_scale is not None:
                out.append(r.violation(
                    f"last int8 layer has out_scale={l.out_scale!r}: "
                    "there is no next layer to re-quantize into",
                    layer=i, fix_hint="the final epilogue dequantizes "
                                      "to f32; out_scale must be None"))
            if l.out_dtype_bytes != 4:
                out.append(r.violation(
                    f"last int8 layer emits out_dtype_bytes="
                    f"{l.out_dtype_bytes!r}; the chain's final epilogue "
                    "writes f32 images (4 B)", layer=i,
                    fix_hint="re-plan; autotuned tiles priced for the "
                             "wrong output width are also stale"))
    return out


@rule("drc.sparse_digest",
      "zero-skip schedule digests match tables and served weights")
def check_sparse_digest(r, plan, params=None) -> List[PlanRuleViolation]:
    out: List[PlanRuleViolation] = []
    if plan.backend != "pallas_sparse":
        return out
    from ...plan.deconv_plan import _sparse_digest

    for i, l in enumerate(plan.layers):
        if l.sparse_digest is None:
            out.append(r.violation(
                "pallas_sparse layer has no pinned schedule digest: "
                "staleness against the served weights is unverifiable",
                layer=i, severity=Severity.WARNING,
                fix_hint="re-plan with the pruned weights so the "
                         "schedule is content-hashed"))
            continue
        if l.sparse_tables is not None:
            got = _sparse_digest(l.sparse_tables)
            if got != l.sparse_digest:
                out.append(r.violation(
                    f"serialized zero-skip tables hash to {got} but the "
                    f"plan pinned {l.sparse_digest}", layer=i,
                    fix_hint="the tables were edited after pinning; "
                             "re-plan from the weights"))
        if params is not None and l.tiles is not None:
            from ...kernels.deconv2d_sparse import make_sparse_plan

            g = l.geometry
            want = _sparse_digest(make_sparse_plan(
                np.asarray(params[f"l{i}"]["w"]), g.stride, g.padding,
                l.tiles.t_ci, l.tiles.t_co))
            if want != l.sparse_digest:
                out.append(r.violation(
                    f"pinned schedule ({l.sparse_digest}) does not match "
                    f"the schedule of the weights being served ({want}): "
                    "a stale schedule silently skips now-nonzero blocks",
                    layer=i,
                    fix_hint="the checkpoint was re-pruned after the "
                             "plan was pinned; re-plan against it"))
    return out


@rule("drc.bucket_mesh",
      "batches consistent across layers and aligned to the mesh")
def check_bucket_mesh(r, plan, n_devices: int = 1,
                      buckets: Optional[Sequence[int]] = None
                      ) -> List[PlanRuleViolation]:
    out: List[PlanRuleViolation] = []
    if plan.batch < 1:
        out.append(r.violation(
            f"network batch {plan.batch} is not positive",
            fix_hint="plans are fitted to a concrete serving bucket"))
        return out
    for i, l in enumerate(plan.layers):
        if l.batch != plan.batch:
            out.append(r.violation(
                f"layer batch {l.batch} != network batch {plan.batch}: "
                "the layer's tiles were fitted to a different bucket",
                layer=i, fix_hint="re-plan; all layers of one plan "
                                  "serve one per-device sub-batch"))
        if l.tiles is not None and l.tiles.t_n > l.batch:
            out.append(r.violation(
                f"batch tile t_n={l.tiles.t_n} exceeds the layer batch "
                f"{l.batch}: the grid would be scored with MXU rows the "
                "clamped kernel can never fill", layer=i,
                fix_hint="re-plan; the autotuner never emits t_n > "
                         "batch, so this plan was edited or corrupted"))
    if n_devices > 1:
        bucket = plan.batch * n_devices
        if buckets is not None and bucket not in tuple(buckets):
            out.append(r.violation(
                f"per-device batch {plan.batch} x {n_devices} device(s) "
                f"implies global bucket {bucket}, which is not in the "
                f"engine bucket set {tuple(buckets)}",
                fix_hint="re-plan for a shard-aligned bucket "
                         "(shard_aligned_buckets rounds buckets to "
                         "device-count multiples)"))
    return out


@rule("drc.epilogue", "fused epilogue activation/width legality")
def check_epilogue(r, plan) -> List[PlanRuleViolation]:
    out: List[PlanRuleViolation] = []
    for i, l in enumerate(plan.layers):
        if l.activation not in KNOWN_ACTIVATIONS:
            out.append(r.violation(
                f"unknown fused activation {l.activation!r}", layer=i,
                fix_hint=f"kernels implement {KNOWN_ACTIVATIONS}"))
        if l.out_dtype_bytes not in (None, 1, 2, 4):
            out.append(r.violation(
                f"out_dtype_bytes={l.out_dtype_bytes!r} is not a "
                "supported epilogue width", layer=i,
                fix_hint="None (same as stream) or 1/2/4 bytes"))
    return out


@rule("drc.roofline",
      "modeled attainable throughput positive, traffic self-consistent")
def check_roofline(r, plan, device: Device = TPU_V5E
                   ) -> List[PlanRuleViolation]:
    out: List[PlanRuleViolation] = []
    try:
        points = plan.modeled_attainable(device)
        traffic = plan.traffic_report()
    except Exception as e:
        return [r.violation(
            f"roofline/traffic model unevaluable: {e}",
            fix_hint="the pinned tiles do not form a modelable grid; "
                     "re-plan")]
    for i, pt in points.items():
        if not (pt.attainable_ops > 0.0 and math.isfinite(
                pt.attainable_ops)):
            out.append(r.violation(
                f"modeled attainable throughput is "
                f"{pt.attainable_ops!r} ops/s", layer=i,
                fix_hint="a zero/NaN roofline means degenerate tiles or "
                         "geometry; re-plan"))
        if pt.ctc <= 0.0 or not math.isfinite(pt.ctc):
            out.append(r.violation(
                f"computation-to-communication ratio is {pt.ctc!r}",
                layer=i, fix_hint="traffic model degenerate; re-plan"))
    for i, t in traffic.items():
        parts = t.n_tiles * (t.n_ci_steps * (t.in_bytes_per_tile
                                             + t.w_bytes_per_tile)
                             + t.out_bytes_per_tile)
        if t.total_bytes != parts:
            out.append(r.violation(
                f"traffic estimate inconsistent: total_bytes="
                f"{t.total_bytes} but components sum to {parts}",
                layer=i, fix_hint="model drift between plan fields; "
                                  "re-plan with this code version"))
        if min(t.n_tiles, t.n_ci_steps, t.in_bytes_per_tile,
               t.w_bytes_per_tile, t.out_bytes_per_tile) <= 0:
            out.append(r.violation(
                "traffic estimate has non-positive components", layer=i,
                fix_hint="re-plan; every tile moves some bytes"))
    return out


# the schema rule never runs over a live plan — it exists so an unloadable
# JSON document reports through the same chassis as every other violation
@rule("drc.schema", "pinned plan JSON loads under the current schema")
def check_schema(r, error: Exception,
                 location: Optional[str] = None) -> List[PlanRuleViolation]:
    return [r.violation(
        f"plan document rejected at load: {error}", location=location,
        fix_hint="re-pin the plan with this code version (stale schema "
                 "or post-pinning edits are never executed)")]


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def check_network_plan(
    plan,
    *,
    device: Device = TPU_V5E,
    n_devices: int = 1,
    buckets: Optional[Sequence[int]] = None,
    params: Optional[Dict[str, Any]] = None,
    name: Optional[str] = None,
) -> CheckReport:
    """Run every plan DRC rule over a `plan.NetworkPlan`.

    ``device`` sets the VMEM budget / roofline constants; ``n_devices``
    and ``buckets`` enable the mesh-alignment rule (the serving engine
    passes its own); ``params`` enables the weights-vs-digest staleness
    check for pallas_sparse plans.  Nothing is executed or compiled."""
    report = CheckReport(name or f"plan-drc:{plan.name}")
    report.extend(check_backend(plan))
    report.extend(check_vmem_budget(plan, device))
    report.extend(check_tile_alignment(plan))
    report.extend(check_geometry_chain(plan))
    report.extend(check_input_root(plan))
    report.extend(check_scale_chain(plan))
    report.extend(check_sparse_digest(plan, params))
    report.extend(check_bucket_mesh(plan, n_devices, buckets))
    report.extend(check_epilogue(plan))
    report.extend(check_roofline(plan, device))
    report.rules_run += [
        "drc.backend", "drc.vmem_budget", "drc.tile_alignment",
        "drc.geometry_chain", "drc.input_root", "drc.scale_chain",
        "drc.sparse_digest", "drc.bucket_mesh", "drc.epilogue",
        "drc.roofline",
    ]
    return report


def check_plan_json(path: str, **kwargs) -> CheckReport:
    """DRC a pinned plan artifact.  A document that cannot even load
    (stale schema, tampered content hash, not a plan) reports as a
    ``drc.schema`` violation instead of raising — the CLI and the
    example driver print rule-by-rule either way."""
    from ...plan import NetworkPlan
    from ...plan.deconv_plan import PlanSchemaError

    try:
        plan = NetworkPlan.load(path)
    except (OSError, PlanSchemaError, KeyError, TypeError,
            ValueError) as e:
        report = CheckReport(f"plan-drc:{path}")
        report.extend(check_schema(e, location=path))
        report.rules_run.append("drc.schema")
        return report
    return check_network_plan(plan, name=f"plan-drc:{path}", **kwargs)
