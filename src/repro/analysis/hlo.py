"""Trip-count-aware HLO cost analyzer.

XLA's `compiled.cost_analysis()` counts each computation ONCE — a
`lax.scan`'s while-body (our scan-over-layers, blocked attention, recurrent
cells) contributes a single iteration, which silently under-reports FLOPs,
bytes and collective traffic by the trip count (30-4096x here).  This module
re-derives the three roofline inputs from the optimized HLO text with loop
multipliers:

  * parse computations + the ops inside them (with result/operand shapes);
  * build the call graph (while body/condition, fusion calls, call/to_apply,
    conditionals), extract while trip counts from the loop condition's
    comparison constant;
  * FLOPs   = sum over dot/convolution ops of 2*M*N*K x multiplier;
  * bytes   = sum over materializing ops (fusion, dot, conv, copy,
    collectives, ...) of (operand + result bytes) x multiplier — i.e. the
    HBM traffic of each fused kernel under a no-spill model;
  * collective link-bytes by kind x multiplier (ring model: all-reduce 2x).

Validated against cost_analysis() on loop-free programs (see
tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0,
    "f4e2m1fn": 1, "f8e8m0fnu": 1, "f8e3m4": 1, "f8e4m3": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{$")
_TRIP_RE = re.compile(r'known_trip_count..:..n.:.(\d+)')
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.+?\)?)\s+([\w\-]+)\((.*)$")
_CALLED = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shapes_in(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_text: str
    rest: str           # everything after the opcode's "("

    @property
    def result_bytes(self) -> int:
        return _bytes_of(self.result_text)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symbols: Dict[str, str]  # op name -> result text (shape info)


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry_name = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(3), m.group(2), m.group(4))
            cur.ops.append(op)
            cur.symbols[op.name] = op.result_text
    if cur is not None:
        comps[cur.name] = cur
    comps["__entry__"] = comps[entry_name] if entry_name else None
    return comps


def _trip_count(cond: Computation) -> int:
    """Loop condition is `compare(induction, constant(N)), direction=LT`
    (scan canonical form).  Heuristic: the max s32 constant in the condition.
    """
    best = 1
    for op in cond.ops:
        if op.opcode == "constant" and "s32" in op.result_text:
            m = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
        m2 = _CONST_RE.search(op.rest)
        if m2:
            best = max(best, int(m2.group(1)))
    return best


def _operand_names(op: Op) -> List[str]:
    # operand list = rest up to the matching ")" at depth 0
    depth = 1
    end = len(op.rest)
    for i, ch in enumerate(op.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    seg = op.rest[:end]
    # newer XLA dumps print typed operands ("f32[128,256]{1,0} %arg.1");
    # when %-prefixed names are present, take exactly those — the loose
    # fallback would otherwise pick up dtype/shape tokens as operands.
    prefixed = re.findall(r"%([\w.\-]+)", seg)
    if prefixed:
        return prefixed
    return _OPERAND_RE.findall(seg)


def compute_multipliers(
    comps: Dict[str, Computation],
) -> Tuple[Dict[str, float], set]:
    """Returns (multiplier per computation, set of fusion-inlined
    computations).  Ops inside fusion/reduce/scatter bodies execute within
    one fused kernel — they contribute flops but NOT HBM traffic (the fusion
    op itself accounts for its operand/result bytes)."""
    entry = comps["__entry__"]
    mult: Dict[str, float] = {}
    fused: set = set()

    def visit(comp: Computation, m: float, inlined: bool):
        mult[comp.name] = mult.get(comp.name, 0.0) + m
        if inlined:
            fused.add(comp.name)
        for op in comp.ops:
            branches = _BRANCHES.search(op.rest)
            if op.opcode == "while":
                cond = None
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                if mc and mc.group(1) in comps:
                    cond = comps[mc.group(1)]
                mt = _TRIP_RE.search(op.rest)
                if mt:  # XLA annotates scans with the exact trip count
                    trips = int(mt.group(1))
                else:   # fallback: constant in the loop condition
                    trips = _trip_count(cond) if cond else 1
                if mb and mb.group(1) in comps:
                    visit(comps[mb.group(1)], m * trips, inlined)
                if cond:
                    visit(cond, m * (trips + 1), inlined)
            elif branches:
                for b in _OPERAND_RE.findall(branches.group(1)):
                    if b in comps:
                        visit(comps[b], m, inlined)
            elif op.opcode in ("call", "async-start"):
                for c in _CALLED.findall(op.rest):
                    if c in comps:
                        visit(comps[c], m, inlined)
            else:
                # fusion bodies / reduce combiners / scatter updaters ...
                for c in _CALLED.findall(op.rest):
                    if c in comps:
                        visit(comps[c], m, True)

    if entry is not None:
        visit(entry, 1.0, False)
    return mult, fused


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 * prod(result dims) * prod(contracted lhs dims)."""
    res_shapes = _shapes_in(op.result_text)
    if not res_shapes:
        return 0.0
    out_elems = 1
    for d in res_shapes[0][1]:
        out_elems *= d
    operands = _operand_names(op)
    if not operands:
        return 0.0
    lhs_text = comp.symbols.get(operands[0], "")
    lhs_shapes = _shapes_in(lhs_text)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    k = 1
    if lhs_shapes and m:
        lhs_dims = lhs_shapes[0][1]
        for idx in m.group(1).split(","):
            if idx:
                k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, comp: Computation) -> float:
    res_shapes = _shapes_in(op.result_text)
    operands = _operand_names(op)
    if not res_shapes or len(operands) < 2:
        return 0.0
    out_elems = 1
    for d in res_shapes[0][1]:
        out_elems *= d
    rhs = _shapes_in(comp.symbols.get(operands[1], ""))
    if not rhs:
        return 0.0
    # kernel elems x Cin: all kernel dims except the output-feature dim.
    kdims = rhs[0][1]
    if not kdims:
        return 0.0
    k = 1
    for d in kdims:
        k *= d
    # dim_labels ...->..io: output feature is one kernel dim; divide it out.
    ml = re.search(r"dim_labels=\w+_(\w+)->", op.rest)
    if ml:
        lbl = ml.group(1)
        o_idx = lbl.index("o")
        k //= max(kdims[o_idx], 1)
    else:
        k //= max(kdims[-1], 1)
    m = re.search(r"feature_group_count=(\d+)", op.rest)
    if m:
        k //= max(int(m.group(1)), 1)
    return 2.0 * out_elems * k


# ops whose operands/results cross HBM (one fused kernel each).  Elementwise
# singletons are wrapped into kLoop fusions by XLA-CPU, so raw elementwise /
# reshape / broadcast ops (usually fused or bitcast) are intentionally
# excluded from the traffic model.
_MATERIALIZING = {
    "fusion", "dot", "convolution", "copy", "custom-call", "scatter",
    "gather", "reduce", "sort", "transpose", "pad", "concatenate", "slice",
    "dynamic-slice", "dynamic-update-slice", "select-and-scatter",
    "reduce-window", "rng",
} | set(COLLECTIVE_KINDS) | {k + "-start" for k in COLLECTIVE_KINDS}


def _fusion_param_traffic(body: Computation) -> Dict[int, Optional[int]]:
    """Per-parameter-index HBM traffic of a fusion body, or None for
    'full operand'.  A parameter consumed ONLY by slice-family ops reads just
    the sliced regions; a parameter that is the in-place target of a
    dynamic-update-slice costs ~the update bytes."""
    params: Dict[str, int] = {}
    for op in body.ops:
        if op.opcode == "parameter":
            m = re.match(r"\s*(\d+)", op.rest)
            if m:
                params[op.name] = int(m.group(1))
    traffic: Dict[int, Optional[int]] = {}
    sliced: Dict[str, int] = {n: 0 for n in params}
    full: set = set()
    for op in body.ops:
        names = _operand_names(op)
        for pos, n in enumerate(names):
            if n not in params:
                continue
            if op.opcode in ("slice", "dynamic-slice", "gather"):
                if pos == 0:
                    sliced[n] += op.result_bytes
                # index operands: negligible
            elif op.opcode == "dynamic-update-slice":
                if pos == 0:  # in-place target: cost ~ update bytes
                    upd = (_bytes_of(body.symbols.get(names[1], ""))
                           if len(names) > 1 else op.result_bytes)
                    sliced[n] += upd
                elif pos == 1:
                    sliced[n] += _bytes_of(body.symbols.get(n, ""))
            else:
                full.add(n)
    for name, idx in params.items():
        traffic[idx] = None if name in full else sliced[name]
    return traffic


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float            # link bytes, ring model
    collectives: Dict[str, Tuple[int, float]]
    n_while: int


# ---------------------------------------------------------------------------
# deconv HBM-traffic accounting (modeled vs measured)
# ---------------------------------------------------------------------------
def deconv_traffic_report(geom, t_oh: int, t_ow: int, t_ci: int, t_co: int,
                          dtype_bytes: int = 4) -> Dict[str, float]:
    """Modeled HBM bytes of one deconv layer (per batch element) under the
    halo-streaming kernel vs the legacy full-image pipeline (which
    re-streamed the whole padded input per grid program).

    ``in_bytes_per_tile`` is the Eq. 5 window — constant per tile and
    independent of image size; ``traffic_reduction`` is the tentpole win.
    """
    from ..core.tiling import deconv_traffic, full_image_traffic

    t = deconv_traffic(geom, t_oh, t_ow, t_ci, t_co, dtype_bytes)
    full = full_image_traffic(geom, t_oh, t_ow, t_ci, t_co, dtype_bytes)
    return {
        "n_tiles": t.n_tiles,
        "n_ci_steps": t.n_ci_steps,
        "in_bytes_per_tile": t.in_bytes_per_tile,
        "w_bytes_per_tile": t.w_bytes_per_tile,
        "out_bytes_per_tile": t.out_bytes_per_tile,
        "halo_total_bytes": t.total_bytes,
        "full_image_in_bytes_per_tile": full.in_bytes_per_tile,
        "full_image_total_bytes": full.total_bytes,
        "traffic_reduction": full.total_bytes / max(t.total_bytes, 1),
    }


def measured_bytes(fn, *args) -> float:
    """`bytes_accessed` of the optimized HLO of ``jit(fn)(*args)``.

    On TPU the Pallas kernel appears as one custom-call whose operand +
    result bytes are the arrays crossing HBM; on CPU (interpret mode) the
    kernel is inlined into plain HLO, so the number is an upper-bound proxy
    — benchmarks label it accordingly."""
    import jax

    compiled = jax.jit(fn).lower(*args).compile()
    return analyze(compiled.as_text()).bytes_accessed


def analyze(hlo: str) -> HloCost:
    comps = parse_module(hlo)
    mult, fused = compute_multipliers(comps)
    flops = 0.0
    bytes_acc = 0.0
    coll: Dict[str, Tuple[int, float]] = {k: (0, 0.0) for k in COLLECTIVE_KINDS}
    n_while = 0
    for key, comp in comps.items():
        if comp is None or key == "__entry__":
            continue
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        inlined = comp.name in fused
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                n_while += 1
            if oc == "dot":
                flops += m * _dot_flops(op, comp)
            elif oc == "convolution":
                flops += m * _conv_flops(op, comp)
            if inlined:
                continue  # no HBM traffic / collectives inside fused kernels
            # collective accounting
            kind = None
            for k in COLLECTIVE_KINDS:
                if oc == k or oc == k + "-start":
                    kind = k
                    break
            if kind is not None and not oc.endswith("-done"):
                if kind == "reduce-scatter":
                    # link bytes ~= the (large) input, not the scattered out
                    payload = sum(_bytes_of(comp.symbols.get(n, ""))
                                  for n in _operand_names(op))
                else:
                    # all-gather/all-to-all/permute: ~result size;
                    # all-reduce: result size, x2 ring factor below
                    payload = op.result_bytes
                factor = 2.0 if kind == "all-reduce" else 1.0
                cnt, tot = coll[kind]
                coll[kind] = (cnt + 1, tot + m * payload * factor)
            # HBM-traffic model: operands + result of materializing ops.
            # Slice-family ops only touch the sliced region, and
            # dynamic-update-slice updates in place (2x update bytes).
            if oc in _MATERIALIZING:
                if oc in ("slice", "dynamic-slice", "gather"):
                    bytes_acc += m * 2 * op.result_bytes
                elif oc == "dynamic-update-slice":
                    ops_ = _operand_names(op)
                    upd = (_bytes_of(comp.symbols.get(ops_[1], ""))
                           if len(ops_) > 1 else op.result_bytes)
                    bytes_acc += m * 2 * upd
                elif oc == "fusion":
                    mfc = re.search(r"calls=%?([\w.\-]+)", op.rest)
                    body = comps.get(mfc.group(1)) if mfc else None
                    ptr = _fusion_param_traffic(body) if body else {}
                    operand_bytes = 0
                    for i, name in enumerate(_operand_names(op)):
                        if name not in comp.symbols:
                            continue
                        t = ptr.get(i, None)
                        operand_bytes += (_bytes_of(comp.symbols[name])
                                          if t is None else t)
                    bytes_acc += m * (operand_bytes + op.result_bytes)
                else:
                    operand_bytes = 0
                    for name in _operand_names(op):
                        if name in comp.symbols:
                            operand_bytes += _bytes_of(comp.symbols[name])
                    bytes_acc += m * (operand_bytes + op.result_bytes)
    return HloCost(
        flops=flops,
        bytes_accessed=bytes_acc,
        collective_bytes=sum(v[1] for v in coll.values()),
        collectives={k: v for k, v in coll.items() if v[0]},
        n_while=n_while,
    )
