"""Three-term roofline from the compiled dry-run artifact.

    compute term    = HLO_FLOPs_global  / (chips * peak_FLOP/s)
    memory term     = HLO_bytes_global  / (chips * HBM_bw)
    collective term = collective_bytes  / (chips * link_bw)

`compiled.cost_analysis()` is per-partition (the SPMD module is the
per-device program), so global = per_device * chips and each term reduces to
per_device / per-chip-peak.  collective_bytes is parsed from the optimized
HLO text: we sum the link-crossing bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (ring-model factors).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (x4 links usable per chip for concurrent transfers ~ we use the
single-link figure, conservative)."""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# matches e.g. bf16[16,512,128]{2,1,0} or f32[]
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> Dict[str, Tuple[int, int]]:
    """{kind: (op_count, link_bytes)} per device.

    Ring-model link bytes per chip: all-reduce ~ 2x payload, others ~ 1x
    (the (n-1)/n factor is dropped — negligible at n >= 16)."""
    out: Dict[str, Tuple[int, int]] = {k: (0, 0) for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-defining collective lines look like:  %name = TYPE[..] kind(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+(\w[\w\-]*)\(", s)
        if not m:
            continue
        result_part, opname = m.group(1), m.group(2)
        kind = None
        for k in _COLL_KINDS:
            if opname == k or opname.startswith(k + "-"):
                kind = k
                break
        if kind is None:
            continue
        if "-start" in opname and kind != "collective-permute":
            pass  # async start carries the payload; done carries none
        if opname.endswith("-done"):
            continue
        payload = sum(_shape_bytes(d, dims)
                      for d, dims in _SHAPE_RE.findall(result_part))
        factor = 2.0 if kind == "all-reduce" else 1.0
        cnt, tot = out[kind]
        out[kind] = (cnt + 1, tot + int(payload * factor))
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, Tuple[int, int]]
    peak_bytes_per_device: Optional[float]
    model_flops_global: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=lambda k: terms[k])

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS_global (catches remat/redundancy waste)."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / max(hlo_global, 1.0)

    @property
    def step_time_bound(self) -> float:
        """Roofline step-time lower bound (terms overlap perfectly)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline-bound step: how close the
        compiled program is to spending all its time on model FLOPs."""
        useful_t = (self.model_flops_global / self.chips) / PEAK_FLOPS
        return useful_t / max(self.step_time_bound, 1e-30)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_global,
            "hlo_flops_global": self.flops_per_device * self.chips,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_bytes_per_device": self.peak_bytes_per_device,
            "collectives": {k: v for k, v in self.collectives.items() if v[0]},
        }


def model_flops(cfg, suite) -> float:
    """MODEL_FLOPS: 6*N*D for training (fwd+bwd), 2*N*D for inference, with
    N = active params, D = processed tokens."""
    n = cfg.active_param_count()
    if suite.kind == "train":
        d = suite.global_batch * suite.seq_len
        return 6.0 * n * d
    if suite.kind == "prefill":
        d = suite.global_batch * suite.seq_len
        return 2.0 * n * d
    d = suite.global_batch * 1  # decode: one token per sequence
    return 2.0 * n * d
