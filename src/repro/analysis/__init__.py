"""Offline analysis: HLO cost accounting, roofline reports, and the
static verification subsystem (`repro.analysis.check`) that design-rule
checks pinned plans and lints the threaded serve stack without executing
anything."""
