"""repro: TPU-native reproduction of "A Competitive Edge" (FPGA DCNN
inference acceleration) as a multi-pod JAX framework."""
__version__ = "1.0.0"
