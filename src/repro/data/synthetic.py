"""Synthetic datasets (offline container: no MNIST/CelebA downloads).

The paper's evaluation targets are throughput/power and distribution-level
quality (MMD) — not label accuracy — so structured synthetic distributions
suffice: procedural "digit stroke" images for the MNIST stand-in and smooth
"face blob" compositions for CelebA, both deterministic functions of a seed.
Token streams for LM training come from a mixture of Zipfian unigrams with
injected bigram structure so the loss has learnable signal.
"""
from __future__ import annotations

import numpy as np


def digit_images(seed: int, n: int, hw: int = 28) -> np.ndarray:
    """(n, hw, hw, 1) float32 in [-1, 1] — randomized stroke patterns."""
    rng = np.random.RandomState(seed)
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32) / hw
    imgs = np.zeros((n, hw, hw, 1), np.float32)
    for i in range(n):
        img = np.zeros((hw, hw), np.float32)
        for _ in range(rng.randint(2, 5)):  # a few strokes
            x0, y0 = rng.rand(2)
            x1, y1 = rng.rand(2)
            t = np.linspace(0, 1, 40)[:, None]
            pts = np.stack([x0 + (x1 - x0) * t[:, 0], y0 + (y1 - y0) * t[:, 0]], 1)
            for px, py in pts:
                d2 = (xx - px) ** 2 + (yy - py) ** 2
                img += np.exp(-d2 / 0.004)
        img = np.clip(img, 0, 1.5) / 1.5
        imgs[i, :, :, 0] = img * 2 - 1
    return imgs


def face_images(seed: int, n: int, hw: int = 64) -> np.ndarray:
    """(n, hw, hw, 3) float32 in [-1, 1] — smooth blob compositions."""
    rng = np.random.RandomState(seed)
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32) / hw
    imgs = np.zeros((n, hw, hw, 3), np.float32)
    for i in range(n):
        img = np.zeros((hw, hw, 3), np.float32)
        base = rng.rand(3) * 0.6 + 0.2
        img += base  # skin-tone-ish base
        for _ in range(rng.randint(3, 7)):  # features as gaussian blobs
            cx, cy = rng.rand(2) * 0.6 + 0.2
            sig = rng.rand() * 0.05 + 0.01
            col = rng.rand(3)
            g = np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * sig))
            img += g[:, :, None] * (col - base) * 0.8
        imgs[i] = np.clip(img, 0, 1) * 2 - 1
    return imgs


def token_stream(seed: int, n_tokens: int, vocab: int) -> np.ndarray:
    """Zipfian unigrams + deterministic bigram successor structure."""
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    base = rng.choice(vocab, size=n_tokens, p=probs)
    # bigram structure: with p=0.5, token t+1 = f(token t)
    succ = rng.permutation(vocab)
    follow = rng.rand(n_tokens) < 0.5
    out = base.copy()
    out[1:][follow[1:]] = succ[out[:-1][follow[1:]]]
    return out.astype(np.int32)
