from .pipeline import (StepIndexedSource, Prefetcher, finite_batches,
                       image_source, lm_source)
from .synthetic import digit_images, face_images, token_stream
