"""Deterministically-resumable sharded data pipeline.

Batches are a pure function of (seed, step) — no iterator state to
checkpoint, no divergence on restart, and every data-parallel host can
compute exactly its own shard (batch axis sliced by host id).  Prefetch is a
small background thread keeping a bounded queue of ready batches.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from .synthetic import digit_images, face_images, token_stream


class StepIndexedSource:
    """batch(step) -> dict of numpy arrays; pure in (seed, step)."""

    def __init__(self, fn: Callable[[int], Dict[str, np.ndarray]]):
        self._fn = fn

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        return self._fn(step)

    def shard(self, host_id: int, n_hosts: int) -> "StepIndexedSource":
        def fn(step):
            full = self._fn(step)
            return {k: np.array_split(v, n_hosts, axis=0)[host_id]
                    for k, v in full.items()}
        return StepIndexedSource(fn)


def image_source(kind: str, seed: int, batch: int) -> StepIndexedSource:
    gen = digit_images if kind == "mnist" else face_images

    def fn(step):
        return {"images": gen(seed + step, batch)}

    return StepIndexedSource(fn)


def lm_source(seed: int, batch: int, seq_len: int, vocab: int) -> StepIndexedSource:
    def fn(step):
        toks = token_stream(seed + step, batch * (seq_len + 1), vocab)
        toks = toks.reshape(batch, seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return StepIndexedSource(fn)


def finite_batches(source: StepIndexedSource, n_steps: int,
                   start: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Adapt a step-indexed source into a finite streaming iterator — the
    `WganTrainer.fit` streaming-source form (one batch per critic
    sub-step, training stops when the iterator drains)."""
    for step in range(start, start + n_steps):
        yield source.batch(step)


class Prefetcher:
    """Bounded background prefetch over a StepIndexedSource."""

    def __init__(self, source: StepIndexedSource, start_step: int,
                 depth: int = 2):
        self._source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            try:
                self._q.put((step, self._source.batch(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def get(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
