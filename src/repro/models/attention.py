"""Attention substrate: GQA/MQA/MHA, RoPE variants, blocked (flash-style)
attention with online softmax, local/global windows, logit softcapping, and
KV caches (contiguous for global layers, ring-buffer for local layers).

The blocked attention is the memory-bounded pure-JAX formulation (O(S·block)
live memory) used for both train and serve paths; a Pallas flash kernel can
replace it transparently (see §Perf in EXPERIMENTS.md).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.context import constrain, current
from . import nn

NEG_INF = -1e30


def _attn_tp_divisible(n_heads: int) -> bool:
    """True when attention heads split the model axis.  When they don't
    (minitron 24H, qwen2-vl 28H, musicgen 24H on model=16), sharding the
    head_dim instead makes every score tile a cross-shard contraction —
    one all-reduce per (q-block, kv-block, layer): measured 267-398 s of
    link time per prefill step (§Perf H4).  Replicating attention compute
    and keeping TP on the FFN/projections costs ~16x attention FLOPs but
    zero collectives: 4.2 s of compute vs 267 s of links for minitron."""
    mesh, _ = current()
    if mesh is None:
        return True
    model = mesh.shape.get("model", 1)
    return n_heads % model == 0


# ---------------------------------------------------------------------------
# RoPE family
# ---------------------------------------------------------------------------
def rope_freqs(rotary_dim: int, theta: float) -> jax.Array:
    i = jnp.arange(0, rotary_dim // 2, dtype=jnp.float32)
    return theta ** (-2.0 * i / rotary_dim)


def apply_rope(
    x: jax.Array,                 # (B, S, H, Dh)
    positions: jax.Array,         # (B, S) int32 or (3, B, S) for M-RoPE
    theta: float = 10000.0,
    rotary_frac: float = 1.0,     # chatglm3 "2d RoPE": 0.5 (partial rotary)
    mrope_sections: Optional[Tuple[int, ...]] = None,  # qwen2-vl: (16, 24, 24)
) -> jax.Array:
    dh = x.shape[-1]
    rd = int(dh * rotary_frac)
    rd -= rd % 2
    freqs = rope_freqs(rd, theta)                      # (rd/2,)
    if positions.ndim == 3:
        # M-RoPE: each frequency band takes its position channel.
        assert mrope_sections is not None
        sec_ids = jnp.concatenate([
            jnp.full((s,), i, dtype=jnp.int32)
            for i, s in enumerate(mrope_sections)
        ])  # (rd/2,)
        pos = positions.astype(jnp.float32)            # (3, B, S)
        angles = pos[sec_ids, :, :].transpose(1, 2, 0) * freqs  # (B, S, rd/2)
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, rd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Blocked attention with online softmax
# ---------------------------------------------------------------------------
def blocked_attention(
    q: jax.Array,                 # (B, Sq, H, Dh)
    k: jax.Array,                 # (B, Skv, Hkv, Dh)
    v: jax.Array,                 # (B, Skv, Hkv, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap_val: Optional[float] = None,
    q_offset: Any = 0,            # int or traced scalar (decode)
    kv_len: Optional[Any] = None, # valid kv prefix length (decode caches)
    kv_positions: Optional[jax.Array] = None,  # (Skv,) ring-buffer positions
    scale: Optional[float] = None,
    k_scale: Optional[jax.Array] = None,  # (B, Skv, Hkv, 1) int8-KV scales
    v_scale: Optional[jax.Array] = None,
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    kv_len = kv_len if kv_len is not None else skv

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = -(-sq // block_q)
    nk = -(-skv // block_k)
    sq_p, skv_p = nq * block_q, nk * block_k

    # NO cache-sized transposes: k/v stay in their native (B, Skv, Hkv, Dh)
    # layout (critical for the 32k decode path — only block-sized copies).
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    quant = k_scale is not None
    if quant:
        ksp = jnp.pad(k_scale, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        vsp = jnp.pad(v_scale, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))

    q_positions = q_offset + jnp.arange(sq_p, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = jnp.arange(skv_p, dtype=jnp.int32)
    else:
        kv_positions = jnp.pad(
            kv_positions, (0, skv_p - skv), constant_values=jnp.iinfo(jnp.int32).max
        )

    def q_block_step(_, qi):
        qb = jax.lax.dynamic_slice_in_dim(qp, qi * block_q, block_q, axis=1)
        qb = qb.reshape(b, block_q, hkv, g, dh).transpose(0, 2, 3, 1, 4)
        qpos = jax.lax.dynamic_slice_in_dim(q_positions, qi * block_q, block_q)

        def kv_step(carry, ki):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kp, ki * block_k, block_k, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vp, ki * block_k, block_k, axis=1)
            if quant:  # dequantize-on-read: only the block leaves int8
                ksb = jax.lax.dynamic_slice_in_dim(ksp, ki * block_k,
                                                   block_k, axis=1)
                vsb = jax.lax.dynamic_slice_in_dim(vsp, ki * block_k,
                                                   block_k, axis=1)
                kb = kb.astype(ksb.dtype) * ksb
                vb = vb.astype(vsb.dtype) * vsb
            kpos = jax.lax.dynamic_slice_in_dim(kv_positions, ki * block_k, block_k)
            s = jnp.einsum("bhgqd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = nn.softcap(s, softcap_val)
            mask = (kpos[None, :] < kv_len)
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, block_q), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((b, hkv, g, block_q), dtype=jnp.float32)
        a0 = jnp.zeros((b, hkv, g, block_q, dh), dtype=jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nk), unroll=1
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, blocks = jax.lax.scan(q_block_step, None, jnp.arange(nq))
    # blocks: (nq, B, Hkv, G, bq, Dh) -> (B, Sq, H, Dh)
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq_p, h, dh)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (init/apply) with KV cache
# ---------------------------------------------------------------------------
def attention_init(
    key, cfg, dtype, layer_kind: str = "global"
) -> Tuple[nn.Params, nn.Specs]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p: nn.Params = {}
    s: nn.Specs = {}
    p["wq"], s["wq"] = nn.dense_init(ks[0], d, h * dh, dtype,
                                     axes=("embed", "heads"), bias=cfg.qkv_bias)
    p["wk"], s["wk"] = nn.dense_init(ks[1], d, hkv * dh, dtype,
                                     axes=("embed", "kv_heads"), bias=cfg.qkv_bias)
    p["wv"], s["wv"] = nn.dense_init(ks[2], d, hkv * dh, dtype,
                                     axes=("embed", "kv_heads"), bias=cfg.qkv_bias)
    p["wo"], s["wo"] = nn.dense_init(ks[3], h * dh, d, dtype,
                                     axes=("heads", "embed"))
    return p, s


def init_kv_cache(cfg, batch: int, max_len: int, layer_kind: str, dtype):
    """Cache for ONE attention layer.  Local layers use a ring buffer bounded
    by the attention window (this is what makes long_500k decode O(window)).
    With cfg.kv_quant, k/v are int8 with per-(token, head) scales."""
    size = max_len if layer_kind == "global" else min(cfg.local_window, max_len)
    kv_dtype = jnp.int8 if cfg.kv_quant else dtype
    cache = {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), kv_dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.head_dim), kv_dtype),
        "slot_pos": jnp.full((size,), -1, dtype=jnp.int32),
    }
    if cfg.kv_quant:
        cache["k_scale"] = jnp.zeros((batch, size, cfg.n_kv_heads, 1), dtype)
        cache["v_scale"] = jnp.zeros((batch, size, cfg.n_kv_heads, 1), dtype)
    return cache


def quantize_kv(x: jax.Array):
    """(B, S, Hkv, Dh) -> (int8 values, per-(token, head) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(x.dtype)


def attention_apply(
    p: nn.Params,
    cfg,
    x: jax.Array,                  # (B, S, D)
    positions: jax.Array,          # (B, S) or (3, B, S)
    layer_kind: str = "global",
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,  # scalar: tokens already cached
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    b, sq, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = nn.dense(p["wq"], x).reshape(b, sq, h, dh)
    k = nn.dense(p["wk"], x).reshape(b, sq, hkv, dh)
    v = nn.dense(p["wv"], x).reshape(b, sq, hkv, dh)
    if cache is None and not _attn_tp_divisible(h):
        # train/prefill with q-heads % model != 0: replicate the attention
        # compute (TP stays on FFN/projections) — see _attn_tp_divisible.
        q = constrain(q, "batch", None, None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    else:
        # classic GQA-TP: q sharded on heads; kv sharded when divisible,
        # replicated otherwise (NEVER head_dim-sharded in compute — that
        # turns every score tile into a cross-shard contraction).
        q = constrain(q, "batch", None, "heads", None)
        k = constrain(k, "batch", None, "kv_heads", None)
        v = constrain(v, "batch", None, "kv_heads", None)

    rope_kwargs = dict(
        theta=cfg.rope_theta,
        rotary_frac=cfg.rotary_frac,
        mrope_sections=cfg.mrope_sections,
    )
    if cfg.rope != "none":
        q = apply_rope(q, positions, **rope_kwargs)
        k = apply_rope(k, positions, **rope_kwargs)

    window = cfg.local_window if layer_kind == "local" else None
    scale = cfg.attn_scale if cfg.attn_scale is not None else dh ** -0.5

    if cache is None:
        out = blocked_attention(
            q, k, v, causal=True, window=window,
            softcap_val=cfg.attn_softcap, scale=scale,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        )
        new_cache = None
    else:
        # decode: append S (==1) new tokens into the cache and attend.
        size = cache["k"].shape[1]
        slot = cache_pos % size
        if cfg.kv_quant:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            k_store, v_store = kq, vq
        else:
            k_store, v_store = k, v
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_store, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_store, slot, axis=1)
        spos = jax.lax.dynamic_update_slice_in_dim(
            cache["slot_pos"],
            (cache_pos + jnp.arange(sq, dtype=jnp.int32)), slot, axis=0,
        )
        kv_positions = jnp.where(spos < 0, jnp.iinfo(jnp.int32).max, spos)
        new_cache = {"k": ck, "v": cv, "slot_pos": spos}
        scales = {}
        if cfg.kv_quant:
            new_cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks, slot, axis=1)
            new_cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs, slot, axis=1)
            scales = {"k_scale": new_cache["k_scale"],
                      "v_scale": new_cache["v_scale"]}
        out = blocked_attention(
            q, ck, cv, causal=True, window=window,
            softcap_val=cfg.attn_softcap, scale=scale,
            q_offset=cache_pos,
            kv_len=cache_pos + sq,
            kv_positions=kv_positions,
            block_q=sq, block_k=cfg.attn_block_k,
            **scales,
        )

    out = out.reshape(b, sq, h * dh)
    return nn.dense(p["wo"], out), new_cache
