from .transformer import ModelConfig, apply_lm, init_cache, init_lm
from .dcnn import CELEBA_DCNN, MNIST_DCNN, DcnnConfig, critic_apply, critic_init, generator_apply, generator_init, tower_input
