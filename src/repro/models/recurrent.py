"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and xLSTM (sLSTM/mLSTM).

All recurrences are expressed as `lax.scan` over time with explicit carried
state, so the same apply function serves training (full sequence), prefill
(state build-up), and decode (single step with state in/out).  State size is
O(d) (RG-LRU, sLSTM) or O(d_head^2) (mLSTM) — independent of context length,
which is what qualifies these archs for the long_500k cell.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import nn

CONV_W = 4  # temporal conv width used by both Griffin and xLSTM blocks
TIME_CHUNK = 256  # remat granularity of the time scans


def time_scan(step, carry, xs, chunk: int = TIME_CHUNK):
    """`lax.scan` over time with chunked rematerialization.

    A plain scan saves every per-step carry for the backward pass — for the
    mLSTM's (B, H, dh, dh) matrix state that is O(T) x 100s of MB.  Chunking
    saves the carry only at chunk boundaries (T/chunk snapshots) and
    recomputes inside the chunk on the backward pass."""
    leaves = jax.tree_util.tree_leaves(xs)
    t = leaves[0].shape[0]
    if t <= chunk:
        return jax.lax.scan(step, carry, xs)
    n_full = t // chunk

    def chunk_body(c, xs_c):
        return jax.lax.scan(step, c, xs_c)

    chunk_body = jax.checkpoint(chunk_body)
    head = jax.tree_util.tree_map(
        lambda x: x[: n_full * chunk].reshape(n_full, chunk, *x.shape[1:]), xs)
    carry, ys_head = jax.lax.scan(chunk_body, carry, head)
    ys_head = jax.tree_util.tree_map(
        lambda y: y.reshape(n_full * chunk, *y.shape[2:]), ys_head)
    if t % chunk:
        tail = jax.tree_util.tree_map(lambda x: x[n_full * chunk:], xs)
        carry, ys_tail = jax.lax.scan(step, carry, tail)
        ys = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), ys_head, ys_tail)
    else:
        ys = ys_head
    return carry, ys


# ---------------------------------------------------------------------------
# temporal conv1d with decode state
# ---------------------------------------------------------------------------
def conv1d_init(key, d: int, dtype):
    p = {"w": nn.lecun_init(key, (CONV_W, d), dtype, fan_in=CONV_W),
         "b": jnp.zeros((d,), dtype)}
    s = {"w": (None, "embed"), "b": ("embed",)}
    return p, s


def conv1d_apply(p, x: jax.Array, state: Optional[jax.Array] = None):
    """Causal depthwise conv.  x: (B,S,D); state: (B, CONV_W-1, D) history."""
    b, sl, d = x.shape
    hist = state if state is not None else jnp.zeros((b, CONV_W - 1, d), x.dtype)
    xx = jnp.concatenate([hist, x], axis=1)
    y = sum(
        xx[:, i : i + sl, :] * p["w"][i] for i in range(CONV_W)
    ) + p["b"]
    new_state = xx[:, -(CONV_W - 1):, :]
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# RG-LRU (Real-Gated Linear Recurrent Unit) — Griffin eq. (1)-(4)
# ---------------------------------------------------------------------------
def rglru_init(key, d: int, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "wa": nn.lecun_init(ks[0], (d, d), dtype),
        "wx": nn.lecun_init(ks[1], (d, d), dtype),
        "lam": (8.0 * jax.random.uniform(ks[2], (d,)) + 2.0).astype(jnp.float32),
    }
    s = {"wa": ("embed", "embed2"), "wx": ("embed", "embed2"), "lam": ("embed2",)}
    return p, s


def rglru_apply(p, x: jax.Array, h0: Optional[jax.Array] = None):
    """x: (B,S,D) -> (y (B,S,D), h_final (B,D)).  c = 8 as in Griffin."""
    b, sl, d = x.shape
    r = jax.nn.sigmoid(x @ p["wa"]).astype(jnp.float32)
    i = jax.nn.sigmoid(x @ p["wx"]).astype(jnp.float32)
    log_a = -8.0 * r * jax.nn.softplus(p["lam"])          # (B,S,D) f32
    a = jnp.exp(log_a)
    gated_x = i * x.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bt = beta * gated_x

    h_init = h0.astype(jnp.float32) if h0 is not None else jnp.zeros((b, d), jnp.float32)

    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    h_fin, ys = time_scan(
        step, h_init, (a.transpose(1, 0, 2), bt.transpose(1, 0, 2))
    )
    return ys.transpose(1, 0, 2).astype(x.dtype), h_fin


def griffin_block_init(key, cfg, dtype):
    """Griffin recurrent block: gate branch + (conv1d -> RG-LRU) branch."""
    d = cfg.d_model
    dr = cfg.rnn_width or d
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    p["in_x"], s["in_x"] = nn.dense_init(ks[0], d, dr, dtype, ("embed", "rnn"))
    p["in_g"], s["in_g"] = nn.dense_init(ks[1], d, dr, dtype, ("embed", "rnn"))
    p["conv"], s["conv"] = conv1d_init(ks[2], dr, dtype)
    s["conv"] = {"w": (None, "rnn"), "b": ("rnn",)}
    p["rglru"], s["rglru"] = rglru_init(ks[3], dr, dtype)
    s["rglru"] = {"wa": ("rnn", "rnn2"), "wx": ("rnn", "rnn2"), "lam": ("rnn2",)}
    p["out"], s["out"] = nn.dense_init(ks[4], dr, d, dtype, ("rnn", "embed"))
    return p, s


def griffin_block_apply(p, cfg, x, state: Optional[Dict] = None):
    gate = nn.gelu(nn.dense(p["in_g"], x))
    xr = nn.dense(p["in_x"], x)
    conv_state = state["conv"] if state is not None else None
    h0 = state["h"] if state is not None else None
    xc, new_conv = conv1d_apply(p["conv"], xr, conv_state)
    y, h_fin = rglru_apply(p["rglru"], xc, h0)
    out = nn.dense(p["out"], gate * y)
    new_state = {"conv": new_conv, "h": h_fin}
    return out, new_state


def griffin_state_init(cfg, batch: int, dtype):
    dr = cfg.rnn_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, CONV_W - 1, dr), dtype),
        "h": jnp.zeros((batch, dr), jnp.float32),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell)
#
# Two equivalent evaluation orders:
#  * step recurrence (decode, short sequences): O(T) sequential, touches the
#    (dh x dh) matrix state every step -> O(T·dh²) HBM traffic;
#  * chunkwise-parallel (train/prefill): within a chunk of L tokens the
#    output is an L x L masked attention with per-source weights
#    exp(li_s - g_s - M_t); the state is read/updated once per chunk ->
#    O(T·dh²/L) HBM traffic.  Exactly the same stabilizer algebra as the
#    step form (m_t = g_t + max(m0, cummax(li - g))), so both orders agree
#    to float tolerance (tests/test_mlstm_chunkwise.py).  §Perf H5.
# ---------------------------------------------------------------------------
MLSTM_CHUNK = 64


def mlstm_chunkwise(q, k, v, log_i, log_f, c0, n0, m0, chunk: int = MLSTM_CHUNK):
    """q,k,v: (B,S,H,dh); log_i/log_f: (B,S,H) f32;
    states: c0 (B,H,dh,dh), n0 (B,H,dh), m0 (B,H).
    Returns (h (B,S,H,dh) f32, (c1, n1, m1))."""
    b, s, hh, dh = q.shape
    nc = s // chunk
    assert s % chunk == 0

    def resh(x):
        return (x.reshape(b, nc, chunk, hh, -1)
                .transpose(1, 0, 3, 2, 4).astype(jnp.float32))

    qc, kc, vc = resh(q), resh(k), resh(v)          # (nc,B,H,L,dh)
    gi = log_i.reshape(b, nc, chunk, hh).transpose(1, 0, 3, 2)
    gf = log_f.reshape(b, nc, chunk, hh).transpose(1, 0, 3, 2)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, xs):
        c0h, n0h, m0_ = carry
        qb, kb, vb, li, lf = xs
        g = jnp.cumsum(lf, axis=-1)                  # (B,H,L)
        a = li - g
        mc_run = jax.lax.cummax(a, axis=a.ndim - 1)
        m_t = jnp.maximum(m0_[..., None], mc_run)    # (B,H,L)
        # intra-chunk: D[t,s] = exp(a_s - M_t), s <= t  (all entries <= 1)
        d = jnp.where(mask, jnp.exp(a[:, :, None, :] - m_t[..., None]), 0.0)
        scores = jnp.einsum("bhtd,bhsd->bhts", qb, kb) * d
        num = jnp.einsum("bhts,bhsd->bhtd", scores, vb)
        den = scores.sum(axis=-1)                    # (B,H,L)
        # inter-chunk (initial state)
        w0 = jnp.exp(m0_[..., None] - m_t)           # (B,H,L)
        num = num + w0[..., None] * jnp.einsum("bhtk,bhvk->bhtv", qb, c0h)
        den = den + w0 * jnp.einsum("bhtk,bhk->bht", qb, n0h)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # state: read + write ONCE per chunk
        mcf = mc_run[..., -1]
        m1 = g[..., -1] + jnp.maximum(m0_, mcf)
        sc_old = jnp.exp(m0_ - jnp.maximum(m0_, mcf))
        w_s = jnp.exp(a - jnp.maximum(m0_, mcf)[..., None])   # (B,H,L)
        c1 = (sc_old[..., None, None] * c0h
              + jnp.einsum("bhsv,bhsk->bhvk", vb * w_s[..., None], kb))
        n1 = sc_old[..., None] * n0h + jnp.einsum("bhs,bhsk->bhk", w_s, kb)
        return (c1, n1, m1), h

    (c1, n1, m1), hs = jax.lax.scan(
        chunk_step, (c0, n0, m0), (qc, kc, vc, gi, gf))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, hh, dh)
    return h, (c1, n1, m1)
def mlstm_block_init(key, cfg, dtype):
    d = cfg.d_model
    di = 2 * d                       # xLSTM proj factor 2
    h = cfg.n_heads
    dh = di // h
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["up"], s["up"] = nn.dense_init(ks[0], d, 2 * di, dtype, ("embed", "rnn"))
    p["conv"], s["conv"] = conv1d_init(ks[1], di, dtype)
    s["conv"] = {"w": (None, "rnn"), "b": ("rnn",)}
    # block-diagonal (per-head) q/k/v projections as in the xLSTM paper
    for nm, kk in (("wq", ks[2]), ("wk", ks[3]), ("wv", ks[4])):
        p[nm] = {"w": nn.lecun_init(kk, (h, dh, dh), dtype, fan_in=dh)}
        s[nm] = {"w": ("heads", None, None)}
    p["wi"], s["wi"] = nn.dense_init(ks[5], di, h, dtype, ("rnn", None))
    p["wf"], s["wf"] = nn.dense_init(ks[6], di, h, dtype, ("rnn", None))
    p["down"], s["down"] = nn.dense_init(ks[7], di, d, dtype, ("rnn", "embed"))
    return p, s


def mlstm_state_init(cfg, batch: int, dtype):
    di = 2 * cfg.d_model
    h = cfg.n_heads
    dh = di // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, CONV_W - 1, di), dtype),
    }


def mlstm_block_apply(p, cfg, x, state: Optional[Dict] = None):
    b, sl, d = x.shape
    di = 2 * d
    hh = cfg.n_heads
    dh = di // hh
    up = nn.dense(p["up"], x)
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = conv1d_apply(p["conv"], xm, conv_state)
    xc = nn.silu(xc)
    # per-head (block-diagonal) projections are tiny (3 H dh^2); contracting
    # a model-sharded di against replicated weights would all-reduce a
    # (B,S,H,dh) f32 per projection per block (measured 3.65 TB/step on
    # train_4k — §Perf H5b).  Replicate the cell, keep TP on up/down.
    from ..dist.context import constrain
    xc = constrain(xc, "batch", None, None)
    xh = xc.reshape(b, sl, hh, dh)
    q = jnp.einsum("bshd,hde->bshe", xh, p["wq"]["w"])
    k = jnp.einsum("bshd,hde->bshe", xh, p["wk"]["w"]) * (dh ** -0.5)
    v = jnp.einsum("bshd,hde->bshe", xh, p["wv"]["w"])
    log_i = nn.dense(p["wi"], xc).astype(jnp.float32)          # (B,S,H)
    log_f = -jax.nn.softplus(-nn.dense(p["wf"], xc).astype(jnp.float32))

    if state is not None:
        c0, n0, m0 = state["C"], state["n"], state["m"]
    else:
        c0 = jnp.zeros((b, hh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, hh, dh), jnp.float32)
        m0 = jnp.full((b, hh), -1e30, jnp.float32)

    if sl % MLSTM_CHUNK == 0 and sl >= 2 * MLSTM_CHUNK:
        # chunkwise-parallel order (train/prefill): state HBM traffic /chunk
        h_cw, (c_f, n_f, m_f) = mlstm_chunkwise(
            q, k, v, log_i, log_f, c0, n0, m0)
        h_seq = h_cw.reshape(b, sl, di).astype(x.dtype)
        out = nn.dense(p["down"], h_seq * nn.silu(z))
        new_state = {"C": c_f, "n": n_f, "m": m_f, "conv": new_conv}
        return out, new_state

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, li, lf = inp                 # (B,H,dh) x3, (B,H) x2
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)[..., None]
        ip = jnp.exp(li - m_new)[..., None]
        kt32, vt32, qt32 = (t.astype(jnp.float32) for t in (kt, vt, qt))
        c = fp[..., None] * c + ip[..., None] * (vt32[..., :, None] * kt32[..., None, :])
        n = fp * n + ip * kt32
        num = jnp.einsum("bhvk,bhk->bhv", c, qt32)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt32)), 1.0)
        h_t = num / den[..., None]
        return (c, n, m_new), h_t

    seq = (
        q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        log_i.transpose(1, 0, 2), log_f.transpose(1, 0, 2),
    )
    (c_f, n_f, m_f), ys = time_scan(step, (c0, n0, m0), seq)
    h_seq = ys.transpose(1, 0, 2, 3).reshape(b, sl, di).astype(x.dtype)
    out = nn.dense(p["down"], h_seq * nn.silu(z))
    new_state = {"C": c_f, "n": n_f, "m": m_f, "conv": new_conv}
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar cell with hidden-state recurrence)
# ---------------------------------------------------------------------------
def slstm_block_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["wx"], s["wx"] = nn.dense_init(ks[0], d, 4 * d, dtype, ("embed", "rnn"))
    # block-diagonal (per-head) recurrent matrices, 4 gates
    p["r"] = nn.lecun_init(ks[1], (4, h, dh, dh), dtype, fan_in=dh)
    s["r"] = (None, "heads", None, None)
    p["out"], s["out"] = nn.dense_init(ks[2], d, d, dtype, ("rnn", "embed"))
    p["ffn"], s["ffn"] = nn.dense_init(ks[3], d, d, dtype, ("embed", "mlp"))
    return p, s


def slstm_state_init(cfg, batch: int, dtype):
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, dh), -1e30, jnp.float32)}


def slstm_block_apply(p, cfg, x, state: Optional[Dict] = None):
    from ..dist.context import constrain

    b, sl, d = x.shape
    hh, dh = cfg.n_heads, d // cfg.n_heads
    gx = nn.dense(p["wx"], x)
    # replicate the (small, d-wide) recurrent cell: a dh-sharded hidden state
    # would all-reduce the gate partials EVERY time step (mult 393k on
    # train_4k — §Perf H5b); TP stays on the in/out projections.
    gx = constrain(gx, "batch", None, None)
    gx = gx.reshape(b, sl, 4, hh, dh).astype(jnp.float32)

    if state is not None:
        c0, n0, h0, m0 = state["c"], state["n"], state["h"], state["m"]
    else:
        z = jnp.zeros((b, hh, dh), jnp.float32)
        c0, n0, h0 = z, z, z
        m0 = jnp.full((b, hh, dh), -1e30, jnp.float32)

    r = p["r"].astype(jnp.float32)

    def step(carry, g_t):
        c, n, h, m = carry
        rec = jnp.einsum("bhd,ghde->gbhe", h, r)          # (4,B,H,dh)
        zi = g_t[:, 0] + rec[0]
        zf = g_t[:, 1] + rec[1]
        zz = g_t[:, 2] + rec[2]
        zo = g_t[:, 3] + rec[3]
        log_f = -jax.nn.softplus(-zf)                     # log sigmoid
        m_new = jnp.maximum(log_f + m, zi)
        ip = jnp.exp(zi - m_new)
        fp = jnp.exp(log_f + m - m_new)
        c = fp * c + ip * jnp.tanh(zz)
        n = fp * n + ip
        h_new = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new

    (c_f, n_f, h_f, m_f), ys = time_scan(step, (c0, n0, h0, m0),
                                         gx.transpose(1, 0, 2, 3, 4))
    h_seq = ys.transpose(1, 0, 2, 3).reshape(b, sl, d).astype(x.dtype)
    y = nn.dense(p["out"], h_seq)
    y = y + nn.gelu(nn.dense(p["ffn"], y))
    new_state = {"c": c_f, "n": n_f, "h": h_f, "m": m_f}
    return y, new_state
