"""FFN substrate: dense gated FFNs and top-k routed Mixture-of-Experts.

The MoE dispatch is sort-based (argsort by expert, capacity-bounded grouped
matmul) — no O(T·E·C) one-hot dispatch tensors, shards cleanly under EP
("experts" -> model axis) or expert-TP ("mlp" -> model axis) depending on
divisibility.  Note the conceptual tie to the paper: routed experts are
*statically-skipped weight blocks* — the MoE analogue of the zero-skipping
schedule in kernels/deconv2d_sparse.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..dist.context import constrain, current
from . import nn


# ---------------------------------------------------------------------------
# Dense gated FFN
# ---------------------------------------------------------------------------
def ffn_init(key, d_model: int, d_ff: int, dtype, activation: str = "swiglu"):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["wu"], s["wu"] = nn.dense_init(ks[0], d_model, d_ff, dtype, ("embed", "mlp"))
    p["wd"], s["wd"] = nn.dense_init(ks[1], d_ff, d_model, dtype, ("mlp", "embed"))
    if activation in ("swiglu", "geglu"):
        p["wg"], s["wg"] = nn.dense_init(ks[2], d_model, d_ff, dtype, ("embed", "mlp"))
    return p, s


def ffn_apply(p: nn.Params, x: jax.Array, activation: str = "swiglu") -> jax.Array:
    if activation == "swiglu":
        h = nn.silu(nn.dense(p["wg"], x)) * nn.dense(p["wu"], x)
    elif activation == "geglu":
        h = nn.gelu(nn.dense(p["wg"], x)) * nn.dense(p["wu"], x)
    else:  # gelu
        h = nn.gelu(nn.dense(p["wu"], x))
    return nn.dense(p["wd"], h)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------
def moe_init(key, cfg, dtype):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["router"], s["router"] = nn.dense_init(
        ks[0], d, e, dtype, ("embed", None)
    )
    p["wg"] = nn.lecun_init(ks[1], (e, d, f), dtype, fan_in=d)
    p["wu"] = nn.lecun_init(ks[2], (e, d, f), dtype, fan_in=d)
    p["wd"] = nn.lecun_init(ks[3], (e, f, d), dtype, fan_in=f)
    s["wg"] = ("experts", "embed", "mlp")
    s["wu"] = ("experts", "embed", "mlp")
    s["wd"] = ("experts", "mlp", "embed")
    if cfg.n_shared_experts > 0:
        sf = cfg.n_shared_experts * cfg.expert_d_ff
        p["shared"], s["shared"] = ffn_init(ks[4], d, sf, dtype, "swiglu")
        p["shared_gate"], s["shared_gate"] = nn.dense_init(
            ks[5], d, 1, dtype, ("embed", None)
        )
    return p, s


def _dispatch_groups(t: int) -> int:
    """Shard-local dispatch groups: each group's scatter/gather stays on its
    own data shard (no replicate-and-all-reduce lowering).  32 covers the
    multi-pod DP degree; tiny token counts (tests) use a single group."""
    for g in (32, 16, 8, 4, 2):
        if t % g == 0 and t // g >= 64:
            return g
    return 1


def moe_apply(
    p: nn.Params, cfg, x: jax.Array, capacity_factor: float = 1.25
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), load-balancing aux loss scalar).

    Sort-based capacity dispatch performed independently per token group
    (group dim sharded over 'data'): scatters and gathers are shard-local;
    inter-shard traffic is only the expert weights (expert-TP) or the
    grouped activations entering EP expert shards."""
    b, sl, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    t = b * sl
    xf = x.reshape(t, d)

    logits = (xf @ p["router"]["w"]).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                  # (T, k)
    if cfg.moe_norm_topk:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    g = _dispatch_groups(t)
    tg = t // g
    cap = int(max(1, round(tg * k / e * capacity_factor)))
    xg = xf.reshape(g, tg, d)
    xg = constrain(xg, "moe_group", None, None)

    flat_e = top_e.reshape(g, tg * k)
    sort_idx = jnp.argsort(flat_e, axis=1)                  # (G, Tg*k)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    counts = jax.vmap(lambda f: jnp.bincount(f, length=e))(flat_e)  # (G, E)
    offsets = jnp.cumsum(counts, axis=1) - counts           # (G, E)
    pos_in_e = (jnp.arange(tg * k)[None, :]
                - jnp.take_along_axis(offsets, sorted_e, axis=1))
    keep = pos_in_e < cap
    pos_safe = jnp.where(keep, pos_in_e, cap)               # cap = OOB drop
    src_tok = sort_idx // k                                 # (G, Tg*k)

    # Shard-local scatter/gather: XLA's scatter partitioner replicates the
    # (G, Tg*k, D) intermediates under pjit auto-sharding; shard_map over the
    # group axis makes the dispatch provably local to each data shard.
    mesh, rules = current()
    dp_axis = (rules or {}).get("moe_group")
    use_sm = (mesh is not None and dp_axis in getattr(mesh, "shape", {})
              and g % mesh.shape[dp_axis] == 0)

    def _scatter_local(xg_l, se_l, ps_l, st_l):
        gl = xg_l.shape[0]
        gi = jnp.arange(gl)[:, None]
        upd = jnp.take_along_axis(xg_l, st_l[..., None], axis=1)
        hb = jnp.zeros((gl, e, cap, d), xg_l.dtype)
        return hb.at[gi, se_l, ps_l].set(upd, mode="drop")

    if use_sm:
        hbuf = shard_map(
            _scatter_local, mesh=mesh,
            in_specs=(P(dp_axis), P(dp_axis), P(dp_axis), P(dp_axis)),
            out_specs=P(dp_axis), check_rep=False,
        )(xg, sorted_e, pos_safe, src_tok)
    else:
        hbuf = _scatter_local(xg, sorted_e, pos_safe, src_tok)
    hbuf = constrain(hbuf, "moe_group", "experts", None, None)

    # ---- grouped expert FFN (SwiGLU) --------------------------------------
    hg = jnp.einsum("gecd,edf->gecf", hbuf, p["wg"])
    hu = jnp.einsum("gecd,edf->gecf", hbuf, p["wu"])
    hh = nn.silu(hg) * hu
    hh = constrain(hh, "moe_group", "experts", None, "mlp")
    out_e = jnp.einsum("gecf,efd->gecd", hh, p["wd"])
    out_e = constrain(out_e, "moe_group", "experts", None, None)

    # ---- combine -----------------------------------------------------------
    w_sorted = jnp.take_along_axis(
        top_p.reshape(g, tg * k), sort_idx, axis=1).astype(x.dtype)

    def _combine_local(oe_l, se_l, ps_l, st_l, ws_l):
        gl = oe_l.shape[0]
        gi = jnp.arange(gl)[:, None]
        gat = oe_l.at[gi, se_l, ps_l].get(mode="fill", fill_value=0)
        yl = jnp.zeros((gl, tg, d), jnp.float32)
        return yl.at[gi, st_l].add(
            (gat * ws_l[..., None]).astype(jnp.float32))

    if use_sm:
        y = shard_map(
            _combine_local, mesh=mesh,
            in_specs=(P(dp_axis),) * 5,
            out_specs=P(dp_axis), check_rep=False,
        )(out_e, sorted_e, pos_safe, src_tok, w_sorted)
    else:
        y = _combine_local(out_e, sorted_e, pos_safe, src_tok, w_sorted)
    y = y.reshape(t, d).astype(x.dtype)

    # ---- shared experts (always-on) ----------------------------------------
    if cfg.n_shared_experts > 0:
        gate = jax.nn.sigmoid(xf @ p["shared_gate"]["w"]).astype(x.dtype)
        y = y + gate * ffn_apply(p["shared"], xf, "swiglu")

    # ---- switch-style load-balance loss ------------------------------------
    frac = counts.sum(0).astype(jnp.float32) / jnp.maximum(counts.sum(), 1)
    mean_prob = probs.mean(axis=0)
    aux = e * jnp.sum(frac * mean_prob)
    return y.reshape(b, sl, d), aux
