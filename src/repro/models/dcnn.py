"""The paper's DCNN architectures (Fig. 4): WGAN-GP generators for MNIST and
CelebA plus mirrored CNN critics.

The generator's deconvolution layers run through a selectable backend:
  * "reverse_loop" — the paper's algorithm, phase-decomposed pure JAX
                     (differentiable; used for training),
  * "pallas"       — the reverse-loop Pallas TPU kernel (inference),
  * "pallas_sparse"— the static zero-skipping kernel (pruned inference),
  * "xla"          — conventional zero-insertion conv_transpose (the
                     GPU-style baseline of Table II).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.deconv import deconv2d_reverse_loop, deconv2d_zero_insertion
from ..core.tiling import DeconvGeometry
from ..dist.context import constrain
from . import nn


@dataclasses.dataclass(frozen=True)
class DeconvLayerCfg:
    c_in: int
    c_out: int
    kernel: int
    stride: int
    padding: int
    activation: str  # relu | tanh


@dataclasses.dataclass(frozen=True)
class DcnnConfig:
    """A deconv tower: input root -> stacked deconv layers -> image.

    The original two networks are latent-rooted WGAN generators (input
    is a flat ``(z_dim,)`` vector reshaped to a 1x1 spatial root), but
    the tower itself is workload-agnostic: ``in_hw > 1`` declares an
    *image-rooted* tower (super-resolution heads, denoising decoders)
    whose input is ``(in_hw, in_hw, in_c)`` with ``in_c ==
    layers[0].c_in``.  Every consumer of the config — kernels, plans,
    quantization, serving — keys off `input_shape`/`geometries()`, so
    the two roots share one execution surface (see `repro.workloads`).
    """

    name: str
    z_dim: int
    img_hw: int
    img_c: int
    layers: Tuple[DeconvLayerCfg, ...]
    dtype: str = "float32"
    in_hw: int = 1

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def in_c(self) -> int:
        """Input channel count of the tower root (== layers[0].c_in)."""
        return self.layers[0].c_in

    @property
    def is_latent(self) -> bool:
        """True for the WGAN-style 1x1 latent root (flat z input)."""
        return self.in_hw == 1

    @property
    def input_shape(self) -> Tuple[int, ...]:
        """Per-example input shape: ``(z_dim,)`` for latent towers,
        ``(in_hw, in_hw, in_c)`` for image-rooted towers."""
        if self.is_latent:
            return (self.z_dim,)
        return (self.in_hw, self.in_hw, self.in_c)

    def geometries(self) -> List[DeconvGeometry]:
        h = w = self.in_hw
        out = []
        for l in self.layers:
            g = DeconvGeometry(h, w, l.c_in, l.c_out, l.kernel, l.stride, l.padding)
            out.append(g)
            h, w = g.out_h, g.out_w
        return out


MNIST_DCNN = DcnnConfig(
    name="dcnn-mnist",
    z_dim=100,
    img_hw=28,
    img_c=1,
    layers=(
        DeconvLayerCfg(100, 256, 7, 1, 0, "relu"),   # 1x1 -> 7x7
        DeconvLayerCfg(256, 128, 4, 2, 1, "relu"),   # 7x7 -> 14x14
        DeconvLayerCfg(128, 1, 4, 2, 1, "tanh"),     # 14x14 -> 28x28
    ),
)

CELEBA_DCNN = DcnnConfig(
    name="dcnn-celeba",
    z_dim=100,
    img_hw=64,
    img_c=3,
    layers=(
        DeconvLayerCfg(100, 1024, 4, 1, 0, "relu"),  # 1x1 -> 4x4
        DeconvLayerCfg(1024, 512, 4, 2, 1, "relu"),  # 4x4 -> 8x8
        DeconvLayerCfg(512, 256, 4, 2, 1, "relu"),   # 8x8 -> 16x16
        DeconvLayerCfg(256, 128, 4, 2, 1, "relu"),   # 16x16 -> 32x32
        DeconvLayerCfg(128, 3, 4, 2, 1, "tanh"),     # 32x32 -> 64x64
    ),
)


# ---------------------------------------------------------------------------
# Generator
# ---------------------------------------------------------------------------
def tower_input(cfg: DcnnConfig, x: jax.Array) -> jax.Array:
    """Canonicalize a tower input to the 4D root ``(B, in_hw, in_hw,
    in_c)``.

    Latent towers take flat ``(B, z_dim)`` latents (reshaped onto the
    1x1 spatial root, the WGAN convention); image-rooted towers take
    ``(B, in_hw, in_hw, in_c)`` images directly.  A shape that matches
    neither is a workload mix-up (e.g. latents submitted to an SR head)
    and fails loudly instead of reshaping into silently wrong images."""
    expect = (cfg.in_hw, cfg.in_hw, cfg.in_c)
    if cfg.is_latent and x.ndim == 2 and x.shape[1] == cfg.z_dim:
        return x.reshape(x.shape[0], 1, 1, cfg.z_dim)
    if x.ndim == 4 and tuple(x.shape[1:]) == expect:
        return x
    want = (f"(B, {cfg.z_dim})" if cfg.is_latent
            else f"(B, {expect[0]}, {expect[1]}, {expect[2]})")
    raise ValueError(
        f"{cfg.name} expects input rows shaped {want}; got {x.shape}")


def generator_init(key, cfg: DcnnConfig):
    ks = jax.random.split(key, len(cfg.layers))
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    for i, (k, l) in enumerate(zip(ks, cfg.layers)):
        kw, kb = jax.random.split(k)
        fan_in = l.c_in * l.kernel * l.kernel
        p[f"l{i}"] = {
            "w": nn.lecun_init(kw, (l.kernel, l.kernel, l.c_in, l.c_out),
                               cfg.jdtype, fan_in=fan_in),
            "b": jnp.zeros((l.c_out,), cfg.jdtype),
        }
        s[f"l{i}"] = {"w": (None, None, "cin", "cout"), "b": ("cout",)}
    return p, s


def _tile_kwargs(t) -> Dict[str, int]:
    """A tile override is a square extent (int) or a full autotuner
    TileChoice (kernels.autotune) carrying all four tile factors."""
    if t is None:
        return {}
    if isinstance(t, int):
        return {"t_oh": t, "t_ow": t}
    return t.as_kwargs()


def generator_apply(
    p, cfg: DcnnConfig, z: jax.Array, backend: str = "reverse_loop",
    tile_overrides: Optional[Dict[int, Any]] = None,
    sparse_plans: Optional[Dict[int, Any]] = None,
    return_intermediates: bool = False,
    plan=None,
):
    """z: (B, z_dim) latents — or (B, in_hw, in_hw, in_c) images for an
    image-rooted tower — -> images (B, H, W, C) in [-1, 1].

    ``plan`` is a `repro.plan.NetworkPlan` (fp32 precision): the backend,
    per-layer tiles, fused epilogues and zero-skip schedules all come
    pinned from the plan — the preferred serving path (int8 plans run
    through `quant.infer.quantized_generator_apply` instead).  Without a
    plan, ``backend`` selects the formulation, ``tile_overrides`` maps
    layer index -> TileChoice / square extent, and ``sparse_plans`` maps
    layer index -> precomputed `make_sparse_plan` result for
    backend="pallas_sparse" (see serve.DcnnServeEngine).

    On the pallas backends each layer's bias + activation run fused in the
    kernel's flush phase, so the chain never materializes a pre-activation
    layer in HBM; the other backends apply the activation separately.
    ``return_intermediates=True`` additionally returns the list of
    per-layer *inputs* (the tensors quantization calibrates against —
    see quant.calibrate): ``(images, [x_0, ..., x_{L-1}])``.
    """
    if plan is not None:
        if plan.precision != "fp32":
            raise ValueError(
                f"generator_apply executes fp32 plans; a {plan.precision!r} "
                "plan runs through quant.infer.quantized_generator_apply")
        plan.validate_for(cfg)
        backend = plan.backend
    x = tower_input(cfg, z).astype(cfg.jdtype)
    x = constrain(x, "batch", None, None, None)
    inters = []
    for i, l in enumerate(cfg.layers):
        if return_intermediates:
            inters.append(x)
        w, b = p[f"l{i}"]["w"], p[f"l{i}"]["b"]
        lp = plan.layers[i] if plan is not None else None
        fused = backend in ("pallas", "pallas_sparse")
        if backend == "reverse_loop":
            x = deconv2d_reverse_loop(x, w, b, l.stride, l.padding)
        elif backend == "xla":
            x = deconv2d_zero_insertion(x, w, b, l.stride, l.padding)
        elif backend == "pallas":
            from ..kernels.deconv2d import deconv2d
            from ..kernels.deconv2d.ops import suppress_tile_warnings
            if lp is not None:
                x = deconv2d(x, w, b, plan=lp)
            else:
                # supported legacy override surface: the expansion into
                # tile kwargs is ours, not the user's — don't warn
                with suppress_tile_warnings():
                    x = deconv2d(
                        x, w, b, l.stride, l.padding,
                        activation=l.activation,
                        **_tile_kwargs((tile_overrides or {}).get(i)))
        elif backend == "pallas_sparse":
            from ..kernels.deconv2d.ops import suppress_tile_warnings
            from ..kernels.deconv2d_sparse import deconv2d_sparse
            if lp is not None:
                x = deconv2d_sparse(x, w, b, plan=lp)
            else:
                with suppress_tile_warnings():
                    x = deconv2d_sparse(
                        x, w, b, l.stride, l.padding,
                        activation=l.activation,
                        plan=(sparse_plans or {}).get(i),
                        **_tile_kwargs((tile_overrides or {}).get(i)))
        else:
            raise ValueError(backend)
        if not fused:
            x = jnp.tanh(x) if l.activation == "tanh" else jax.nn.relu(x)
        x = constrain(x, "batch", None, None, None)
    if return_intermediates:
        return x, inters
    return x


def make_fused_generator(
    cfg: DcnnConfig,
    tiles: Optional[Dict[int, Any]] = None,
    fwd_backend: str = "pallas",
    bwd_backend: str = "reverse_loop",
    plan=None,
):
    """Differentiable generator whose *primal* runs the batch-fused Pallas
    serving kernels and whose *cotangent* runs through the reverse-loop
    formulation's VJP.

    The two backends compute the same function (pinned by the backend
    parity tests), so the gradient is consistent with the forward up to
    kernel-level float reassociation — which lets the WGAN training step
    fill the MXU exactly the way serving does (``tiles`` carries the
    autotuned per-layer batch tile ``t_n``) while staying trainable.  The
    backward pass rematerializes the reverse-loop forward (one extra
    forward per VJP; nothing from the Pallas residuals is reused).

    ``plan`` is a `repro.plan.NetworkPlan`: the primal's backend and
    per-layer tiles (incl. ``t_n``) come pinned from it instead of the
    ``tiles``/``fwd_backend`` pair.

    ``pallas_sparse`` is deliberately rejected: its zero-skip schedule is
    compiled against *frozen* weights, which training mutates every step.
    """
    if plan is not None:
        fwd_backend = plan.backend
        tiles = plan.tile_overrides()
    if fwd_backend == "pallas_sparse":
        raise ValueError(
            "pallas_sparse is inference-only: the static zero-skip plan is "
            "derived from frozen weights, which training updates each step")

    @jax.custom_vjp
    def apply(p, z):
        return generator_apply(p, cfg, z, backend=fwd_backend,
                               tile_overrides=tiles, plan=plan)

    def fwd(p, z):
        return apply(p, z), (p, z)

    def bwd(res, ct):
        p, z = res
        _, vjp = jax.vjp(
            lambda p_, z_: generator_apply(p_, cfg, z_, backend=bwd_backend),
            p, z)
        return vjp(ct)

    apply.defvjp(fwd, bwd)
    return apply


# ---------------------------------------------------------------------------
# Critic (WGAN-GP discriminator: strided convs, LeakyReLU, no norm)
# ---------------------------------------------------------------------------
def critic_init(key, cfg: DcnnConfig):
    chans = [cfg.img_c] + [64 * (2 ** i) for i in range(len(cfg.layers) - 1)]
    ks = jax.random.split(key, len(chans))
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    hw = cfg.img_hw
    for i in range(len(chans) - 1):
        kw, _ = jax.random.split(ks[i])
        fan_in = chans[i] * 16
        p[f"c{i}"] = {
            "w": nn.lecun_init(kw, (4, 4, chans[i], chans[i + 1]), cfg.jdtype,
                               fan_in=fan_in),
            "b": jnp.zeros((chans[i + 1],), cfg.jdtype),
        }
        s[f"c{i}"] = {"w": (None, None, "cin", "cout"), "b": ("cout",)}
        hw = hw // 2
    d_flat = hw * hw * chans[-1]
    p["head"], s["head"] = nn.dense_init(ks[-1], d_flat, 1, cfg.jdtype,
                                         (None, None), bias=True)
    return p, s


def critic_apply(p, cfg: DcnnConfig, x: jax.Array) -> jax.Array:
    n_conv = len([k for k in p if k.startswith("c")])
    for i in range(n_conv):
        x = jax.lax.conv_general_dilated(
            x, p[f"c{i}"]["w"], (2, 2), ((1, 1), (1, 1)),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + p[f"c{i}"]["b"]
        x = jax.nn.leaky_relu(x, 0.2)
        x = constrain(x, "batch", None, None, None)
    x = x.reshape(x.shape[0], -1)
    return nn.dense(p["head"], x)[:, 0]
