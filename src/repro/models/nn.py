"""Minimal functional NN substrate (no external framework).

Params are nested dicts of jnp arrays.  Every layer is a pair of functions
(`init` returning params + logical-axis specs, `apply` pure).  Logical axis
names are consumed by `repro.dist.sharding` to build NamedShardings.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Specs = Dict[str, Any]  # mirrors Params; leaves are tuples of logical axes


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def normal_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def lecun_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan, 1))
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def zeros_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------
def dense_init(
    key, d_in: int, d_out: int, dtype,
    axes: Tuple[Optional[str], Optional[str]] = ("embed", "mlp"),
    bias: bool = False,
) -> Tuple[Params, Specs]:
    kw, kb = jax.random.split(key)
    p: Params = {"w": lecun_init(kw, (d_in, d_out), dtype)}
    s: Specs = {"w": axes}
    if bias:
        p["b"] = zeros_init(kb, (d_out,), dtype)
        s["b"] = (axes[1],)
    return p, s


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_init(key, d: int, dtype) -> Tuple[Params, Specs]:
    return {"scale": ones_init(key, (d,), dtype)}, {"scale": ("embed",)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(key, d: int, dtype) -> Tuple[Params, Specs]:
    return (
        {"scale": ones_init(key, (d,), dtype), "bias": zeros_init(key, (d,), dtype)},
        {"scale": ("embed",), "bias": ("embed",)},
    )


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
def embedding_init(key, vocab: int, d: int, dtype) -> Tuple[Params, Specs]:
    return (
        {"table": normal_init(key, (vocab, d), dtype)},
        {"table": ("vocab", "embed")},
    )


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["table"].T


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------
def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# pytree utilities
# ---------------------------------------------------------------------------
def tree_size(params) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))


def tree_bytes(params) -> int:
    return sum(p.size * p.dtype.itemsize for p in jax.tree_util.tree_leaves(params))


def stack_trees(trees: Sequence[Params]) -> Params:
    """Stack a list of identical pytrees along a new leading 'layers' axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stack_specs(spec: Specs) -> Specs:
    """Prefix every leaf spec with the (never-sharded) 'layers' axis."""
    return jax.tree_util.tree_map(
        lambda s: ("layers",) + tuple(s),
        spec,
        is_leaf=lambda s: isinstance(s, tuple),
    )
