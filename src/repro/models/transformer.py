"""Unified LM: one scan-over-layers decoder covering all 10 assigned
architectures (dense GQA, MoE, local/global alternation, softcaps, M-RoPE,
Griffin hybrid, xLSTM) plus modality-frontend stubs (vision/audio).

Layers are grouped into repeat *units* (the arch's block pattern); parameters
are stacked across units and the stack is traversed with `lax.scan`, so HLO
size and compile time are depth-independent — required for the 512-device
dry-runs and standard practice at scale.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.context import constrain
from . import nn
from .attention import attention_apply, attention_init, init_kv_cache
from .ffn import ffn_apply, ffn_init, moe_apply, moe_init
from .recurrent import (
    griffin_block_apply, griffin_block_init, griffin_state_init,
    mlstm_block_apply, mlstm_block_init, mlstm_state_init,
    slstm_block_apply, slstm_block_init, slstm_state_init,
)

ATTN_KINDS = ("global", "local")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    block_pattern: Tuple[str, ...] = ("global",)
    activation: str = "swiglu"
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    rope: str = "standard"           # standard | 2d | mrope | none
    rope_theta: float = 10000.0
    rotary_frac: float = 1.0
    mrope_sections: Optional[Tuple[int, int, int]] = None
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    attn_scale: Optional[float] = None
    local_window: int = 4096
    qkv_bias: bool = False
    embed_scale: bool = False
    # MoE
    n_experts: int = 0
    moe_top_k: int = 2
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    moe_norm_topk: bool = True
    moe_capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # recurrent
    rnn_width: int = 0
    # modality frontend stub
    frontend: Optional[str] = None   # vision | audio
    frontend_len: int = 0
    frontend_dim: int = 0
    # execution
    dtype: str = "bfloat16"
    attn_block_q: int = 512
    attn_block_k: int = 512
    remat: bool = True
    # int8 KV cache (per-token-per-head symmetric scales): halves decode
    # cache HBM — beyond-paper optimization, see EXPERIMENTS.md §Perf
    kv_quant: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def n_rem(self) -> int:
        return self.n_layers % len(self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs: every block is recurrent or windowed."""
        return all(k in ("griffin", "mlstm", "slstm", "local")
                   for k in self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        d, dh = self.d_model, self.head_dim
        n_attn = sum(1 for k in self.block_pattern if k in ATTN_KINDS)
        n_grif = sum(1 for k in self.block_pattern if k == "griffin")
        n_ml = sum(1 for k in self.block_pattern if k == "mlstm")
        n_sl = sum(1 for k in self.block_pattern if k == "slstm")
        per_unit = 0
        per_unit += n_attn * (d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh
                              + self.n_heads * dh * d)
        if self.n_experts:
            per_unit += n_attn * (d * self.n_experts
                                  + 3 * self.n_experts * d * self.expert_d_ff)
            if self.n_shared_experts:
                per_unit += n_attn * 3 * d * self.n_shared_experts * self.expert_d_ff
        else:
            mult = 3 if self.activation in ("swiglu", "geglu") else 2
            per_unit += n_attn * mult * d * self.d_ff
        dr = self.rnn_width or d
        per_unit += n_grif * (2 * d * dr + 2 * dr * dr + dr * d
                              + 3 * d * self.d_ff)
        di = 2 * d
        per_unit += n_ml * (d * 2 * di + 3 * di * (di // self.n_heads)
                            + di * d)
        per_unit += n_sl * (4 * d * d + 4 * d * (d // self.n_heads) + 2 * d * d)
        total = self.n_units * per_unit
        if self.n_rem:
            total += per_unit * self.n_rem // max(len(self.block_pattern), 1)
        total += self.vocab_size * d  # tied embeddings
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        routed_all = 3 * self.n_experts * d * self.expert_d_ff
        routed_act = 3 * self.moe_top_k * d * self.expert_d_ff
        n_attn_layers = sum(1 for k in self.block_pattern if k in ATTN_KINDS)
        n_moe = self.n_units * n_attn_layers + self.n_rem
        return self.param_count() - n_moe * (routed_all - routed_act)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _norm_init(key, cfg):
    if cfg.norm == "layernorm":
        return nn.layernorm_init(key, cfg.d_model, cfg.jdtype)
    return nn.rmsnorm_init(key, cfg.d_model, cfg.jdtype)


def _norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return nn.layernorm(p, x, cfg.norm_eps)
    return nn.rmsnorm(p, x, cfg.norm_eps)


def init_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["norm1"], s["norm1"] = _norm_init(ks[0], cfg)
    if kind in ATTN_KINDS:
        p["attn"], s["attn"] = attention_init(ks[1], cfg, cfg.jdtype, kind)
        p["norm2"], s["norm2"] = _norm_init(ks[2], cfg)
        if cfg.n_experts:
            p["moe"], s["moe"] = moe_init(ks[3], cfg, cfg.jdtype)
        else:
            p["ffn"], s["ffn"] = ffn_init(ks[3], cfg.d_model, cfg.d_ff,
                                          cfg.jdtype, cfg.activation)
    elif kind == "griffin":
        p["mixer"], s["mixer"] = griffin_block_init(ks[1], cfg, cfg.jdtype)
        p["norm2"], s["norm2"] = _norm_init(ks[2], cfg)
        p["ffn"], s["ffn"] = ffn_init(ks[3], cfg.d_model, cfg.d_ff,
                                      cfg.jdtype, cfg.activation)
    elif kind == "mlstm":
        p["mixer"], s["mixer"] = mlstm_block_init(ks[1], cfg, cfg.jdtype)
    elif kind == "slstm":
        p["mixer"], s["mixer"] = slstm_block_init(ks[1], cfg, cfg.jdtype)
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p, s


def apply_block(p, cfg: ModelConfig, kind: str, x, positions, mode,
                cache, cache_pos):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, p["norm1"], x)
    if kind in ATTN_KINDS:
        if mode == "train":
            out, new_cache = attention_apply(p["attn"], cfg, h, positions, kind)
        elif mode == "prefill":
            out, _ = attention_apply(p["attn"], cfg, h, positions, kind)
            new_cache = _fill_cache(cfg, cache, h, p, positions, kind)
        else:  # decode
            out, new_cache = attention_apply(
                p["attn"], cfg, h, positions, kind, cache, cache_pos
            )
        x = x + out
        h2 = _norm(cfg, p["norm2"], x)
        if cfg.n_experts:
            y, aux = moe_apply(p["moe"], cfg, h2,
                               capacity_factor=cfg.moe_capacity_factor)
        else:
            y = ffn_apply(p["ffn"], h2, cfg.activation)
        x = x + y
    elif kind == "griffin":
        out, new_cache = griffin_block_apply(
            p["mixer"], cfg, h, cache if mode == "decode" else None
        )
        if mode == "train":
            new_cache = None
        x = x + out
        h2 = _norm(cfg, p["norm2"], x)
        x = x + ffn_apply(p["ffn"], h2, cfg.activation)
    elif kind in ("mlstm", "slstm"):
        fn = mlstm_block_apply if kind == "mlstm" else slstm_block_apply
        out, new_cache = fn(p["mixer"], cfg, h,
                            cache if mode == "decode" else None)
        if mode == "train":
            new_cache = None
        x = x + out
    return x, new_cache, aux


def _fill_cache(cfg, cache, h, p, positions, kind):
    """Prefill: recompute k/v once more into the cache buffers (cheap linear
    projections; avoids threading k/v out of attention_apply)."""
    from .attention import apply_rope

    b, sl, _ = h.shape
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (h @ p["attn"]["wk"]["w"]).reshape(b, sl, hkv, dh)
    v = (h @ p["attn"]["wv"]["w"]).reshape(b, sl, hkv, dh)
    if "b" in p["attn"]["wk"]:
        k = k + p["attn"]["wk"]["b"].reshape(1, 1, hkv, dh)
        v = v + p["attn"]["wv"]["b"].reshape(1, 1, hkv, dh)
    if cfg.rope != "none":
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       rotary_frac=cfg.rotary_frac,
                       mrope_sections=cfg.mrope_sections)
    scales = {}
    if cfg.kv_quant:
        from .attention import quantize_kv
        k, ks = quantize_kv(k)
        v, vs = quantize_kv(v)
    size = cache["k"].shape[1]
    if sl >= size:
        ck = k[:, -size:]
        cv = v[:, -size:]
        spos = jnp.arange(sl - size, sl, dtype=jnp.int32)
        if cfg.kv_quant:
            scales = {"k_scale": ks[:, -size:], "v_scale": vs[:, -size:]}
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
        spos = jnp.where(jnp.arange(size) < sl,
                         jnp.arange(size, dtype=jnp.int32), -1)
        if cfg.kv_quant:
            scales = {
                "k_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_scale"], ks, 0, axis=1),
                "v_scale": jax.lax.dynamic_update_slice_in_dim(
                    cache["v_scale"], vs, 0, axis=1),
            }
    return {"k": ck, "v": cv, "slot_pos": spos, **scales}


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ATTN_KINDS:
        return init_kv_cache(cfg, batch, max_len, kind, cfg.jdtype)
    if kind == "griffin":
        return griffin_state_init(cfg, batch, cfg.jdtype)
    if kind == "mlstm":
        return mlstm_state_init(cfg, batch, cfg.jdtype)
    if kind == "slstm":
        return slstm_state_init(cfg, batch, cfg.jdtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def init_lm(key, cfg: ModelConfig):
    """Returns (params, specs) with unit-stacked block params."""
    ks = jax.random.split(key, cfg.n_units + cfg.n_rem + 3)
    pattern = cfg.block_pattern

    def init_unit(k):
        kk = jax.random.split(k, len(pattern))
        up, us = {}, {}
        for i, kind in enumerate(pattern):
            up[f"b{i}"], us[f"b{i}"] = init_block(kk[i], cfg, kind)
        return up, us

    units = [init_unit(ks[i]) for i in range(cfg.n_units)]
    unit_params = nn.stack_trees([u[0] for u in units])
    unit_specs = nn.stack_specs(units[0][1])

    params: Dict[str, Any] = {"units": unit_params}
    specs: Dict[str, Any] = {"units": unit_specs}

    if cfg.n_rem:
        rem, rem_s = {}, {}
        for i in range(cfg.n_rem):
            kind = pattern[i]
            rem[f"b{i}"], rem_s[f"b{i}"] = init_block(ks[cfg.n_units + i], cfg, kind)
        params["rem"] = rem
        specs["rem"] = rem_s

    params["embed"], specs["embed"] = nn.embedding_init(
        ks[-3], cfg.vocab_size, cfg.d_model, cfg.jdtype
    )
    params["final_norm"], specs["final_norm"] = _norm_init(ks[-2], cfg)
    if cfg.frontend is not None:
        params["frontend_proj"], specs["frontend_proj"] = nn.dense_init(
            ks[-1], cfg.frontend_dim, cfg.d_model, cfg.jdtype, (None, "embed")
        )
    return params, specs


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Serving cache pytree: unit-stacked block caches + remainder + pos."""
    pattern = cfg.block_pattern

    def one_unit():
        return {f"b{i}": init_block_cache(cfg, kind, batch, max_len)
                for i, kind in enumerate(pattern)}

    units = nn.stack_trees([one_unit() for _ in range(cfg.n_units)])
    cache = {"units": units, "pos": jnp.zeros((), jnp.int32)}
    if cfg.n_rem:
        cache["rem"] = {f"b{i}": init_block_cache(cfg, pattern[i], batch, max_len)
                        for i in range(cfg.n_rem)}
    return cache


def default_positions(cfg: ModelConfig, batch: int, start, length: int):
    """Position ids; (3, B, S) for M-RoPE (text: t=h=w)."""
    pos = start + jnp.arange(length, dtype=jnp.int32)
    pos = jnp.broadcast_to(pos, (batch, length))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos, (3, batch, length))
    return pos


def apply_lm(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,                    # (B, S_tok) int32
    frontend_embeds: Optional[jax.Array] = None,  # (B, L_f, frontend_dim)
    mode: str = "train",
    cache: Optional[Dict] = None,
    positions: Optional[jax.Array] = None,
):
    """Returns (logits (B, S_total, V), new_cache, aux_loss)."""
    b = tokens.shape[0]
    x = nn.embed(params["embed"], tokens).astype(cfg.jdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.jdtype)
    if frontend_embeds is not None:
        fe = nn.dense(params["frontend_proj"], frontend_embeds.astype(cfg.jdtype))
        x = jnp.concatenate([fe, x], axis=1)
    s_total = x.shape[1]

    cache_pos = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    if positions is None:
        start = cache_pos if mode == "decode" else 0
        positions = default_positions(cfg, b, start, s_total)

    pattern = cfg.block_pattern

    def unit_fn(x, unit_p, unit_c):
        aux = jnp.zeros((), jnp.float32)
        new_c = {}
        x = constrain(x, "batch", None, None)
        for i, kind in enumerate(pattern):
            c_i = unit_c[f"b{i}"] if unit_c is not None else None
            x, nc, a = apply_block(unit_p[f"b{i}"], cfg, kind, x, positions,
                                   mode, c_i, cache_pos)
            aux = aux + a
            if nc is not None:
                new_c[f"b{i}"] = nc
        return x, (new_c if new_c else None), aux

    unit_callable = unit_fn
    if cfg.remat and mode == "train":
        unit_callable = jax.checkpoint(
            unit_fn, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(),
        )

    if mode == "train":
        def scan_body(carry, unit_p):
            x, aux = carry
            x, _, a = unit_callable(x, unit_p, None)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                                   params["units"])
        new_cache = None
    else:
        def scan_body(carry, xs):
            x, aux = carry
            unit_p, unit_c = xs
            x, new_c, a = unit_fn(x, unit_p, unit_c)
            return (x, aux + a), new_c

        (x, aux), new_units = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)),
            (params["units"], cache["units"]),
        )
        new_cache = {"units": new_units,
                     "pos": cache_pos + (s_total if mode != "train" else 0)}

    if cfg.n_rem:
        new_rem = {}
        for i in range(cfg.n_rem):
            kind = pattern[i]
            c_i = cache["rem"][f"b{i}"] if cache is not None else None
            x, nc, a = apply_block(params["rem"][f"b{i}"], cfg, kind, x,
                                   positions, mode, c_i, cache_pos)
            aux = aux + a
            if nc is not None:
                new_rem[f"b{i}"] = nc
        if new_cache is not None:
            new_cache["rem"] = new_rem

    x = _norm(cfg, params["final_norm"], x)
    logits = nn.unembed(params["embed"], x)
    # keep giant logits sharded on vocab (model axis) end-to-end
    logits = constrain(logits, "batch", None, "vocab")
    logits = nn.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    logits = constrain(logits, "batch", None, "vocab")
    return logits, new_cache, aux
