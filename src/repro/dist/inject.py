"""Deterministic fault injection for the serving stack.

A `FaultInjector` is a scripted schedule over *bucket dispatches*: the
serving engine calls `before_call` immediately before every generator
dispatch (warmup calls excluded), and whatever is scripted for that
global call index fires — a sleep (`SlowCall`, a straggler the
`StragglerMonitor` should flag), a raised `TransientCallError`
(retryable: the engine backs off and re-dispatches), or a raised
`DeviceLossError` (not retryable: the engine shrinks onto the surviving
device prefix via an elastic remesh and re-runs the interrupted work).

Everything is counted, not timed, so a fault sequence replays
identically across runs and across the fake-device meshes the dist
tests force — the property that turns "lose half the devices at call k"
from a flake into an assertable scenario.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence, Tuple


class FaultError(RuntimeError):
    """Base class for injected (or detected) serving-call faults."""


class TransientCallError(FaultError):
    """A retryable per-call failure — the moral equivalent of a dropped
    RPC or a preempted dispatch.  The engine retries with backoff."""


class DeviceLossError(FaultError):
    """``keep`` devices survive (the leading prefix of the mesh's device
    list); the rest are gone.  The engine answers with an elastic
    remesh, not a retry — the failed dispatch re-runs on the shrunken
    mesh."""

    def __init__(self, keep: int, message: str = ""):
        super().__init__(
            message or f"device loss: {keep} device(s) survive")
        self.keep = keep


@dataclasses.dataclass(frozen=True)
class SlowCall:
    """Delay call ``at_call`` by ``delay_s`` — a straggler, not an error."""
    at_call: int
    delay_s: float


@dataclasses.dataclass(frozen=True)
class TransientFailure:
    """Fail call ``at_call`` with `TransientCallError` (fires once; the
    retry is a new call index, so consecutive indices model a repeated
    failure)."""
    at_call: int


@dataclasses.dataclass(frozen=True)
class DeviceLoss:
    """At call ``at_call``, lose every device but the first ``keep``."""
    at_call: int
    keep: int


class FaultInjector:
    """Replayable fault script, indexed by global dispatch count.

    ``calls`` is the number of dispatches seen so far; ``log`` records
    every fault that fired as ``(call_index, fault)``.  Faults may be
    passed at construction or armed later with `schedule` —
    ``schedule(DeviceLoss(at_call=inj.calls, keep=4))`` fires at the
    NEXT dispatch, which is how the degraded-mode bench injects a loss
    "now" after a warm-up phase of unknown call count."""

    def __init__(self, faults: Sequence = ()):
        self.calls = 0
        self.log: List[Tuple[int, object]] = []
        self._scripted: Dict[int, List[object]] = {}
        for f in faults:
            self.schedule(f)

    def schedule(self, fault) -> None:
        self._scripted.setdefault(fault.at_call, []).append(fault)

    def before_call(self, bucket: int) -> None:
        """Engine hook: fire whatever is scripted for this dispatch."""
        idx = self.calls
        self.calls += 1
        for f in self._scripted.get(idx, ()):
            self.log.append((idx, f))
            if isinstance(f, SlowCall):
                time.sleep(f.delay_s)
            elif isinstance(f, TransientFailure):
                raise TransientCallError(
                    f"injected transient failure at call {idx} "
                    f"(bucket {bucket})")
            elif isinstance(f, DeviceLoss):
                raise DeviceLossError(
                    f.keep,
                    f"injected device loss at call {idx}: "
                    f"{f.keep} device(s) survive")
            else:
                raise TypeError(f"unknown fault {f!r}")
