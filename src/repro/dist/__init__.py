"""Distribution substrate: sharding context, logical-axis rules, fault
tolerance, and pipeline parallelism.

The model code never names mesh axes directly — it annotates arrays with
*logical* axes (``constrain(x, "batch", None, "mlp")``) and the active
`sharding_context` maps them onto physical mesh axes through the policy
rules (`make_rules`).  Outside a context every annotation is a no-op, so
the same model runs unchanged on one device.
"""
from .context import constrain, current, sharding_context
from .fault import Heartbeat, StragglerMonitor, elastic_mesh, reshard_tree
from .inject import (DeviceLoss, DeviceLossError, FaultError, FaultInjector,
                     SlowCall, TransientCallError, TransientFailure)
from .sharding import (batch_pspec, cache_specs, make_rules, spec_to_pspec,
                       tree_shardings)

__all__ = [
    "constrain", "current", "sharding_context",
    "Heartbeat", "StragglerMonitor", "elastic_mesh", "reshard_tree",
    "DeviceLoss", "DeviceLossError", "FaultError", "FaultInjector",
    "SlowCall", "TransientCallError", "TransientFailure",
    "batch_pspec", "cache_specs", "make_rules", "spec_to_pspec",
    "tree_shardings",
]
