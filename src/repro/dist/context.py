"""Thread-local sharding context: (mesh, logical-axis rules).

`constrain` is the single annotation primitive the models use.  It is a
no-op unless a `sharding_context` is active, which keeps every model
runnable on a single device (tests, CPU smoke) with zero branching at the
call sites.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax

_state = threading.local()


def current() -> Tuple[Optional[object], Optional[dict]]:
    """The active (mesh, rules), or (None, None) outside any context."""
    return getattr(_state, "mesh", None), getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_context(mesh, rules):
    """Activate (mesh, rules) for the dynamic extent of a step function."""
    prev = current()
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint through the logical-axis rules.

    Each positional arg names the logical axis of the corresponding array
    dim (None = replicated).  Axes without a rule, or whose dim does not
    divide the mapped mesh-axis extent, silently fall back to replicated —
    the constraint is a performance hint, never a correctness requirement.
    """
    mesh, rules = current()
    if mesh is None or rules is None:
        return x
    from .sharding import spec_to_pspec

    spec = spec_to_pspec(rules, tuple(logical_axes), mesh=mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )
