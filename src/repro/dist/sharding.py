"""Logical-axis -> mesh-axis rules and NamedSharding construction.

Policies (mesh axes are ("pod",)? + ("data", "model")):

* ``tp``       — tensor parallelism only: weight feature axes (mlp, heads,
                 kv_heads, vocab, experts) shard the model axis; params are
                 replicated across data.  Avoids the per-microbatch FSDP
                 weight all-gather.
* ``fsdp_tp``  — tp plus FSDP: the embed (d_model) axis of every weight
                 shards the data axis, so optimizer state scales with the
                 full mesh.

The batch axis always shards data (and pod when present).  A logical axis
whose dim does not divide the mapped mesh extent degrades to replicated
(checked per array in `spec_to_pspec`).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[str, Tuple[str, ...]]]

# axes that are never sharded (scan-stacked layer dim, small norms)
_UNSHARDED = ("layers",)


def make_rules(policy: str, multi_pod: bool = False) -> Rules:
    batch_axes: Union[str, Tuple[str, ...]] = (
        ("pod", "data") if multi_pod else "data"
    )
    rules: Rules = {
        "batch": batch_axes,
        "moe_group": batch_axes,
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "vocab": "model",
        "experts": "model",
    }
    if policy == "fsdp_tp":
        rules["embed"] = "data"
    elif policy != "tp":
        raise ValueError(f"unknown sharding policy {policy!r}")
    return rules


def _axis_size(mesh, axis: Union[str, Tuple[str, ...]]) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape.get(a, 1)
        return n
    return mesh.shape.get(axis, 1)


def spec_to_pspec(
    rules: Rules,
    spec: Sequence[Optional[str]],
    mesh=None,
    shape: Optional[Tuple[int, ...]] = None,
) -> P:
    """Map a logical-axis tuple onto a PartitionSpec.

    Unknown logical names and never-sharded axes map to None; when `shape`
    is given, any dim that does not divide the mesh extent also degrades to
    None (replicated) so the sharding is always constructible.
    """
    out = []
    used: set = set()
    for i, name in enumerate(spec):
        axis = None
        if name is not None and name not in _UNSHARDED:
            axis = rules.get(name)
        if axis is not None:
            flat = axis if isinstance(axis, tuple) else (axis,)
            if any(a in used for a in flat):
                axis = None  # a mesh axis may appear once per spec
        if axis is not None and mesh is not None:
            n = _axis_size(mesh, axis)
            present = all(a in mesh.shape
                          for a in (axis if isinstance(axis, tuple) else (axis,)))
            if not present or n <= 1:
                axis = None
            elif shape is not None and shape[i] % n != 0:
                axis = None
        if axis is not None:
            used.update(axis if isinstance(axis, tuple) else (axis,))
        out.append(axis)
    return P(*out)


def _is_spec_leaf(s: Any) -> bool:
    return isinstance(s, tuple)


def tree_shardings(mesh, rules: Rules, shapes_tree, specs_tree):
    """NamedSharding tree from a (params/shapes, logical specs) pair.

    `shapes_tree` leaves are arrays or ShapeDtypeStructs; `specs_tree`
    mirrors it with tuple-of-logical-axis leaves.
    """
    spec_leaves, treedef = jax.tree_util.tree_flatten(
        specs_tree, is_leaf=_is_spec_leaf
    )
    shape_leaves = treedef.flatten_up_to(shapes_tree)
    out = []
    for shp, spec in zip(shape_leaves, spec_leaves):
        shape = getattr(shp, "shape", None)
        out.append(NamedSharding(
            mesh, spec_to_pspec(rules, spec, mesh=mesh, shape=shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated_specs(tree):
    """All-replicated logical spec tree mirroring ``tree`` (arrays or
    ShapeDtypeStructs): every dim maps to None.  Feed to `tree_shardings`
    when a param tree has no sharded axes — e.g. the DCNN generator/critic
    weights, which are small enough to replicate on every device."""
    return jax.tree_util.tree_map(
        lambda a: (None,) * len(getattr(a, "shape", ())), tree)


def data_axis_size(mesh, rules: Rules) -> int:
    """Total data-parallel extent the batch dim shards over (1 when the
    mesh or the batch rule is absent)."""
    if mesh is None:
        return 1
    axis = rules.get("batch")
    if axis is None:
        return 1
    return _axis_size(mesh, axis)


def shard_index(mesh, rules: Rules):
    """Linearized index of the current batch shard, for use *inside* a
    shard_map body: 0 .. data_axis_size-1, row-major over the batch axes
    (matches how a batch-leading array is laid out across them)."""
    axis = rules.get("batch")
    if axis is None:
        return 0
    flat = axis if isinstance(axis, tuple) else (axis,)
    idx = 0
    for a in flat:
        idx = idx * mesh.shape.get(a, 1) + (
            jax.lax.axis_index(a) if a in mesh.shape else 0)
    return idx


def batch_pspec(mesh, rules: Rules, batch_size: int, ndim: int) -> P:
    """PartitionSpec for a batch-leading array: dim 0 on the batch axes when
    divisible, everything else replicated."""
    axis = rules.get("batch")
    if axis is not None:
        n = _axis_size(mesh, axis)
        if n <= 1 or batch_size % n != 0:
            axis = None
    return P(axis, *([None] * (ndim - 1)))


# ---------------------------------------------------------------------------
# serving-cache logical specs (mirrors models.transformer.init_cache)
# ---------------------------------------------------------------------------
def _attn_cache_spec(cfg) -> Dict[str, tuple]:
    kv = ("batch", None, "kv_heads", None)
    spec = {"k": kv, "v": kv, "slot_pos": (None,)}
    if cfg.kv_quant:
        spec["k_scale"] = kv
        spec["v_scale"] = kv
    return spec


def _block_cache_spec(cfg, kind: str) -> Dict[str, tuple]:
    if kind in ("global", "local"):
        return _attn_cache_spec(cfg)
    if kind == "griffin":
        return {"conv": ("batch", None, None), "h": ("batch", None)}
    if kind == "mlstm":
        return {
            "C": ("batch", "heads", None, None),
            "n": ("batch", "heads", None),
            "m": ("batch", "heads"),
            "conv": ("batch", None, None),
        }
    if kind == "slstm":
        st = ("batch", "heads", None)
        return {"c": st, "n": st, "h": st, "m": st}
    raise ValueError(kind)


def cache_specs(cfg) -> Dict[str, Any]:
    """Logical spec tree matching init_cache(cfg, ...)'s pytree structure."""
    from ..models import nn

    pattern = cfg.block_pattern
    unit = {f"b{i}": _block_cache_spec(cfg, kind)
            for i, kind in enumerate(pattern)}
    specs: Dict[str, Any] = {
        "units": nn.stack_specs(unit),
        "pos": (),
    }
    if cfg.n_rem:
        specs["rem"] = {f"b{i}": _block_cache_spec(cfg, pattern[i])
                        for i in range(cfg.n_rem)}
    return specs
