"""Fault tolerance primitives: straggler detection, liveness heartbeats,
and elastic meshes that scale the data axis down when devices are lost.

The training driver (train/loop.py) composes these with the async
checkpointer: a straggler is logged, a missed heartbeat triggers the
failure callback, and recovery re-enters the step loop on a smaller mesh
with `reshard_tree`-migrated state.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


class StragglerMonitor:
    """EMA-based step-time outlier detector.

    A step slower than ``factor`` x the EMA is flagged; flagged steps do NOT
    update the EMA (a straggler must not poison the baseline it is judged
    against).  The first ``warmup_steps`` observations only seed the EMA —
    all of them, with their running mean, so one noisy first call does not
    become the baseline every later call is judged against — and are never
    flagged themselves.
    """

    def __init__(self, factor: float = 3.0, warmup_steps: int = 2,
                 decay: float = 0.9):
        self.factor = factor
        self.warmup_steps = warmup_steps
        self.decay = decay
        self.ema: Optional[float] = None
        self.flagged: List[int] = []
        self._n = 0
        self._warmup_sum = 0.0
        self._warmup_n = 0

    def estimate(self) -> Optional[float]:
        """Current EMA of the healthy per-step wall clock (None before any
        observation).  Stragglers never update the EMA, so this is the
        engine's best *healthy* service-time estimate — the capacity
        signal SLO admission control and deadline-aware scheduling feed
        on (serve.scheduler.ServiceModel seeds from it)."""
        return self.ema

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup_steps or self.ema is None:
            # warmup (or warmup_steps=0 needing a first seed): every
            # observation contributes to the seed mean
            self._warmup_sum += dt
            self._warmup_n += 1
            self.ema = self._warmup_sum / self._warmup_n
            return False
        if dt > self.factor * self.ema:
            self.flagged.append(step)
            return True
        self.ema = self.decay * self.ema + (1.0 - self.decay) * dt
        return False


class Heartbeat:
    """Fires ``on_failure`` once per silence: no tick within ``timeout_s``
    while armed.

    A daemon thread polls the last-tick timestamp; `tick()` is the only
    thing the (possibly blocked) training loop must call.  `close()` stops
    the watcher; it never fires after close.

    Thread-safety: `tick()` and the watcher race on the fired/last pair
    (a tick landing between the watcher's check and its set used to
    double-fire or eat the reset), so both run under one lock — the
    check-and-set is atomic.  ``on_failure`` runs OUTSIDE the lock (it
    may call `tick` or `close` itself) and an exception it raises is
    recorded in ``callback_errors`` instead of silently killing the
    watcher thread; ``fire_count`` counts every fire.

    `arm()`/`disarm()` gate the watcher for callers whose liveness signal
    is intermittent: a serving engine arms around each dispatched call so
    an idle queue is not a "failure".  Constructed armed (the training
    driver's always-on usage).
    """

    def __init__(self, timeout_s: float, on_failure: Callable[[], None],
                 poll_s: Optional[float] = None):
        self.timeout_s = timeout_s
        self.on_failure = on_failure
        self.callback_errors: List[BaseException] = []
        self.fire_count = 0
        self._lock = threading.Lock()
        self._armed = True
        self._last = time.monotonic()
        self._fired = False
        self._stop = threading.Event()
        self._poll = poll_s if poll_s is not None else max(timeout_s / 10, 0.01)
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()

    def tick(self) -> None:
        with self._lock:
            self._last = time.monotonic()
            self._fired = False

    def arm(self) -> None:
        """Start watching (fresh silence window from now)."""
        with self._lock:
            self._armed = True
            self._last = time.monotonic()
            self._fired = False

    def disarm(self) -> None:
        """Stop watching until the next `arm()` (idle is not a failure)."""
        with self._lock:
            self._armed = False

    def _watch(self) -> None:
        while not self._stop.is_set():
            fire = False
            with self._lock:
                if (self._armed and not self._fired
                        and time.monotonic() - self._last > self.timeout_s):
                    self._fired = True
                    fire = True
            if fire:
                self.fire_count += 1
                try:
                    self.on_failure()
                except Exception as e:
                    self.callback_errors.append(e)
            self._stop.wait(self._poll)

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=1.0)


def elastic_mesh(devices: Sequence, model_parallel: int = 1) -> Mesh:
    """(data, model) mesh over the largest usable prefix of ``devices``.

    The model axis is fixed by the sharded weights; losing devices shrinks
    the data axis: data = len(devices) // model_parallel.  Surviving
    devices beyond data*model are left idle (they rejoin at the next
    remesh) — the paper-style graceful degradation for edge fleets.
    """
    if model_parallel < 1:
        raise ValueError("model_parallel must be >= 1")
    data = len(devices) // model_parallel
    if data < 1:
        raise ValueError(
            f"{len(devices)} device(s) cannot host model_parallel="
            f"{model_parallel}")
    used = np.array(devices[: data * model_parallel]).reshape(
        data, model_parallel)
    return Mesh(used, ("data", "model"))


def reshard_tree(tree, sharding):
    """Migrate a pytree onto new sharding(s) (e.g. after an elastic remesh).

    ``sharding`` is either one sharding applied to every leaf or a
    matching pytree of shardings; jax routes the transfer device-to-device
    where possible and through the host otherwise.
    """
    return jax.device_put(tree, sharding)
