"""Pipeline parallelism over a mesh axis (GPipe-style skewed schedule).

All stages execute the same tick in lockstep over a stage-stacked buffer:
stage ``s`` processes microbatch ``t - s`` at tick ``t``.  The stage dim of
the buffer is sharded on the pipeline mesh axis, so the per-tick
``vmap(stage_fn)`` is one SPMD program whose collectives are the
stage-to-stage shifts (a collective-permute under the hood) — the standard
TPU pipelining formulation.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def microbatch(x: jax.Array, n_micro: int) -> jax.Array:
    """(B, ...) -> (n_micro, B // n_micro, ...)."""
    b = x.shape[0]
    if b % n_micro:
        raise ValueError(f"batch {b} not divisible into {n_micro} microbatches")
    return x.reshape((n_micro, b // n_micro) + x.shape[1:])


def pipeline_apply(
    mesh,
    axis: Optional[str],
    stage_fn: Callable[[jax.Array, jax.Array], jax.Array],
    stage_weights: jax.Array,     # (n_stages, ...) stacked per-stage params
    xm: jax.Array,                # (n_micro, mb, ...) microbatched input
) -> jax.Array:
    """Run every microbatch through all stages; returns (n_micro, mb, ...).

    ``stage_fn(w, x) -> y`` must be shape-preserving (uniform stage width),
    which is what lets one stacked buffer carry all in-flight activations.
    Total ticks = n_micro + n_stages - 1; the first n_stages - 1 outputs are
    bubble and are dropped.
    """
    n_stages = stage_weights.shape[0]
    n_micro = xm.shape[0]
    mb_shape = xm.shape[1:]

    def shard_stages(buf):
        if mesh is None or axis is None or axis not in mesh.shape:
            return buf
        spec = P(axis, *([None] * (buf.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            buf, NamedSharding(mesh, spec))

    buf = shard_stages(jnp.zeros((n_stages,) + mb_shape, xm.dtype))
    outs = []
    for t in range(n_micro + n_stages - 1):
        feed = xm[t] if t < n_micro else jnp.zeros(mb_shape, xm.dtype)
        # shift-in: stage 0 takes the next microbatch, stage s takes stage
        # s-1's previous output (the inter-stage permute).
        buf = shard_stages(jnp.concatenate([feed[None], buf[:-1]], axis=0))
        buf = shard_stages(jax.vmap(stage_fn)(stage_weights, buf))
        if t >= n_stages - 1:
            outs.append(buf[-1])
    return jnp.stack(outs, axis=0)
