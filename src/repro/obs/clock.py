"""The one timebase for the serve stack.

Before this module existed the engine timed dispatches with
``time.perf_counter`` while the frontend stamped deadlines with
``time.monotonic`` — two clocks that happen to agree on Linux but are
not guaranteed to share an epoch or a rate anywhere else.  Spans,
dispatch timings, queue deadlines, and heartbeat windows all flow
through :func:`now` so every duration and every deadline comparison is
taken on a single monotonic timebase.

``perf_counter`` is the choice: it is monotonic (safe for deadlines)
and is the highest-resolution clock Python exposes (what Table II's
run-to-run CV actually needs).
"""
from __future__ import annotations

import time


def now() -> float:
    """Seconds on the process-wide monotonic timebase.

    Only differences and comparisons between two :func:`now` values are
    meaningful; the epoch is arbitrary.
    """
    return time.perf_counter()
