"""Thread-safe typed metrics: Counters, Gauges, fixed-bucket Histograms.

One :class:`MetricsRegistry` is shared by every engine/frontend in a
serving stack (``AsyncServeFrontend.from_config`` wires a single
registry through all per-precision engines), so the whole deployment's
counters land in one place.  Series are labelable by any string keys —
the serve stack uses ``(net, precision, bucket, tenant)`` — and a
histogram keeps streaming moments (count, sum, sum of squares) plus
fixed bucket counts, so the paper's Table II statistics (mean, std,
run-to-run CV) reduce in O(1) without retaining samples.

Locking discipline (checked by ``repro.analysis.check`` lint): each
metric owns one ``threading.Lock`` guarding its series dict; the
registry owns one lock guarding the name→metric table.  Metric locks
are leaves — no metric method calls back into the registry.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricTypeError",
    "default_registry",
]

LabelKey = Tuple[Tuple[str, str], ...]


class MetricTypeError(TypeError):
    """A metric name was re-requested with a different type."""


def _label_key(labels: Dict[str, object]) -> LabelKey:
    # values stringified so int bucket sizes and their str forms collide
    # deliberately — JSON round-trips cannot split a series in two
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_dict(key: LabelKey) -> Dict[str, str]:
    return dict(key)


def _matches(key: LabelKey, match: Dict[str, object]) -> bool:
    want = {str(k): str(v) for k, v in match.items()}
    have = dict(key)
    return all(have.get(k) == v for k, v in want.items())


class Counter:
    """Monotonically increasing count per label set."""

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: Dict[LabelKey, float] = {}

    def inc(self, value: float = 1, **labels: object) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name}: negative increment {value}")
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + value

    def value(self, **labels: object) -> float:
        with self._lock:
            return self._series.get(_label_key(labels), 0)

    def total(self, **match: object) -> float:
        """Sum over every series whose labels are a superset of ``match``."""
        with self._lock:
            return sum(v for k, v in self._series.items() if _matches(k, match))

    def series(self) -> Dict[LabelKey, float]:
        with self._lock:
            return dict(self._series)

    def reset(self) -> None:
        with self._lock:
            self._series.clear()

    def snapshot(self) -> dict:
        with self._lock:
            rows = [{"labels": _label_dict(k), "value": v}
                    for k, v in sorted(self._series.items())]
        return {"type": "counter", "help": self.help, "series": rows}


class Gauge:
    """Last-write-wins value per label set."""

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: object) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = value

    def value(self, **labels: object) -> Optional[float]:
        with self._lock:
            return self._series.get(_label_key(labels))

    def series(self) -> Dict[LabelKey, float]:
        with self._lock:
            return dict(self._series)

    def reset(self) -> None:
        with self._lock:
            self._series.clear()

    def snapshot(self) -> dict:
        with self._lock:
            rows = [{"labels": _label_dict(k), "value": v}
                    for k, v in sorted(self._series.items())]
        return {"type": "gauge", "help": self.help, "series": rows}


class _HistSeries:
    __slots__ = ("count", "total", "sumsq", "min", "max", "bucket_counts")

    def __init__(self, n_bounds: int) -> None:
        self.count = 0
        self.total = 0.0
        self.sumsq = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.bucket_counts = [0] * (n_bounds + 1)  # last = overflow

    def observe(self, value: float, bounds: Sequence[float]) -> None:
        self.count += 1
        self.total += value
        self.sumsq += value * value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        for i, b in enumerate(bounds):
            if value <= b:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    def merge_into(self, other: "_HistSeries") -> None:
        other.count += self.count
        other.total += self.total
        other.sumsq += self.sumsq
        other.min = min(other.min, self.min)
        other.max = max(other.max, self.max)
        for i, c in enumerate(self.bucket_counts):
            other.bucket_counts[i] += c

    def stats(self) -> dict:
        if self.count == 0:
            return {"count": 0, "mean": 0.0, "std": 0.0, "cv": 0.0,
                    "min": 0.0, "max": 0.0, "total": 0.0}
        mean = self.total / self.count
        # population variance from streaming moments, clamped against
        # catastrophic cancellation on near-constant samples
        var = max(self.sumsq / self.count - mean * mean, 0.0)
        std = math.sqrt(var)
        cv = std / mean if mean > 0 else 0.0
        return {"count": self.count, "mean": mean, "std": std, "cv": cv,
                "min": self.min, "max": self.max, "total": self.total}


class Histogram:
    """Fixed-bucket histogram with streaming mean/std/CV per label set."""

    # dispatch wall-clocks on CPU interpret mode span ~100µs..10s
    DEFAULT_BUCKETS = (1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 1e-1, 5e-1,
                       1.0, 5.0, 10.0)

    def __init__(self, name: str, help: str = "",
                 buckets: Optional[Sequence[float]] = None) -> None:
        self.name = name
        self.help = help
        bounds = tuple(buckets) if buckets is not None else self.DEFAULT_BUCKETS
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram {name}: bucket bounds must be "
                             f"strictly increasing, got {bounds}")
        self.bounds = bounds
        self._lock = threading.Lock()
        self._series: Dict[LabelKey, _HistSeries] = {}

    def observe(self, value: float, **labels: object) -> None:
        key = _label_key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = _HistSeries(len(self.bounds))
                self._series[key] = s
            s.observe(value, self.bounds)

    def summary(self, **labels: object) -> dict:
        """mean/std/cv/min/max for one exact label set."""
        with self._lock:
            s = self._series.get(_label_key(labels))
            return s.stats() if s is not None else _HistSeries(0).stats()

    def merged_summary(self, **match: object) -> dict:
        """Pool moments across every series matching a label subset."""
        pooled = _HistSeries(len(self.bounds))
        with self._lock:
            for key, s in self._series.items():
                if _matches(key, match):
                    s.merge_into(pooled)
        return pooled.stats()

    def label_values(self, label: str) -> List[str]:
        """Distinct observed values of one label key, sorted."""
        with self._lock:
            keys = list(self._series)
        out = {dict(k)[label] for k in keys if label in dict(k)}
        return sorted(out)

    def series_summaries(self) -> Dict[LabelKey, dict]:
        with self._lock:
            return {k: s.stats() for k, s in self._series.items()}

    def reset(self) -> None:
        with self._lock:
            self._series.clear()

    def snapshot(self) -> dict:
        with self._lock:
            rows = [{"labels": _label_dict(k), **s.stats(),
                     "bucket_counts": list(s.bucket_counts)}
                    for k, s in sorted(self._series.items())]
        return {"type": "histogram", "help": self.help,
                "bounds": list(self.bounds), "series": rows}


class MetricsRegistry:
    """Get-or-create registry of typed metrics, safe to share across threads."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get_or_create(self, name: str, cls, *args, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args, **kwargs)
                self._metrics[name] = m
        if not isinstance(m, cls):
            raise MetricTypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get_or_create(name, Histogram, help, buckets)

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-able dump of every metric and series."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.snapshot() for name, m in items}

    def reset(self) -> None:
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            m.reset()


_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """Process-wide registry (used by module-level code like autotune)."""
    return _default
