"""Lightweight span tracing with a Chrome/Perfetto ``trace_event`` exporter.

A request's whole life — admission → EDF queue wait → wave dispatch →
per-bucket kernel call → collect — renders as one timeline in
https://ui.perfetto.dev (or chrome://tracing), with fault-injection
retries, stragglers, heartbeat fires and elastic-remesh events as
instant markers.

Design constraints, in order:

* **near-zero overhead when disabled** — the hot path is one attribute
  read; :meth:`Tracer.span` returns a shared null singleton (no
  allocation), :meth:`Tracer.complete`/:meth:`Tracer.instant` return
  immediately.
* **monotonic-clock only** — all timestamps come from
  :func:`repro.obs.clock.now`; wall-clock never leaks into a trace.
* **ring-buffered** — a bounded ``deque`` keeps the newest ``capacity``
  events; a long soak can stay traced without growing memory.

Three recording styles cover the serve stack's shapes:

* ``with tracer.span("generate", rows=n):`` — scoped work on one thread.
* ``h = tracer.begin("queue_wait"); ... tracer.end(h)`` — spans that
  start on one thread (submit) and finish on another (worker).
* ``tracer.complete(name, t0, t1)`` — retroactive, for code that already
  timed itself (dispatch retries keep their own ``t0``).
"""
from __future__ import annotations

import collections
import json
import os
import threading
from typing import Dict, List, Optional, Tuple

from . import clock

__all__ = ["Tracer", "get_tracer", "enable", "disable"]


class _NullSpan:
    """Shared no-op span/handle returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL = _NullSpan()


class _Span:
    """Context-manager span; records one complete event on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: dict) -> None:
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = clock.now()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._args["error"] = exc_type.__name__
        self._tracer.complete(self._name, self._t0, clock.now(),
                              cat=self._cat, **self._args)
        return False


class SpanHandle:
    """Explicit begin/end handle; may be ended from a different thread."""

    __slots__ = ("name", "cat", "args", "t0", "ident", "tname")

    def __init__(self, name: str, cat: str, args: dict, t0: float,
                 ident: int, tname: str) -> None:
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = t0
        self.ident = ident
        self.tname = tname


class Tracer:
    """Ring-buffered span recorder emitting Chrome ``trace_event`` JSON."""

    def __init__(self, capacity: int = 65536, enabled: bool = False) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._events: collections.deque = collections.deque(maxlen=capacity)
        # OS thread ident -> (small display tid, thread name at first record)
        self._tids: Dict[int, Tuple[int, str]] = {}
        self._enabled = bool(enabled)

    # -- enable/disable: plain flag writes, deliberately lock-free so the
    # -- disabled fast path is a single unguarded attribute read
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    # -- recording ----------------------------------------------------------
    def span(self, name: str, cat: str = "serve", **args: object):
        """Scoped span; returns a shared null object while disabled."""
        if not self._enabled:
            return _NULL
        return _Span(self, name, cat, dict(args))

    def begin(self, name: str, cat: str = "serve", **args: object):
        """Start a span that may be ended from another thread."""
        if not self._enabled:
            return _NULL
        th = threading.current_thread()
        return SpanHandle(name, cat, dict(args), clock.now(),
                          th.ident or 0, th.name)

    def end(self, handle, **extra: object) -> None:
        """Finish a :meth:`begin` handle; attributed to the begin thread."""
        if handle is None or handle is _NULL or not self._enabled:
            return
        t1 = clock.now()
        args = dict(handle.args)
        args.update(extra)
        self._record("X", handle.name, handle.cat, handle.t0, t1,
                     handle.ident, handle.tname, args)

    def complete(self, name: str, t0: float, t1: float, cat: str = "serve",
                 **args: object) -> None:
        """Record an already-timed span retroactively (current thread)."""
        if not self._enabled:
            return
        th = threading.current_thread()
        self._record("X", name, cat, t0, t1, th.ident or 0, th.name,
                     dict(args))

    def instant(self, name: str, cat: str = "serve", **args: object) -> None:
        """Thread-scoped instant marker (retries, remesh, sheds...)."""
        if not self._enabled:
            return
        th = threading.current_thread()
        t = clock.now()
        self._record("i", name, cat, t, t, th.ident or 0, th.name,
                     dict(args))

    def _record(self, ph: str, name: str, cat: str, t0: float, t1: float,
                ident: int, tname: str, args: dict) -> None:
        ev = {"ph": ph, "name": name, "cat": cat, "ts": t0 * 1e6,
              "pid": os.getpid(), "args": args}
        if ph == "X":
            ev["dur"] = max(t1 - t0, 0.0) * 1e6
        else:
            ev["s"] = "t"
        with self._lock:
            ev["tid"] = self._tid_locked(ident, tname)
            self._events.append(ev)

    def _tid_locked(self, ident: int, tname: str) -> int:
        # small stable display ids beat raw pthread idents in the UI
        entry = self._tids.get(ident)
        if entry is None:
            entry = (len(self._tids) + 1, tname)
            self._tids[ident] = entry
        return entry[0]

    # -- inspection / export ------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._tids.clear()

    def to_chrome(self) -> dict:
        """Chrome/Perfetto ``trace_event`` document (JSON object format)."""
        with self._lock:
            events = list(self._events)
            tids = dict(self._tids)
        pid = os.getpid()
        meta: List[dict] = [{
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": "repro-serve"}}]
        for tid, tname in sorted(tids.values()):
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "args": {"name": tname}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the trace JSON; returns the number of non-meta events."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return sum(1 for ev in doc["traceEvents"] if ev["ph"] != "M")


_tracer = Tracer()


def get_tracer() -> Tracer:
    """The process-wide tracer the serve stack records into."""
    return _tracer


def enable(clear: bool = False) -> Tracer:
    """Turn on the global tracer (optionally dropping old events)."""
    if clear:
        _tracer.clear()
    _tracer.enable()
    return _tracer


def disable() -> Tracer:
    _tracer.disable()
    return _tracer
