"""Observability layer: one clock, typed metrics, span tracing, Table II.

* :mod:`repro.obs.clock` — the single monotonic timebase
  (:func:`clock.now`) every serve-stack duration and deadline uses.
* :mod:`repro.obs.metrics` — thread-safe Counters / Gauges / fixed-bucket
  Histograms with streaming mean/std/CV, labeled by
  ``(net, precision, bucket, tenant)``.
* :mod:`repro.obs.trace` — ring-buffered span tracing with a
  Chrome/Perfetto ``trace_event`` exporter (open at https://ui.perfetto.dev).
* :mod:`repro.obs.report` — reduces dispatch histograms to the paper's
  Table II statistics (mean, std, run-to-run CV over healthy calls).
"""
from . import clock, metrics, report, trace  # noqa: F401
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,  # noqa: F401
                      default_registry)
from .report import render_table2, table2_rows  # noqa: F401
from .trace import Tracer, get_tracer  # noqa: F401

__all__ = [
    "clock", "metrics", "trace", "report",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_registry",
    "Tracer", "get_tracer", "table2_rows", "render_table2",
]
