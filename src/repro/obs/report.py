"""Table II reporter: reduce dispatch histograms to the paper's methodology.

The paper's Table II reports, per network and implementation, the mean
throughput and the **run-to-run coefficient of variation** — its core
claim is that the FPGA pipeline's timing is not just fast but *stable*.
This module reduces the ``engine.dispatch_seconds`` histogram (healthy
steady-state dispatches only — retried/tainted calls are counted
separately and excluded, matching the engine's ``bucket_stats``
taint discipline) into rows of that shape:

* one row per ``(net, precision, bucket)`` — run-to-run mean/std/CV at
  a fixed compiled configuration, the statistic the paper actually
  tabulates;
* one roll-up row per ``(net, precision)`` with ``bucket="all"`` —
  ``cv`` there is the calls-weighted average of the per-bucket CVs
  (pooling raw moments across buckets would conflate bucket-size
  spread with run-to-run jitter, which is not Table II's quantity).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from .metrics import Counter, Histogram, MetricsRegistry

__all__ = ["table2_rows", "render_table2", "DISPATCH_METRIC", "TAINT_METRIC"]

DISPATCH_METRIC = "engine.dispatch_seconds"
TAINT_METRIC = "engine.tainted_calls"


def _tainted(counter, **labels) -> int:
    if not isinstance(counter, Counter):
        return 0
    return int(counter.total(**labels))


def table2_rows(registry: MetricsRegistry,
                metric: str = DISPATCH_METRIC) -> List[dict]:
    """Reduce a registry's dispatch histogram to Table II rows."""
    hist = registry.get(metric)
    if not isinstance(hist, Histogram):
        return []
    taint = registry.get(TAINT_METRIC)
    groups: Dict[Tuple[str, str], List[dict]] = {}
    for key, stats in hist.series_summaries().items():
        labels = dict(key)
        if "net" not in labels or stats["count"] == 0:
            continue
        net = labels["net"]
        precision = labels.get("precision", "fp32")
        bucket = labels.get("bucket", "?")
        row = {
            "net": net,
            # registry workload name (falls back to the net for series
            # recorded before the workload label existed)
            "workload": labels.get("workload", net),
            "precision": precision,
            "bucket": int(bucket) if str(bucket).isdigit() else str(bucket),
            "calls": stats["count"],
            "mean_s": stats["mean"],
            "std_s": stats["std"],
            "cv": stats["cv"],
            "min_s": stats["min"],
            "max_s": stats["max"],
            "tainted_calls": _tainted(taint, net=net, precision=precision,
                                      bucket=bucket),
        }
        groups.setdefault((net, precision), []).append(row)

    rows: List[dict] = []
    for (net, precision) in sorted(groups):
        per_bucket = sorted(groups[(net, precision)],
                            key=lambda r: (str(r["bucket"])))
        rows.extend(per_bucket)
        calls = sum(r["calls"] for r in per_bucket)
        seconds = sum(r["mean_s"] * r["calls"] for r in per_bucket)
        images = sum(r["bucket"] * r["calls"] for r in per_bucket
                     if isinstance(r["bucket"], int))
        rollup = {
            "net": net,
            "workload": per_bucket[0]["workload"],
            "precision": precision,
            "bucket": "all",
            "calls": calls,
            "mean_s": seconds / calls,
            # calls-weighted averages keep run-to-run semantics (see module doc)
            "std_s": sum(r["std_s"] * r["calls"] for r in per_bucket) / calls,
            "cv": sum(r["cv"] * r["calls"] for r in per_bucket) / calls,
            "min_s": min(r["min_s"] for r in per_bucket),
            "max_s": max(r["max_s"] for r in per_bucket),
            "tainted_calls": sum(r["tainted_calls"] for r in per_bucket),
        }
        if images and seconds > 0:
            rollup["img_per_s"] = images / seconds
        rows.append(rollup)
    return rows


def render_table2(rows: List[dict]) -> str:
    """Fixed-width text table (bench output / CI logs)."""
    if not rows:
        return "(no table2 rows — registry has no healthy dispatches)"
    hdr = (f"{'net':<14} {'prec':<6} {'bucket':>6} {'calls':>6} "
           f"{'mean_ms':>9} {'std_ms':>8} {'cv':>7} {'tainted':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['net']:<14} {r['precision']:<6} {str(r['bucket']):>6} "
            f"{r['calls']:>6d} {r['mean_s'] * 1e3:>9.3f} "
            f"{r['std_s'] * 1e3:>8.3f} {r['cv']:>7.3f} "
            f"{r['tainted_calls']:>8d}")
    return "\n".join(lines)
