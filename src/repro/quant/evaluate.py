"""Accuracy harness for the int8 inference path.

The paper's quality metric is distribution-level (MMD, §V-C), so the
quantization acceptance metric is the same: the MMD between the images
the *quantized* generator produces and the images the fp32 reference
produces from identical latents — per calibration strategy, so the
statistical observers can be compared the way the paper compares
bit-width choices.  An MMD near zero means the int8 distribution is
indistinguishable from fp32's; per-pixel error is reported alongside as
the microscopic view.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.mmd import mmd
from ..models.dcnn import DcnnConfig, generator_apply
from .calibrate import OBSERVERS, calibrate, quantize_params
from .infer import quantized_generator_apply


def mmd_degradation(
    params,
    cfg: DcnnConfig,
    key: jax.Array,
    strategies: Sequence[str] = OBSERVERS,
    n: int = 64,
    calib_n: int = 64,
    percentile: float = 99.9,
    k: float = 6.0,
    use_kernel: bool = True,
    tile_overrides: Optional[dict] = None,
) -> List[Dict[str, float]]:
    """MMD-vs-fp32 degradation of the int8 path per calibration strategy.

    Calibrates on ``calib_n`` fresh latents, evaluates on ``n`` held-out
    latents (calibration never sees the eval batch).  ``use_kernel=False``
    swaps the Pallas chain for the integer-exact reference — identical
    math, useful where interpret-mode wall clock matters."""
    kc, ke = jax.random.split(key)
    z_cal = jax.random.normal(kc, (calib_n, cfg.z_dim), jnp.float32)
    z_ev = jax.random.normal(ke, (n, cfg.z_dim), jnp.float32)
    base = generator_apply(params, cfg, z_ev, backend="reverse_loop")
    base_flat = np.asarray(base).reshape(n, -1)
    rows = []
    for strategy in strategies:
        qcfg = calibrate(params, cfg, z_cal, strategy=strategy,
                         percentile=percentile, k=k)
        qp = quantize_params(params, cfg, qcfg)
        if use_kernel:
            imgs = quantized_generator_apply(qp, cfg, qcfg, z_ev,
                                             tile_overrides=tile_overrides)
        else:
            from .infer import quantized_generator_ref
            imgs = quantized_generator_ref(qp, cfg, qcfg, z_ev)
        imgs = np.asarray(imgs)
        err = np.abs(imgs - np.asarray(base))
        rows.append({
            "net": cfg.name,
            "strategy": strategy,
            "mmd_vs_fp32": float(mmd(jnp.asarray(base_flat),
                                     jnp.asarray(imgs.reshape(n, -1)))),
            "max_abs_err": float(err.max()),
            "mean_abs_err": float(err.mean()),
        })
    return rows
