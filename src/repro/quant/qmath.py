"""Shared symmetric int8 quantization math.

The single source of the scale/round/clip arithmetic used by BOTH
quantization call sites in the tree:

* post-training inference quantization (`quant.calibrate` /
  `kernels.deconv2d.deconv2d_int8`), and
* gradient compression for the DP all-reduce (`optim.compression`).

Symmetric, zero-point-free: q = clip(round(x / s), -127, 127), x' = q * s.
Zero maps to zero exactly, which is what lets the deconv kernels zero-pad
quantized tensors (halo rows, ragged tiles) without a zero-point offset.
Works on jax arrays inside jit and on numpy arrays on the host.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

QMAX = 127          # int8 symmetric range [-127, 127] (-128 unused)
_EPS = 1e-12        # keeps all-zero tensors from dividing by zero

Scalar = Union[float, jax.Array]


def symmetric_scale(amax: Scalar, qmax: int = QMAX) -> Scalar:
    """Scale mapping the clip value ``amax`` onto the integer range."""
    return amax / qmax + _EPS


def quantize_symmetric(x: jax.Array, scale: Scalar,
                       qmax: int = QMAX) -> jax.Array:
    """round-to-nearest symmetric quantization, saturating at +-qmax."""
    return jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)


def dequantize_symmetric(q: jax.Array, scale: Scalar) -> jax.Array:
    return q.astype(jnp.float32) * scale


def fake_quant(x: jax.Array, scale: Scalar, qmax: int = QMAX) -> jax.Array:
    """Quantize-dequantize in f32 — the reference the int8 kernel is
    parity-tested against (same rounding, same saturation)."""
    return dequantize_symmetric(quantize_symmetric(x, scale, qmax), scale)


def quantize_absmax(x: jax.Array, qmax: int = QMAX
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-shot min/max (absmax) quantization of a whole tensor; returns
    (q, scale).  This is the gradient-compression entry point."""
    scale = symmetric_scale(jnp.max(jnp.abs(x)), qmax)
    return quantize_symmetric(x, scale, qmax), scale
