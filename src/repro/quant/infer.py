"""int8 inference path for the DCNN generators.

`quantized_generator_apply` is the quantized twin of
`models.dcnn.generator_apply(backend="pallas")`: the calibrated input
scale quantizes z once, then every deconv layer runs the int8 batch-fused
Pallas kernel with its fused requant epilogue re-quantizing straight into
the next layer's calibrated range — the activation chain stays int8 in
HBM end-to-end, with only the final tanh layer emitting f32 images.

Jit/shard_map friendly: the quantized params ride as ordinary traced
arrays (the serving engine replicates them on a mesh exactly like f32
params) while the per-layer scales bake in as compile-time constants of
the per-bucket executable.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..dist.context import constrain
from ..models.dcnn import DcnnConfig, _tile_kwargs, tower_input
from .calibrate import QuantConfig
from .qmath import quantize_symmetric


def quantized_generator_apply(
    qp: Dict[str, Any],
    cfg: DcnnConfig,
    qcfg: Optional[QuantConfig],
    z: jax.Array,
    tile_overrides: Optional[Dict[int, Any]] = None,
    interpret: Optional[bool] = None,
    plan=None,
) -> jax.Array:
    """z: (B, z_dim) f32 -> images (B, H, W, C) f32 in [-1, 1].

    ``qp`` is the `quant.calibrate.quantize_params` tree (int8 ``w_q``,
    f32 ``b``, f32 per-channel combined ``scale``); ``qcfg`` carries the
    calibrated activation scales that chain the layers together.

    ``plan`` is a `repro.plan.NetworkPlan` at precision="int8": per-layer
    tiles AND the requant epilogue scales come pinned from it, and
    ``qcfg`` may be None (the plan carries the calibration)."""
    from ..kernels.deconv2d import deconv2d_int8

    if plan is not None:
        if plan.precision != "int8":
            raise ValueError(
                f"quantized_generator_apply needs an int8 plan, got "
                f"{plan.precision!r}")
        plan.validate_for(cfg)
        if qcfg is None:
            qcfg = plan.quant_config()
    if qcfg is None:
        raise ValueError("quantized_generator_apply needs a QuantConfig "
                         "(directly or via an int8 plan)")
    if len(qcfg.layers) != len(cfg.layers):
        raise ValueError(
            f"QuantConfig has {len(qcfg.layers)} layers; "
            f"{cfg.name} has {len(cfg.layers)}")
    x = tower_input(cfg, z).astype(jnp.float32)
    x = quantize_symmetric(x, qcfg.layers[0].x_scale)
    x = constrain(x, "batch", None, None, None)
    for i, l in enumerate(cfg.layers):
        lq = qp[f"l{i}"]
        if plan is not None:
            x = deconv2d_int8(x, lq["w_q"], lq["scale"], lq["b"],
                              plan=plan.layers[i], interpret=interpret)
        else:
            from ..kernels.deconv2d.ops import suppress_tile_warnings

            # supported legacy override surface: the tile-kwarg expansion
            # is ours, not the user's — don't warn
            with suppress_tile_warnings():
                x = deconv2d_int8(
                    x, lq["w_q"], lq["scale"], lq["b"], l.stride,
                    l.padding, activation=l.activation,
                    out_scale=qcfg.out_scale(i), interpret=interpret,
                    **_tile_kwargs((tile_overrides or {}).get(i)))
        x = constrain(x, "batch", None, None, None)
    return x


def quantized_generator_ref(
    qp: Dict[str, Any],
    cfg: DcnnConfig,
    qcfg: QuantConfig,
    z: jax.Array,
) -> jax.Array:
    """Fake-quant oracle of the whole chain: the same quantize -> int32
    conv -> requant per layer through `deconv2d_int8_ref` (integer-exact
    accumulation, identical epilogue) — what the Pallas chain is
    parity-tested against end to end."""
    from ..kernels.deconv2d import deconv2d_int8_ref

    x = tower_input(cfg, z).astype(jnp.float32)
    x = quantize_symmetric(x, qcfg.layers[0].x_scale)
    for i, l in enumerate(cfg.layers):
        lq = qp[f"l{i}"]
        x = deconv2d_int8_ref(
            x, jnp.asarray(lq["w_q"]), jnp.asarray(lq["scale"]),
            jnp.asarray(lq["b"]), l.stride, l.padding,
            activation=l.activation, out_scale=qcfg.out_scale(i))
    return x
