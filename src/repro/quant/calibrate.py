"""Statistical calibration of per-layer, per-channel int8 ranges.

The paper sizes its fixed-point datapaths by statistical analysis of the
weight/activation distributions rather than worst-case ranges; the same
methodology drives this module's *activation observers*:

* ``minmax``      — clip at max|x| (lossless range, widest steps);
* ``percentile``  — clip at the p-th percentile of |x| (drops the long
                    activation tail that would otherwise inflate the step);
* ``mean_ksigma`` — clip at mean(|x|) + k * std(|x|), the mean +- k-sigma
                    statistical clipping the paper's methodology describes.

``calibrate`` pushes a calibration batch through ``generator_apply``
(reverse-loop backend: pure JAX, no kernels involved) and observes the
*input* of every deconv layer — that is the tensor the int8 kernel
quantizes.  Weights are quantized per output channel (amax over the
(K, K, C_in) slab of each C_out), the granularity Zhang et al. and
Alhussain both show deconv inference needs to survive int8.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import numpy as np

from ..models.dcnn import DcnnConfig, generator_apply
from .qmath import QMAX, quantize_symmetric, symmetric_scale

OBSERVERS = ("minmax", "percentile", "mean_ksigma")


def observe_amax(x, strategy: str = "mean_ksigma", percentile: float = 99.9,
                 k: float = 6.0) -> float:
    """Clip value (pre-scale absolute max) for one activation tensor."""
    a = np.abs(np.asarray(x, np.float32)).ravel()
    if strategy == "minmax":
        return float(a.max())
    if strategy == "percentile":
        return float(np.percentile(a, percentile))
    if strategy == "mean_ksigma":
        return float(min(a.max(), a.mean() + k * a.std()))
    raise ValueError(
        f"unknown observer {strategy!r}; expected one of {OBSERVERS}")


@dataclasses.dataclass(frozen=True)
class LayerQuant:
    """Calibrated ranges for one deconv layer.

    ``x_scale`` is the per-tensor scale of the layer's *input* activation;
    ``w_scale`` is the per-output-channel weight scale tuple (length
    C_out).  Stored as plain floats so the config is hashable/serializable
    and bakes into compiled executables as constants."""

    x_scale: float
    w_scale: Tuple[float, ...]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Per-layer calibration result for a whole generator network."""

    name: str
    strategy: str
    layers: Tuple[LayerQuant, ...]

    def out_scale(self, i: int):
        """Requant scale of layer i's output == layer i+1's input scale
        (None for the last layer, which stays f32 after its epilogue)."""
        return (self.layers[i + 1].x_scale
                if i + 1 < len(self.layers) else None)


def calibrate(params, cfg: DcnnConfig, z: jax.Array,
              strategy: str = "mean_ksigma", percentile: float = 99.9,
              k: float = 6.0) -> QuantConfig:
    """Observe a calibration batch and emit the per-layer QuantConfig.

    ``z``: (B, z_dim) calibration latents (the serving input distribution).
    The observed tensors are each layer's input — z itself for layer 0,
    then every post-activation intermediate of the fp32 reference chain.
    """
    if strategy not in OBSERVERS:
        raise ValueError(
            f"unknown observer {strategy!r}; expected one of {OBSERVERS}")
    _, inters = generator_apply(params, cfg, z, backend="reverse_loop",
                                return_intermediates=True)
    assert len(inters) == len(cfg.layers)
    layers = []
    for i, x_in in enumerate(inters):
        amax = observe_amax(x_in, strategy, percentile=percentile, k=k)
        w = np.asarray(params[f"l{i}"]["w"], np.float32)
        w_amax = np.abs(w).reshape(-1, w.shape[3]).max(axis=0)
        layers.append(LayerQuant(
            x_scale=float(symmetric_scale(amax)),
            w_scale=tuple(float(symmetric_scale(a)) for a in w_amax),
        ))
    return QuantConfig(name=cfg.name, strategy=strategy,
                       layers=tuple(layers))


def quantize_params(params, cfg: DcnnConfig, qcfg: QuantConfig
                    ) -> Dict[str, Any]:
    """int8 weight tree for the quantized serving path.

    Per layer: ``w_q`` (K, K, C_in, C_out) int8 quantized per output
    channel, ``b`` the untouched f32 bias, and ``scale`` the *combined*
    requant factor x_scale * w_scale per channel — the one multiply the
    kernel's epilogue applies to the int32 accumulator."""
    qp: Dict[str, Any] = {}
    for i in range(len(cfg.layers)):
        w = np.asarray(params[f"l{i}"]["w"], np.float32)
        lq = qcfg.layers[i]
        w_scale = np.asarray(lq.w_scale, np.float32)
        w_q = np.asarray(
            quantize_symmetric(w, w_scale[None, None, None, :], QMAX))
        qp[f"l{i}"] = {
            "w_q": w_q,
            "b": np.asarray(params[f"l{i}"]["b"], np.float32),
            "scale": (lq.x_scale * w_scale).astype(np.float32),
        }
    return qp
