"""Post-training int8 quantization for the deconv inference stack.

The paper's FPGA accelerator picks its fixed-point bit-widths by
*statistical analysis* of the weight/activation distributions; this
package is the TPU analogue: activation observers calibrate per-layer
ranges (`calibrate`), weights quantize per output channel
(`quantize_params`), and the int8 batch-fused Pallas kernel
(`kernels.deconv2d.deconv2d_int8`) runs the whole generator with int32
accumulation and a fused requant + bias + activation epilogue.

One quantization math module (`qmath`) serves two call sites: this
inference path and the gradient-compression path in `optim.compression`.
"""
from .calibrate import (OBSERVERS, LayerQuant, QuantConfig, calibrate,
                        observe_amax, quantize_params)
from .evaluate import mmd_degradation
from .infer import quantized_generator_apply, quantized_generator_ref
from .qmath import (QMAX, dequantize_symmetric, fake_quant, quantize_absmax,
                    quantize_symmetric, symmetric_scale)

__all__ = [
    "OBSERVERS", "LayerQuant", "QuantConfig", "calibrate", "observe_amax",
    "quantize_params", "mmd_degradation", "quantized_generator_apply",
    "quantized_generator_ref", "QMAX", "dequantize_symmetric", "fake_quant",
    "quantize_absmax", "quantize_symmetric", "symmetric_scale",
]
