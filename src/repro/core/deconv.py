"""The paper's reverse-loop deconvolution algorithm.

Three artifacts live here:

* ``deconv2d_algorithm1_numpy`` — a literal, instrumented transcription of the
  paper's Algorithm 1 (reverse loop over the output space, precomputed Eq. 3
  offsets, optional zero-skipping).  Used as the faithful-baseline oracle and
  to count executed MACs for the sparsity study (Fig. 6).
* ``deconv2d_reverse_loop`` — the TPU-native pure-JAX formulation: the Eq. 3
  offsets are folded into a trace-time *phase decomposition* so the device
  executes only static slices + channel matmuls (MXU-friendly), and the output
  is assembled with one pixel-shuffle.  This is the algorithm the Pallas
  kernel (kernels/deconv2d) implements per-tile.
* ``deconv2d_zero_insertion`` — the conventional zero-insertion formulation
  (what [23], [24], [22] build on, and what cuDNN/XLA execute): the paper's
  comparison baseline.

All take NHWC activations and (K, K, C_in, C_out) weights, with the
PyTorch-style geometry  O = (I-1)*S + K - 2P.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .offsets import make_phase_plan, offset_table
from .tiling import out_size


# ---------------------------------------------------------------------------
# Literal Algorithm 1 (numpy, instrumented)
# ---------------------------------------------------------------------------
def deconv2d_algorithm1_numpy(
    x: np.ndarray,
    w: np.ndarray,
    b: Optional[np.ndarray],
    stride: int,
    padding: int,
    t_oh: Optional[int] = None,
    t_ow: Optional[int] = None,
    zero_skip: bool = False,
) -> Tuple[np.ndarray, int]:
    """Paper Algorithm 1, per output tile, with Eq. 3 offsets precomputed.

    x: (IH, IW, CI);  w: (K, K, CI, CO);  returns (y (OH, OW, CO), macs).
    ``zero_skip`` reproduces the conditional-execution paradigm: weights equal
    to zero are skipped and the returned MAC count drops accordingly.
    """
    ih, iw, ci = x.shape
    k = w.shape[0]
    oh = out_size(ih, k, stride, padding)
    ow = out_size(iw, k, stride, padding)
    t_oh = t_oh or oh
    t_ow = t_ow or ow
    f = offset_table(k, stride, padding)  # enhancement (1): 2K modulo ops total
    y = np.zeros((oh, ow, w.shape[3]), dtype=np.float64)
    if b is not None:
        y += b  # initializeToBias()
    macs = 0
    # spatially-parallel CU workloads: disjoint output tiles
    for base_h in range(0, oh, t_oh):
        for base_w in range(0, ow, t_ow):
            # enhancement (2): weight loops outermost (loop interchange)
            for kh in range(k):
                for kw in range(k):
                    fh, fw = int(f[kh]), int(f[kw])
                    for oh_hat in range(0, t_oh, stride):
                        for ow_hat in range(0, t_ow, stride):
                            o_h = base_h + oh_hat + fh
                            o_w = base_w + ow_hat + fw
                            if o_h >= oh or o_w >= ow:
                                continue
                            i_h, rh = divmod(o_h + padding - kh, stride)
                            i_w, rw = divmod(o_w + padding - kw, stride)
                            assert rh == 0 and rw == 0, "offset math broken"
                            if not (0 <= i_h < ih and 0 <= i_w < iw):
                                continue
                            wv = w[kh, kw]  # (CI, CO)
                            if zero_skip:
                                nz = wv != 0.0
                                y[o_h, o_w] += x[i_h, i_w] @ (wv * nz)
                                macs += int(nz.sum())
                            else:
                                y[o_h, o_w] += x[i_h, i_w] @ wv
                                macs += wv.size
    return y.astype(x.dtype), macs


# ---------------------------------------------------------------------------
# TPU-native phase-decomposed reverse loop (pure JAX)
# ---------------------------------------------------------------------------
def _phase_pads(n_h: int, n_w: int, ih: int, iw: int, plan) -> Tuple[int, int, int, int]:
    pad_l = plan.left_halo
    pad_rh = max(0, (n_h - 1 + plan.delta_max) - (ih - 1))
    pad_rw = max(0, (n_w - 1 + plan.delta_max) - (iw - 1))
    return pad_l, pad_rh, pad_l, pad_rw


def deconv2d_reverse_loop(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
    accum_dtype=jnp.float32,
) -> jax.Array:
    """Reverse-loop deconvolution with trace-time phase decomposition.

    Per output phase (ph, pw) and contributing tap (kh, kw) the contribution
    is a shifted slice of x contracted with w[kh, kw] — a channel matmul that
    maps onto the MXU.  Output is assembled with a single pixel shuffle
    (disjoint one-shot writes: enhancement (2)/(3)).
    """
    n, ih, iw, ci = x.shape
    k = w.shape[0]
    s = stride
    oh = out_size(ih, k, s, padding)
    ow = out_size(iw, k, s, padding)
    plan = make_phase_plan(k, s, padding)
    n_h = -(-oh // s)  # ceil: padded phase grid
    n_w = -(-ow // s)
    pl_, prh, pt, prw = _phase_pads(n_h, n_w, ih, iw, plan)
    xp = jnp.pad(x, ((0, 0), (pl_, prh), (pt, prw), (0, 0)))

    # (S, S) grid of phase accumulators, each (N, n_h, n_w, CO)
    co = w.shape[3]
    rows = []
    for ph in range(s):
        cols = []
        for pw in range(s):
            acc = jnp.zeros((n, n_h, n_w, co), dtype=accum_dtype)
            for kh, dh in plan.taps[ph]:
                for kw, dw in plan.taps[pw]:
                    xs = jax.lax.dynamic_slice(
                        xp,
                        (0, pl_ + dh, pt + dw, 0),
                        (n, n_h, n_w, ci),
                    )
                    acc = acc + jnp.einsum(
                        "nhwc,cd->nhwd",
                        xs,
                        w[kh, kw],
                        preferred_element_type=accum_dtype,
                    )
            cols.append(acc)
        rows.append(jnp.stack(cols, axis=0))  # (S_w, N, n_h, n_w, CO)
    y = jnp.stack(rows, axis=0)  # (S_h, S_w, N, n_h, n_w, CO)
    # pixel shuffle: (N, n_h, S_h, n_w, S_w, CO) -> (N, n_h*S, n_w*S, CO)
    y = y.transpose(2, 3, 0, 4, 1, 5).reshape(n, n_h * s, n_w * s, co)
    y = y[:, :oh, :ow, :]
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Conventional zero-insertion formulation (the GPU/XLA baseline)
# ---------------------------------------------------------------------------
def deconv2d_zero_insertion(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
) -> jax.Array:
    """Transposed conv via input dilation: correlate the S-dilated, (K-1-P)-
    padded input with the spatially-flipped kernel.  This is the standard
    formulation the paper contrasts against (zero-insertion wastes
    (S^2-1)/S^2 of the MACs on zeros)."""
    k = w.shape[0]
    wf = jnp.flip(w, axis=(0, 1))
    pad = k - 1 - padding
    return _conv(x, wf, b, pad, stride)


def _conv(x, w, b, pad, lhs_dilation):
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding=((pad, pad), (pad, pad)),
        lhs_dilation=(lhs_dilation, lhs_dilation),
        rhs_dilation=(1, 1),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b
    return y
