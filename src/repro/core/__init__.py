"""Core reproduction of the paper's algorithmic contribution."""
from .deconv import (
    deconv2d_algorithm1_numpy,
    deconv2d_reverse_loop,
    deconv2d_zero_insertion,
)
from .dse import (PYNQ_Z2, TPU_V5E, Device, layer_dse, optimize_unified_tile,
                  tile_attainable)
from .metric import optimal_sparsity, quality_speed_metric
from .mmd import median_bandwidth, mmd, mmd2
from .offsets import make_phase_plan, offset, offset_table, taps_for_phase
from .sparsity import block_mask, magnitude_prune, prune_tree, zero_skip_stats
from .tiling import (
    DeconvGeometry,
    deconv_traffic,
    deconv_traffic_batched,
    exact_input_extent,
    full_image_traffic,
    halo_tile,
    input_tile_extent,
    kernel_vmem_bytes,
    legal_tile_factors,
    out_size,
)

__all__ = [
    "deconv2d_algorithm1_numpy",
    "deconv2d_reverse_loop",
    "deconv2d_zero_insertion",
    "Device",
    "TPU_V5E",
    "PYNQ_Z2",
    "layer_dse",
    "optimize_unified_tile",
    "optimal_sparsity",
    "quality_speed_metric",
    "median_bandwidth",
    "mmd",
    "mmd2",
    "make_phase_plan",
    "offset",
    "offset_table",
    "taps_for_phase",
    "block_mask",
    "magnitude_prune",
    "prune_tree",
    "zero_skip_stats",
    "tile_attainable",
    "DeconvGeometry",
    "deconv_traffic",
    "deconv_traffic_batched",
    "exact_input_extent",
    "full_image_traffic",
    "halo_tile",
    "input_tile_extent",
    "kernel_vmem_bytes",
    "legal_tile_factors",
    "out_size",
]
