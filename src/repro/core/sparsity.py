"""Weight pruning + zero-skipping analysis (paper §V-C, Fig. 6).

Magnitude pruning as in Han et al. [11]; zero-skipping execution-time model
at element granularity (the FPGA's conditional execution) and at block
granularity (our TPU adaptation — the MXU executes in lockstep, so skipping
happens at (C_in-block x C_out-block) slab granularity, statically known at
weight-load time)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def magnitude_prune(w: jax.Array, sparsity: float) -> Tuple[jax.Array, jax.Array]:
    """Zero the smallest-|w| fraction ``sparsity`` of entries.  Returns
    (pruned weights, keep-mask)."""
    if sparsity <= 0.0:
        return w, jnp.ones_like(w, dtype=bool)
    flat = jnp.abs(w).reshape(-1)
    k = int(np.clip(round(sparsity * flat.size), 0, flat.size))
    if k == 0:
        return w, jnp.ones_like(w, dtype=bool)
    thresh = jnp.sort(flat)[k - 1]
    mask = jnp.abs(w) > thresh
    return w * mask, mask


def prune_tree(params, sparsity: float, key_filter=lambda path: True):
    """Magnitude-prune every weight leaf of a pytree (biases excluded by the
    caller's filter)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    leaves = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if key_filter(name) and leaf.ndim >= 2:
            leaves.append(magnitude_prune(leaf, sparsity)[0])
        else:
            leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass(frozen=True)
class SkipStats:
    total_macs: int
    element_macs: int        # MACs under element-level zero-skip (FPGA)
    block_macs: int          # MACs under block-level zero-skip (TPU, ours)
    element_speedup: float   # t0 / tp model: total / executed
    block_speedup: float


def zero_skip_stats(
    w: np.ndarray, block_ci: int = 8, block_co: int = 128
) -> SkipStats:
    """Execution-time model of zero-skipping for one (K,K,CI,CO) weight.

    * element level: every zero weight's MAC is skipped (paper's FPGA CUs);
    * block level: a (block_ci x block_co) slab of a tap is skipped iff it is
      entirely zero (our static scalar-prefetch skip in deconv2d_sparse).
    """
    k1, k2, ci, co = w.shape
    total = k1 * k2 * ci * co
    nz = np.asarray(w) != 0.0
    element = int(nz.sum())
    n_ci = -(-ci // block_ci)
    n_co = -(-co // block_co)
    block = 0
    for kh in range(k1):
        for kw in range(k2):
            for bi in range(n_ci):
                sl_i = slice(bi * block_ci, min((bi + 1) * block_ci, ci))
                for bo in range(n_co):
                    sl_o = slice(bo * block_co, min((bo + 1) * block_co, co))
                    slab = nz[kh, kw, sl_i, sl_o]
                    if slab.any():
                        block += slab.size
    return SkipStats(
        total_macs=total,
        element_macs=element,
        block_macs=block,
        element_speedup=total / max(element, 1),
        block_speedup=total / max(block, 1),
    )


def block_mask(w: np.ndarray, block_ci: int, block_co: int) -> np.ndarray:
    """(K, K, n_ci_blocks, n_co_blocks) bool — True where the slab has any
    nonzero (must be computed).  Consumed by kernels/deconv2d_sparse."""
    k1, k2, ci, co = w.shape
    n_ci = -(-ci // block_ci)
    n_co = -(-co // block_co)
    pad_ci = n_ci * block_ci - ci
    pad_co = n_co * block_co - co
    nz = np.pad(np.asarray(w) != 0.0, ((0, 0), (0, 0), (0, pad_ci), (0, pad_co)))
    nz = nz.reshape(k1, k2, n_ci, block_ci, n_co, block_co)
    return nz.any(axis=(3, 5))
