"""Design-space exploration over output tiling factors (paper §V-A, Fig. 5).

Methodology of Zhang et al. [25] as used by the paper: for every *legal*
tiling factor, compute the computation-to-communication (CTC) ratio and the
attainable throughput

    attainable(T) = min(peak_ops, CTC(T) * sustainable_bandwidth)

then pick the tiling factor maximizing attainable throughput (solutions left
of the bandwidth slope are infeasible).  The paper optimizes one *unified*
T_OH across all layers of a network (the accelerator multiplexes layers);
we reproduce that and also report the per-layer optimum it sacrifices.

On TPU, VMEM capacity plays BRAM's role and HBM bandwidth plays DDR's; the
same construction drives our Pallas block-shape choice.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .tiling import (DeconvGeometry, deconv_traffic_batched,
                     legal_tile_factors, vmem_footprint)


@dataclasses.dataclass(frozen=True)
class Device:
    name: str
    peak_ops: float          # ops/s (1 MAC = 2 ops)
    bandwidth: float         # sustainable external bytes/s
    onchip_bytes: int        # VMEM / BRAM capacity available to the kernel
    dtype_bytes: int = 4
    # on-chip footprint model: our kernel ("full_spatial") vs the paper's
    # FPGA streaming dataflow ("eq5")
    footprint_model: str = "full_spatial"
    # int8 MXU rate (ops/s); 0.0 = no dedicated int8 path (fall back to
    # peak_ops).  This is the compute-roofline side of the paper's
    # low-precision advantage — quantization also quarters the traffic.
    int8_peak_ops: float = 0.0

    def peak_for(self, dtype_bytes: Optional[int] = None) -> float:
        """Compute roofline for a given element width: the int8 datapath
        doubles the MXU rate where the hardware has one."""
        if dtype_bytes == 1 and self.int8_peak_ops > 0.0:
            return self.int8_peak_ops
        return self.peak_ops

    def __str__(self) -> str:  # pragma: no cover
        return self.name


# TPU v5e chip (target hardware; roofline constants from the task spec).
TPU_V5E = Device(
    name="tpu-v5e",
    peak_ops=197e12,
    bandwidth=819e9,
    onchip_bytes=16 * 1024 * 1024,
    dtype_bytes=2,  # bf16
    int8_peak_ops=394e12,  # the MXU's doubled int8 rate
)

# The paper's PYNQ-Z2 point design: 16 CUs @ 125 MHz, 1 MAC/cycle/CU,
# STREAM-measured DDR bandwidth on the PS-PL interface.
PYNQ_Z2 = Device(
    name="pynq-z2",
    peak_ops=16 * 125e6 * 2,
    bandwidth=2.0e9,
    onchip_bytes=int(0.6 * 1024 * 1024),  # 140 x 36Kb BRAMs, ~60% usable
    dtype_bytes=4,  # 32-bit fixed point
    footprint_model="eq5",  # the FPGA streams Eq.-5 input tiles
)


@dataclasses.dataclass(frozen=True)
class DsePoint:
    t_oh: int
    ctc: float                # ops per external byte
    attainable_ops: float     # ops/s
    vmem_bytes: int
    bandwidth_bound: bool


def layer_dse(
    geom: DeconvGeometry,
    device: Device = TPU_V5E,
    co_tile: int = 128,
) -> List[DsePoint]:
    """All legal (T_OH = T_OW) design points for one layer on one device."""
    points: List[DsePoint] = []
    for t in legal_tile_factors(
        geom, vmem_budget_bytes=device.onchip_bytes,
        dtype_bytes=device.dtype_bytes, co_tile=co_tile,
        model=device.footprint_model,
    ):
        ctc = _ctc_ratio(geom, t, co_tile, device.dtype_bytes)
        attainable = min(device.peak_ops, ctc * device.bandwidth)
        points.append(
            DsePoint(
                t_oh=t,
                ctc=ctc,
                attainable_ops=attainable,
                vmem_bytes=vmem_footprint(geom, t, co_tile,
                                           device.dtype_bytes,
                                           device.footprint_model),
                bandwidth_bound=ctc * device.bandwidth < device.peak_ops,
            )
        )
    return points


def _ctc_ratio(geom: DeconvGeometry, t_oh: int, co_tile: int,
               dtype_bytes: int) -> float:
    """Computation-to-communication ratio for tiling factor t_oh.

    External traffic per tile (paper §III enhancement (3)): one Eq.-5 input
    block, one weight block, one one-shot output block."""
    from .tiling import input_tile_extent

    s = geom.stride
    t_ih = input_tile_extent(t_oh, geom.kernel, s)
    co_t = min(co_tile, geom.c_out)
    n_tiles_h = -(-geom.out_h // t_oh)
    n_tiles_w = -(-geom.out_w // t_oh)
    n_tiles_co = -(-geom.c_out // co_t)
    n_tiles = n_tiles_h * n_tiles_w * n_tiles_co
    in_bytes = t_ih * t_ih * geom.c_in * dtype_bytes
    w_bytes = geom.kernel ** 2 * geom.c_in * co_t * dtype_bytes
    out_bytes = t_oh * t_oh * co_t * dtype_bytes
    total_bytes = n_tiles * (in_bytes + w_bytes + out_bytes)
    return geom.ops / max(total_bytes, 1)


def tile_attainable(
    geom: DeconvGeometry,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    device: Device = TPU_V5E,
    t_n: int = 1,
    batch: Optional[int] = None,
    dtype_bytes: Optional[int] = None,
    out_dtype_bytes: Optional[int] = None,
) -> DsePoint:
    """Roofline-attainable throughput for one *full* tile choice.

    Generalizes `layer_dse` (square spatial, fixed co_tile) to the five
    tile factors the Pallas kernel actually takes — this is the scoring
    function the autotuner (kernels/autotune.py) ranks candidates by.
    CTC uses the halo-streaming traffic model: the kernel re-streams
    ``t_n`` Eq. 5 windows + ONE weight slab per CI step of every output
    tile, so batch tiling amortizes weight traffic AND fills the MXU row
    dimension (``t_n * T_OH/S * T_OW/S`` contraction rows).  The MXU-fill
    factor scales the compute roofline: a tap matmul with fewer than 128
    rows leaves the systolic array proportionally idle.

    ``dtype_bytes`` makes the model precision-aware: it sets the
    bytes/element of the streamed traffic AND selects the device's peak
    for that width (int8 runs the doubled MXU rate), defaulting to the
    device's native ``dtype_bytes``."""
    batch = t_n if batch is None else batch
    dtype_bytes = device.dtype_bytes if dtype_bytes is None else dtype_bytes
    peak = device.peak_for(dtype_bytes)
    traffic = deconv_traffic_batched(geom, batch, t_n, t_oh, t_ow, t_ci,
                                     t_co, dtype_bytes,
                                     out_dtype_bytes=out_dtype_bytes)
    ctc = batch * geom.ops / max(traffic.total_bytes, 1)
    rows = t_n * (t_oh // geom.stride) * (t_ow // geom.stride)
    mxu_fill = min(1.0, rows / 128.0)
    attainable = min(peak * mxu_fill, ctc * device.bandwidth)
    from .tiling import kernel_vmem_bytes

    return DsePoint(
        t_oh=t_oh,
        ctc=ctc,
        attainable_ops=attainable,
        vmem_bytes=kernel_vmem_bytes(geom, t_oh, t_ow, t_ci, t_co,
                                     dtype_bytes, t_n=t_n,
                                     out_dtype_bytes=out_dtype_bytes),
        bandwidth_bound=ctc * device.bandwidth < peak * mxu_fill,
    )


def optimize_unified_tile(
    geoms: Sequence[DeconvGeometry],
    device: Device = TPU_V5E,
    co_tile: int = 128,
) -> Tuple[int, Dict[int, float]]:
    """Paper §V-A: one unified T_OH across all layers of a network, chosen to
    maximize the *network* attainable throughput (total ops / sum of per-layer
    times).  A layer whose output is smaller than T_OH clamps the tile to its
    own extent (the paper's MNIST T=12 vs L1's 7x7 output).
    Returns (optimal T_OH, {T_OH: network attainable ops/s})."""
    per_layer = [{p.t_oh: p for p in layer_dse(g, device, co_tile)}
                 for g in geoms]
    if any(not pts for pts in per_layer):
        raise ValueError("a layer has no legal tiling factor on this device")
    candidates = sorted(set().union(*[set(p) for p in per_layer]))
    scores: Dict[int, float] = {}
    for t in candidates:
        total_ops = 0.0
        total_time = 0.0
        feasible = True
        for g, pts in zip(geoms, per_layer):
            legal = [k for k in pts if k <= t]
            if not legal:
                feasible = False
                break
            eff = max(legal)  # clamp the unified tile to this layer
            total_ops += g.ops
            total_time += g.ops / pts[eff].attainable_ops
        if feasible:
            scores[t] = total_ops / total_time
    best = max(scores, key=lambda t: scores[t])
    return best, scores


def per_layer_optimum(
    geoms: Sequence[DeconvGeometry],
    device: Device = TPU_V5E,
    co_tile: int = 128,
) -> List[DsePoint]:
    """What dynamically reconfiguring per layer (paper's future work) buys."""
    best = []
    for g in geoms:
        pts = layer_dse(g, device, co_tile)
        best.append(max(pts, key=lambda p: p.attainable_ops))
    return best
