"""The paper's sparsity operating-point metric (Eq. 6).

    M(p) = (d0 / dp) * (t0 / tp)

where (t0, d0) are latency / MMD of the dense network and (tp, dp) of the
pruned network.  Latency drops with sparsity (zero-skipping) while MMD rises,
so M is concave with an interior peak — the sparsity balancing image quality
against execution time (paper Fig. 6)."""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def quality_speed_metric(
    t0: float, d0: float, tp: Sequence[float], dp: Sequence[float]
) -> np.ndarray:
    tp = np.asarray(tp, dtype=np.float64)
    dp = np.asarray(dp, dtype=np.float64)
    return (d0 / dp) * (t0 / tp)


def optimal_sparsity(
    sparsities: Sequence[float],
    t0: float,
    d0: float,
    tp: Sequence[float],
    dp: Sequence[float],
) -> Tuple[float, np.ndarray]:
    """Returns (argmax sparsity, metric curve)."""
    m = quality_speed_metric(t0, d0, tp, dp)
    idx = int(np.argmax(m))
    return float(np.asarray(sparsities)[idx]), m
