"""Output-space tile calculus (paper Eq. 5 + legality constraints).

The reverse-loop algorithm tiles the *output* space into disjoint
``T_OH x T_OW`` blocks (no overlapping-sum problem), and the input tile
required per output tile has the *constant* extent of Eq. 5:

    T_IH = ceil(T_OH / S) + ceil(K / S)                       (Eq. 5)

independent of the tile position — the property that makes the FPGA CU
workloads uniform, and that makes our Pallas BlockSpecs static.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

from .offsets import PhasePlan, make_phase_plan


def out_size(in_size: int, kernel: int, stride: int, padding: int) -> int:
    """Transposed-conv output extent (PyTorch ConvTranspose2d convention)."""
    return (in_size - 1) * stride + kernel - 2 * padding


def in_size_for(out_size_: int, kernel: int, stride: int, padding: int) -> int:
    n = out_size_ - kernel + 2 * padding
    assert n % stride == 0, "inconsistent deconv geometry"
    return n // stride + 1


def input_tile_extent(t_oh: int, kernel: int, stride: int) -> int:
    """Paper Eq. 5 (an upper bound on the exact extent; see tests)."""
    return math.ceil(t_oh / stride) + math.ceil(kernel / stride)


def exact_input_extent(
    t_oh: int, kernel: int, stride: int, padding: int
) -> int:
    """Exact max-over-tiles input extent max(i)-min(i)+1 for an S-aligned tile
    of T_OH output pixels.  Property-tested to be <= Eq. 5's bound."""
    plan = make_phase_plan(kernel, stride, padding)
    # rows accessed for tile rows [0, T_OH): i = t + delta, t in [0, ceil(T_OH/S))
    lo = plan.delta_min
    hi = (t_oh - 1) // stride + plan.delta_max
    return hi - lo + 1


@dataclasses.dataclass(frozen=True)
class DeconvGeometry:
    """Static geometry of one deconv layer."""

    in_h: int
    in_w: int
    c_in: int
    c_out: int
    kernel: int
    stride: int
    padding: int

    @property
    def out_h(self) -> int:
        return out_size(self.in_h, self.kernel, self.stride, self.padding)

    @property
    def out_w(self) -> int:
        return out_size(self.in_w, self.kernel, self.stride, self.padding)

    @property
    def macs(self) -> int:
        """Multiply-accumulates for the full layer (per batch element).
        Every (input pixel, tap, c_in, c_out) combination is one MAC."""
        return self.in_h * self.in_w * self.kernel * self.kernel * self.c_in * self.c_out

    @property
    def ops(self) -> int:
        """GOps convention of the paper: 2 ops per MAC."""
        return 2 * self.macs

    def phase_plan(self) -> PhasePlan:
        return make_phase_plan(self.kernel, self.stride, self.padding)

    def halo_padding(self) -> Tuple[int, int]:
        """(pad_left, pad_right) applied to the input spatial dims so that
        every tap access of every S-aligned output tile is in bounds
        (enhancement (3): all address arithmetic is resolved ahead of the
        kernel; the device performs only static in-bounds slices)."""
        plan = self.phase_plan()
        pad_l = plan.left_halo
        # Worst-case right access for the last (possibly ragged) tile:
        # o = out_h - 1 -> t_max = (out_h - 1) // S within its phase, plus halo.
        i_max = (self.out_h - 1) // self.stride + plan.delta_max
        pad_r = max(0, i_max - (self.in_h - 1))
        return pad_l, pad_r


def legal_tile_factors(
    geom: DeconvGeometry,
    vmem_budget_bytes: int = 12 * 1024 * 1024,
    dtype_bytes: int = 4,
    co_tile: int = 128,
    model: str = "full_spatial",
) -> List[int]:
    """Enumerate legal square output tiling factors T_OH = T_OW (the paper
    explores square tiles).  Legality (the paper's Fig. 5 'legal solutions'):

    * S | T_OH       — tiles are stride-aligned so the phase structure is
                        identical for every tile (uniform CU workloads);
    * on-chip fit    — input block + weight block + output block + f32
                        accumulator fit the budget (VMEM / BRAM).

    `model`: "full_spatial" budgets our Pallas kernel (whole input spatial
    resident per C_in tile); "eq5" budgets the paper's FPGA dataflow (an
    Eq.-5 T_IH x T_IW input tile per output tile)."""
    out: List[int] = []
    s = geom.stride
    for t in range(s, geom.out_h + s, s):
        if t % s:
            continue
        t_oh = min(t, geom.out_h)
        footprint = _vmem_footprint(geom, t_oh, co_tile, dtype_bytes, model)
        if footprint <= vmem_budget_bytes:
            out.append(t)
        if t >= geom.out_h:
            break
    return sorted(set(out))


def _vmem_footprint(
    geom: DeconvGeometry, t_oh: int, co_tile: int, dtype_bytes: int,
    model: str = "full_spatial",
) -> int:
    co_t = min(co_tile, geom.c_out)
    if model == "eq5":
        # the FPGA dataflow streams Eq.-5 input tiles AND input-channel
        # blocks (Algorithm 1's i_c loop) through BRAM
        t_ih = input_tile_extent(t_oh, geom.kernel, geom.stride)
        in_spatial = t_ih * t_ih
        ci_t = min(32, geom.c_in)
    else:
        pad_l, pad_r = geom.halo_padding()
        in_spatial = ((geom.in_h + pad_l + pad_r)
                      * (geom.in_w + pad_l + pad_r))
        ci_t = geom.c_in
    x_bytes = in_spatial * ci_t * dtype_bytes
    w_bytes = geom.kernel * geom.kernel * ci_t * co_t * dtype_bytes
    y_bytes = t_oh * t_oh * co_t * dtype_bytes
    acc_bytes = t_oh * t_oh * co_t * 4  # f32 accumulator scratch
    return x_bytes + w_bytes + y_bytes + acc_bytes


def vmem_footprint(geom: DeconvGeometry, t_oh: int, co_tile: int = 128,
                   dtype_bytes: int = 4, model: str = "full_spatial") -> int:
    return _vmem_footprint(geom, t_oh, co_tile, dtype_bytes, model)
