"""Output-space tile calculus (paper Eq. 5 + legality constraints).

The reverse-loop algorithm tiles the *output* space into disjoint
``T_OH x T_OW`` blocks (no overlapping-sum problem), and the input tile
required per output tile has the *constant* extent of Eq. 5:

    T_IH = ceil(T_OH / S) + ceil(K / S)                       (Eq. 5)

independent of the tile position — the property that makes the FPGA CU
workloads uniform, and that makes our Pallas BlockSpecs static.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from .offsets import PhasePlan, make_phase_plan


def out_size(in_size: int, kernel: int, stride: int, padding: int) -> int:
    """Transposed-conv output extent (PyTorch ConvTranspose2d convention)."""
    return (in_size - 1) * stride + kernel - 2 * padding


def in_size_for(out_size_: int, kernel: int, stride: int, padding: int) -> int:
    n = out_size_ - kernel + 2 * padding
    assert n % stride == 0, "inconsistent deconv geometry"
    return n // stride + 1


def input_tile_extent(t_oh: int, kernel: int, stride: int) -> int:
    """Paper Eq. 5 (an upper bound on the exact extent; see tests)."""
    return math.ceil(t_oh / stride) + math.ceil(kernel / stride)


def exact_input_extent(
    t_oh: int, kernel: int, stride: int, padding: int
) -> int:
    """Exact max-over-tiles input extent max(i)-min(i)+1 for an S-aligned tile
    of T_OH output pixels.  Property-tested to be <= Eq. 5's bound."""
    plan = make_phase_plan(kernel, stride, padding)
    # rows accessed for tile rows [0, T_OH): i = t + delta, t in [0, ceil(T_OH/S))
    lo = plan.delta_min
    hi = (t_oh - 1) // stride + plan.delta_max
    return hi - lo + 1


@dataclasses.dataclass(frozen=True)
class HaloTile:
    """Eq. 5 input-tile geometry for one spatial dim of the Pallas kernel.

    An S-aligned output tile of ``t_out`` pixels starting at output row
    ``j * t_out`` reads the *constant-extent* input window

        rows [ j * (t_out // S) + base,  j * (t_out // S) + base + extent )

    of the host-padded input — ``extent = t_out/S + delta_max - delta_min``
    (the exact form of the paper's Eq. 5 bound) and ``base >= 0`` because
    the host pads ``left_halo`` rows on the left.  Consecutive windows
    overlap by ``extent - t_out/S`` halo rows; the kernel's per-tap slices
    inside the window are *static*: tap displacement ``d`` lives at local
    row ``d - delta_min``.
    """

    t_out: int       # output tile extent (multiple of S)
    stride: int
    extent: int      # input window extent T_I (rows streamed per tile)
    base: int        # element offset of tile j's window: j*(t_out/S) + base
    local_zero: int  # local row of displacement delta=0 == -delta_min

    @property
    def step(self) -> int:
        """Window start advance per output tile (t_out / S input rows)."""
        return self.t_out // self.stride

    @property
    def overlap(self) -> int:
        """Halo rows shared by consecutive windows."""
        return self.extent - self.step

    def local_offset(self, delta: int) -> int:
        """Static in-window row of a tap with input displacement ``delta``."""
        return delta + self.local_zero

    def min_padded_extent(self, n_tiles: int) -> int:
        """Smallest padded input extent covering all n_tiles windows."""
        return (n_tiles - 1) * self.step + self.base + self.extent


def halo_tile(t_out: int, kernel: int, stride: int, padding: int) -> HaloTile:
    """Input-window geometry for an S-aligned output tile (paper Eq. 5).

    The window extent equals ``exact_input_extent`` — the max-over-tiles
    input span — so the Pallas BlockSpec streams exactly the rows the tile
    touches (plus nothing), which is what drops per-tile HBM traffic from
    O(padded image) to O(T_I).
    """
    assert t_out % stride == 0, "tiles must be stride-aligned"
    plan = make_phase_plan(kernel, stride, padding)
    step = t_out // stride
    extent = step + plan.delta_max - plan.delta_min
    # host pads left_halo = max(0, -delta_min) rows; window j then starts at
    # j*step + (left_halo + delta_min) = j*step + max(0, delta_min) >= 0.
    base = plan.left_halo + plan.delta_min
    return HaloTile(
        t_out=t_out,
        stride=stride,
        extent=extent,
        base=base,
        local_zero=-plan.delta_min,
    )


def kernel_vmem_bytes(
    geom: DeconvGeometry,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    dtype_bytes: int = 4,
    t_n: int = 1,
    out_dtype_bytes: Optional[int] = None,
) -> int:
    """Precise VMEM footprint of the halo-streaming Pallas kernel.

    Input/weight/bias blocks are double-buffered by the Mosaic pipeline
    (x2); the 4-byte accumulator scratch (f32 for the dense/sparse
    kernels, int32 for the int8 kernel) and the output block are single.
    ``t_n`` is the batch tile: each grid program owns ``t_n`` images' halo
    windows / output blocks (the weight slab is batch-stationary).
    ``dtype_bytes`` is the streamed element width (1 for the int8 kernel);
    ``out_dtype_bytes`` overrides the output block's width when it differs
    from the inputs' (an int8 layer whose epilogue emits f32)."""
    ht_h = halo_tile(t_oh, geom.kernel, geom.stride, geom.padding)
    ht_w = halo_tile(t_ow, geom.kernel, geom.stride, geom.padding)
    out_b = dtype_bytes if out_dtype_bytes is None else out_dtype_bytes
    x_bytes = t_n * ht_h.extent * ht_w.extent * t_ci * dtype_bytes
    w_bytes = geom.kernel * geom.kernel * t_ci * t_co * dtype_bytes
    # epilogue vectors stream as f32: bias for the float kernels, bias AND
    # the per-channel requant scale for the int8 kernel (two in_specs)
    b_bytes = (2 if dtype_bytes == 1 else 1) * t_co * max(dtype_bytes, 4)
    y_bytes = t_n * t_oh * t_ow * t_co * out_b
    acc_bytes = t_n * t_oh * t_ow * t_co * 4
    return 2 * (x_bytes + w_bytes + b_bytes) + y_bytes + acc_bytes


@dataclasses.dataclass(frozen=True)
class DeconvGeometry:
    """Static geometry of one deconv layer."""

    in_h: int
    in_w: int
    c_in: int
    c_out: int
    kernel: int
    stride: int
    padding: int

    @property
    def out_h(self) -> int:
        return out_size(self.in_h, self.kernel, self.stride, self.padding)

    @property
    def out_w(self) -> int:
        return out_size(self.in_w, self.kernel, self.stride, self.padding)

    @property
    def macs(self) -> int:
        """Multiply-accumulates for the full layer (per batch element).
        Every (input pixel, tap, c_in, c_out) combination is one MAC."""
        return self.in_h * self.in_w * self.kernel * self.kernel * self.c_in * self.c_out

    @property
    def ops(self) -> int:
        """GOps convention of the paper: 2 ops per MAC."""
        return 2 * self.macs

    def phase_plan(self) -> PhasePlan:
        return make_phase_plan(self.kernel, self.stride, self.padding)

    def halo_padding(self) -> Tuple[int, int]:
        """(pad_left, pad_right) applied to the input spatial dims so that
        every tap access of every S-aligned output tile is in bounds
        (enhancement (3): all address arithmetic is resolved ahead of the
        kernel; the device performs only static in-bounds slices)."""
        plan = self.phase_plan()
        pad_l = plan.left_halo
        # Worst-case right access for the last (possibly ragged) tile:
        # o = out_h - 1 -> t_max = (out_h - 1) // S within its phase, plus halo.
        i_max = (self.out_h - 1) // self.stride + plan.delta_max
        pad_r = max(0, i_max - (self.in_h - 1))
        return pad_l, pad_r


@dataclasses.dataclass(frozen=True)
class DeconvTraffic:
    """Modeled HBM traffic of the halo-streaming kernel for one layer
    (per batch element).  ``in_bytes_per_tile`` is the Eq. 5 window — a
    constant per tile, independent of image size (the paper's point).
    Bytes only; CTC / attainable throughput live in `dse.tile_attainable`.
    """

    n_tiles: int              # spatial x C_out output tiles
    n_ci_steps: int           # C_in grid steps per output tile
    in_bytes_per_tile: int    # halo window bytes per (tile, ci step)
    w_bytes_per_tile: int     # weight slab bytes per (tile, ci step)
    out_bytes_per_tile: int   # one-shot output block bytes
    total_bytes: int


def deconv_traffic(
    geom: DeconvGeometry,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    dtype_bytes: int = 4,
) -> DeconvTraffic:
    """HBM bytes moved by the halo-streaming kernel (per batch element).

    Per output tile the CI grid re-streams one Eq. 5 input window and one
    weight slab per CI step; the output block is written once.  This is the
    modeled side of the modeled-vs-measured accounting in
    benchmarks/bench_deconv.py."""
    ht_h = halo_tile(t_oh, geom.kernel, geom.stride, geom.padding)
    ht_w = halo_tile(t_ow, geom.kernel, geom.stride, geom.padding)
    n_h = -(-geom.out_h // t_oh)
    n_w = -(-geom.out_w // t_ow)
    n_co = -(-geom.c_out // t_co)
    n_ci = -(-geom.c_in // t_ci)
    in_b = ht_h.extent * ht_w.extent * t_ci * dtype_bytes
    w_b = geom.kernel * geom.kernel * t_ci * t_co * dtype_bytes
    out_b = t_oh * t_ow * t_co * dtype_bytes
    n_tiles = n_h * n_w * n_co
    total = n_tiles * (n_ci * (in_b + w_b) + out_b)
    return DeconvTraffic(
        n_tiles=n_tiles,
        n_ci_steps=n_ci,
        in_bytes_per_tile=in_b,
        w_bytes_per_tile=w_b,
        out_bytes_per_tile=out_b,
        total_bytes=total,
    )


def deconv_traffic_batched(
    geom: DeconvGeometry,
    batch: int,
    t_n: int,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    dtype_bytes: int = 4,
    out_dtype_bytes: Optional[int] = None,
) -> DeconvTraffic:
    """HBM bytes moved for a *batch* under the batch-fused kernel.

    The batch dimension is tiled by ``t_n`` (batch folded into the MXU row
    dimension): each grid program streams ``t_n`` halo windows but only ONE
    weight slab per CI step, so weight traffic per image falls by ``t_n`` —
    the spatio-temporal amortization that makes the batched path win on the
    fat-channel early layers.  ``dtype_bytes`` is the streamed element
    width — 1 on the int8 path, where the quartered stream is half the
    paper's low-precision advantage — and ``out_dtype_bytes`` overrides
    the written block's width when the epilogue changes precision."""
    ht_h = halo_tile(t_oh, geom.kernel, geom.stride, geom.padding)
    ht_w = halo_tile(t_ow, geom.kernel, geom.stride, geom.padding)
    o_bytes = dtype_bytes if out_dtype_bytes is None else out_dtype_bytes
    n_n = -(-batch // t_n)
    n_h = -(-geom.out_h // t_oh)
    n_w = -(-geom.out_w // t_ow)
    n_co = -(-geom.c_out // t_co)
    n_ci = -(-geom.c_in // t_ci)
    in_b = t_n * ht_h.extent * ht_w.extent * t_ci * dtype_bytes
    w_b = geom.kernel * geom.kernel * t_ci * t_co * dtype_bytes
    out_b = t_n * t_oh * t_ow * t_co * o_bytes
    n_tiles = n_n * n_h * n_w * n_co
    total = n_tiles * (n_ci * (in_b + w_b) + out_b)
    return DeconvTraffic(
        n_tiles=n_tiles,
        n_ci_steps=n_ci,
        in_bytes_per_tile=in_b,
        w_bytes_per_tile=w_b,
        out_bytes_per_tile=out_b,
        total_bytes=total,
    )


def full_image_traffic(
    geom: DeconvGeometry,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    dtype_bytes: int = 4,
) -> DeconvTraffic:
    """HBM traffic of the pre-halo pipeline (every grid program re-streamed
    the whole padded input per CI step) — the baseline the tentpole kills.
    Same structure as `deconv_traffic`; only ``in_bytes_per_tile`` differs
    (the whole padded image instead of the Eq. 5 window)."""
    pad_l, pad_r = geom.halo_padding()
    ihp = geom.in_h + pad_l + pad_r
    iwp = geom.in_w + pad_l + pad_r
    n_h = -(-geom.out_h // t_oh)
    n_w = -(-geom.out_w // t_ow)
    n_co = -(-geom.c_out // t_co)
    n_ci = -(-geom.c_in // t_ci)
    in_b = ihp * iwp * t_ci * dtype_bytes
    w_b = geom.kernel * geom.kernel * t_ci * t_co * dtype_bytes
    out_b = t_oh * t_ow * t_co * dtype_bytes
    n_tiles = n_h * n_w * n_co
    return DeconvTraffic(
        n_tiles=n_tiles,
        n_ci_steps=n_ci,
        in_bytes_per_tile=in_b,
        w_bytes_per_tile=w_b,
        out_bytes_per_tile=out_b,
        total_bytes=n_tiles * (n_ci * (in_b + w_b) + out_b),
    )


def legal_tile_factors(
    geom: DeconvGeometry,
    vmem_budget_bytes: int = 12 * 1024 * 1024,
    dtype_bytes: int = 4,
    co_tile: int = 128,
    model: str = "full_spatial",
) -> List[int]:
    """Enumerate legal square output tiling factors T_OH = T_OW (the paper
    explores square tiles).  Legality (the paper's Fig. 5 'legal solutions'):

    * S | T_OH       — tiles are stride-aligned so the phase structure is
                        identical for every tile (uniform CU workloads);
    * on-chip fit    — input block + weight block + output block + f32
                        accumulator fit the budget (VMEM / BRAM).

    `model`: "full_spatial" budgets our Pallas kernel (whole input spatial
    resident per C_in tile); "eq5" budgets the paper's FPGA dataflow (an
    Eq.-5 T_IH x T_IW input tile per output tile)."""
    out: List[int] = []
    s = geom.stride
    for t in range(s, geom.out_h + s, s):
        if t % s:
            continue
        t_oh = min(t, geom.out_h)
        footprint = _vmem_footprint(geom, t_oh, co_tile, dtype_bytes, model)
        if footprint <= vmem_budget_bytes:
            out.append(t)
        if t >= geom.out_h:
            break
    return sorted(set(out))


def _vmem_footprint(
    geom: DeconvGeometry, t_oh: int, co_tile: int, dtype_bytes: int,
    model: str = "full_spatial",
) -> int:
    co_t = min(co_tile, geom.c_out)
    if model == "eq5":
        # the FPGA dataflow streams Eq.-5 input tiles AND input-channel
        # blocks (Algorithm 1's i_c loop) through BRAM
        t_ih = input_tile_extent(t_oh, geom.kernel, geom.stride)
        in_spatial = t_ih * t_ih
        ci_t = min(32, geom.c_in)
    else:
        pad_l, pad_r = geom.halo_padding()
        in_spatial = ((geom.in_h + pad_l + pad_r)
                      * (geom.in_w + pad_l + pad_r))
        ci_t = geom.c_in
    x_bytes = in_spatial * ci_t * dtype_bytes
    w_bytes = geom.kernel * geom.kernel * ci_t * co_t * dtype_bytes
    y_bytes = t_oh * t_oh * co_t * dtype_bytes
    acc_bytes = t_oh * t_oh * co_t * 4  # f32 accumulator scratch
    return x_bytes + w_bytes + y_bytes + acc_bytes


def vmem_footprint(geom: DeconvGeometry, t_oh: int, co_tile: int = 128,
                   dtype_bytes: int = 4, model: str = "full_spatial") -> int:
    return _vmem_footprint(geom, t_oh, co_tile, dtype_bytes, model)
