"""Maximum Mean Discrepancy (paper §V-C) with the Gaussian kernel.

The paper writes k(x,x') = exp(||x-x'||^2); the reproducing-kernel requirement
(Gretton et al. [9]) needs the negative exponent, and the paper selects "the
median euclidean distance between ground truth samples as the bandwidth" — we
implement k(x,x') = exp(-||x-x'||^2 / (2 sigma^2)) with sigma = median pairwise
distance (the standard median heuristic the paper references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _sq_dists(a: jax.Array, b: jax.Array) -> jax.Array:
    a2 = jnp.sum(a * a, axis=1)[:, None]
    b2 = jnp.sum(b * b, axis=1)[None, :]
    return jnp.maximum(a2 + b2 - 2.0 * (a @ b.T), 0.0)


def median_bandwidth(x: jax.Array) -> jax.Array:
    """Median euclidean distance between ground-truth samples (off-diagonal)."""
    d2 = _sq_dists(x, x)
    n = x.shape[0]
    off = d2[jnp.triu_indices(n, k=1)]
    return jnp.sqrt(jnp.median(off))


def mmd2(
    x: jax.Array,
    y: jax.Array,
    bandwidth: Optional[jax.Array] = None,
    unbiased: bool = True,
) -> jax.Array:
    """Squared MMD between sample sets x ~ P_g (ground truth) and y ~ P_theta.

    x, y: (n, d) / (m, d) flattened samples."""
    x = x.reshape(x.shape[0], -1).astype(jnp.float32)
    y = y.reshape(y.shape[0], -1).astype(jnp.float32)
    sigma = median_bandwidth(x) if bandwidth is None else bandwidth
    gamma = 1.0 / (2.0 * sigma ** 2 + 1e-12)
    kxx = jnp.exp(-gamma * _sq_dists(x, x))
    kyy = jnp.exp(-gamma * _sq_dists(y, y))
    kxy = jnp.exp(-gamma * _sq_dists(x, y))
    n, m = x.shape[0], y.shape[0]
    if unbiased:
        exx = (kxx.sum() - jnp.trace(kxx)) / (n * (n - 1))
        eyy = (kyy.sum() - jnp.trace(kyy)) / (m * (m - 1))
    else:
        exx = kxx.mean()
        eyy = kyy.mean()
    exy = kxy.mean()
    return exx + eyy - 2.0 * exy


def mmd(x: jax.Array, y: jax.Array, bandwidth: Optional[jax.Array] = None) -> jax.Array:
    """MMD distance (non-negative sqrt of the clipped squared estimate)."""
    return jnp.sqrt(jnp.maximum(mmd2(x, y, bandwidth), 0.0))
