"""Stride-hole-skipping offsets (paper Eq. 3) and their phase-decomposition.

The paper's enhancement (1): the offset

    f_h = mod(S - mod(P - k_h, S), S)                       (Eq. 3)

depends only on the filter-tap index ``k_h`` (not on the output pixel), so the
2K offsets are precomputed once per layer.  On TPU we go one step further and
fold the offsets into a *trace-time phase decomposition*: output pixel ``o``
receives tap ``k`` iff ``(o + P - k) % S == 0``, i.e. iff the output phase
``o % S`` equals ``(k - P) % S`` (== ``f_h`` — proved by ``test_offsets``).
The device therefore executes zero modulo instructions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


def offset(k: int, stride: int, padding: int) -> int:
    """Paper Eq. 3: f = mod(S - mod(P - k, S), S).

    ``np.mod`` follows the mathematical (non-negative) convention assumed by
    the paper's derivation.
    """
    s = int(stride)
    return int(np.mod(s - np.mod(padding - k, s), s))


def offset_table(kernel_size: int, stride: int, padding: int) -> np.ndarray:
    """Precompute the K offsets of enhancement (1).  2K ops total per layer
    (one table per spatial dim; square kernels share the table)."""
    return np.array(
        [offset(k, stride, padding) for k in range(kernel_size)], dtype=np.int32
    )


def taps_for_phase(phase: int, kernel_size: int, stride: int, padding: int) -> List[int]:
    """All tap indices k whose contributions land on output pixels of
    ``o % S == phase``; equivalently {k : f(k) == phase} (Eq. 3)."""
    return [k for k in range(kernel_size) if offset(k, stride, padding) == phase]


@dataclasses.dataclass(frozen=True)
class PhasePlan:
    """Static per-layer plan: for each output phase, the contributing taps and
    their input displacements ``delta = (phase + P - k) // S`` (an exact
    integer division by construction — this is Eq. 4 with the modulo removed).
    """

    kernel_size: int
    stride: int
    padding: int
    # phase -> list of (tap k, delta)
    taps: Dict[int, List[Tuple[int, int]]]
    delta_min: int
    delta_max: int

    @property
    def left_halo(self) -> int:
        """Input rows needed before the tile's base row (>= 0)."""
        return max(0, -self.delta_min)

    @property
    def right_halo(self) -> int:
        return max(0, self.delta_max)


def make_phase_plan(kernel_size: int, stride: int, padding: int) -> PhasePlan:
    taps: Dict[int, List[Tuple[int, int]]] = {p: [] for p in range(stride)}
    deltas: List[int] = []
    for phase in range(stride):
        for k in taps_for_phase(phase, kernel_size, stride, padding):
            num = phase + padding - k
            assert num % stride == 0, "phase decomposition must be exact"
            delta = num // stride
            taps[phase].append((k, delta))
            deltas.append(delta)
    if not deltas:  # degenerate (K == 0) — never used, keep total
        deltas = [0]
    return PhasePlan(
        kernel_size=kernel_size,
        stride=stride,
        padding=padding,
        taps=taps,
        delta_min=min(deltas),
        delta_max=max(deltas),
    )


def modulo_op_count_naive(kernel_size: int, out_h: int, out_w: int) -> int:
    """Modulo ops executed by the un-enhanced reverse-loop algorithm (Eq. 4
    evaluated per (tap, output pixel))."""
    return 2 * kernel_size * kernel_size * out_h * out_w


def modulo_op_count_paper(kernel_size: int) -> int:
    """Modulo ops with the paper's enhancement (1): 2K per layer."""
    return 2 * kernel_size


def modulo_op_count_ours() -> int:
    """Modulo ops on-device with trace-time phase decomposition: zero."""
    return 0
