"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries
pure data parallelism by default (one cross-pod gradient all-reduce per
step) and can alternatively host pipeline stages (dist.pipeline).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization)."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # axis_types / AxisType only exist on newer jax; Auto is the default
    # behavior there, so omitting it is equivalent where it is missing.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_serving_mesh(data: int = 0):
    """Pure data-parallel mesh for the DCNN bucket-serving / WGAN paths:
    one ``data`` axis over ``data`` devices (default: every visible
    device).  Params replicate; only the batch dim shards."""
    n = data or len(jax.devices())
    return _make_mesh((n,), ("data",))


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for host-device tests (subprocesses set
    --xla_force_host_platform_device_count accordingly)."""
    if pod:
        return _make_mesh((pod, data, model), ("pod", "data", "model"))
    return _make_mesh((data, model), ("data", "model"))
