"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real cluster this runs under one process per host with jax.distributed
initialized; the same code path compiles for the production mesh via
--mesh pod/multipod (see dryrun.py for the no-hardware check).

XLA flags for collective/compute overlap at scale are set here (latency-
hiding scheduler, async collectives) — they are harmless on CPU."""
from __future__ import annotations

import os

_OVERLAP_FLAGS = (
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    " --xla_tpu_enable_async_collective_fusion=true"
    " --xla_tpu_overlap_compute_collective_tc=true"
)
if "tpu" in os.environ.get("JAX_PLATFORMS", ""):
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + _OVERLAP_FLAGS

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="family-faithful reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs import LM_CONFIGS, reduced_config
    from ..data.pipeline import lm_source
    from ..models.transformer import init_lm
    from ..optim.compression import init_error_feedback
    from ..optim.optimizer import AdamW
    from ..optim.schedule import warmup_cosine
    from ..train.lm import make_train_step
    from ..train.loop import TrainDriver

    cfg = reduced_config(args.arch) if args.reduced else LM_CONFIGS[args.arch]
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M (full-config "
          f"count; reduced={args.reduced})")

    key = jax.random.PRNGKey(args.seed)
    params, _ = init_lm(key, cfg)
    n_p = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"instantiated params: {n_p/1e6:.2f}M")

    opt = AdamW(lr=warmup_cosine(3e-4, 20, args.steps), weight_decay=0.1)
    opt_state = opt.init(params)
    ef = init_error_feedback(params) if args.compress_grads else None
    step_fn_inner = jax.jit(
        make_train_step(cfg, opt, args.grad_accum, args.compress_grads))

    src = lm_source(args.seed, args.batch, args.seq, cfg.vocab_size)

    def step_fn(state, batch):
        params, opt_state, ef = state
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, ef, met = step_fn_inner(params, opt_state, ef, b)
        return (params, opt_state, ef), met

    driver = TrainDriver(step_fn, src, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every)
    t0 = time.time()
    state = driver.run((params, opt_state, ef), args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in driver.metrics_log if "loss" in m]
    print(f"done: {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers={len(driver.monitor.flagged)} "
          f"recoveries={driver.recoveries}")


if __name__ == "__main__":
    main()
