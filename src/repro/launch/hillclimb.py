import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb A/B harness: compiles baseline-vs-optimized variants of
the three chosen cells and records the roofline deltas.

    PYTHONPATH=src python -m repro.launch.hillclimb --out experiments/hillclimb

Cells (chosen per the methodology in EXPERIMENTS.md §Perf):
  H1  qwen2-moe-a2.7b x prefill_32k : pjit-auto MoE dispatch (replicating
      scatter) -> shard-local shard_map dispatch.
  H2  deepseek-7b x decode_32k      : bf16 KV cache -> int8 KV + dequant-on-
      read (+ the transpose-free blocked attention).
  H3  deepseek-7b x train_4k        : fsdp_tp (per-microbatch weight
      all-gather) -> tp (weights resident, grads reduce-scattered).
"""
import argparse
import dataclasses
import json
import time


def measure(cfg, shape, mesh, tag, out_dir, policy="auto", grad_accum=None):
    from ..analysis.hlo import analyze
    from ..analysis.roofline import model_flops
    from ..configs import SHAPES
    from .steps import lower_cell

    suite = SHAPES[shape]
    t0 = time.time()
    compiled = lower_cell(cfg, suite, mesh, policy=policy,
                          grad_accum=grad_accum).compile()
    hc = analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    rec = {
        "tag": tag, "arch": cfg.name, "shape": shape, "policy": policy,
        "compile_s": round(time.time() - t0, 1),
        "flops_per_device": hc.flops,
        "bytes_per_device": hc.bytes_accessed,
        "collective_bytes_per_device": hc.collective_bytes,
        "collectives": {k: list(v) for k, v in hc.collectives.items()},
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "args_gb": mem.argument_size_in_bytes / 1e9,
        "model_flops": model_flops(cfg, suite),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"{tag:40s} flops/dev={hc.flops:.3e} bytes/dev="
          f"{hc.bytes_accessed:.3e} coll/dev={hc.collective_bytes:.3e} "
          f"temp={rec['temp_gb']:.1f}GB")
    return rec


def measure_dcnn(backend: str, tag: str, out_dir: str, mesh,
                 global_batch: int = 4096):
    """H0 — the paper's own workload at pod scale: batched DCNN inference,
    reverse-loop vs zero-insertion formulation (the Table II comparison,
    expressed as compiled-FLOP waste)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..analysis.hlo import analyze
    from ..models.dcnn import CELEBA_DCNN, generator_apply, generator_init

    cfg = CELEBA_DCNN
    box = {}

    def init(k):
        p, s = generator_init(k, cfg)
        box["s"] = s
        return p

    p_shapes = jax.eval_shape(init, jax.random.PRNGKey(0))
    z = jax.ShapeDtypeStruct((global_batch, cfg.z_dim), jnp.float32)
    fn = jax.jit(
        lambda p, z: generator_apply(p, cfg, z, backend=backend),
        in_shardings=(None, NamedSharding(mesh, P(("data",)))),
    )
    t0 = time.time()
    compiled = fn.lower(p_shapes, z).compile()
    hc = analyze(compiled.as_text())
    ops = sum(g.ops for g in cfg.geometries()) * global_batch
    rec = {
        "tag": tag, "arch": "dcnn-celeba", "backend": backend,
        "global_batch": global_batch, "compile_s": round(time.time() - t0, 1),
        "flops_per_device": hc.flops,
        "bytes_per_device": hc.bytes_accessed,
        "collective_bytes_per_device": hc.collective_bytes,
        "model_flops": float(ops),
        "useful_ratio": ops / max(hc.flops * mesh.devices.size, 1),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"{tag:40s} flops/dev={hc.flops:.3e} bytes/dev="
          f"{hc.bytes_accessed:.3e} useful={rec['useful_ratio']:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/hillclimb")
    ap.add_argument("--only", default=None, help="h0|h1|h2|h3")
    args = ap.parse_args()

    from ..configs import LM_CONFIGS
    from .mesh import make_production_mesh

    mesh = make_production_mesh()

    if args.only in (None, "h0"):
        # H0: the paper's technique itself at pod scale
        measure_dcnn("xla", "h0_dcnn_serve_zero_insertion", args.out, mesh)
        measure_dcnn("reverse_loop", "h0_dcnn_serve_reverse_loop", args.out,
                     mesh)

    if args.only in (None, "h2"):
        # H2: int8 KV cache on deepseek decode
        base = LM_CONFIGS["deepseek-7b"]
        measure(dataclasses.replace(base, kv_quant=False),
                "decode_32k", mesh, "h2_decode_bf16kv_baseline", args.out)
        measure(dataclasses.replace(base, kv_quant=True),
                "decode_32k", mesh, "h2_decode_int8kv", args.out)

    if args.only in (None, "h3"):
        # H3: fsdp_tp vs tp on deepseek train
        base = LM_CONFIGS["deepseek-7b"]
        measure(base, "train_4k", mesh, "h3_train_fsdp_baseline", args.out,
                policy="fsdp_tp")
        measure(base, "train_4k", mesh, "h3_train_tp", args.out, policy="tp")
        # grad-accum sensitivity under tp
        measure(base, "train_4k", mesh, "h3_train_tp_ga4", args.out,
                policy="tp", grad_accum=4)
        measure(base, "train_4k", mesh, "h3_train_tp_ga16", args.out,
                policy="tp", grad_accum=16)

    if args.only in (None, "h1"):
        # H1: MoE prefill — the pre-shard_map baseline is recorded from the
        # sweep of 2026-07-14 (see EXPERIMENTS.md); here we A/B the dispatch
        # group count sensitivity of the current implementation.
        base = LM_CONFIGS["qwen2-moe-a2.7b"]
        measure(base, "prefill_32k", mesh, "h1_moe_prefill_current", args.out)
        measure(dataclasses.replace(base, moe_capacity_factor=1.0),
                "prefill_32k", mesh, "h1_moe_prefill_cf1.0", args.out)
        measure(dataclasses.replace(base, moe_capacity_factor=2.0),
                "prefill_32k", mesh, "h1_moe_prefill_cf2.0", args.out)


if __name__ == "__main__":
    main()
