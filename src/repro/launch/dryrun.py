import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
on placeholder host devices and extract memory/cost/collective analyses.

Usage:
    python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]

Every cell writes a JSON record consumed by benchmarks/roofline_report.py
and EXPERIMENTS.md.  Failures (sharding mismatch, OOM at compile, unsupported
collective) are bugs in the system — they surface here, not on hardware.
"""
import argparse
import hashlib
import json
import time
import traceback


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             policy: str = "auto", grad_accum=None) -> dict:
    import jax

    from ..analysis.hlo import analyze
    from ..analysis.roofline import model_flops
    from ..configs import LM_CONFIGS, SHAPES, shape_applicable
    from .mesh import make_production_mesh
    from .steps import lower_cell

    cfg = LM_CONFIGS[arch]
    suite = SHAPES[shape]
    skip = shape_applicable(cfg, suite)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "policy": policy}
    if skip is not None:
        rec.update(status="skipped", reason=skip)
        return _write(rec, out_dir)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rec["chips"] = mesh.devices.size
    try:
        t0 = time.time()
        lowered = lower_cell(cfg, suite, mesh, policy=policy,
                             grad_accum=grad_accum)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        hc = analyze(hlo)  # trip-count-aware (scans counted x trip)
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            # corrected per-device numbers (analysis/hlo.py)
            flops_per_device=hc.flops,
            bytes_per_device=hc.bytes_accessed,
            collective_bytes_per_device=hc.collective_bytes,
            collectives={k: [v[0], v[1]] for k, v in hc.collectives.items()},
            n_while=hc.n_while,
            # raw XLA numbers (loop bodies counted once) for reference
            xla_flops_per_device=float(cost.get("flops", 0.0)),
            xla_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
            memory_analysis={
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(mem, k)
            },
            model_flops=model_flops(cfg, suite),
            hlo_sha1=hashlib.sha1(hlo.encode()).hexdigest()[:12],
            hlo_lines=len(hlo.splitlines()),
        )
        # proves it fits / cost terms for §Roofline (printed per task spec)
        print(f"[{arch} x {shape} x {mesh_kind}] memory_analysis:",
              rec["memory_analysis"])
        print(f"[{arch} x {shape} x {mesh_kind}] flops/dev="
              f"{rec['flops_per_device']:.3e} bytes/dev="
              f"{rec['bytes_per_device']:.3e} coll_bytes/dev="
              f"{rec['collective_bytes_per_device']:.3e} "
              f"model/hlo={rec['model_flops'] / max(rec['flops_per_device'] * rec['chips'], 1):.3f}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[{arch} x {shape} x {mesh_kind}] FAILED: {rec['error']}")
    return _write(rec, out_dir)


def _write(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--policy", default="auto")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from ..configs import LM_CONFIGS, SHAPES

    archs = list(LM_CONFIGS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_kind}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        st = json.load(f).get("status")
                    if st in ("ok", "skipped"):
                        continue
                rec = run_cell(arch, shape, mesh_kind, args.out, args.policy)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
    print(f"dryrun complete: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
