"""Shardings + step functions shared by the dry-run, the trainer, and the
server.  Everything here works from ShapeDtypeStructs (no allocation)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.shapes import ShapeSuite, input_specs
from ..dist.context import sharding_context
from ..dist.sharding import (batch_pspec, cache_specs, make_rules,
                             spec_to_pspec, tree_shardings)
from ..models.transformer import ModelConfig, apply_lm, init_cache, init_lm
from ..optim.optimizer import AdamState, AdamW
from ..train.lm import lm_loss


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct params tree, logical spec tree) — no allocation."""
    box = {}

    def fn(key):
        p, s = init_lm(key, cfg)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(fn, jax.random.PRNGKey(0))
    return shapes, box["specs"]


def opt_state_shapes(params_shapes) -> AdamState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree_util.tree_map(f32, params_shapes),
        nu=jax.tree_util.tree_map(f32, params_shapes),
    )


def opt_shardings(mesh, rules, params_shapes, specs):
    p_sh = tree_shardings(mesh, rules, params_shapes, specs)
    return AdamState(
        step=NamedSharding(mesh, P()),
        mu=p_sh,
        nu=p_sh,
    )


def batch_shardings(mesh, rules, batch_specs: Dict[str, jax.ShapeDtypeStruct]):
    out = {}
    for k, v in batch_specs.items():
        if k == "cache":
            cspecs = None  # handled separately
            continue
        out[k] = NamedSharding(
            mesh, batch_pspec(mesh, rules, v.shape[0], len(v.shape)))
    return out


def cache_shardings(mesh, rules, cfg, cache_shapes):
    cspecs = cache_specs(cfg)
    return tree_shardings(mesh, rules, cache_shapes, cspecs)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def make_optimizer(cfg: ModelConfig) -> AdamW:
    return AdamW(lr=3e-4, weight_decay=0.1, clip_norm=1.0)


def default_policy(cfg: ModelConfig) -> str:
    """FSDP where TP-only optimizer state would blow HBM: >20B dense params,
    or any MoE (expert-TP shards d_ff only 16-way; Adam moments of 14-42B
    expert weights need the data axis too).  TP-only elsewhere avoids the
    per-microbatch FSDP weight all-gather (the dominant collective in the
    fsdp_tp baseline — §Perf H3)."""
    if cfg.n_experts > 0 or cfg.param_count() > 20e9:
        return "fsdp_tp"
    return "tp"


def default_grad_accum(cfg: ModelConfig, suite, mesh: Mesh,
                       target_tokens_per_device: int = 6144) -> int:
    """Microbatching so per-device microbatch activations stay HBM-friendly —
    grads accumulate in f32 across the sequential scan; each microbatch's
    reduce-scatter overlaps the next microbatch's compute under the
    latency-hiding scheduler.  ga is a divisor of the per-device batch so
    the batch-dim sharding survives the microbatch split."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    per_dev_batch = max(1, suite.global_batch // dp)
    per_dev_tokens = per_dev_batch * suite.seq_len
    divisors = [d for d in range(1, per_dev_batch + 1)
                if per_dev_batch % d == 0 and suite.global_batch % d == 0]
    for ga in divisors:  # smallest ga meeting the activation target
        if per_dev_tokens // ga <= target_tokens_per_device:
            return ga
    return divisors[-1]


def build_train_step(cfg: ModelConfig, mesh: Mesh, rules,
                     grad_accum: int = 1):
    from ..train.lm import make_train_step

    optimizer = make_optimizer(cfg)
    inner = make_train_step(cfg, optimizer, grad_accum=grad_accum,
                            compress=False)

    def train_step(params, opt_state, batch):
        with sharding_context(mesh, rules):
            params, opt_state, _, met = inner(params, opt_state, None, batch)
        return params, opt_state, met

    return train_step


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, rules, batch: int,
                       max_len: int):
    def prefill_step(params, batch_inputs):
        with sharding_context(mesh, rules):
            cache = init_cache(cfg, batch, max_len)
            logits, cache, _ = apply_lm(
                params, cfg, batch_inputs["tokens"],
                batch_inputs.get("frontend_embeds"),
                mode="prefill", cache=cache)
        return logits[:, -1, :], cache

    return prefill_step


def build_decode_step(cfg: ModelConfig, mesh: Mesh, rules):
    def decode_step(params, cache, tokens):
        with sharding_context(mesh, rules):
            logits, cache, _ = apply_lm(params, cfg, tokens,
                                        mode="decode", cache=cache)
        return logits[:, -1, :], cache

    return decode_step


# ---------------------------------------------------------------------------
# cell lowering (arch x shape x mesh) -> jax.stages.Lowered
# ---------------------------------------------------------------------------
def lower_cell(cfg: ModelConfig, suite: ShapeSuite, mesh: Mesh,
               policy: str = "auto", donate: bool = True,
               grad_accum: Optional[int] = None):
    multi_pod = "pod" in mesh.shape
    if policy == "auto":
        policy = default_policy(cfg)
    rules = make_rules(policy, multi_pod=multi_pod)
    p_shapes, specs = abstract_params(cfg)
    p_sh = tree_shardings(mesh, rules, p_shapes, specs)
    in_specs = input_specs(cfg, suite)

    if suite.kind == "train":
        if grad_accum is None:
            grad_accum = default_grad_accum(cfg, suite, mesh)
        o_shapes = opt_state_shapes(p_shapes)
        o_sh = opt_shardings(mesh, rules, p_shapes, specs)
        b_sh = batch_shardings(mesh, rules, in_specs)
        step = build_train_step(cfg, mesh, rules, grad_accum=grad_accum)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1) if donate else (),
        )
        lowered = jitted.lower(p_shapes, o_shapes, in_specs)
    elif suite.kind == "prefill":
        b_sh = batch_shardings(mesh, rules, in_specs)
        step = build_prefill_step(cfg, mesh, rules, suite.global_batch,
                                  suite.seq_len)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(p_shapes, in_specs)
    else:  # decode
        cache_shapes = in_specs["cache"]
        c_sh = cache_shardings(mesh, rules, cfg, cache_shapes)
        tok_sh = NamedSharding(
            mesh, batch_pspec(mesh, rules, suite.global_batch, 2))
        step = build_decode_step(cfg, mesh, rules)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, tok_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(1,) if donate else (),
        )
        lowered = jitted.lower(p_shapes, cache_shapes,
                               in_specs["tokens"])
    return lowered
