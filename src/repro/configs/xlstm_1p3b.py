"""xlstm-1.3b [arXiv:2405.04517, unverified]: 48 blocks, d2048 4H,
mLSTM:sLSTM 7:1, no separate FFN (d_ff=0), vocab 50304."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    rope="none", norm="layernorm",
)
