"""chatglm3-6b [arXiv:2406.12793]: 28L d4096 32H (kv=2) d_ff=13696,
vocab 65024, 2d (partial, rotary_frac=0.5) RoPE, qkv bias."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    rope="2d", rotary_frac=0.5, qkv_bias=True,
)
