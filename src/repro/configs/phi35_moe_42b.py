"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]: 32L d4096 32H
(kv=8) expert d_ff=6400, vocab 32064, 16 experts top-2."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=0, vocab_size=32064,
    n_experts=16, moe_top_k=2, expert_d_ff=6400, n_shared_experts=0,
    rope="standard", rope_theta=10000.0,
)
