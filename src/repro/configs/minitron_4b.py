"""minitron-4b [arXiv:2407.14679]: pruned nemotron, 32L d3072 24H (kv=8)
d_ff=9216, vocab 256000.  Nemotron uses squared-ReLU FFN; we use the
(non-gated) GeLU variant — same matmul structure (noted in DESIGN.md)."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000,
    activation="gelu", norm="layernorm",
    rope="standard", rope_theta=10000.0, rotary_frac=0.5,
)
