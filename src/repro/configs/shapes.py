"""Assigned input-shape suites and ShapeDtypeStruct input specs.

Every LM arch is paired with 4 shapes (40 cells total):
  train_4k    : seq 4096,   global_batch 256  -> train_step
  prefill_32k : seq 32768,  global_batch 32   -> serve prefill
  decode_32k  : cache 32768, global_batch 128 -> serve_step (1 new token)
  long_500k   : cache 524288, global_batch 1  -> serve_step; requires
                sub-quadratic attention (run: recurrentgemma, xlstm;
                skipped for full-attention archs, see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models.transformer import ModelConfig, init_cache


@dataclasses.dataclass(frozen=True)
class ShapeSuite:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSuite] = {
    "train_4k": ShapeSuite("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSuite("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSuite("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSuite("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSuite) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the reason for the skip."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: O(L^2) at 524k; sub-quadratic archs "
                "only (DESIGN.md §6)")
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSuite) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if shape.kind == "train":
        specs: Dict[str, jax.ShapeDtypeStruct] = {}
        s_tok = s - cfg.frontend_len if cfg.frontend else s
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_tok), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s_tok), i32)
        if cfg.frontend:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.frontend_dim), cfg.jdtype)
        return specs

    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct(
            (b, s - cfg.frontend_len if cfg.frontend else s), i32)}
        if cfg.frontend:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.frontend_dim), cfg.jdtype)
        return specs

    # decode: one new token against a cache of seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32), "cache": cache}
