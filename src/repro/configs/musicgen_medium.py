"""musicgen-medium [arXiv:2306.05284]: decoder-only over EnCodec tokens,
48L d1536 24H (kv=24) d_ff=6144, vocab 2048.  Audio frontend is a stub
(precomputed EnCodec frame embeddings).  MusicGen uses sinusoidal positions;
we use RoPE as the TPU-era positional mechanism (noted in DESIGN.md)."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    activation="gelu", norm="layernorm",
    frontend="audio", frontend_len=256, frontend_dim=128,
    kv_quant=True,  # 48L x kv=24 cache at 32k
)
