"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 24L d2048 16H (kv=16)
routed d_ff=1408, vocab 151936, MoE 60 routed top-4 + 4 shared."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab_size=151936,
    n_experts=60, moe_top_k=4, expert_d_ff=1408, n_shared_experts=4,
    moe_norm_topk=True, qkv_bias=True,
    rope="standard", rope_theta=1e6,
    kv_quant=True,  # 24L x kv=16 cache at 32k decode
)
