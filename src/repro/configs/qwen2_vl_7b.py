"""qwen2-vl-7b [arXiv:2409.12191]: 28L d3584 28H (kv=4) d_ff=18944,
vocab 152064, M-RoPE (sections 16/24/24 on head_dim 128), vision frontend
stub (precomputed ViT patch embeddings via input_specs)."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    rope="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    qkv_bias=True,
    frontend="vision", frontend_len=256, frontend_dim=1280,
)
