"""deepseek-7b [arXiv:2401.02954]: llama-arch, 30L d4096 32H (kv=32 MHA)
d_ff=11008, vocab 102400."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=102400,
    kv_quant=True,  # 32k MHA cache (kv=32): bf16 would need 8 GB/chip + loop buffers
)
