"""gemma2-27b [arXiv:2408.00118]: 46L d4608 32H (kv=16) d_ff=36864,
vocab 256000, local(4k)/global alternating, attn softcap 50 / final 30,
head_dim 128, query scale (d_model/n_heads)^-0.5."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
    d_ff=36864, vocab_size=256000, head_dim=128,
    block_pattern=("local", "global"), local_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    attn_scale=(4608 / 32) ** -0.5,
    activation="geglu", embed_scale=True,
)
