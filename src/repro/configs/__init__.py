"""Config registry: the 10 assigned LM architectures + the paper's own
DCNN configs, selectable via --arch <id>."""
from __future__ import annotations

from typing import Dict, List

from ..models.dcnn import CELEBA_DCNN, MNIST_DCNN, DcnnConfig
from ..models.transformer import ModelConfig
from . import (
    chatglm3_6b,
    deepseek_7b,
    gemma2_27b,
    minitron_4b,
    musicgen_medium,
    phi35_moe_42b,
    qwen2_moe_a2p7b,
    qwen2_vl_7b,
    recurrentgemma_2b,
    xlstm_1p3b,
)
from .shapes import SHAPES, ShapeSuite, input_specs, shape_applicable

LM_CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        qwen2_moe_a2p7b.CONFIG,
        phi35_moe_42b.CONFIG,
        minitron_4b.CONFIG,
        chatglm3_6b.CONFIG,
        deepseek_7b.CONFIG,
        gemma2_27b.CONFIG,
        qwen2_vl_7b.CONFIG,
        musicgen_medium.CONFIG,
        recurrentgemma_2b.CONFIG,
        xlstm_1p3b.CONFIG,
    ]
}

DCNN_CONFIGS: Dict[str, DcnnConfig] = {
    "dcnn-mnist": MNIST_DCNN,
    "dcnn-celeba": CELEBA_DCNN,
}


def get_config(name: str):
    if name in LM_CONFIGS:
        return LM_CONFIGS[name]
    if name in DCNN_CONFIGS:
        return DCNN_CONFIGS[name]
    raise KeyError(
        f"unknown arch {name!r}; available: {sorted(LM_CONFIGS) + sorted(DCNN_CONFIGS)}"
    )


def list_configs() -> List[str]:
    return sorted(LM_CONFIGS) + sorted(DCNN_CONFIGS)


def reduced_config(name: str) -> ModelConfig:
    """Family-faithful reduced config for CPU smoke tests: same block
    pattern/features, tiny dims."""
    import dataclasses

    cfg = LM_CONFIGS[name]
    pattern = cfg.block_pattern
    n_layers = max(len(pattern), 2) if len(pattern) > 1 else 2
    if cfg.name == "recurrentgemma-2b":
        n_layers = 5  # keep the remainder-unit path covered (3 + 2)
    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        n_experts=8 if cfg.n_experts else 0,
        expert_d_ff=32 if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 2),
        local_window=16,
        rnn_width=64 if cfg.rnn_width else 0,
        frontend_len=8 if cfg.frontend else 0,
        frontend_dim=24 if cfg.frontend else 0,
        mrope_sections=(4, 2, 2) if cfg.mrope_sections else None,
        attn_scale=None,
        dtype="float32",
        attn_block_q=16,
        attn_block_k=16,
        remat=False,
    )
