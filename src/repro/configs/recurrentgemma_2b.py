"""recurrentgemma-2b [arXiv:2402.19427]: Griffin — RG-LRU + local attention
1:2, 26L d2560 10H (MQA kv=1, head_dim 256) d_ff=7680, vocab 256000,
window 2048.  26 = 8 full (rec,rec,attn) units + 2 remainder rec blocks."""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    block_pattern=("griffin", "griffin", "local"), local_window=2048,
    rnn_width=2560, activation="geglu", embed_scale=True,
)
