"""WGAN-GP training (Gulrajani et al. [10]) — the framework the paper uses to
train both DCNNs (Fig. 4).  Generator deconvolutions run through the
differentiable reverse-loop formulation."""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.dcnn import DcnnConfig, critic_apply, generator_apply


def critic_loss(dp, gp_params, cfg: DcnnConfig, real, z, key, gp_coef=10.0):
    fake = generator_apply(gp_params, cfg, z)
    d_real = critic_apply(dp, cfg, real)
    d_fake = critic_apply(dp, cfg, fake)
    # gradient penalty on interpolates
    eps = jax.random.uniform(key, (real.shape[0], 1, 1, 1), real.dtype)
    x_hat = eps * real + (1.0 - eps) * fake
    grad_x = jax.grad(lambda x: critic_apply(dp, cfg, x).sum())(x_hat)
    gnorm = jnp.sqrt(jnp.sum(grad_x ** 2, axis=(1, 2, 3)) + 1e-12)
    gp = jnp.mean((gnorm - 1.0) ** 2)
    wdist = jnp.mean(d_real) - jnp.mean(d_fake)
    loss = -wdist + gp_coef * gp
    return loss, {"wdist": wdist, "gp": gp}


def generator_loss(gp_params, dp, cfg: DcnnConfig, z):
    fake = generator_apply(gp_params, cfg, z)
    return -jnp.mean(critic_apply(dp, cfg, fake))


def make_wgan_steps(cfg: DcnnConfig, g_opt, d_opt):
    """Returns jitted (critic_step, gen_step)."""

    @jax.jit
    def critic_step(dp, d_state, gp, real, key):
        kz, kgp = jax.random.split(key)
        z = jax.random.normal(kz, (real.shape[0], cfg.z_dim), real.dtype)
        (loss, met), grads = jax.value_and_grad(critic_loss, has_aux=True)(
            dp, gp, cfg, real, z, kgp)
        dp, d_state = d_opt.update(grads, d_state, dp)
        return dp, d_state, dict(met, d_loss=loss)

    @functools.partial(jax.jit, static_argnums=(4,))
    def gen_step(gp, g_state, dp, key, batch: int):
        z = jax.random.normal(key, (batch, cfg.z_dim), jnp.dtype(cfg.dtype))
        loss, grads = jax.value_and_grad(generator_loss)(gp, dp, cfg, z)
        gp, g_state = g_opt.update(grads, g_state, gp)
        return gp, g_state, {"g_loss": loss}

    return critic_step, gen_step


def train_wgan(
    cfg: DcnnConfig,
    source,
    steps: int,
    key,
    g_opt,
    d_opt,
    n_critic: int = 5,
    log_every: int = 50,
    ckpt=None,           # optional AsyncCheckpointer
    ckpt_every: int = 200,
):
    from ..models.dcnn import critic_init, generator_init

    kg, kd, key = jax.random.split(key, 3)
    gp, _ = generator_init(kg, cfg)
    dp, _ = critic_init(kd, cfg)
    g_state = g_opt.init(gp)
    d_state = d_opt.init(dp)
    critic_step, gen_step = make_wgan_steps(cfg, g_opt, d_opt)

    history = []
    for step in range(steps):
        met = {}
        for _ in range(n_critic):
            key, k = jax.random.split(key)
            real = jnp.asarray(source.batch(step)["images"], jnp.dtype(cfg.dtype))
            dp, d_state, met_d = critic_step(dp, d_state, gp, real, k)
            met.update(met_d)
        key, k = jax.random.split(key)
        gp, g_state, met_g = gen_step(gp, g_state, dp, k, real.shape[0])
        met.update(met_g)
        if step % log_every == 0 or step == steps - 1:
            history.append({k: float(v) for k, v in met.items()} | {"step": step})
        if ckpt is not None and step and step % ckpt_every == 0:
            ckpt.save(step, {"g": gp, "d": dp})
    return gp, dp, history
