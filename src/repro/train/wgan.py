"""WGAN-GP training (Gulrajani et al. [10]) — the framework the paper uses to
train both DCNNs (Fig. 4).

`WganTrainer` is the training-side mirror of `serve.DcnnServeEngine`:

* **Bucketed step functions.**  Ragged batch sizes are rounded up to
  power-of-two buckets (padded `real` rows are masked out of the loss with
  exact sum/n_valid accounting, the generator's z batch is drawn at the
  bucket size), so a changing data batch re-uses a compiled executable
  instead of tracing a fresh one.  `trace_counts` exposes the guarantee.
* **Mesh sharding.**  With ``mesh=`` the critic and generator steps run as
  data-parallel SPMD via shard_map: params/optimizer states are
  replicated, the batch dim shards the `data` axis per `dist.sharding`
  rules, every shard draws its own z/eps from a per-shard key
  (`jax.random.fold_in` on the shard index), and gradients/metrics are
  `psum`'d so each device applies the identical optimizer update.  The
  single-device path runs the *same* per-shard math in a loop, so a mesh
  run is numerically equivalent to a 1-device run with matching
  ``z_shards``.
* **Batch-fused generator.**  ``backend="pallas"`` routes the generator
  forward through the batch-fused serving kernels (per-bucket tiles, incl.
  the batch tile ``t_n``, autotuned for the per-shard sub-batch) with the
  reverse-loop VJP as the backward — the training step fills the MXU the
  same way serving does.  The default ``reverse_loop`` stays the plain
  differentiable formulation.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..models.dcnn import (DcnnConfig, critic_apply, critic_init,
                           generator_apply, generator_init,
                           make_fused_generator)


def critic_loss(dp, gp_params, cfg: DcnnConfig, real, z, key, gp_coef=10.0,
                mask=None, n_valid=None, gen_fn=None):
    """WGAN-GP critic loss.

    With ``mask``/``n_valid`` the means become ``sum(mask * term) /
    n_valid`` — pad rows of a bucketed batch contribute exactly zero, and
    per-shard values of a sharded batch *sum* to the global loss (the
    divisor is the global valid count, not the shard size)."""
    gen = gen_fn if gen_fn is not None else (
        lambda p, z_: generator_apply(p, cfg, z_))
    fake = gen(gp_params, z)
    d_real = critic_apply(dp, cfg, real)
    d_fake = critic_apply(dp, cfg, fake)
    # gradient penalty on interpolates
    eps = jax.random.uniform(key, (real.shape[0], 1, 1, 1), real.dtype)
    x_hat = eps * real + (1.0 - eps) * fake
    grad_x = jax.grad(lambda x: critic_apply(dp, cfg, x).sum())(x_hat)
    gnorm = jnp.sqrt(jnp.sum(grad_x ** 2, axis=(1, 2, 3)) + 1e-12)
    if mask is None:
        wdist = jnp.mean(d_real) - jnp.mean(d_fake)
        gp = jnp.mean((gnorm - 1.0) ** 2)
    else:
        nv = jnp.asarray(n_valid, d_real.dtype)
        wdist = (jnp.sum(d_real * mask) - jnp.sum(d_fake * mask)) / nv
        gp = jnp.sum(((gnorm - 1.0) ** 2) * mask) / nv
    loss = -wdist + gp_coef * gp
    return loss, {"wdist": wdist, "gp": gp}


def generator_loss(gp_params, dp, cfg: DcnnConfig, z, gen_fn=None,
                   denom=None):
    """-E[critic(G(z))]; ``denom`` replaces the local mean with a global
    divisor so sharded partial losses sum to the global one."""
    gen = gen_fn if gen_fn is not None else (
        lambda p, z_: generator_apply(p, cfg, z_))
    fake = gen(gp_params, z)
    scores = critic_apply(dp, cfg, fake)
    if denom is None:
        return -jnp.mean(scores)
    return -jnp.sum(scores) / denom


def _tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


class WganTrainer:
    """Bucketed, optionally mesh-sharded WGAN-GP trainer (see module doc).

    ``critic_step(dp, d_state, gp, real, key)`` and
    ``gen_step(gp, g_state, dp, key, batch)`` keep the signatures of the
    old hand-rolled jitted closures; padding, bucketing, sharding and
    per-bucket executable caching all happen behind them."""

    def __init__(self, cfg: DcnnConfig, g_opt, d_opt, *,
                 n_critic: int = 5, gp_coef: float = 10.0,
                 backend: str = "reverse_loop",
                 autotune: bool = True, refine: bool = False,
                 mesh=None, rules=None, z_shards: Optional[int] = None,
                 plan=None):
        if n_critic < 1:
            raise ValueError(
                f"n_critic must be >= 1 (got {n_critic}): the generator "
                "batch is derived from the critic's data batch")
        if backend == "pallas_sparse":
            raise ValueError(
                "pallas_sparse is inference-only: the static zero-skip "
                "plan is derived from frozen weights, which training "
                "updates each step")
        if backend not in ("reverse_loop", "xla", "pallas"):
            raise ValueError(f"unknown training backend {backend!r}")
        self.cfg = cfg
        self.g_opt = g_opt
        self.d_opt = d_opt
        self.n_critic = n_critic
        self.gp_coef = gp_coef
        self.backend = backend
        self._autotune = autotune
        self._refine = refine
        self.mesh = mesh
        if mesh is not None:
            from ..dist.sharding import data_axis_size, make_rules
            self.rules = rules if rules is not None else make_rules("tp")
            self.n_data = data_axis_size(mesh, self.rules)
            if z_shards is not None and z_shards != self.n_data:
                raise ValueError(
                    f"z_shards ({z_shards}) must match the mesh's data "
                    f"extent ({self.n_data}): each device draws one shard")
            self.shards = self.n_data
        else:
            self.rules = rules
            self.n_data = 1
            # z_shards replays the mesh's per-shard key-splitting on one
            # device: trainer(mesh 8-way) == trainer(z_shards=8) exactly
            self.shards = z_shards or 1
        # optional pinned serve-side NetworkPlan: the trainer's bucket
        # whose per-shard sub-batch matches plan.batch runs *exactly* that
        # executable configuration (hash-asserted in _gen_for), so
        # training and serving provably share one plan
        if plan is not None:
            if backend != "pallas":
                raise ValueError(
                    "a pinned NetworkPlan needs backend='pallas' (plans "
                    f"pin the fused serving kernels); got {backend!r}")
            if plan.backend != "pallas" or plan.precision != "fp32":
                raise ValueError(
                    "training consumes fp32 pallas plans; got "
                    f"backend={plan.backend!r} / "
                    f"precision={plan.precision!r}")
            plan.validate_for(cfg)
        self._pinned_plan = plan
        # bucket -> compiled step; trace_counts is the no-retrace probe
        self._critic_fns: Dict[int, Callable] = {}
        self._gen_fns: Dict[int, Callable] = {}
        self._gen_apply: Dict[int, Callable] = {}
        self.trace_counts: Dict[str, Dict[int, int]] = {"critic": {},
                                                        "gen": {}}
        self.tile_choices: Dict[int, Optional[dict]] = {}
        # bucket -> NetworkPlan the generator forward actually runs
        # (pallas backend only) — what plan_fingerprints() reports
        self.plans: Dict[int, Any] = {}

    # -- bucketing ------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest power-of-two >= n (cf. serve.pow2_buckets), rounded up
        to a shard-count multiple so every shard owns an equal sub-batch."""
        if n < 1:
            raise ValueError(f"batch must be >= 1 (got {n})")
        b = 1
        while b < n:
            b <<= 1
        return -(-b // self.shards) * self.shards

    def _local(self, bucket: int) -> int:
        return bucket // self.shards

    # -- generator forward for the loss path ----------------------------
    def _gen_for(self, bucket: int) -> Callable:
        """Per-bucket generator apply: the batch-fused Pallas kernels
        (tiles autotuned against the per-shard sub-batch) with the
        reverse-loop VJP, or the plain differentiable backends."""
        if bucket not in self._gen_apply:
            if self.backend == "pallas":
                from ..plan import build_network_plan
                local = self._local(bucket)
                pinned = self._pinned_plan
                plan = build_network_plan(
                    self.cfg, batch=local, backend="pallas",
                    autotune=self._autotune, refine=self._refine)
                if pinned is not None and plan.batch == pinned.batch:
                    # hash-asserted parity with the serve-side plan: the
                    # bucket that matches the pinned per-device batch must
                    # resolve to the identical executable configuration
                    if plan.stable_hash() != pinned.stable_hash():
                        raise ValueError(
                            f"trainer-built plan for per-shard batch "
                            f"{local} ({plan.stable_hash()}) does not "
                            f"match the pinned serve-side plan "
                            f"({pinned.stable_hash()}); training would "
                            "fill the MXU differently than serving — "
                            "re-pin one side")
                    plan = pinned
                self.plans[bucket] = plan
                self.tile_choices[bucket] = plan.tile_overrides()
                self._gen_apply[bucket] = make_fused_generator(
                    self.cfg, plan=plan)
            else:
                backend = self.backend
                self._gen_apply[bucket] = (
                    lambda p, z, _b=backend: generator_apply(
                        p, self.cfg, z, backend=_b))
        return self._gen_apply[bucket]

    # -- step construction ----------------------------------------------
    def _wrap(self, body, kind: str, bucket: int, n_batch_arg: int):
        """shard_map (mesh) + jit + trace-count probe around a step body.
        ``n_batch_arg`` is the position of the batch-sharded argument."""
        if self.mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            baxes = self.rules.get("batch", "data")
            n_in = body.__code__.co_argcount
            in_specs = tuple(P(baxes) if i == n_batch_arg else P()
                             for i in range(n_in))
            body = shard_map(body, mesh=self.mesh, in_specs=in_specs,
                             out_specs=P(), check_rep=False)

        def traced(*args):
            counts = self.trace_counts[kind]
            counts[bucket] = counts.get(bucket, 0) + 1
            return body(*args)

        return jax.jit(traced)

    def _psum(self, tree):
        baxes = self.rules.get("batch", "data")
        return jax.tree_util.tree_map(
            lambda x: jax.lax.psum(x, baxes), tree)

    def _shard_index(self):
        from ..dist.sharding import shard_index
        return shard_index(self.mesh, self.rules)

    def _critic_shard_terms(self, bucket: int):
        """One shard's sum-based loss/grads: `local` rows starting at
        global row idx*local; divisor = the global valid count."""
        cfg, gp_coef = self.cfg, self.gp_coef
        local = self._local(bucket)
        gen_fn = self._gen_for(bucket)

        def terms(dp, gp, real_l, nv, key, idx):
            kz, kgp = jax.random.split(jax.random.fold_in(key, idx))
            z = jax.random.normal(kz, (local, cfg.z_dim), real_l.dtype)
            rows = idx * local + jnp.arange(local)
            mask = (rows < nv).astype(real_l.dtype)

            def loss_fn(dp_):
                return critic_loss(dp_, gp, cfg, real_l, z, kgp,
                                   gp_coef=gp_coef, mask=mask, n_valid=nv,
                                   gen_fn=gen_fn)

            return jax.value_and_grad(loss_fn, has_aux=True)(dp)

        return terms

    def _build_critic_fn(self, bucket: int) -> Callable:
        terms = self._critic_shard_terms(bucket)
        d_opt = self.d_opt
        local = self._local(bucket)

        if self.mesh is not None:
            def body(dp, d_state, gp, real_l, nv, key):
                (loss, met), grads = terms(dp, gp, real_l, nv, key,
                                           self._shard_index())
                loss, met, grads = self._psum((loss, met, grads))
                dp, d_state = d_opt.update(grads, d_state, dp)
                return dp, d_state, dict(met, d_loss=loss)
        else:
            shards = self.shards

            def body(dp, d_state, gp, real, nv, key):
                acc = None
                for i in range(shards):
                    out = terms(dp, gp, real[i * local:(i + 1) * local],
                                nv, key, i)
                    acc = out if acc is None else _tree_add(acc, out)
                (loss, met), grads = acc
                dp, d_state = d_opt.update(grads, d_state, dp)
                return dp, d_state, dict(met, d_loss=loss)

        return self._wrap(body, "critic", bucket, n_batch_arg=3)

    def _build_gen_fn(self, bucket: int) -> Callable:
        cfg, g_opt = self.cfg, self.g_opt
        local = self._local(bucket)
        gen_fn = self._gen_for(bucket)
        denom = float(bucket)

        def terms(gp, dp, key, idx):
            z = jax.random.normal(jax.random.fold_in(key, idx),
                                  (local, cfg.z_dim), jnp.dtype(cfg.dtype))
            return jax.value_and_grad(generator_loss)(
                gp, dp, cfg, z, gen_fn=gen_fn, denom=denom)

        if self.mesh is not None:
            def body(gp, g_state, dp, key):
                loss, grads = terms(gp, dp, key, self._shard_index())
                loss, grads = self._psum((loss, grads))
                gp, g_state = g_opt.update(grads, g_state, gp)
                return gp, g_state, {"g_loss": loss}
        else:
            shards = self.shards

            def body(gp, g_state, dp, key):
                acc = None
                for i in range(shards):
                    out = terms(gp, dp, key, i)
                    acc = out if acc is None else _tree_add(acc, out)
                loss, grads = acc
                gp, g_state = g_opt.update(grads, g_state, gp)
                return gp, g_state, {"g_loss": loss}

        return self._wrap(body, "gen", bucket, n_batch_arg=-1)

    # -- public steps ----------------------------------------------------
    def critic_step(self, dp, d_state, gp, real, key):
        """One critic update on a (possibly ragged) real batch: pads to
        the bucket, masks the pad rows out of the loss exactly."""
        real = jnp.asarray(real, jnp.dtype(self.cfg.dtype))
        n = real.shape[0]
        bucket = self.bucket_for(n)
        if bucket > n:
            real = jnp.concatenate(
                [real, jnp.zeros((bucket - n,) + real.shape[1:],
                                 real.dtype)], axis=0)
        if bucket not in self._critic_fns:
            self._critic_fns[bucket] = self._build_critic_fn(bucket)
        nv = jnp.asarray(n, jnp.int32)  # dynamic: no retrace per raggedness
        return self._critic_fns[bucket](dp, d_state, gp, real, nv, key)

    def gen_step(self, gp, g_state, dp, key, batch: int):
        """One generator update; ``batch`` is rounded up to its bucket and
        the z batch drawn at the bucket size (a ragged final data batch
        re-uses the bucket executable instead of compiling a new one)."""
        bucket = self.bucket_for(int(batch))
        if bucket not in self._gen_fns:
            self._gen_fns[bucket] = self._build_gen_fn(bucket)
        return self._gen_fns[bucket](gp, g_state, dp, key)

    @property
    def total_compiles(self) -> int:
        return sum(v for d in self.trace_counts.values()
                   for v in d.values())

    def plan_fingerprints(self) -> Dict[int, str]:
        """{per-shard batch -> stable hash} of the plans the generator
        forward actually ran (pallas backend) — compare against the serve
        engine's `plans` to prove training and serving pin the same
        executables (`plan.executable_fingerprints` semantics)."""
        from ..plan import executable_fingerprints
        return executable_fingerprints(self.plans.values())

    # -- training loop ----------------------------------------------------
    def init_state(self, key):
        kg, kd = jax.random.split(key)
        gp, _ = generator_init(kg, self.cfg)
        dp, _ = critic_init(kd, self.cfg)
        return gp, dp, self.g_opt.init(gp), self.d_opt.init(dp)

    def fit(self, source, steps: int, key, log_every: int = 50,
            ckpt=None, ckpt_every: int = 200,
            resume_from: Optional[str] = None):
        """Train for (up to) ``steps`` steps.

        ``source`` is either a step-indexed source (anything exposing
        ``batch(step) -> {"images": ...}``, pure in the step — the
        resumable default) or a *streaming batch iterator*: any iterable
        of ``{"images": ...}`` dicts (or bare image arrays).  A streaming
        source is consumed one batch per critic sub-step and training
        stops when it is exhausted — a finite iterator drains exactly,
        with no synthetic batches invented past its end.  Only a
        step-indexed source can replay batches on resume; a resumed
        streaming run continues from wherever its iterator now starts.

        Checkpoints carry generator, critic AND both optimizer states plus
        the step (so a resumed run is bitwise the run that never stopped);
        per-step keys are ``fold_in(key, step)``-derived, which is what
        makes the resumed trajectory identical to the uninterrupted one."""
        kinit, key = jax.random.split(key)
        gp, dp, g_state, d_state = self.init_state(kinit)
        start = 0
        if resume_from is not None:
            from ..ckpt.checkpoint import restore
            tree_like = {"g": gp, "d": dp, "gs": g_state, "ds": d_state}
            tree, step0, extra = restore(resume_from, tree_like)
            if tree is not None:
                gp, dp = tree["g"], tree["d"]
                g_state, d_state = tree["gs"], tree["ds"]
                start = int(extra.get("step", step0)) + 1

        stream = None if hasattr(source, "batch") else iter(source)

        def next_real(step):
            if stream is None:
                return source.batch(step)["images"]
            try:
                rec = next(stream)
            except StopIteration:
                return None
            return rec["images"] if isinstance(rec, dict) else rec

        history: List[dict] = []
        for step in range(start, steps):
            skey = jax.random.fold_in(key, step)
            met: Dict[str, Any] = {}
            batch = None
            for j in range(self.n_critic):
                k = jax.random.fold_in(skey, j)
                real = next_real(step)
                if real is None:
                    # stream drained mid-step: stop before an unpaired
                    # generator update (the step's critic/gen balance
                    # would otherwise silently differ from every other's)
                    return gp, dp, history
                batch = real.shape[0]
                dp, d_state, met_d = self.critic_step(dp, d_state, gp,
                                                      real, k)
                met.update(met_d)
            kg = jax.random.fold_in(skey, self.n_critic)
            gp, g_state, met_g = self.gen_step(gp, g_state, dp, kg, batch)
            met.update(met_g)
            if step % log_every == 0 or step == steps - 1:
                history.append({k: float(v) for k, v in met.items()}
                               | {"step": step})
            if ckpt is not None and step % ckpt_every == 0:
                ckpt.save(step, {"g": gp, "d": dp, "gs": g_state,
                                 "ds": d_state}, extra={"step": step})
        return gp, dp, history


def make_wgan_steps(cfg: DcnnConfig, g_opt, d_opt, mesh=None,
                    backend: str = "reverse_loop", **kwargs):
    """Returns (critic_step, gen_step) with the legacy signatures, now
    bucketed (and mesh-sharded when ``mesh`` is given) via `WganTrainer`.
    The trainer is reachable as ``critic_step.__self__`` for the compile
    probes."""
    trainer = WganTrainer(cfg, g_opt, d_opt, mesh=mesh, backend=backend,
                          **kwargs)
    return trainer.critic_step, trainer.gen_step


def train_wgan(
    cfg: DcnnConfig,
    source,
    steps: int,
    key,
    g_opt,
    d_opt,
    n_critic: int = 5,
    log_every: int = 50,
    ckpt=None,           # optional AsyncCheckpointer
    ckpt_every: int = 200,
    backend: str = "reverse_loop",
    mesh=None,
    resume_from: Optional[str] = None,
):
    trainer = WganTrainer(cfg, g_opt, d_opt, n_critic=n_critic,
                          backend=backend, mesh=mesh)
    return trainer.fit(source, steps, key, log_every=log_every, ckpt=ckpt,
                       ckpt_every=ckpt_every, resume_from=resume_from)
