"""Resilient generic training driver: checkpoint/restart, async saves,
straggler monitoring, deterministic data resume, simulated-failure recovery.

The driver owns no model specifics — it runs any step_fn over any state
pytree with a StepIndexedSource, which is what makes restart exact: data is
a pure function of the step index, and the state checkpoint carries the step.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax

from ..ckpt.checkpoint import AsyncCheckpointer, restore
from ..dist.fault import StragglerMonitor


class TrainDriver:
    def __init__(
        self,
        step_fn: Callable[[Any, Dict], Any],   # (state, batch) -> (state, metrics)
        source,                                 # StepIndexedSource
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        keep: int = 3,
        straggler_factor: float = 3.0,
        failure_injector: Optional[Callable[[int], bool]] = None,
    ):
        self.step_fn = step_fn
        self.source = source
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep) if ckpt_dir else None
        self.monitor = StragglerMonitor(factor=straggler_factor)
        self.failure_injector = failure_injector
        self.recoveries = 0
        self.metrics_log = []

    def _maybe_restore(self, state):
        if not self.ckpt_dir:
            return state, 0
        restored, step, _ = restore(self.ckpt_dir, state)
        if restored is None:
            return state, 0
        return restored, step + 1

    def run(self, state, n_steps: int):
        state, start = self._maybe_restore(state)
        init_state_template = state
        step = start
        while step < n_steps:
            try:
                if self.failure_injector and self.failure_injector(step):
                    raise RuntimeError(f"injected node failure at step {step}")
                batch = self.source.batch(step)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
                dt = time.monotonic() - t0
                self.monitor.observe(step, dt)
                self.metrics_log.append(
                    {"step": step, "time_s": dt,
                     **{k: float(v) for k, v in metrics.items()}})
                if self.ckpt and step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
                step += 1
            except RuntimeError:
                # node failure: restore last committed checkpoint and resume.
                self.recoveries += 1
                if self.ckpt:
                    self.ckpt.wait()
                state, step = self._maybe_restore(init_state_template)
        if self.ckpt:
            self.ckpt.save(n_steps - 1, state)
            self.ckpt.wait()
        return state
