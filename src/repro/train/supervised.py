"""Supervised reconstruction training for image-rooted deconv towers.

`SupervisedTrainer` is the reconstruction-loss twin of
`train.wgan.WganTrainer`, built for the workload zoo's supervised heads
(super-resolution, denoising): the same power-of-two bucketing with
exact masked sum/n_valid loss accounting over pad rows, the same
per-bucket compiled-executable caching with `trace_counts` as the
no-retrace probe, and — with ``backend="pallas"`` — the same
`build_network_plan` -> `make_fused_generator` path the serving engine
runs, so a training step fills the MXU exactly the way serving does and
`plan_fingerprints()` proves it (hash-asserted against an optional
pinned serve-side plan, `WganTrainer` semantics).

The objective is per-pixel masked MSE between the tower's output and
the target image — the reconstruction loss both SRCNN/ESPCN-style SR
and denoising autoencoders train with.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..data.pipeline import StepIndexedSource
from ..models.dcnn import (DcnnConfig, generator_apply, generator_init,
                           make_fused_generator)

__all__ = ["SupervisedTrainer", "pair_source", "train_supervised"]


def pair_source(workload, seed: int, batch: int) -> StepIndexedSource:
    """Step-indexed ``{"x": inputs, "y": targets}`` source from a
    registered supervised workload's pair synthesizer (pure in
    (seed, step), so training is deterministically resumable)."""
    def fn(step):
        x, y = workload.training_pairs(seed + step, batch)
        return {"x": x, "y": y}

    return StepIndexedSource(fn)


class SupervisedTrainer:
    """Bucketed masked-MSE trainer for image-in/image-out towers.

    ``step(p, state, x, y, key=None)`` takes a (possibly ragged) batch of
    (input, target) images, pads it to its power-of-two bucket, and runs
    the bucket's compiled update — pad rows are masked out of the loss
    with exact sum/n_valid accounting, so a ragged final batch reuses the
    executable without perturbing the gradient."""

    def __init__(self, cfg: DcnnConfig, opt, *,
                 backend: str = "reverse_loop",
                 autotune: bool = True, refine: bool = False,
                 plan=None):
        if backend == "pallas_sparse":
            raise ValueError(
                "pallas_sparse is inference-only: the static zero-skip "
                "plan is derived from frozen weights, which training "
                "updates each step")
        if backend not in ("reverse_loop", "xla", "pallas"):
            raise ValueError(f"unknown training backend {backend!r}")
        if plan is not None:
            if backend != "pallas":
                raise ValueError(
                    "a pinned NetworkPlan needs backend='pallas' (plans "
                    f"pin the fused serving kernels); got {backend!r}")
            if plan.backend != "pallas" or plan.precision != "fp32":
                raise ValueError(
                    "training consumes fp32 pallas plans; got "
                    f"backend={plan.backend!r} / "
                    f"precision={plan.precision!r}")
            plan.validate_for(cfg)
        self.cfg = cfg
        self.opt = opt
        self.backend = backend
        self._autotune = autotune
        self._refine = refine
        self._pinned_plan = plan
        self._fns: Dict[int, Callable] = {}
        self._gen_apply: Dict[int, Callable] = {}
        self.trace_counts: Dict[int, int] = {}
        self.tile_choices: Dict[int, Optional[dict]] = {}
        self.plans: Dict[int, Any] = {}

    # -- bucketing ------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest power-of-two >= n (cf. serve.pow2_buckets)."""
        if n < 1:
            raise ValueError(f"batch must be >= 1 (got {n})")
        b = 1
        while b < n:
            b <<= 1
        return b

    # -- generator forward for the loss path ----------------------------
    def _gen_for(self, bucket: int) -> Callable:
        if bucket not in self._gen_apply:
            if self.backend == "pallas":
                from ..plan import build_network_plan
                pinned = self._pinned_plan
                plan = build_network_plan(
                    self.cfg, batch=bucket, backend="pallas",
                    autotune=self._autotune, refine=self._refine)
                if pinned is not None and plan.batch == pinned.batch:
                    # hash-asserted parity with the serve-side plan
                    if plan.stable_hash() != pinned.stable_hash():
                        raise ValueError(
                            f"trainer-built plan for batch {bucket} "
                            f"({plan.stable_hash()}) does not match the "
                            f"pinned serve-side plan "
                            f"({pinned.stable_hash()}); training would "
                            "fill the MXU differently than serving — "
                            "re-pin one side")
                    plan = pinned
                self.plans[bucket] = plan
                self.tile_choices[bucket] = plan.tile_overrides()
                self._gen_apply[bucket] = make_fused_generator(
                    self.cfg, plan=plan)
            else:
                backend = self.backend
                self._gen_apply[bucket] = (
                    lambda p, x, _b=backend: generator_apply(
                        p, self.cfg, x, backend=_b))
        return self._gen_apply[bucket]

    # -- step construction ----------------------------------------------
    def _build_fn(self, bucket: int) -> Callable:
        gen_fn = self._gen_for(bucket)
        opt = self.opt
        out_elems = float(self.cfg.img_hw * self.cfg.img_hw
                          * self.cfg.img_c)

        def body(p, state, x, y, nv):
            rows = jnp.arange(bucket)

            def loss_fn(p_):
                pred = gen_fn(p_, x)
                mask = (rows < nv).astype(pred.dtype)
                per_row = jnp.sum((pred - y.astype(pred.dtype)) ** 2,
                                  axis=(1, 2, 3))
                # masked mean over valid pixels: pad rows contribute
                # exactly zero and the divisor is the true batch size
                return jnp.sum(per_row * mask) / (
                    jnp.asarray(nv, pred.dtype) * out_elems)

            loss, grads = jax.value_and_grad(loss_fn)(p)
            p, state = opt.update(grads, state, p)
            return p, state, {"loss": loss}

        def traced(*args):
            self.trace_counts[bucket] = self.trace_counts.get(bucket, 0) + 1
            return body(*args)

        return jax.jit(traced)

    # -- public API ------------------------------------------------------
    def init_state(self, key):
        p, _ = generator_init(key, self.cfg)
        return p, self.opt.init(p)

    def step(self, p, state, x, y):
        """One update on a (possibly ragged) batch of (input, target)
        image pairs; returns ``(params, opt_state, {"loss": ...})``."""
        dt = jnp.dtype(self.cfg.dtype)
        x = jnp.asarray(x, dt)
        y = jnp.asarray(y, dt)
        if x.shape[0] != y.shape[0]:
            raise ValueError(
                f"input/target batches disagree: {x.shape[0]} vs "
                f"{y.shape[0]}")
        n = x.shape[0]
        bucket = self.bucket_for(n)
        if bucket > n:
            pad = bucket - n
            x = jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
            y = jnp.concatenate(
                [y, jnp.zeros((pad,) + y.shape[1:], y.dtype)], axis=0)
        if bucket not in self._fns:
            self._fns[bucket] = self._build_fn(bucket)
        nv = jnp.asarray(n, jnp.int32)  # dynamic: no retrace per raggedness
        return self._fns[bucket](p, state, x, y, nv)

    @property
    def total_compiles(self) -> int:
        return sum(self.trace_counts.values())

    def plan_fingerprints(self) -> Dict[int, str]:
        """{batch -> stable hash} of the plans the forward actually ran
        (pallas backend) — compare against the serve engine's to prove
        training and serving pin the same executables."""
        from ..plan import executable_fingerprints
        return executable_fingerprints(self.plans.values())

    # -- training loop ----------------------------------------------------
    def fit(self, source, steps: int, key, log_every: int = 50):
        """Train for ``steps`` steps over a step-indexed pair source
        (anything exposing ``batch(step) -> {"x": ..., "y": ...}``; see
        `pair_source`).  Returns ``(params, history)``."""
        p, state = self.init_state(key)
        history: List[dict] = []
        for step in range(steps):
            rec = source.batch(step)
            p, state, met = self.step(p, state, rec["x"], rec["y"])
            if step % log_every == 0 or step == steps - 1:
                history.append({"step": step, "loss": float(met["loss"])})
        return p, history


def train_supervised(workload, steps: int, key, opt, *, batch: int = 8,
                     seed: int = 0, backend: str = "reverse_loop",
                     **kwargs):
    """Train a registered supervised workload end to end: synthesize its
    pair source, run ``steps`` bucketed updates, return
    ``(params, trainer, history)`` (the trainer for its pinned plans)."""
    trainer = SupervisedTrainer(workload.cfg, opt, backend=backend,
                                **kwargs)
    src = pair_source(workload, seed, batch)
    p, history = trainer.fit(src, steps, key)
    return p, trainer, history
