"""LM training step: causal LM loss (+ MoE aux), gradient accumulation,
optional int8 gradient compression with error feedback, remat via the model
config.  Pure functions suitable for jax.jit with shardings."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.transformer import ModelConfig, apply_lm
from ..optim.compression import EFState, compress_grads, decompress_grads


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    fe = batch.get("frontend_embeds")
    logits, _, aux = apply_lm(params, cfg, batch["tokens"], fe, mode="train")
    if fe is not None:  # loss over the token region only
        logits = logits[:, fe.shape[1]:, :]
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    loss = ce + cfg.aux_loss_coef * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(
    cfg: ModelConfig,
    optimizer,
    grad_accum: int = 1,
    compress: bool = False,
):
    """Returns train_step(params, opt_state, ef_state, batch) ->
    (params, opt_state, ef_state, metrics).

    grad_accum > 1 splits the batch into microbatches scanned sequentially —
    the reduce-scatter of microbatch i overlaps the compute of i+1 under
    XLA's latency-hiding scheduler.  `compress` runs grads through int8
    quantization + error feedback (models the compressed cross-pod
    all-reduce; quantization happens where the collective would)."""

    def grads_of(params, batch):
        return jax.value_and_grad(lm_loss, has_aux=True)(params, cfg, batch)

    def train_step(params, opt_state, ef_state: Optional[EFState], batch):
        if grad_accum == 1:
            (loss, met), grads = grads_of(params, batch)
        else:
            # microbatch layout (B/ga, ga, ...): contiguous batch blocks stay
            # on their data shard — slicing axis 1 needs NO resharding.
            def split(x):
                return x.reshape(x.shape[0] // grad_accum, grad_accum,
                                 *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def body(carry, idx):
                acc, loss_acc = carry
                mb = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_index_in_dim(
                        x, idx, axis=1, keepdims=False), micro)
                (l, _), g = grads_of(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, 0.0), jnp.arange(grad_accum))
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            met = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        if compress:
            q, s, ef_state = compress_grads(grads, ef_state)
            grads = decompress_grads(q, s)

        params, opt_state = optimizer.update(grads, opt_state, params)
        met = dict(met, loss=loss)
        return params, opt_state, ef_state, met

    return train_step
