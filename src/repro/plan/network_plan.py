"""Whole-generator execution plan: the repo's analogue of a pinned bitstream.

`NetworkPlan` composes one `DeconvPlan` per generator layer and owns
everything the serving stack used to re-decide per call: the autotune
cache interaction (each layer's plan hash is its cache key), precision
selection (fp32 vs the calibrated int8 chain), the zero-skip schedules,
and the roofline/traffic estimates.  A deployment serializes the plan
(`to_json`) next to its checkpoint and reloads it (`from_json`) to serve
exactly the configuration that was validated — the way the paper's FPGA
deployment pins a bitstream.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from .deconv_plan import (PLAN_SCHEMA_VERSION, DeconvPlan, PlanSchemaError,
                          build_layer_plan)

PRECISIONS = ("fp32", "int8")


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """Per-layer `DeconvPlan`s plus the network-level choices that bind
    them: backend, precision, the (per-device) batch every layer's tiles
    were fitted to, and — for int8 — the calibration strategy the layer
    scales came from."""

    name: str
    backend: str
    precision: str
    batch: int
    layers: Tuple[DeconvPlan, ...]
    quant_strategy: Optional[str] = None
    # canonical `repro.workloads` registry name (None on legacy plans
    # pinned before the workload zoo existed — their hashes are stable)
    workload: Optional[str] = None
    schema_version: int = PLAN_SCHEMA_VERSION

    def __post_init__(self):
        if self.precision not in PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"expected one of {PRECISIONS}")

    # -- executor-facing views -----------------------------------------
    def tile_overrides(self) -> Optional[Dict[int, Any]]:
        """Per-layer TileChoice map (what generator_apply consumes), or
        None for backends without tile factors."""
        if any(l.tiles is None for l in self.layers):
            return None
        return {i: l.tiles for i, l in enumerate(self.layers)}

    def sparse_plans(self) -> Optional[Dict[int, tuple]]:
        """Per-layer zero-skip schedules for backend="pallas_sparse"."""
        if self.backend != "pallas_sparse":
            return None
        if any(l.sparse_tables is None for l in self.layers):
            return None
        return {i: l.sparse_tables for i, l in enumerate(self.layers)}

    def quant_config(self):
        """Reconstruct the `quant.calibrate.QuantConfig` pinned in the
        per-layer plans (None for fp32 plans)."""
        if self.precision != "int8":
            return None
        from ..quant.calibrate import QuantConfig

        if any(l.quant is None for l in self.layers):
            raise ValueError("int8 plan is missing per-layer quant scales")
        return QuantConfig(
            name=self.name,
            strategy=self.quant_strategy or "mean_ksigma",
            layers=tuple(l.quant for l in self.layers),
        )

    def validate_for(self, cfg) -> None:
        """Reject a plan built for a different network geometry (the
        plan/params mismatch a pinned deployment must fail loudly on)."""
        geoms = list(cfg.geometries())
        if len(geoms) != len(self.layers):
            raise ValueError(
                f"plan '{self.name}' has {len(self.layers)} layers; "
                f"{cfg.name} has {len(geoms)}")
        for i, (g, l) in enumerate(zip(geoms, self.layers)):
            if g != l.geometry:
                raise ValueError(
                    f"plan layer {i} geometry {l.geometry} does not match "
                    f"{cfg.name} layer {i} geometry {g}")

    def verify_sparse_tables(self, params) -> None:
        """Fail loudly when a pinned pallas_sparse plan's zero-skip
        schedules no longer match the weights about to be served (e.g.
        the checkpoint was re-pruned after the plan was pinned) — a stale
        schedule would silently skip now-nonzero blocks.  One O(weights)
        host pass; call it where plan and concrete params meet (the
        serving engine does at construction)."""
        if self.backend != "pallas_sparse":
            return
        from ..kernels.deconv2d_sparse import make_sparse_plan
        from .deconv_plan import _sparse_digest

        for i, l in enumerate(self.layers):
            if l.sparse_digest is None:
                continue
            g = l.geometry
            want = _sparse_digest(make_sparse_plan(
                np.asarray(params[f"l{i}"]["w"]), g.stride, g.padding,
                l.tiles.t_ci, l.tiles.t_co))
            if want != l.sparse_digest:
                raise ValueError(
                    f"layer {i}: the pinned zero-skip schedule "
                    f"({l.sparse_digest}) does not match the schedule of "
                    f"the weights being served ({want}); the plan is "
                    "stale — re-plan against these params")

    # -- hashing / serialization ---------------------------------------
    def stable_hash(self) -> str:
        import hashlib

        d = {"schema": self.schema_version, "name": self.name,
             "backend": self.backend, "precision": self.precision,
             "batch": self.batch, "quant_strategy": self.quant_strategy,
             "layers": [l.request_dict("full") for l in self.layers]}
        # keyed in only when set, so legacy (pre-zoo) plan hashes hold
        if self.workload is not None:
            d["workload"] = self.workload
        blob = json.dumps(d, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:24]

    def to_json(self, path: Optional[str] = None) -> str:
        s = json.dumps({
            "schema": self.schema_version,
            "kind": "repro.NetworkPlan",
            "name": self.name,
            "backend": self.backend,
            "precision": self.precision,
            "batch": self.batch,
            "quant_strategy": self.quant_strategy,
            "workload": self.workload,
            "stable_hash": self.stable_hash(),
            "layers": [l.to_json_dict() for l in self.layers],
        }, indent=1, sort_keys=True)
        if path is not None:
            with open(path, "w") as f:
                f.write(s)
        return s

    @classmethod
    def from_json(cls, s: str) -> "NetworkPlan":
        try:
            d = json.loads(s)
        except ValueError as e:
            raise PlanSchemaError(f"not a NetworkPlan JSON document: {e}")
        if not isinstance(d, dict) or d.get("kind") != "repro.NetworkPlan":
            raise PlanSchemaError(
                "not a NetworkPlan JSON document (missing kind tag)")
        if d.get("schema") != PLAN_SCHEMA_VERSION:
            raise PlanSchemaError(
                f"NetworkPlan schema {d.get('schema')!r} is not the "
                f"supported v{PLAN_SCHEMA_VERSION}; re-plan with this "
                "version instead of executing a stale configuration")
        plan = cls(
            name=d["name"], backend=d["backend"], precision=d["precision"],
            batch=int(d["batch"]), quant_strategy=d.get("quant_strategy"),
            workload=d.get("workload"),
            layers=tuple(DeconvPlan.from_json_dict(l) for l in d["layers"]),
        )
        want = d.get("stable_hash")
        if want is not None and plan.stable_hash() != want:
            raise PlanSchemaError(
                "NetworkPlan content hash mismatch: the document was "
                "edited after it was pinned")
        return plan

    @classmethod
    def load(cls, path: str) -> "NetworkPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- roofline / traffic estimates ----------------------------------
    def traffic_report(self) -> Dict[int, Any]:
        """Per-layer modeled HBM traffic (`core.tiling.DeconvTraffic`) at
        this plan's batch and tiles; empty for non-tiled backends."""
        from ..core.tiling import deconv_traffic_batched

        out: Dict[int, Any] = {}
        for i, l in enumerate(self.layers):
            if l.tiles is None:
                continue
            t = l.tiles
            out[i] = deconv_traffic_batched(
                l.geometry, self.batch, t.t_n, t.t_oh, t.t_ow, t.t_ci,
                t.t_co, l.dtype_bytes, out_dtype_bytes=l.out_dtype_bytes)
        return out

    def modeled_attainable(self, device=None) -> Dict[int, Any]:
        """Per-layer roofline `DsePoint` at this plan's tiles."""
        from ..core.dse import TPU_V5E, tile_attainable

        device = TPU_V5E if device is None else device
        out: Dict[int, Any] = {}
        for i, l in enumerate(self.layers):
            if l.tiles is None:
                continue
            t = l.tiles
            out[i] = tile_attainable(
                l.geometry, t.t_oh, t.t_ow, t.t_ci, t.t_co, device,
                t_n=t.t_n, batch=self.batch, dtype_bytes=l.dtype_bytes,
                out_dtype_bytes=l.out_dtype_bytes)
        return out

    def modeled_network_ops(self, device=None) -> Optional[float]:
        """Whole-network modeled throughput (total ops / sum of per-layer
        roofline times) — the paper's network metric; None if untiled."""
        pts = self.modeled_attainable(device)
        if len(pts) != len(self.layers):
            return None
        total_ops = sum(l.geometry.ops * self.batch for l in self.layers)
        total_t = sum(l.geometry.ops * self.batch / pts[i].attainable_ops
                      for i, l in enumerate(self.layers))
        return total_ops / total_t


def build_network_plan(
    cfg,
    *,
    batch: int = 1,
    backend: str = "pallas",
    precision: str = "fp32",
    params=None,
    quant_cfg=None,
    calib_batch: int = 64,
    calib_seed: int = 0,
    calib_strategy: str = "mean_ksigma",
    autotune: bool = True,
    refine: bool = False,
    device=None,
    sparse_table_cache: Optional[Dict] = None,
) -> NetworkPlan:
    """Plan a whole generator (``cfg`` is a `models.dcnn.DcnnConfig`).

    ``batch`` is the batch every layer's kernel will actually see — a
    serving bucket on one device, or the per-device sub-batch on a mesh.
    For precision="int8" a ``quant_cfg`` pins pre-calibrated scales;
    without one, ``params`` are calibrated here (statistical observers on
    the z ~ N(0,1) serving distribution).  For backend="pallas_sparse",
    ``params`` supply the static pruned weights the zero-skip schedules
    are compiled from.  Timing cost: plan building is the ONLY place tile
    resolution happens — executors run the pinned plan with zero per-call
    re-planning."""
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"expected one of {PRECISIONS}")
    if precision == "int8" and backend != "pallas":
        raise ValueError(
            "precision='int8' runs the dense int8 Pallas kernel; "
            f"backend={backend!r} has no quantized variant")
    if backend == "pallas_sparse" and params is None:
        # a weightless sparse plan would re-derive the O(weights) schedule
        # on every call (and crash under an outer jit) — the exact
        # per-call re-planning this API exists to eliminate
        raise ValueError(
            "backend='pallas_sparse' planning needs params: the zero-skip "
            "schedule is compiled from the static pruned weights")
    geoms = list(cfg.geometries())
    if precision == "int8" and quant_cfg is None:
        if params is None:
            raise ValueError(
                "int8 planning needs either a pre-computed quant_cfg or "
                "params to calibrate")
        from ..quant.calibrate import calibrate
        from ..workloads import calibration_input

        # N(0,1) latents for generative towers, workload-synthesized
        # inputs for image-rooted ones; deterministic in calib_seed so
        # the engine's independent self-calibration lands the same scales
        z_cal = calibration_input(cfg, seed=calib_seed, batch=calib_batch)
        quant_cfg = calibrate(params, cfg, z_cal, strategy=calib_strategy)

    dtype = np.dtype(np.int8) if precision == "int8" else np.dtype(cfg.dtype)
    int8_chain = precision == "int8"
    layers = []
    for i, (g, l) in enumerate(zip(geoms, cfg.layers)):
        last = i == len(geoms) - 1
        layers.append(build_layer_plan(
            g,
            batch=batch,
            dtype=dtype,
            backend=backend,
            activation=l.activation,
            out_scale=(quant_cfg.out_scale(i) if int8_chain else None),
            # the int8 chain's final epilogue emits f32 images while every
            # intermediate layer re-quantizes to int8 (matches the
            # dtype-aware autotuner's pricing)
            out_dtype_bytes=(4 if int8_chain and last else None),
            quant=(quant_cfg.layers[i] if int8_chain else None),
            # only the zero-skip schedule needs the raw weights (an int8
            # engine holds a quantized tree without "w" leaves by now)
            weights=(params[f"l{i}"]["w"]
                     if backend == "pallas_sparse" and params is not None
                     else None),
            autotune=autotune,
            refine=refine,
            device=device,
            sparse_table_cache=sparse_table_cache,
            sparse_cache_key=i,
        ))
    from ..workloads import workload_name_for

    return NetworkPlan(
        name=cfg.name, backend=backend, precision=precision, batch=batch,
        layers=tuple(layers),
        quant_strategy=(quant_cfg.strategy if int8_chain else None),
        workload=workload_name_for(cfg),
    )


def executable_fingerprints(plans) -> Dict[int, str]:
    """{per-device batch -> stable hash} over a collection of
    `NetworkPlan`s — the "same executable everywhere" check.

    Two plans that agree on the per-device batch must agree on the hash:
    one mesh's bucket-16 at 8 devices is another's bucket-8 at 4, and a
    deployment that cannot prove that identity is running an executable
    nobody validated.  The elastic serving engine records these before
    and after a device-loss remesh and asserts the overlap matches;
    multi-host deployments can compare the fingerprints of the plan
    JSONs each host pinned.  Raises on an internal conflict (two plans
    for the same per-device batch that disagree)."""
    out: Dict[int, str] = {}
    for p in plans:
        h = p.stable_hash()
        prev = out.setdefault(p.batch, h)
        if prev != h:
            raise ValueError(
                f"two plans for per-device batch {p.batch} disagree: "
                f"{prev} vs {h}")
    return out


def variant_fingerprints(plans) -> Dict[str, str]:
    """{"b{per-device batch}/{precision}" -> stable hash} over plans that
    span *precision variants* (the async frontend pins one plan per
    bucket x precision: the fp32 chain and its int8 degradation both
    serve the same bucket, so `executable_fingerprints`' batch-only key
    would see a false conflict).  Same contract otherwise: two plans for
    the same (batch, precision) must agree on the hash, and a deployment
    compares these dicts across hosts / across a remesh to prove "same
    executables everywhere"."""
    out: Dict[str, str] = {}
    for p in plans:
        key = f"b{p.batch}/{p.precision}"
        h = p.stable_hash()
        prev = out.setdefault(key, h)
        if prev != h:
            raise ValueError(
                f"two plans for {key} disagree: {prev} vs {h}")
    return out


def timed_build(fn, *args, **kwargs):
    """(result, seconds) helper for plan-build cost accounting."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0
