"""Frozen per-layer execution plan for the deconv kernels.

The paper's accelerator decides geometry, tiling, precision and sparsity
handling once at design time and then executes the fixed datapath at
inference (Sec. III; Zhang et al. formalize the plan-then-execute split).
`DeconvPlan` is that design point for one deconv layer on the TPU stack:
it pins the layer geometry, the resolved tile assignment (including the
batch tile ``t_n``), the dtype / calibrated quantization scales, the
zero-skip schedule, and the fused epilogue — everything a kernel wrapper
needs to dispatch without re-deciding anything per call.

Plans are frozen dataclasses: hashable, comparable, and serializable
(`to_json_dict`/`from_json_dict`).  `stable_hash` is a content digest of
the *planning inputs* — the autotune cache is keyed on it (schema v4), so
two requests differing in dtype, batch, backend or epilogue can never
silently alias one cache entry.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..core.tiling import DeconvGeometry
from ..kernels.autotune import TileChoice

# Bump when the serialized plan layout changes incompatibly.  Loaders
# refuse a stale schema outright (PlanSchemaError) — a silently mis-read
# plan would execute a different configuration than the one that was
# pinned, the exact failure the plan exists to prevent.
PLAN_SCHEMA_VERSION = 1


class PlanSchemaError(ValueError):
    """A serialized plan carries a schema this code cannot execute."""


def _sparse_digest(tables: Tuple[np.ndarray, np.ndarray, np.ndarray]) -> str:
    """Content hash of a zero-skip schedule (make_sparse_plan output)."""
    h = hashlib.sha256()
    for a in tables:
        a = np.ascontiguousarray(a)
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class DeconvPlan:
    """One layer's pinned execution configuration.

    Planning inputs (hashed by `stable_hash`):
      * ``geometry``  — the static layer geometry;
      * ``batch``     — the batch the tiles are fitted to (a serving
                        bucket's per-device sub-batch);
      * ``dtype``     — streamed element dtype name ("float32"/"int8");
      * ``backend``   — "pallas" | "pallas_sparse" (or a non-tiled
                        backend, in which case ``tiles`` stays None);
      * ``activation``/``out_scale``/``out_dtype_bytes`` — the fused
                        epilogue: bias+activation, optional int8 requant
                        into the next layer's scale, optional widened
                        output block (the last int8 layer emits f32);
      * ``quant``     — the calibrated `quant.calibrate.LayerQuant`
                        scales for int8 layers;
      * ``sparse_digest`` — content hash of the zero-skip schedule.

    Resolved execution state:
      * ``tiles``         — the `TileChoice` the kernel grid runs at;
      * ``sparse_tables`` — the host-built (ci_idx, valid, tap_mask)
                            schedule (excluded from equality/hash; its
                            ``sparse_digest`` stands in for it).
    """

    geometry: DeconvGeometry
    batch: int = 1
    dtype: str = "float32"
    backend: str = "pallas"
    activation: Optional[str] = None
    out_scale: Optional[float] = None
    out_dtype_bytes: Optional[int] = None
    quant: Optional[Any] = None            # quant.calibrate.LayerQuant
    sparse_digest: Optional[str] = None
    tiles: Optional[TileChoice] = None
    sparse_tables: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = \
        dataclasses.field(default=None, compare=False, repr=False)

    # -- hashing --------------------------------------------------------
    def request_dict(self, scope: str = "full") -> Dict[str, Any]:
        """Canonical planning-input dict.

        ``scope="tiles"`` keeps only the fields the tile autotuner's
        choice depends on — the v4 cache key hashes exactly this subset,
        so e.g. two weight sets with different sparsity patterns share
        one tile entry (the zero-skip schedule is DMA-level, not a tile
        legality/ranking input) while dtype/batch/backend never alias.
        """
        d: Dict[str, Any] = {
            "schema": PLAN_SCHEMA_VERSION,
            "geometry": dataclasses.asdict(self.geometry),
            "batch": self.batch,
            "dtype": self.dtype,
            "backend": self.backend,
            "out_dtype_bytes": self.out_dtype_bytes,
        }
        if scope == "tiles":
            return d
        d.update({
            "activation": self.activation,
            "out_scale": self.out_scale,
            "quant": (dataclasses.asdict(self.quant)
                      if self.quant is not None else None),
            "sparse_digest": self.sparse_digest,
            "tiles": (self.tiles.as_kwargs()
                      if self.tiles is not None else None),
        })
        return d

    def stable_hash(self, scope: str = "full") -> str:
        """Deterministic content digest of the plan.

        ``scope="full"`` pins the complete executable configuration
        (including the resolved tiles); ``scope="tiles"`` hashes only the
        tile-planning inputs and is what `kernels.autotune.cache_key`
        keys the v4 cache on."""
        blob = json.dumps(self.request_dict(scope), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:24]

    # -- convenience ----------------------------------------------------
    @property
    def dtype_bytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize)

    def tile_kwargs(self) -> Dict[str, int]:
        if self.tiles is None:
            raise ValueError("plan has no resolved tiles "
                             f"(backend={self.backend!r})")
        return self.tiles.as_kwargs()

    def padded_geometry(self) -> Tuple[int, ...]:
        """The resolved `halo_pad_geometry` output for this plan's batch
        and tiles: ``(oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop,
        t_n, np_)`` — every address-arithmetic quantity the kernel's
        padding/grid depends on, pinned at plan time (the kernels
        recompute the same numbers from the same static inputs, so this
        is the documented/inspectable form, not a second source of
        truth)."""
        from ..core.offsets import make_phase_plan
        from ..kernels.deconv2d.ops import halo_pad_geometry

        g = self.geometry
        t = self.tiles
        if t is None:
            raise ValueError("plan has no resolved tiles "
                             f"(backend={self.backend!r})")
        pp = make_phase_plan(g.kernel, g.stride, g.padding)
        return halo_pad_geometry(self.batch, g.in_h, g.in_w, g.c_in,
                                 g.c_out, pp, t.t_oh, t.t_ow, t.t_ci,
                                 t.t_co, t.t_n)

    # -- (de)serialization ---------------------------------------------
    def to_json_dict(self) -> Dict[str, Any]:
        d = self.request_dict("full")
        if self.sparse_tables is not None:
            d["sparse_tables"] = [np.asarray(a).tolist()
                                  for a in self.sparse_tables]
        if self.tiles is not None:
            # keep the provenance/model fields the cache also stores
            d["tiles"] = dataclasses.asdict(self.tiles)
        return d

    @classmethod
    def from_json_dict(cls, d: Dict[str, Any]) -> "DeconvPlan":
        if d.get("schema") != PLAN_SCHEMA_VERSION:
            raise PlanSchemaError(
                f"DeconvPlan schema {d.get('schema')!r} is not the "
                f"supported v{PLAN_SCHEMA_VERSION}; re-build the plan with "
                "this version of the code instead of executing a stale one")
        from ..quant.calibrate import LayerQuant

        quant = d.get("quant")
        tiles = d.get("tiles")
        tables = d.get("sparse_tables")
        if tables is not None:
            tables = tuple(np.asarray(a, np.int32) for a in tables)
        plan = cls(
            geometry=DeconvGeometry(**d["geometry"]),
            batch=int(d["batch"]),
            dtype=str(d["dtype"]),
            backend=str(d["backend"]),
            activation=d.get("activation"),
            out_scale=d.get("out_scale"),
            out_dtype_bytes=d.get("out_dtype_bytes"),
            quant=(LayerQuant(x_scale=float(quant["x_scale"]),
                              w_scale=tuple(float(v)
                                            for v in quant["w_scale"]))
                   if quant is not None else None),
            sparse_digest=d.get("sparse_digest"),
            tiles=(TileChoice(**{k: v for k, v in tiles.items()
                                 if k in TileChoice.__dataclass_fields__})
                   if tiles is not None else None),
            sparse_tables=tables,
        )
        if tables is not None and plan.sparse_digest is not None:
            got = _sparse_digest(tables)
            if got != plan.sparse_digest:
                raise PlanSchemaError(
                    "sparse schedule content hash mismatch "
                    f"({got} != {plan.sparse_digest}): the serialized "
                    "zero-skip tables do not match the plan that was pinned")
        return plan


def build_layer_plan(
    geom: DeconvGeometry,
    *,
    batch: int = 1,
    dtype="float32",
    backend: str = "pallas",
    activation: Optional[str] = None,
    out_scale: Optional[float] = None,
    out_dtype_bytes: Optional[int] = None,
    quant=None,
    weights: Optional[np.ndarray] = None,
    tiles: Optional[TileChoice] = None,
    autotune: bool = True,
    refine: bool = False,
    device=None,
    sparse_table_cache: Optional[Dict] = None,
    sparse_cache_key=None,
) -> DeconvPlan:
    """Resolve one layer's `DeconvPlan` (tiles via the DSE autotuner).

    ``weights`` (the pruned static weight array) is required to build the
    zero-skip schedule for backend="pallas_sparse"; ``sparse_table_cache``
    memoizes host-built tables across plans that share
    (``sparse_cache_key``, t_ci, t_co) — e.g. a serving engine's buckets,
    which key by layer index.  The memo is only consulted when the caller
    names a ``sparse_cache_key`` (an object identity would be reused by
    the allocator and could serve another weight set's schedule).
    Non-tiled backends ("reverse_loop", "xla") get a plan with
    ``tiles=None``."""
    from ..core.dse import TPU_V5E

    device = TPU_V5E if device is None else device
    dtype_name = np.dtype(dtype).name
    if backend not in ("pallas", "pallas_sparse"):
        return DeconvPlan(geometry=geom, batch=batch, dtype=dtype_name,
                          backend=backend, activation=activation)
    if tiles is None:
        from ..kernels.autotune import choose_tiles, fallback_tiles

        if autotune:
            tiles = choose_tiles(geom, np.dtype(dtype), backend=backend,
                                 refine=refine, device=device, batch=batch,
                                 out_dtype_bytes=out_dtype_bytes)
        else:
            tiles = fallback_tiles(geom, np.dtype(dtype).itemsize,
                                   device.onchip_bytes, batch=batch,
                                   out_dtype_bytes=out_dtype_bytes)
    sparse_tables = None
    digest = None
    if backend == "pallas_sparse" and weights is not None:
        from ..kernels.deconv2d_sparse import make_sparse_plan

        use_memo = (sparse_table_cache is not None
                    and sparse_cache_key is not None)
        memo_key = (sparse_cache_key, tiles.t_ci, tiles.t_co)
        if use_memo and memo_key in sparse_table_cache:
            sparse_tables = sparse_table_cache[memo_key]
        else:
            sparse_tables = make_sparse_plan(
                np.asarray(weights), geom.stride, geom.padding,
                tiles.t_ci, tiles.t_co)
            if use_memo:
                sparse_table_cache[memo_key] = sparse_tables
        digest = _sparse_digest(sparse_tables)
    return DeconvPlan(
        geometry=geom, batch=batch, dtype=dtype_name, backend=backend,
        activation=activation, out_scale=out_scale,
        out_dtype_bytes=out_dtype_bytes, quant=quant,
        sparse_digest=digest, tiles=tiles, sparse_tables=sparse_tables,
    )
