"""Plan/execute API: the single way to run deconv work.

Build once (`build_layer_plan` / `build_network_plan`), execute many —
every kernel wrapper takes a ``plan=`` fast path, `generator_apply` /
`quantized_generator_apply` / `make_fused_generator` consume a
`NetworkPlan`, and `serve.DcnnServeEngine.from_config` serves one plan
per bucket.  Plans serialize to JSON so a deployment pins its compiled
configuration the way the paper pins a bitstream.
"""
from .deconv_plan import (PLAN_SCHEMA_VERSION, DeconvPlan, PlanSchemaError,
                          build_layer_plan)
from .network_plan import (NetworkPlan, build_network_plan,
                           executable_fingerprints, variant_fingerprints)

__all__ = [
    "PLAN_SCHEMA_VERSION", "DeconvPlan", "PlanSchemaError",
    "build_layer_plan", "NetworkPlan", "build_network_plan",
    "executable_fingerprints", "variant_fingerprints",
]
