"""Optimizers from scratch (no optax): AdamW / Adam / SGD with pytree states,
f32 master accumulators over possibly-bf16 params, global-norm clipping.

State layout mirrors the param tree so the same sharding specs apply (ZeRO-1
when the sharding rules put params' data axes on 'data')."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamState, params) -> Tuple[Any, AdamState]:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        step = state.step + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                    state.mu, grads)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                    state.nu, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float = 1e-2
    momentum: float = 0.0

    def init(self, params):
        if self.momentum:
            return jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        return ()

    def update(self, grads, state, params):
        if self.momentum:
            state = jax.tree_util.tree_map(
                lambda b, g: self.momentum * b + g.astype(jnp.float32),
                state, grads)
            new = jax.tree_util.tree_map(
                lambda p, b: (p.astype(jnp.float32) - self.lr * b).astype(p.dtype),
                params, state)
            return new, state
        new = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - self.lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new, state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
