"""Gradient compression for cross-pod data parallelism.

int8 stochastic-free symmetric quantization with per-leaf scales plus error
feedback (residual carried to the next step), applied *before* the DP
all-reduce so inter-pod ICI traffic drops ~4x (bf16->int8 with f32 scales).
Error feedback keeps convergence (Karimireddy et al. style).

The scale/round/clip arithmetic is `quant.qmath` — the same symmetric
int8 math the inference quantization path uses (one quantization math
module, two call sites).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..quant.qmath import dequantize_symmetric, quantize_absmax


class EFState(NamedTuple):
    residual: Any


def init_error_feedback(grads_like) -> EFState:
    return EFState(residual=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    return quantize_absmax(g)


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return dequantize_symmetric(q, scale)


def compress_grads(grads, ef: EFState) -> Tuple[Any, Any, EFState]:
    """Returns (quantized tree, scales tree, new error-feedback state)."""
    corrected = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, ef.residual)
    qs = jax.tree_util.tree_map(quantize_leaf, corrected)
    q = jax.tree_util.tree_map(lambda t: t[0], qs,
                               is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree_util.tree_map(lambda t: t[1], qs,
                               is_leaf=lambda t: isinstance(t, tuple))
    deq = jax.tree_util.tree_map(dequantize_leaf, q, s)
    new_res = jax.tree_util.tree_map(lambda c, d: c - d, corrected, deq)
    return q, s, EFState(residual=new_res)


def decompress_grads(q, s):
    return jax.tree_util.tree_map(dequantize_leaf, q, s)


def compression_ratio(grads) -> float:
    raw = sum(g.size * 4 for g in jax.tree_util.tree_leaves(grads))
    comp = sum(g.size * 1 + 4 for g in jax.tree_util.tree_leaves(grads))
    return raw / comp
