from .optimizer import AdamW, SGD, AdamState, global_norm
from .schedule import warmup_cosine, constant
from .compression import (init_error_feedback, compress_grads,
                          decompress_grads, compression_ratio, EFState)
