"""Workload registry: named deconv towers on the plan surface.

A `Workload` binds a `models.dcnn.DcnnConfig` tower to everything the
rest of the stack needs to treat it as a first-class citizen: a stable
registry name (what `EngineConfig.model` / `--net` / plan JSONs carry),
the training objective kind ("generative" adversarial vs "supervised"
reconstruction), a deterministic calibration-batch synthesizer for the
int8 observers, and — for supervised heads — a training-pair
synthesizer.  Registration is open: third-party towers call
`register()` at import time and immediately train/plan/serve through
the same machinery as the built-ins (see `repro.workloads.zoo`).

Name resolution is strict by design: `get`/`resolve_model` raise a
typed `UnknownWorkloadError` listing the known names — a typo'd
workload must never silently fall back to an MNIST generator.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional, Tuple

from ..models.dcnn import DcnnConfig

__all__ = [
    "Workload",
    "WorkloadError",
    "UnknownWorkloadError",
    "register",
    "get",
    "names",
    "resolve_model",
    "workload_for",
    "workload_name_for",
    "calibration_input",
]


class WorkloadError(ValueError):
    """A model/workload reference the registry cannot satisfy."""


class UnknownWorkloadError(WorkloadError, KeyError):
    """A workload name that is not registered (typed, never a fallback)."""

    def __str__(self) -> str:  # KeyError quotes its arg; keep the message
        return self.args[0] if self.args else ""


# (seed, n) -> array; pair synthesizers return (x, y)
PairFn = Callable[[int, int], Tuple]
CalibFn = Callable[[int, int], object]


@dataclasses.dataclass(frozen=True)
class Workload:
    """A named deconv tower plus its task wiring.

    ``kind`` is "generative" (latent-z tower trained adversarially via
    `train.wgan.WganTrainer`) or "supervised" (image-rooted tower
    trained on (input, target) pairs via
    `train.supervised.SupervisedTrainer`).  ``pair_fn(seed, n)``
    synthesizes n training pairs ``(x, y)``; ``calib_fn(seed, n)``
    synthesizes n calibration inputs matching the serving distribution
    (defaults: N(0,1) latents for generative towers, ``pair_fn`` inputs
    for supervised ones)."""

    name: str
    cfg: DcnnConfig
    kind: str
    description: str = ""
    aliases: Tuple[str, ...] = ()
    pair_fn: Optional[PairFn] = None
    calib_fn: Optional[CalibFn] = None

    def __post_init__(self):
        if self.kind not in ("generative", "supervised"):
            raise WorkloadError(
                f"workload {self.name!r}: kind must be 'generative' or "
                f"'supervised', got {self.kind!r}")
        if self.kind == "supervised" and self.pair_fn is None:
            raise WorkloadError(
                f"workload {self.name!r}: supervised workloads need a "
                "pair_fn to synthesize (input, target) training pairs")

    # -- convenience passthroughs to the tower implementation ----------
    def init(self, key):
        from ..models.dcnn import generator_init

        return generator_init(key, self.cfg)

    def apply(self, params, x, **kwargs):
        from ..models.dcnn import generator_apply

        return generator_apply(params, self.cfg, x, **kwargs)

    def ref(self, params, x):
        """The unplanned reverse-loop oracle every fast path is
        parity-tested against."""
        from ..models.dcnn import generator_apply

        return generator_apply(params, self.cfg, x, backend="reverse_loop")

    def training_pairs(self, seed: int, n: int):
        if self.pair_fn is None:
            raise WorkloadError(
                f"workload {self.name!r} is {self.kind}; it has no "
                "(input, target) pair synthesizer")
        return self.pair_fn(seed, n)

    def calibration_batch(self, seed: int, n: int):
        return calibration_input(self.cfg, seed=seed, batch=n,
                                 _workload=self)


_lock = threading.Lock()
_by_name: Dict[str, Workload] = {}   # canonical name -> workload
_index: Dict[str, str] = {}          # name | cfg.name | alias -> canonical


def register(workload: Workload) -> Workload:
    """Add a workload; every key (name, cfg.name, aliases) must be free
    or already point at this same workload (idempotent re-import)."""
    keys = (workload.name, workload.cfg.name) + tuple(workload.aliases)
    with _lock:
        for k in keys:
            owner = _index.get(k)
            if owner is not None and owner != workload.name:
                raise WorkloadError(
                    f"workload key {k!r} is already registered to "
                    f"{owner!r}")
        prev = _by_name.get(workload.name)
        if prev is not None and prev.cfg != workload.cfg:
            raise WorkloadError(
                f"workload {workload.name!r} is already registered with "
                "a different tower config")
        _by_name[workload.name] = workload
        for k in keys:
            _index[k] = workload.name
    return workload


def names() -> Tuple[str, ...]:
    """Canonical registered workload names, sorted."""
    with _lock:
        return tuple(sorted(_by_name))


def get(name: str) -> Workload:
    """Look a workload up by name, cfg.name, or alias — typed error on
    an unknown key, never a fallback."""
    with _lock:
        canonical = _index.get(name)
        if canonical is not None:
            return _by_name[canonical]
        known = sorted(_by_name)
    raise UnknownWorkloadError(
        f"unknown workload {name!r}; registered workloads: {known}")


def workload_for(cfg: DcnnConfig) -> Optional[Workload]:
    """The registered workload whose tower is ``cfg``, else None
    (unregistered ad-hoc towers still plan/serve; they just lose the
    registry's calibration/pair synthesizers)."""
    with _lock:
        canonical = _index.get(cfg.name)
        w = _by_name.get(canonical) if canonical is not None else None
    if w is not None and w.cfg == cfg:
        return w
    return None


def workload_name_for(cfg: DcnnConfig) -> str:
    """Canonical registry name for a tower config, falling back to the
    config's own name for unregistered towers (what `NetworkPlan` and
    the serve metrics stamp as the ``workload`` label)."""
    w = workload_for(cfg)
    return w.name if w is not None else cfg.name


def resolve_model(model) -> DcnnConfig:
    """`EngineConfig.model` resolution: a `DcnnConfig` passes through,
    a string resolves via the registry, anything else is a typed
    error."""
    if isinstance(model, DcnnConfig):
        return model
    if isinstance(model, str):
        return get(model).cfg
    raise WorkloadError(
        f"model must be a DcnnConfig or a registered workload name, "
        f"got {type(model).__name__}")


def calibration_input(cfg: DcnnConfig, *, seed: int = 0, batch: int = 64,
                      _workload: Optional[Workload] = None):
    """A deterministic f32 calibration batch matching ``cfg``'s input
    root.

    Latent towers calibrate on the z ~ N(0,1) serving distribution
    (bit-identical to the pre-registry behaviour, so pinned int8 plan
    hashes are stable).  Image-rooted towers use the registered
    workload's ``calib_fn`` when one exists — realistic input statistics
    matter for activation observers — else unit normals over the input
    shape.  Both the plan builder and the serving engine route their
    self-calibration here with the same (seed, batch), which is what
    keeps their independently-derived quant scales — and therefore plan
    hashes — in agreement."""
    import jax
    import jax.numpy as jnp

    w = _workload if _workload is not None else workload_for(cfg)
    if cfg.is_latent:
        return jax.random.normal(jax.random.PRNGKey(seed),
                                 (batch, cfg.z_dim), jnp.float32)
    if w is not None and w.calib_fn is not None:
        return jnp.asarray(w.calib_fn(seed, batch), jnp.float32)
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (batch,) + cfg.input_shape, jnp.float32)
