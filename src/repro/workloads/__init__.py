"""Workload zoo: named deconv towers on the plan surface.

`registry` is the mechanism (register/resolve typed lookups plus
calibration-input synthesis); `zoo` registers the built-ins — the two
paper WGAN generators and the super-resolution / denoising heads the
paper motivates edge DCNN inference with.  Importing this package
registers the zoo."""
from .registry import (UnknownWorkloadError, Workload, WorkloadError,
                       calibration_input, get, names, register,
                       resolve_model, workload_for, workload_name_for)
from .zoo import DAE_DENOISE, SR_X2

__all__ = [
    "Workload",
    "WorkloadError",
    "UnknownWorkloadError",
    "register",
    "get",
    "names",
    "resolve_model",
    "workload_for",
    "workload_name_for",
    "calibration_input",
    "SR_X2",
    "DAE_DENOISE",
]
