"""Built-in workloads: the two paper WGAN generators plus the edge
workloads the paper motivates DCNN inference with — an ESPCN/FSRCNN-style
x2 super-resolution head and a denoising autoencoder decoder.

The SR head maps a 14x14 low-res digit to its 28x28 reconstruction:
stride-1 feature extraction / nonlinear mapping stages followed by one
strided deconv doing the x2 upsample (the FSRCNN layout, with the final
deconv exactly the paper's accelerable primitive).  The denoiser is the
decoder of a convolutional DAE: a stride-1 hourglass that maps a
noise-corrupted 28x28 digit back to the clean image.  Both are
image-rooted towers (`DcnnConfig.in_hw > 1`) and ride the same kernels,
plans, quantization and serving engine as the generators.

Training pairs are synthesized from `data.synthetic.digit_images`
(deterministic in the seed, so calibration batches — and therefore
pinned int8 plan hashes — are reproducible)."""
from __future__ import annotations

import numpy as np

from ..data.synthetic import digit_images
from ..models.dcnn import CELEBA_DCNN, MNIST_DCNN, DcnnConfig, DeconvLayerCfg
from .registry import Workload, register

__all__ = ["SR_X2", "DAE_DENOISE", "SR", "DENOISE", "MNIST", "CELEBA"]


# ---------------------------------------------------------------------------
# Super-resolution head: 14x14x1 -> 28x28x1 (x2, FSRCNN-style)
# ---------------------------------------------------------------------------
SR_X2 = DcnnConfig(
    name="sr-espcn-x2",
    z_dim=1,          # unused for image-rooted towers (input is in_hw^2*in_c)
    img_hw=28,
    img_c=1,
    in_hw=14,
    layers=(
        DeconvLayerCfg(1, 32, 5, 1, 2, "relu"),    # 14x14 feature extraction
        DeconvLayerCfg(32, 16, 3, 1, 1, "relu"),   # nonlinear mapping
        DeconvLayerCfg(16, 1, 4, 2, 1, "tanh"),    # 14x14 -> 28x28 upsample
    ),
)


def _sr_pairs(seed: int, n: int):
    """(low-res 14x14 input, clean 28x28 target) pairs: the target is a
    synthetic digit, the input its 2x2 box-downsampled copy."""
    y = np.asarray(digit_images(seed, n, hw=28), np.float32)
    x = y.reshape(n, 14, 2, 14, 2, 1).mean(axis=(2, 4))
    return x, y


def _sr_calib(seed: int, n: int):
    return _sr_pairs(seed, n)[0]


SR = register(Workload(
    name="sr",
    cfg=SR_X2,
    kind="supervised",
    description="FSRCNN-style x2 super-resolution head (14x14 -> 28x28)",
    aliases=("sr-x2", "super-resolution"),
    pair_fn=_sr_pairs,
    calib_fn=_sr_calib,
))


# ---------------------------------------------------------------------------
# Denoising autoencoder decoder: noisy 28x28x1 -> clean 28x28x1
# ---------------------------------------------------------------------------
DAE_DENOISE = DcnnConfig(
    name="dae-denoise",
    z_dim=1,
    img_hw=28,
    img_c=1,
    in_hw=28,
    layers=(
        DeconvLayerCfg(1, 24, 5, 1, 2, "relu"),    # encode to feature maps
        DeconvLayerCfg(24, 8, 3, 1, 1, "relu"),    # channel bottleneck
        DeconvLayerCfg(8, 24, 3, 1, 1, "relu"),    # expand
        DeconvLayerCfg(24, 1, 5, 1, 2, "tanh"),    # reconstruct the image
    ),
)

DENOISE_SIGMA = 0.5


def _denoise_pairs(seed: int, n: int):
    """(noise-corrupted input, clean target) pairs at a fixed Gaussian
    corruption level, both clipped to the image range."""
    y = np.asarray(digit_images(seed, n, hw=28), np.float32)
    rng = np.random.default_rng(seed + 0x5EED)
    x = np.clip(y + DENOISE_SIGMA * rng.standard_normal(
        y.shape, dtype=np.float32), -1.0, 1.0)
    return x, y


def _denoise_calib(seed: int, n: int):
    return _denoise_pairs(seed, n)[0]


DENOISE = register(Workload(
    name="denoise",
    cfg=DAE_DENOISE,
    kind="supervised",
    description="denoising autoencoder decoder (noisy 28x28 -> clean 28x28)",
    aliases=("dae", "denoising"),
    pair_fn=_denoise_pairs,
    calib_fn=_denoise_calib,
))


# ---------------------------------------------------------------------------
# The paper's two WGAN generators, registered under their CLI names
# ---------------------------------------------------------------------------
MNIST = register(Workload(
    name="mnist",
    cfg=MNIST_DCNN,
    kind="generative",
    description="paper Fig.4 MNIST WGAN-GP generator (z100 -> 28x28x1)",
))

CELEBA = register(Workload(
    name="celeba",
    cfg=CELEBA_DCNN,
    kind="generative",
    description="paper Fig.4 CelebA WGAN-GP generator (z100 -> 64x64x3)",
))
