"""Fault-tolerant checkpointing (no orbax): sharded npz, atomic renames,
async background saves, retention policy, corrupted/partial-checkpoint
detection on restore.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json (+ .COMMITTED marker).
A checkpoint is valid iff .COMMITTED exists; restore picks the newest valid
step, so a crash mid-save can never poison a restart (atomicity = write to
tmp dir + os.replace + marker last)."""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def save(path: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Atomic synchronous save.  Returns the committed directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": l for i, l in enumerate(leaves)})
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "dtypes": [str(l.dtype) for l in leaves],
        "shapes": [list(l.shape) for l in leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # commit marker written last: partial directories are never "valid"
    with open(os.path.join(final, ".COMMITTED"), "w") as f:
        f.write("ok")
    return final


def valid_steps(path: str) -> List[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for d in os.listdir(path):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(path, d, ".COMMITTED")):
                out.append(int(d.split("_")[1]))
    return sorted(out)


def restore(path: str, tree_like, step: Optional[int] = None):
    """Restore newest (or given) valid checkpoint into tree_like's structure.
    Returns (tree, step, extra) or (None, -1, {}) when nothing valid."""
    steps = valid_steps(path)
    if not steps:
        return None, -1, {}
    step = step if step is not None else steps[-1]
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves = [data[f"a{i}"] for i in range(manifest["n_leaves"])]
    _, treedef = jax.tree_util.tree_flatten(tree_like)
    ref_leaves = jax.tree_util.tree_leaves(tree_like)
    assert len(ref_leaves) == len(leaves), (
        f"checkpoint has {len(leaves)} leaves, model expects {len(ref_leaves)}")
    restored = [np.asarray(l).astype(r.dtype).reshape(r.shape)
                for l, r in zip(leaves, ref_leaves)]
    return (jax.tree_util.tree_unflatten(treedef, restored), step,
            manifest["extra"])


def retain(path: str, keep: int) -> None:
    steps = valid_steps(path)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread checkpointing: device->host transfer happens on the
    caller (cheap, avoids racing live buffers), serialization+fsync happen
    off-thread.  `wait()` joins the in-flight save (call before exit)."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            try:
                save(self.path, step, host_tree, extra)
                retain(self.path, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
