from .checkpoint import save, restore, retain, valid_steps, AsyncCheckpointer
