"""Jit'd wrapper for the block-sparse zero-skipping deconv kernel.

The sparsity schedule is computed on the host from the (static) pruned
weights — the paper's zero-skipping, hoisted to compile/load time."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.offsets import make_phase_plan
from ...core.sparsity import block_mask
from ...core.tiling import out_size
from ..deconv2d.ops import default_tiles, _round_up
from .kernel import build_schedule, deconv2d_sparse_pallas_call


def make_sparse_plan(
    w: np.ndarray, stride: int, padding: int,
    t_ci: int, t_co: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side schedule from pruned weights (static per network)."""
    k = w.shape[0]
    cip = _round_up(w.shape[2], t_ci)
    cop = _round_up(w.shape[3], t_co)
    wp = np.pad(np.asarray(w), ((0, 0), (0, 0), (0, cip - w.shape[2]),
                                (0, cop - w.shape[3])))
    mask = block_mask(wp, t_ci, t_co)  # (K, K, n_ci, n_co)
    ci_idx, valid, tap_mask, _ = build_schedule(mask)
    return ci_idx, valid, tap_mask


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "t_oh", "t_ow", "t_ci", "t_co",
                     "interpret"),
)
def _deconv2d_sparse_jit(
    x, w, b, ci_idx, valid, tap_mask,
    stride, padding, t_oh, t_ow, t_ci, t_co, interpret,
):
    n, ih, iw, ci = x.shape
    k, _, _, co = w.shape
    s = stride
    oh = out_size(ih, k, s, padding)
    ow = out_size(iw, k, s, padding)
    plan = make_phase_plan(k, s, padding)
    ohp = _round_up(oh, t_oh)
    owp = _round_up(ow, t_ow)
    n_h_pad = ohp // s
    n_w_pad = owp // s
    pad_l = plan.left_halo
    pad_rh = max(0, (n_h_pad - 1 + plan.delta_max) - (ih - 1))
    pad_rw = max(0, (n_w_pad - 1 + plan.delta_max) - (iw - 1))
    cip = _round_up(ci, t_ci)
    cop = _round_up(co, t_co)
    xp = jnp.pad(x, ((0, 0), (pad_l, pad_rh), (pad_l, pad_rw), (0, cip - ci)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cip - ci), (0, cop - co)))
    bb = b if b is not None else jnp.zeros((co,), dtype=x.dtype)
    bp = jnp.pad(bb, (0, cop - co)).reshape(1, cop).astype(x.dtype)
    y = deconv2d_sparse_pallas_call(
        xp, wp, bp, ci_idx, valid, tap_mask,
        plan=plan, ohp=ohp, owp=owp,
        t_oh=t_oh, t_ow=t_ow, t_ci=t_ci, t_co=t_co,
        pad_l=pad_l, interpret=interpret,
    )
    return y[:, :oh, :ow, :co]


def deconv2d_sparse(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
    t_oh: Optional[int] = None,
    t_ow: Optional[int] = None,
    t_ci: Optional[int] = None,
    t_co: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Sparse transposed conv; weights are expected pre-pruned (zeros)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, ih, iw, ci = x.shape
    k, _, _, co = w.shape
    oh = out_size(ih, k, stride, padding)
    ow = out_size(iw, k, stride, padding)
    dt_oh, dt_ow, dt_ci, dt_co = default_tiles(oh, ow, ci, co, stride)
    t_oh = t_oh or dt_oh
    t_ow = t_ow or dt_ow
    t_ci = t_ci or dt_ci
    t_co = t_co or dt_co
    ci_idx, valid, tap_mask = make_sparse_plan(
        np.asarray(w), stride, padding, t_ci, t_co
    )
    return _deconv2d_sparse_jit(
        x, w, b, jnp.asarray(ci_idx), jnp.asarray(valid),
        jnp.asarray(tap_mask), stride, padding,
        t_oh, t_ow, t_ci, t_co, interpret,
    )
