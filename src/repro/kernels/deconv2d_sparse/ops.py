"""Jit'd wrapper for the block-sparse zero-skipping deconv kernel.

The sparsity schedule is computed on the host from the (static) pruned
weights — the paper's zero-skipping, hoisted to compile/load time.  Tile
resolution shares `deconv2d.ops.resolve_tiles` (autotuner-backed, keyed
under backend="pallas_sparse")."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.offsets import make_phase_plan
from ...core.sparsity import block_mask
from ...core.tiling import out_size
from ..deconv2d.ops import (_round_up, check_layer_plan, resolve_tiles,
                            warn_legacy_tiles)
from .kernel import build_schedule, deconv2d_sparse_pallas_call


def make_sparse_plan(
    w: np.ndarray, stride: int, padding: int,
    t_ci: int, t_co: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side schedule from pruned weights (static per network)."""
    k = w.shape[0]
    cip = _round_up(w.shape[2], t_ci)
    cop = _round_up(w.shape[3], t_co)
    wp = np.pad(np.asarray(w), ((0, 0), (0, 0), (0, cip - w.shape[2]),
                                (0, cop - w.shape[3])))
    mask = block_mask(wp, t_ci, t_co)  # (K, K, n_ci, n_co)
    ci_idx, valid, tap_mask, _ = build_schedule(mask)
    return ci_idx, valid, tap_mask


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "t_oh", "t_ow", "t_ci", "t_co",
                     "t_n", "activation", "interpret"),
)
def _deconv2d_sparse_jit(
    x, w, b, ci_idx, valid, tap_mask,
    stride, padding, t_oh, t_ow, t_ci, t_co, t_n, activation, interpret,
):
    n, ih, iw, ci = x.shape
    k, _, _, co = w.shape
    s = stride
    oh = out_size(ih, k, s, padding)
    ow = out_size(iw, k, s, padding)
    plan = make_phase_plan(k, s, padding)
    ohp = _round_up(oh, t_oh)
    owp = _round_up(ow, t_ow)
    n_h_pad = ohp // s
    n_w_pad = owp // s
    pad_l = plan.left_halo
    pad_rh = max(0, (n_h_pad - 1 + plan.delta_max) - (ih - 1))
    pad_rw = max(0, (n_w_pad - 1 + plan.delta_max) - (iw - 1))
    cip = _round_up(ci, t_ci)
    cop = _round_up(co, t_co)
    t_n = min(t_n, n) if n > 0 else 1
    np_ = _round_up(n, t_n)
    xp = jnp.pad(x, ((0, np_ - n), (pad_l, pad_rh), (pad_l, pad_rw),
                     (0, cip - ci)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cip - ci), (0, cop - co)))
    bb = b if b is not None else jnp.zeros((co,), dtype=x.dtype)
    bp = jnp.pad(bb, (0, cop - co)).reshape(1, cop).astype(x.dtype)
    y = deconv2d_sparse_pallas_call(
        xp, wp, bp, ci_idx, valid, tap_mask,
        plan=plan, ohp=ohp, owp=owp,
        t_oh=t_oh, t_ow=t_ow, t_ci=t_ci, t_co=t_co, t_n=t_n,
        activation=activation, interpret=interpret,
    )
    return y[:n, :oh, :ow, :co]


def deconv2d_sparse(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: Optional[int] = None,
    padding: Optional[int] = None,
    t_oh: Optional[int] = None,
    t_ow: Optional[int] = None,
    t_ci: Optional[int] = None,
    t_co: Optional[int] = None,
    t_n: Optional[int] = None,
    activation: Optional[str] = None,
    interpret: Optional[bool] = None,
    autotune: bool = True,
    plan=None,
) -> jax.Array:
    """Sparse transposed conv; weights are expected pre-pruned (zeros).

    ``plan`` is either a `repro.plan.DeconvPlan` (the fast path: tiles,
    fused activation AND the zero-skip schedule all pinned at plan time)
    or — legacy — a bare `make_sparse_plan` tables tuple built with the
    same t_ci/t_co; both avoid re-deriving the static schedule, an
    O(weights) host computation, on every call.  ``t_n`` batch-tiles the
    grid exactly as in the dense kernel (the schedule is batch-
    independent, so one plan serves every bucket)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if plan is not None and hasattr(plan, "geometry"):
        check_layer_plan(plan, x, w, "pallas_sparse", "deconv2d_sparse")
        t = plan.tiles
        if activation is None:
            activation = plan.activation
        tables = plan.sparse_tables
        if tables is None:
            tables = make_sparse_plan(np.asarray(w), plan.geometry.stride,
                                      plan.geometry.padding, t.t_ci, t.t_co)
        stride, padding = plan.geometry.stride, plan.geometry.padding
        t_oh, t_ow, t_ci, t_co, t_n = t.t_oh, t.t_ow, t.t_ci, t.t_co, t.t_n
        plan = tables
    else:
        if stride is None or padding is None:
            raise TypeError(
                "deconv2d_sparse needs stride and padding (or a "
                "repro.plan.DeconvPlan via plan=)")
        if any(v is not None for v in (t_oh, t_ow, t_ci, t_co, t_n)):
            warn_legacy_tiles("deconv2d_sparse")
        t_oh, t_ow, t_ci, t_co, t_n = resolve_tiles(
            x, w, stride, padding, t_oh, t_ow, t_ci, t_co, t_n,
            backend="pallas_sparse", autotune=autotune,
        )
    if plan is None:
        plan = make_sparse_plan(np.asarray(w), stride, padding, t_ci, t_co)
    ci_idx, valid, tap_mask = plan
    n_co = _round_up(w.shape[3], t_co) // t_co
    if ci_idx.shape[0] != n_co:
        raise ValueError(
            f"sparse plan was built for {ci_idx.shape[0]} C_out tiles but the "
            f"resolved t_co={t_co} yields {n_co}; rebuild the plan with the "
            f"same channel tiles (or pass matching t_ci/t_co overrides)")
    return _deconv2d_sparse_jit(
        x, w, b, jnp.asarray(ci_idx), jnp.asarray(valid),
        jnp.asarray(tap_mask), stride, padding,
        t_oh, t_ow, t_ci, t_co, t_n, activation, interpret,
    )
