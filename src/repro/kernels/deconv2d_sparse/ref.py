"""Oracle for the sparse kernel: the dense oracle applied to pruned weights
(zero-skipping must not change results, only skip work)."""
from ..deconv2d.ref import deconv2d_ref as deconv2d_sparse_ref  # noqa: F401
