"""Block-sparse reverse-loop deconvolution with static zero-skipping.

TPU adaptation of the paper's zero-skipping (§V-C): the FPGA skips individual
zero-weight MACs via conditional execution; the MXU executes in lockstep, so
per-element skips have no TPU analogue (documented in DESIGN.md).  Instead we
exploit that *inference weights are static*: after magnitude pruning, the
host computes which ``(C_in-tile, C_out-tile)`` weight slabs are entirely zero
and builds a compressed schedule that

* skips the **HBM→VMEM DMA** of skipped input/weight slabs entirely, via a
  scalar-prefetched indirection on the CI grid dimension (only slabs with any
  nonzero are streamed), and
* skips the **compute** of zero taps inside surviving slabs, via a
  scalar-prefetched per-tap bitmask and `pl.when` predication.

The schedule is fixed per network — the execution time is data-independent,
preserving the run-to-run determinism the paper argues for.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.offsets import PhasePlan
from ...core.tiling import HaloTile, halo_tile
from ..deconv2d.kernel import COMPILER_PARAMS, apply_activation


def build_schedule(block_tap_mask: np.ndarray):
    """Compress the CI-tile dimension per CO tile.

    block_tap_mask: (K, K, n_ci, n_co) bool — slab has any nonzero.
    Returns (ci_idx (n_co, L) int32, valid (n_co, L) int32,
             tap_mask (n_co, L, K*K) int32) where L = max surviving CI tiles.
    Padding entries repeat index 0 with valid=0 (DMA'd but not computed).
    """
    k1, k2, n_ci, n_co = block_tap_mask.shape
    any_tap = block_tap_mask.any(axis=(0, 1))  # (n_ci, n_co)
    lists = [np.nonzero(any_tap[:, co])[0] for co in range(n_co)]
    max_len = max(1, max(len(l) for l in lists))
    ci_idx = np.zeros((n_co, max_len), dtype=np.int32)
    valid = np.zeros((n_co, max_len), dtype=np.int32)
    tap_mask = np.zeros((n_co, max_len, k1 * k2), dtype=np.int32)
    for co, l in enumerate(lists):
        for j, ci in enumerate(l):
            ci_idx[co, j] = ci
            valid[co, j] = 1
            tap_mask[co, j] = block_tap_mask[:, :, ci, co].reshape(-1)
    return ci_idx, valid, tap_mask, max_len


def _sparse_kernel(
    # scalar prefetch (SMEM)
    ci_idx_ref,    # (n_co, L)
    valid_ref,     # (n_co, L)
    tap_ref,       # (n_co, L, K*K)
    # VMEM blocks
    x_ref,         # (T_N, T_IH, T_IW, T_CI)  halo windows
    w_ref,         # (K, K, T_CI, T_CO)
    b_ref,         # (1, T_CO)
    o_ref,         # (T_N, T_OH, T_OW, T_CO)
    acc_ref,       # (T_N, T_OH/S, S, T_OW/S, S, T_CO) f32
    *,
    plan: PhasePlan,
    ht_h: HaloTile,
    ht_w: HaloTile,
    t_oh: int,
    t_ow: int,
    n_sched: int,
    kernel_size: int,
    activation,
    out_dtype,
):
    s = plan.stride
    th, tw = t_oh // s, t_ow // s
    t_n = x_ref.shape[0]
    l_idx = pl.program_id(4)
    co_t = pl.program_id(3)

    @pl.when(l_idx == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(
            b_ref[0].astype(jnp.float32), acc_ref.shape
        )

    t_ci = x_ref.shape[3]
    t_co = w_ref.shape[3]
    is_valid = valid_ref[co_t, l_idx] > 0

    @pl.when(is_valid)
    def _compute():
        for ph in range(s):
            for pw in range(s):
                acc = jnp.zeros((t_n * th * tw, t_co), dtype=jnp.float32)
                for kh, dh in plan.taps[ph]:
                    for kw, dw in plan.taps[pw]:
                        # static-schedule zero-skipping: the tap bit is a
                        # scalar in SMEM, so Mosaic predicates the matmul.
                        tap_live = tap_ref[co_t, l_idx, kh * kernel_size + kw] > 0
                        # static halo-local rows (window follows the grid);
                        # batch folded into the contraction rows, weight
                        # slab stationary across the T_N images.
                        r0 = ht_h.local_offset(dh)
                        c0 = ht_w.local_offset(dw)
                        xs = x_ref[:, r0:r0 + th, c0:c0 + tw, :]
                        contrib = jnp.dot(
                            xs.reshape(t_n * th * tw, t_ci),
                            w_ref[kh, kw],
                            preferred_element_type=jnp.float32,
                        )
                        acc = acc + jnp.where(tap_live, contrib, 0.0)
                acc_ref[:, :, ph, :, pw, :] += acc.reshape(t_n, th, tw, t_co)

    @pl.when(l_idx == n_sched - 1)
    def _flush():
        y = acc_ref[...].reshape(t_n, t_oh, t_ow, t_co)
        o_ref[...] = apply_activation(y, activation).astype(out_dtype)


def deconv2d_sparse_pallas_call(
    x_padded: jax.Array,
    w: jax.Array,
    b: jax.Array,
    ci_idx: jax.Array,     # (n_co, L) int32
    valid: jax.Array,      # (n_co, L) int32
    tap_mask: jax.Array,   # (n_co, L, K*K) int32
    *,
    plan: PhasePlan,
    ohp: int,
    owp: int,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    t_n: int = 1,
    activation=None,
    interpret: bool = False,
) -> jax.Array:
    n, ihp, iwp, cip = x_padded.shape
    k = w.shape[0]
    cop = w.shape[3]
    s = plan.stride
    assert n % t_n == 0, "batch must be padded to a t_n multiple"
    ht_h = halo_tile(t_oh, k, s, plan.padding)
    ht_w = halo_tile(t_ow, k, s, plan.padding)
    n_tiles_h = ohp // t_oh
    n_tiles_w = owp // t_ow
    assert ihp >= ht_h.min_padded_extent(n_tiles_h), "input under-padded (h)"
    assert iwp >= ht_w.min_padded_extent(n_tiles_w), "input under-padded (w)"
    n_sched = ci_idx.shape[1]
    grid = (n // t_n, n_tiles_h, n_tiles_w, cop // t_co, n_sched)

    kernel = functools.partial(
        _sparse_kernel,
        plan=plan,
        ht_h=ht_h,
        ht_w=ht_w,
        t_oh=t_oh,
        t_ow=t_ow,
        n_sched=n_sched,
        kernel_size=k,
        activation=activation,
        out_dtype=x_padded.dtype,
    )
    step_h, base_h = ht_h.step, ht_h.base
    step_w, base_w = ht_w.step, ht_w.base
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (t_n, ht_h.extent, ht_w.extent, t_ci),
                # Eq. 5 halo windows (t_n images) following the output grid,
                # with DMA indirection on channels: only surviving CI slabs
                # stream.
                lambda nb, oh, ow, co, l, ci_idx, valid, taps: (
                    nb * t_n, oh * step_h + base_h, ow * step_w + base_w,
                    ci_idx[co, l] * t_ci,
                ),
                indexing_mode=pl.unblocked,
            ),
            pl.BlockSpec(
                (k, k, t_ci, t_co),
                lambda nb, oh, ow, co, l, ci_idx, valid, taps: (
                    0, 0, ci_idx[co, l], co,
                ),
            ),
            pl.BlockSpec(
                (1, t_co),
                lambda nb, oh, ow, co, l, ci_idx, valid, taps: (0, co),
            ),
        ],
        out_specs=pl.BlockSpec(
            (t_n, t_oh, t_ow, t_co),
            lambda nb, oh, ow, co, l, ci_idx, valid, taps: (nb, oh, ow, co),
        ),
        scratch_shapes=[
            pltpu.VMEM((t_n, t_oh // plan.stride, plan.stride,
                        t_ow // plan.stride, plan.stride, t_co), jnp.float32)
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, ohp, owp, cop), x_padded.dtype),
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "parallel", "arbitrary",
            ),
        ),
        interpret=interpret,
        name="deconv2d_sparse_reverse_loop",
    )(ci_idx, valid, tap_mask, x_padded, w, b)
