from .ops import deconv2d_sparse, make_sparse_plan

__all__ = ["deconv2d_sparse", "make_sparse_plan"]
