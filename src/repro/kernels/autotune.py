"""DSE-driven tile autotuner for the deconv Pallas kernels.

Tile selection runs in three stages, cheapest first:

1. **Cache** — a JSON store keyed by (backend, dtype, layer geometry);
   serving engines and repeated benchmark runs never re-tune.
2. **Roofline model** — enumerate legal candidates (stride-aligned square
   spatial tiles x channel-tile options), drop everything whose
   `kernel_vmem_bytes` exceeds the device's on-chip budget, and rank the
   rest by `dse.tile_attainable` (the paper's §V-A attainable-throughput
   construction, Fig. 5).
3. **On-device timing** (optional, ``refine=True``) — time the few
   top-ranked candidates with the real kernel and keep the fastest.  Only
   available outside a jit trace; inside a trace the model choice stands.

The cache file lives at ``$REPRO_AUTOTUNE_CACHE`` (default
``~/.cache/repro/autotune.json``); ``clear_cache()`` wipes it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import threading
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dse import TPU_V5E, Device, tile_attainable
from ..core.tiling import DeconvGeometry, kernel_vmem_bytes

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
# v2: the batch tile t_n joined the schema — both the key format and the
# stored entry gained a field, so v1 entries (4-tuple tiles, no batch in
# the key) must never be served.  The version is embedded in every key and
# `_valid_entry` drops anything that does not carry the full 5-tuple.
# v3: the ranking model became dtype-aware (the requested dtype's byte
# width drives the traffic/VMEM models and selects the int8 MXU peak), so
# a v2 entry — ranked with the device's native width regardless of the
# request — is stale even though its key already named the dtype.
# `_load_cache` drops every key from a different schema version.
# v4: keys are no longer hand-assembled tuples — the planning inputs are
# canonicalized by `plan.DeconvPlan.stable_hash(scope="tiles")`, so a new
# field (dtype, t_n-relevant batch, out_dtype_bytes, backend, ...) can
# never be forgotten from the key and silently alias two requests again.
# v3 keys, which did hand-assemble, are dropped on load like every other
# stale schema.
_CACHE_VERSION = 4
_lock = threading.Lock()
_cache: Optional[Dict[str, dict]] = None

_TILE_FIELDS = ("t_oh", "t_ow", "t_ci", "t_co", "t_n")


@dataclasses.dataclass(frozen=True)
class TileChoice:
    """One resolved tile assignment for the deconv kernel grid."""

    t_oh: int
    t_ow: int
    t_ci: int
    t_co: int
    t_n: int = 1              # batch tile (images per grid program)
    # provenance, not semantics: two choices with the same factors are the
    # same executable wherever they came from (plan equality relies on it)
    source: str = dataclasses.field(default="model", compare=False)
    attainable_ops: float = 0.0
    vmem_bytes: int = 0

    def as_kwargs(self) -> Dict[str, int]:
        return {"t_oh": self.t_oh, "t_ow": self.t_ow,
                "t_ci": self.t_ci, "t_co": self.t_co, "t_n": self.t_n}


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# persistent cache
# ---------------------------------------------------------------------------
def cache_path() -> pathlib.Path:
    default = pathlib.Path.home() / ".cache" / "repro" / "autotune.json"
    return pathlib.Path(os.environ.get(_CACHE_ENV, str(default)))


def cache_key(geom: DeconvGeometry, dtype, backend: str,
              device: Device = TPU_V5E, batch: int = 1,
              out_dtype_bytes: Optional[int] = None) -> str:
    """v4 cache key: a `DeconvPlan` content hash over the tile-planning
    inputs (geometry, dtype, batch, backend, epilogue output width).

    The platform and the modeled device stay in the readable prefix:
    refine=True timings taken in CPU interpret mode must never be served
    as authoritative on TPU, and a choice fitted to one device's VMEM
    budget/roofline must not leak to another's.  Everything else is
    hashed through one canonical dict — the schema-v3 failure mode
    (a new ranking input hand-appended to the key string, or forgotten
    from it) cannot alias entries anymore."""
    from ..plan import DeconvPlan

    plan = DeconvPlan(geometry=geom, batch=batch,
                      dtype=np.dtype(dtype).name, backend=backend,
                      out_dtype_bytes=out_dtype_bytes)
    return plan_cache_key(plan, device)


def plan_cache_key(plan, device: Device = TPU_V5E) -> str:
    """Cache key for a (possibly unresolved) `plan.DeconvPlan`: a resolved
    plan and the bare planning request hash identically, so the tiles a
    plan was built with are exactly the tiles its key serves back."""
    plat = jax.default_backend()
    return (f"v{_CACHE_VERSION}|{plat}|{device.name}|"
            f"{plan.stable_hash(scope='tiles')}")


def _valid_entry(v) -> bool:
    """A cache entry must carry the full current tile schema.  Entries from
    an older schema (e.g. v1's 4-tuple, before t_n existed) or corrupted
    by hand-editing are dropped instead of being served as stale tiles."""
    return (isinstance(v, dict)
            and all(isinstance(v.get(f), int) and v[f] > 0
                    for f in _TILE_FIELDS))


def _load_cache() -> Dict[str, dict]:
    global _cache
    if _cache is None:
        path = cache_path()
        try:
            raw = json.loads(path.read_text())
        except (OSError, ValueError):
            raw = {}
        if not isinstance(raw, dict):  # corrupt top-level: recover empty
            raw = {}
        prefix = f"v{_CACHE_VERSION}|"
        _cache = {k: v for k, v in raw.items()
                  if k.startswith(prefix) and _valid_entry(v)}
    return _cache


def _store(key: str, choice: TileChoice) -> None:
    with _lock:
        cache = _load_cache()
        cache[key] = dataclasses.asdict(choice)
        path = cache_path()
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            tmp = path.with_suffix(".tmp")
            tmp.write_text(json.dumps(cache, indent=1, sort_keys=True))
            tmp.replace(path)
        except OSError:
            pass  # cache is an optimization; never fail the call


def clear_cache() -> None:
    """Drop the in-memory cache and delete the cache file."""
    global _cache
    with _lock:
        _cache = {}
        try:
            cache_path().unlink()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# candidate enumeration + model ranking
# ---------------------------------------------------------------------------
def _channel_tile_options(c: int) -> List[int]:
    """Channel-tile candidates: lane-width multiples clamped to the padded
    channel count (the kernel pads channels up to the tile)."""
    cp = _round_up(c, 8)
    return sorted({min(cp, v) for v in (32, 64, 128)})


def _batch_tile_options(batch: int, cap: int = 64) -> List[int]:
    """Batch-tile candidates: powers of two up to (never beyond) the
    batch, plus the batch itself so non-power-of-two batches can run as a
    single grid step.  t_n > batch is never enumerated — it would be
    scored with an MXU-row fill the real (clamped) kernel can't reach."""
    hi = min(batch, cap)
    opts = {1, hi}
    t = 1
    while t * 2 <= hi:
        t *= 2
        opts.add(t)
    return sorted(opts)


def legal_tile_candidates(
    geom: DeconvGeometry,
    dtype_bytes: int = 4,
    vmem_budget: int = TPU_V5E.onchip_bytes,
    max_spatial: int = 64,
    batch: int = 1,
    out_dtype_bytes: Optional[int] = None,
) -> List[Tuple[int, int, int, int, int]]:
    """All (t_oh, t_ow, t_ci, t_co, t_n) with stride-aligned square spatial
    tiles that fit the on-chip budget (paper Fig. 5 'legal solutions'),
    jointly enumerated with the batch tile.  ``out_dtype_bytes`` prices a
    wider output block than the streamed dtype (the last int8 layer's f32
    epilogue) so near-budget candidates don't pass the filter at a
    quarter of their real output footprint."""
    s = geom.stride
    oh_cap = _round_up(min(geom.out_h, max_spatial), s)
    spatial = list(range(s, oh_cap + 1, s))
    # the full-output tile (single spatial program) is always a candidate,
    # even beyond max_spatial — the VMEM filter below still applies
    spatial.append(_round_up(geom.out_h, s))
    out: List[Tuple[int, int, int, int, int]] = []
    for t in sorted(set(spatial)):
        for t_ci in _channel_tile_options(geom.c_in):
            for t_co in _channel_tile_options(geom.c_out):
                for t_n in _batch_tile_options(batch):
                    fp = kernel_vmem_bytes(geom, t, t, t_ci, t_co,
                                           dtype_bytes, t_n=t_n,
                                           out_dtype_bytes=out_dtype_bytes)
                    if fp <= vmem_budget:
                        out.append((t, t, t_ci, t_co, t_n))
    return out


def rank_candidates(
    geom: DeconvGeometry,
    candidates: List[Tuple[int, int, int, int, int]],
    device: Device = TPU_V5E,
    batch: int = 1,
    dtype_bytes: Optional[int] = None,
    out_dtype_bytes: Optional[int] = None,
) -> List[TileChoice]:
    """Sort by modeled attainable throughput (desc), tie-breaking toward
    higher CTC then larger tiles (fewer grid programs).  ``dtype_bytes``
    makes the ranking precision-aware: int8 candidates are scored with
    quarter-width traffic and the device's doubled int8 MXU peak
    (``out_dtype_bytes`` widening the output block where the epilogue
    emits f32)."""
    scored = []
    for (t_oh, t_ow, t_ci, t_co, t_n) in candidates:
        pt = tile_attainable(geom, t_oh, t_ow, t_ci, t_co, device,
                             t_n=t_n, batch=batch, dtype_bytes=dtype_bytes,
                             out_dtype_bytes=out_dtype_bytes)
        scored.append(TileChoice(
            t_oh=t_oh, t_ow=t_ow, t_ci=t_ci, t_co=t_co, t_n=t_n,
            source="model",
            attainable_ops=pt.attainable_ops,
            vmem_bytes=pt.vmem_bytes,
        ))
    return sorted(
        scored,
        key=lambda c: (-c.attainable_ops, -c.t_n * c.t_oh * c.t_ow,
                       -c.t_ci * c.t_co),
    )


def fallback_tiles(
    geom: DeconvGeometry,
    dtype_bytes: int = 4,
    vmem_budget: int = TPU_V5E.onchip_bytes,
    batch: int = 1,
    out_dtype_bytes: Optional[int] = None,
) -> TileChoice:
    """The old fixed heuristic (~32x32 spatial, 128-channel tiles), now
    clamped through `kernel_vmem_bytes` so large CI x CO layers can no
    longer blow the VMEM budget: shrink channels first (halving), then the
    spatial tile, until the footprint fits.  The batch tile grows (powers
    of two, within the batch and the budget) until the tap matmuls reach
    ~128 contraction rows — a full MXU column load."""
    s = geom.stride
    t_oh = min(_round_up(geom.out_h, s), _round_up(32, s))
    t_ow = min(_round_up(geom.out_w, s), _round_up(32, s))
    t_ci = min(_round_up(geom.c_in, 8), 128)
    t_co = min(_round_up(geom.c_out, 8), 128)
    t_n = 1

    def fits(tn=None) -> bool:
        return kernel_vmem_bytes(
            geom, t_oh, t_ow, t_ci, t_co, dtype_bytes,
            t_n=(t_n if tn is None else tn),
            out_dtype_bytes=out_dtype_bytes) <= vmem_budget

    while not fits():
        if t_ci > 8:
            t_ci = max(8, t_ci // 2)
        elif t_co > 8:
            t_co = max(8, t_co // 2)
        elif t_oh > s or t_ow > s:
            t_oh = max(s, _round_up(t_oh // 2, s))
            t_ow = max(s, _round_up(t_ow // 2, s))
        else:
            break  # smallest legal tile; nothing left to shrink
    rows_per_img = (t_oh // s) * (t_ow // s)
    while (t_n * 2 <= batch and t_n * rows_per_img < 128
           and fits(tn=t_n * 2)):
        t_n *= 2
    return TileChoice(
        t_oh=t_oh, t_ow=t_ow, t_ci=t_ci, t_co=t_co, t_n=t_n,
        source="fallback",
        vmem_bytes=kernel_vmem_bytes(geom, t_oh, t_ow, t_ci, t_co,
                                     dtype_bytes, t_n=t_n,
                                     out_dtype_bytes=out_dtype_bytes),
    )


def network_tiles(
    cfg,
    dtype=None,
    backend: str = "pallas",
    batch: int = 1,
    refine: bool = False,
    autotune: bool = True,
    device: Device = TPU_V5E,
) -> Optional[Dict[int, TileChoice]]:
    """Per-layer tile choices for a whole generator network.

    ``cfg`` is any config exposing ``geometries()`` (and ``jdtype`` when
    ``dtype`` is omitted) — in practice a ``models.dcnn.DcnnConfig``.
    ``batch`` is the batch size each layer's kernel will actually see: a
    serving bucket on one device, or the *per-device sub-batch* when the
    caller shards the bucket across a mesh (the DSE then picks ``t_n``
    against the shard, not the global batch).  Returns None for backends
    without tile factors.  For integer dtypes the *last* layer is tuned
    with a 4-byte output block: the int8 chain's final epilogue emits f32
    images while every intermediate layer re-quantizes to int8."""
    if backend not in ("pallas", "pallas_sparse"):
        return None
    if dtype is None:
        dtype = cfg.jdtype
    geoms = list(cfg.geometries())
    int8_chain = np.dtype(dtype).kind in ("i", "u")

    def out_bytes(i: int) -> Optional[int]:
        return 4 if int8_chain and i == len(geoms) - 1 else None

    if autotune:
        return {i: choose_tiles(g, dtype, backend=backend, refine=refine,
                                device=device, batch=batch,
                                out_dtype_bytes=out_bytes(i))
                for i, g in enumerate(geoms)}
    itemsize = np.dtype(dtype).itemsize
    return {i: fallback_tiles(g, itemsize, device.onchip_bytes, batch=batch,
                              out_dtype_bytes=out_bytes(i))
            for i, g in enumerate(geoms)}


# ---------------------------------------------------------------------------
# on-device timing refinement
# ---------------------------------------------------------------------------
def _time_candidate(
    geom: DeconvGeometry,
    choice: TileChoice,
    dtype,
    backend: str,
    reps: int = 3,
    batch: int = 1,
) -> float:
    """Median wall-clock of the real kernel at this tile choice (seconds).

    Proxy caveats: inputs/weights are dense random samples, so for
    backend="pallas_sparse" the measured schedule keeps every CI slab —
    the ranking reflects the dense workload, not a pruned network's; and
    on non-TPU hosts the kernel runs in interpret mode, where relative
    timings only loosely track TPU behavior."""
    from .deconv2d import deconv2d

    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (batch, geom.in_h, geom.in_w, geom.c_in),
                          dtype)
    w = (jax.random.normal(
        kw, (geom.kernel, geom.kernel, geom.c_in, geom.c_out), dtype) * 0.1
    ).astype(dtype)
    if backend == "pallas_sparse":
        from .deconv2d_sparse import deconv2d_sparse as fn
    else:
        fn = deconv2d
    from .deconv2d.ops import suppress_tile_warnings

    from ..obs import clock as obsclock
    from ..obs import metrics as obsmetrics

    # refine timings are observability, not just a ranking input: the
    # process registry keeps them as a histogram so a tuning run's
    # run-to-run spread is inspectable next to the serve-path Table II
    hist = obsmetrics.default_registry().histogram(
        "autotune.refine_seconds",
        "per-rep candidate wall clock during refine=True tuning")
    kwargs = choice.as_kwargs()
    with suppress_tile_warnings():  # internal harness, not a user call
        jax.block_until_ready(
            fn(x, w, None, geom.stride, geom.padding, **kwargs))  # compile
        ts = []
        for _ in range(reps):
            t0 = obsclock.now()
            jax.block_until_ready(
                fn(x, w, None, geom.stride, geom.padding, **kwargs))
            ts.append(obsclock.now() - t0)
            hist.observe(ts[-1], backend=backend, batch=batch,
                         dtype=np.dtype(dtype).name)
    return float(np.median(ts))


def choose_tiles(
    geom: DeconvGeometry,
    dtype=jnp.float32,
    backend: str = "pallas",
    refine: bool = False,
    refine_top_k: int = 3,
    device: Device = TPU_V5E,
    use_cache: bool = True,
    batch: int = 1,
    out_dtype_bytes: Optional[int] = None,
) -> TileChoice:
    """Resolve the tile assignment for one deconv layer.

    ``batch`` is the (bucketed) serving batch the choice is fitted to: the
    DSE enumerates the batch tile t_n jointly with the spatial/channel
    tiles, trading MXU row fill + weight amortization against VMEM.
    ``refine=True`` times the top-`refine_top_k` model-ranked candidates on
    the current backend and keeps the fastest (then persists it, so the
    timing cost is paid once per (geometry, dtype, backend, batch)).
    ``out_dtype_bytes`` widens the modeled output block when the kernel's
    epilogue emits a wider dtype than it streams (the last int8 layer
    writes f32 images)."""
    dtype_bytes = np.dtype(dtype).itemsize
    if refine and np.dtype(dtype).kind != "f":
        # the timing harness drives the float kernels with random normal
        # inputs; integer (int8) requests keep the model ranking — the
        # dtype-aware roofline is what differentiates them anyway
        refine = False
    key = cache_key(geom, dtype, backend, device, batch, out_dtype_bytes)
    if use_cache:
        hit = _load_cache().get(key)
        # a refine=True request is only satisfied by a *timed* entry; a
        # stored model/fallback choice must not suppress the requested
        # on-device refinement (the re-tune overwrites it below)
        if hit is not None and (not refine or hit.get("source") == "timed"):
            return dataclasses.replace(
                TileChoice(**{k: v for k, v in hit.items()
                              if k in TileChoice.__dataclass_fields__}),
                source="cache")

    cands = legal_tile_candidates(geom, dtype_bytes, device.onchip_bytes,
                                  batch=batch,
                                  out_dtype_bytes=out_dtype_bytes)
    if not cands:
        choice = fallback_tiles(geom, dtype_bytes, device.onchip_bytes,
                                batch=batch,
                                out_dtype_bytes=out_dtype_bytes)
    else:
        ranked = rank_candidates(geom, cands, device, batch=batch,
                                 dtype_bytes=dtype_bytes,
                                 out_dtype_bytes=out_dtype_bytes)
        choice = ranked[0]
        if refine:
            timed = []
            for c in ranked[:refine_top_k]:
                try:
                    timed.append((_time_candidate(geom, c, dtype, backend,
                                                  batch=max(batch, c.t_n)),
                                  c))
                except Exception:  # a candidate may fail to lower; skip it
                    continue
            if timed:
                choice = dataclasses.replace(
                    min(timed, key=lambda tc: tc[0])[1], source="timed")
    if use_cache:
        _store(key, choice)
    return choice
