from .int8 import deconv2d_int8
from .ops import deconv2d
from .ref import deconv2d_int8_ref, deconv2d_ref

__all__ = ["deconv2d", "deconv2d_int8", "deconv2d_int8_ref", "deconv2d_ref"]
