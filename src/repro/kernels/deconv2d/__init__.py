from .ops import deconv2d
from .ref import deconv2d_ref

__all__ = ["deconv2d", "deconv2d_ref"]
