"""Output-tiled, phase-decomposed transposed-convolution Pallas TPU kernel.

This is the paper's FPGA accelerator re-derived for the TPU memory hierarchy:

* **Grid = disjoint output tiles** (reverse loop over the *output* space):
  each grid program owns one ``(T_N, T_OH, T_OW, T_CO)`` output block —
  one-shot writes, no overlapping-sum, exactly the paper's CU array.  The
  leading ``T_N`` is the *batch tile*: the batch is folded into the MXU row
  dimension so each tap matmul contracts over ``T_N * T_OH/S * T_OW/S``
  rows with the weight slab stationary — on the fat-channel early layers
  (16–49 spatial rows vs a 128x128 MXU) this is what fills the systolic
  array, and it amortizes the weight-slab HBM stream over T_N images.
* **Eq. 5 input streaming**: the x BlockSpec is a per-output-tile *halo
  window* of constant extent ``T_IH x T_IW`` (core.tiling.halo_tile) whose
  unblocked index map follows the output grid — each program streams only
  the input rows its tile touches (overlapping halo reads), never the whole
  image.  HBM traffic per tile is O(T_IH*T_IW), independent of image size.
* **Eq. 3 offsets → trace-time phase plan**: the stride-hole-skipping offsets
  are folded into a static (phase → taps, input displacement) table computed
  on the host; inside the halo window every tap slice is *static* (local row
  ``delta - delta_min``) — the kernel body contains zero modulo/division ops
  and zero grid-dependent address arithmetic.
* **Enhancement (2) — loop interchange**: the K×K tap loops are the outermost
  static loops; each (tap, phase) contribution is a channel-contraction
  matmul on the MXU with the weight slab held stationary.
* **Fused epilogue**: bias is the accumulator's initial value (Algorithm 1's
  initializeToBias) and the activation (relu/tanh) runs in the ``_flush``
  phase on the f32 accumulator — the generator never materializes a
  pre-activation layer in HBM.

The accumulator scratch is laid out ``(T_OH/S, S, T_OW/S, S, T_CO)`` so the
final phase reassembly is a pure reshape (no transpose).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.offsets import PhasePlan
from ...core.tiling import HaloTile, halo_tile

# renamed TPUCompilerParams -> CompilerParams across jax versions
COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

ACTIVATIONS = (None, "none", "relu", "tanh")


def apply_activation(y: jax.Array, activation: Optional[str]) -> jax.Array:
    """Epilogue nonlinearity on the f32 accumulator (shared with refs)."""
    if activation not in ACTIVATIONS:
        raise ValueError(f"unsupported fused activation {activation!r}; "
                         f"expected one of {ACTIVATIONS}")
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "tanh":
        return jnp.tanh(y)
    return y


def x_halo_blockspec(
    ht_h: HaloTile, ht_w: HaloTile, t_ci: int, t_n: int = 1
) -> pl.BlockSpec:
    """Per-output-tile input window BlockSpec (the Eq. 5 streaming read).

    Unblocked indexing: the index map returns *element* offsets, which is
    what lets consecutive output tiles read overlapping halo windows —
    impossible with block-granular indexing.  The leading dimension is the
    batch tile: one program streams the windows of ``t_n`` images (batch
    folded into the MXU row dimension).  Exposed as a function so the
    tests can assert the block shape / index map directly.
    """
    step_h, base_h = ht_h.step, ht_h.base
    step_w, base_w = ht_w.step, ht_w.base

    def index_map(nb, oh, ow, co, ci):
        return (nb * t_n, oh * step_h + base_h, ow * step_w + base_w,
                ci * t_ci)

    return pl.BlockSpec(
        (t_n, ht_h.extent, ht_w.extent, t_ci),
        index_map,
        indexing_mode=pl.unblocked,
    )


def _deconv2d_kernel(
    x_ref,      # (T_N, T_IH, T_IW, T_CI)  VMEM halo windows
    w_ref,      # (K, K, T_CI, T_CO)       VMEM (batch-stationary)
    b_ref,      # (1, T_CO)                VMEM
    o_ref,      # (T_N, T_OH, T_OW, T_CO)  VMEM
    acc_ref,    # (T_N, T_OH/S, S, T_OW/S, S, T_CO) f32 scratch
    *,
    plan: PhasePlan,
    ht_h: HaloTile,
    ht_w: HaloTile,
    t_oh: int,
    t_ow: int,
    n_ci_tiles: int,
    activation: Optional[str],
    out_dtype,
):
    s = plan.stride
    th, tw = t_oh // s, t_ow // s
    t_n = x_ref.shape[0]
    ci_idx = pl.program_id(4)

    @pl.when(ci_idx == 0)
    def _init():
        # initializeToBias() — broadcast bias into every phase slot.
        acc_ref[...] = jnp.broadcast_to(
            b_ref[0].astype(jnp.float32), acc_ref.shape
        )

    t_ci = x_ref.shape[3]
    t_co = w_ref.shape[3]
    # Loop interchange (enhancement 2): taps outermost, weight slab stationary
    # across both the phase loops AND the T_N batch images — each tap matmul
    # contracts over T_N*th*tw rows (the batch-fused MXU fill).
    for ph in range(s):
        for pw in range(s):
            acc = jnp.zeros((t_n * th * tw, t_co), dtype=jnp.float32)
            for kh, dh in plan.taps[ph]:
                for kw, dw in plan.taps[pw]:
                    # static halo-local rows: the window already starts at
                    # this tile's minimum displacement.
                    r0 = ht_h.local_offset(dh)
                    c0 = ht_w.local_offset(dw)
                    xs = x_ref[:, r0:r0 + th, c0:c0 + tw, :]
                    acc = acc + jnp.dot(
                        xs.reshape(t_n * th * tw, t_ci),
                        w_ref[kh, kw],
                        preferred_element_type=jnp.float32,
                    )
            acc_ref[:, :, ph, :, pw, :] += acc.reshape(t_n, th, tw, t_co)

    @pl.when(ci_idx == n_ci_tiles - 1)
    def _flush():
        # One-shot disjoint write: reassemble phases, fused epilogue, cast.
        y = acc_ref[...].reshape(t_n, t_oh, t_ow, t_co)
        o_ref[...] = apply_activation(y, activation).astype(out_dtype)


def deconv2d_pallas_call(
    x_padded: jax.Array,     # (N, IHp, IWp, CIp)  host-padded
    w: jax.Array,            # (K, K, CIp, COp)
    b: jax.Array,            # (1, COp)
    *,
    plan: PhasePlan,
    ohp: int,
    owp: int,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    t_n: int = 1,
    activation: Optional[str] = None,
    interpret: bool = False,
) -> jax.Array:
    n, ihp, iwp, cip = x_padded.shape
    k = w.shape[0]
    cop = w.shape[3]
    s = plan.stride
    assert t_oh % s == 0 and t_ow % s == 0, "tiles must be stride-aligned"
    assert cip % t_ci == 0 and cop % t_co == 0
    assert n % t_n == 0, "batch must be padded to a t_n multiple"
    ht_h = halo_tile(t_oh, k, s, plan.padding)
    ht_w = halo_tile(t_ow, k, s, plan.padding)
    n_tiles_h = ohp // t_oh
    n_tiles_w = owp // t_ow
    assert ihp >= ht_h.min_padded_extent(n_tiles_h), "input under-padded (h)"
    assert iwp >= ht_w.min_padded_extent(n_tiles_w), "input under-padded (w)"
    n_ci = cip // t_ci
    grid = (n // t_n, n_tiles_h, n_tiles_w, cop // t_co, n_ci)

    kernel = functools.partial(
        _deconv2d_kernel,
        plan=plan,
        ht_h=ht_h,
        ht_w=ht_w,
        t_oh=t_oh,
        t_ow=t_ow,
        n_ci_tiles=n_ci,
        activation=activation,
        out_dtype=x_padded.dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            x_halo_blockspec(ht_h, ht_w, t_ci, t_n),
            pl.BlockSpec(
                (k, k, t_ci, t_co),
                lambda nb, oh, ow, co, ci: (0, 0, ci, co),
            ),
            pl.BlockSpec((1, t_co), lambda nb, oh, ow, co, ci: (0, co)),
        ],
        out_specs=pl.BlockSpec(
            (t_n, t_oh, t_ow, t_co),
            lambda nb, oh, ow, co, ci: (nb, oh, ow, co),
        ),
        out_shape=jax.ShapeDtypeStruct((n, ohp, owp, cop), x_padded.dtype),
        scratch_shapes=[
            pltpu.VMEM((t_n, t_oh // s, s, t_ow // s, s, t_co), jnp.float32)
        ],
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "parallel", "arbitrary",
            ),
        ),
        interpret=interpret,
        name="deconv2d_halo_reverse_loop",
    )(x_padded, w, b)
