"""Output-tiled, phase-decomposed transposed-convolution Pallas TPU kernel.

This is the paper's FPGA accelerator re-derived for the TPU memory hierarchy:

* **Grid = disjoint output tiles** (reverse loop over the *output* space):
  each grid program owns one ``(T_OH, T_OW, T_CO)`` output block — one-shot
  writes, no overlapping-sum, exactly the paper's CU array.
* **Eq. 3 offsets → trace-time phase plan**: the stride-hole-skipping offsets
  are folded into a static (phase → taps, input displacement) table computed
  on the host; the kernel body contains *zero* modulo/division ops.
* **Enhancement (3) — decoupled memory access**: the HBM→VMEM streaming of
  the next input/weight blocks overlaps compute via the Mosaic pipeline
  (BlockSpec double buffering); the non-sequential (strided, per-phase)
  access pattern happens only on VMEM-resident tiles.
* **Enhancement (2) — loop interchange**: the K×K tap loops are the outermost
  static loops; each (tap, phase) contribution is a channel-contraction
  matmul on the MXU with the weight slab held stationary.

Geometry notes: the input is host-padded (`halo` rows/cols) so that every tap
access of every stride-aligned tile is in bounds — all address arithmetic is
resolved before the kernel runs, as in the paper.  The accumulator scratch is
laid out ``(T_OH/S, S, T_OW/S, S, T_CO)`` so the final phase reassembly is a
pure reshape (no transpose).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.offsets import PhasePlan


def _deconv2d_kernel(
    x_ref,      # (1, IHp, IWp, T_CI)   VMEM
    w_ref,      # (K, K, T_CI, T_CO)    VMEM
    b_ref,      # (1, T_CO)             VMEM
    o_ref,      # (1, T_OH, T_OW, T_CO) VMEM
    acc_ref,    # (T_OH/S, S, T_OW/S, S, T_CO) f32 scratch
    *,
    plan: PhasePlan,
    t_oh: int,
    t_ow: int,
    pad_l: int,
    n_ci_tiles: int,
    out_dtype,
):
    s = plan.stride
    th, tw = t_oh // s, t_ow // s
    ci_idx = pl.program_id(4)
    oh_t = pl.program_id(1)
    ow_t = pl.program_id(2)

    @pl.when(ci_idx == 0)
    def _init():
        # initializeToBias() — broadcast bias into every phase slot.
        acc_ref[...] = jnp.broadcast_to(
            b_ref[0].astype(jnp.float32), acc_ref.shape
        )

    t_ci = x_ref.shape[3]
    t_co = w_ref.shape[3]
    # Loop interchange (enhancement 2): taps outermost, weight slab stationary.
    for ph in range(s):
        for pw in range(s):
            acc = jnp.zeros((th * tw, t_co), dtype=jnp.float32)
            for kh, dh in plan.taps[ph]:
                for kw, dw in plan.taps[pw]:
                    r0 = oh_t * th + dh + pad_l
                    c0 = ow_t * tw + dw + pad_l
                    xs = x_ref[0, pl.ds(r0, th), pl.ds(c0, tw), :]
                    acc = acc + jnp.dot(
                        xs.reshape(th * tw, t_ci),
                        w_ref[kh, kw],
                        preferred_element_type=jnp.float32,
                    )
            acc_ref[:, ph, :, pw, :] += acc.reshape(th, tw, t_co)

    @pl.when(ci_idx == n_ci_tiles - 1)
    def _flush():
        # One-shot disjoint write of the finished output block.
        o_ref[0] = acc_ref[...].reshape(t_oh, t_ow, t_co).astype(out_dtype)


def deconv2d_pallas_call(
    x_padded: jax.Array,     # (N, IHp, IWp, CIp)  host-padded
    w: jax.Array,            # (K, K, CIp, COp)
    b: jax.Array,            # (1, COp)
    *,
    plan: PhasePlan,
    ohp: int,
    owp: int,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    pad_l: int,
    interpret: bool = False,
) -> jax.Array:
    n, ihp, iwp, cip = x_padded.shape
    k = w.shape[0]
    cop = w.shape[3]
    s = plan.stride
    assert t_oh % s == 0 and t_ow % s == 0, "tiles must be stride-aligned"
    assert cip % t_ci == 0 and cop % t_co == 0
    n_ci = cip // t_ci
    grid = (n, ohp // t_oh, owp // t_ow, cop // t_co, n_ci)

    kernel = functools.partial(
        _deconv2d_kernel,
        plan=plan,
        t_oh=t_oh,
        t_ow=t_ow,
        pad_l=pad_l,
        n_ci_tiles=n_ci,
        out_dtype=x_padded.dtype,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, ihp, iwp, t_ci),
                lambda nb, oh, ow, co, ci: (nb, 0, 0, ci),
            ),
            pl.BlockSpec(
                (k, k, t_ci, t_co),
                lambda nb, oh, ow, co, ci: (0, 0, ci, co),
            ),
            pl.BlockSpec((1, t_co), lambda nb, oh, ow, co, ci: (0, co)),
        ],
        out_specs=pl.BlockSpec(
            (1, t_oh, t_ow, t_co),
            lambda nb, oh, ow, co, ci: (nb, oh, ow, co),
        ),
        out_shape=jax.ShapeDtypeStruct((n, ohp, owp, cop), x_padded.dtype),
        scratch_shapes=[
            pltpu.VMEM((t_oh // s, s, t_ow // s, s, t_co), jnp.float32)
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "parallel", "arbitrary",
            ),
        ),
        interpret=interpret,
        name="deconv2d_reverse_loop",
    )(x_padded, w, b)
