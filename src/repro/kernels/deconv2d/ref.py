"""Pure-jnp oracles for the deconv2d Pallas kernels.

The f32 oracle is the conventional zero-insertion transposed convolution
lowered through XLA's conv (`core.deconv.deconv2d_zero_insertion`) — an
implementation entirely independent of the reverse-loop/phase machinery
under test.  The int8 oracle runs the same zero-insertion formulation as
an *integer-exact* int32 convolution, then applies the identical requant
epilogue, so kernel-vs-reference parity has no float-reassociation slack
in the reduction."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...core.deconv import deconv2d_zero_insertion


def deconv2d_ref(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
) -> jax.Array:
    """x: (N, IH, IW, CI); w: (K, K, CI, CO); y: (N, OH, OW, CO)."""
    return deconv2d_zero_insertion(x, w, b, stride, padding)


def deconv2d_int8_ref(
    x_q: jax.Array,          # (N, IH, IW, CI) int8
    w_q: jax.Array,          # (K, K, CI, CO)  int8
    scale: jax.Array,        # (CO,) f32 combined s_x * s_w
    b: Optional[jax.Array],  # (CO,) f32
    stride: int,
    padding: int,
    activation: Optional[str] = None,
    out_scale: Optional[float] = None,
) -> jax.Array:
    """int32-exact fake-quant oracle for `deconv2d_int8`.

    The integer accumulator is exact (no rounding before requant), so the
    Pallas kernel — which also accumulates in int32 — must match the
    epilogue output to float ulp, not just approximately."""
    from .int8 import requant_epilogue

    k = w_q.shape[0]
    wf = jnp.flip(w_q, axis=(0, 1))
    pad = k - 1 - padding
    acc = jax.lax.conv_general_dilated(
        x_q,
        wf,
        window_strides=(1, 1),
        padding=((pad, pad), (pad, pad)),
        lhs_dilation=(stride, stride),
        rhs_dilation=(1, 1),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )
    bias = (b.astype(jnp.float32) if b is not None
            else jnp.zeros((w_q.shape[3],), jnp.float32))
    return requant_epilogue(acc, scale.astype(jnp.float32), bias,
                            activation, out_scale)
