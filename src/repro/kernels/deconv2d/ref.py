"""Pure-jnp oracle for the deconv2d Pallas kernel.

The oracle is the conventional zero-insertion transposed convolution lowered
through XLA's conv (`core.deconv.deconv2d_zero_insertion`) — an implementation
entirely independent of the reverse-loop/phase machinery under test."""
from __future__ import annotations

from typing import Optional

import jax

from ...core.deconv import deconv2d_zero_insertion


def deconv2d_ref(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
) -> jax.Array:
    """x: (N, IH, IW, CI); w: (K, K, CI, CO); y: (N, OH, OW, CO)."""
    return deconv2d_zero_insertion(x, w, b, stride, padding)
