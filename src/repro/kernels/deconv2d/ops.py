"""Public wrapper for the deconv2d Pallas kernel.

`deconv2d` is a thin host-side wrapper: it resolves geometry and the tile
assignment (explicit overrides > autotuner > clamped fallback heuristic)
and dispatches into the jit'd `_deconv2d_jit`, which performs the halo /
channel padding and invokes the kernel.  Tile resolution is pure host
arithmetic over static shapes, so the wrapper also works while being
traced inside an outer jit (timing refinement is skipped there — pass
pre-resolved tiles, e.g. from serve.engine, for timed choices).

On non-TPU backends the kernel runs in interpret mode."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.offsets import make_phase_plan
from ...core.tiling import DeconvGeometry, out_size
from .kernel import deconv2d_pallas_call


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def halo_pad_geometry(n: int, ih: int, iw: int, ci: int, co: int,
                      plan, t_oh: int, t_ow: int, t_ci: int, t_co: int,
                      t_n: int):
    """Host-side padded geometry shared by the f32 and int8 jit wrappers.

    Returns ``(oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop, t_n,
    np_)``: the true output extents, the tile-multiple output grid, the
    halo padding that keeps every per-tile window in bounds (enhancement
    3: all address arithmetic resolved ahead of the kernel), the channel
    tiles' padded extents, the batch tile clamped to the batch, and the
    t_n-multiple padded batch.  One implementation, two kernels — the
    padded geometry (and the final un-padding slice) can never drift
    between the precisions."""
    oh = out_size(ih, plan.kernel_size, plan.stride, plan.padding)
    ow = out_size(iw, plan.kernel_size, plan.stride, plan.padding)
    ohp = _round_up(oh, t_oh)
    owp = _round_up(ow, t_ow)
    n_h_pad = ohp // plan.stride
    n_w_pad = owp // plan.stride
    pad_l = plan.left_halo
    pad_rh = max(0, (n_h_pad - 1 + plan.delta_max) - (ih - 1))
    pad_rw = max(0, (n_w_pad - 1 + plan.delta_max) - (iw - 1))
    cip = _round_up(ci, t_ci)
    cop = _round_up(co, t_co)
    t_n = min(t_n, n) if n > 0 else 1
    np_ = _round_up(n, t_n)
    return oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop, t_n, np_


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "padding", "t_oh", "t_ow", "t_ci", "t_co", "t_n",
        "activation", "interpret",
    ),
)
def _deconv2d_jit(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    t_n: int,
    activation: Optional[str],
    interpret: bool,
) -> jax.Array:
    n, ih, iw, ci = x.shape
    k, _, _, co = w.shape
    plan = make_phase_plan(k, stride, padding)

    # padded output grid + halo padding (enhancement 3: all address
    # arithmetic resolved up front; the per-tile windows the kernel
    # streams stay in bounds by construction)
    (oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop, t_n,
     np_) = halo_pad_geometry(n, ih, iw, ci, co, plan, t_oh, t_ow, t_ci,
                              t_co, t_n)
    xp = jnp.pad(
        x, ((0, np_ - n), (pad_l, pad_rh), (pad_l, pad_rw), (0, cip - ci))
    )
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cip - ci), (0, cop - co)))
    bb = b if b is not None else jnp.zeros((co,), dtype=x.dtype)
    bp = jnp.pad(bb, (0, cop - co)).reshape(1, cop).astype(x.dtype)

    y = deconv2d_pallas_call(
        xp, wp, bp,
        plan=plan,
        ohp=ohp, owp=owp,
        t_oh=t_oh, t_ow=t_ow, t_ci=t_ci, t_co=t_co, t_n=t_n,
        activation=activation,
        interpret=interpret,
    )
    return y[:n, :oh, :ow, :co]


def resolve_tiles(
    x: jax.Array,
    w: jax.Array,
    stride: int,
    padding: int,
    t_oh: Optional[int],
    t_ow: Optional[int],
    t_ci: Optional[int],
    t_co: Optional[int],
    t_n: Optional[int] = None,
    backend: str = "pallas",
    autotune: bool = True,
    out_dtype_bytes: Optional[int] = None,
):
    """Fill unspecified tile factors (shared by dense and sparse wrappers).

    The batch tile ``t_n`` is resolved jointly with the spatial/channel
    tiles against the caller's batch size (``x.shape[0]``): the autotuner
    DSE scores candidates by MXU row fill + amortized weight traffic.
    Explicitly passing all four legacy factors but not ``t_n`` keeps the
    per-image grid (t_n=1) — the pre-batch-fusion behavior."""
    n, ih, iw, ci = x.shape
    k, _, _, co = w.shape
    if None not in (t_oh, t_ow, t_ci, t_co):
        return t_oh, t_ow, t_ci, t_co, (t_n or 1)
    geom = DeconvGeometry(ih, iw, ci, co, k, stride, padding)
    if autotune:
        from ..autotune import choose_tiles

        c = choose_tiles(geom, x.dtype, backend=backend, batch=n,
                         out_dtype_bytes=out_dtype_bytes)
    else:
        from ..autotune import fallback_tiles

        c = fallback_tiles(geom, jnp.dtype(x.dtype).itemsize, batch=n,
                           out_dtype_bytes=out_dtype_bytes)
    return (t_oh or c.t_oh, t_ow or c.t_ow, t_ci or c.t_ci, t_co or c.t_co,
            t_n or c.t_n)


def deconv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
    t_oh: Optional[int] = None,
    t_ow: Optional[int] = None,
    t_ci: Optional[int] = None,
    t_co: Optional[int] = None,
    t_n: Optional[int] = None,
    activation: Optional[str] = None,
    interpret: Optional[bool] = None,
    autotune: bool = True,
) -> jax.Array:
    """Transposed conv y = act(deconv(x, w) + b) via the reverse-loop kernel.

    x: (N, IH, IW, CI); w: (K, K, CI, CO); b: (CO,) or None.
    Output: (N, OH, OW, CO), OH = (IH-1)*S + K - 2P.
    `activation` ("relu"/"tanh"/None) runs fused in the kernel's flush phase.
    ``t_n`` is the batch tile: each grid program owns ``t_n`` images and the
    tap matmuls contract over ``t_n * T_OH/S * T_OW/S`` rows (the batch is
    zero-padded to a ``t_n`` multiple and sliced back).  Unspecified tile
    factors come from the DSE autotuner cache/model (`autotune=False`
    selects the clamped fixed heuristic instead).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t_oh, t_ow, t_ci, t_co, t_n = resolve_tiles(
        x, w, stride, padding, t_oh, t_ow, t_ci, t_co, t_n,
        backend="pallas", autotune=autotune,
    )
    return _deconv2d_jit(
        x, w, b, stride, padding, t_oh, t_ow, t_ci, t_co, t_n, activation,
        interpret,
    )
