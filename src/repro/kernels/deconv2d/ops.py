"""Jit'd public wrapper for the deconv2d Pallas kernel.

Resolves geometry (halo padding per core.tiling, channel padding to tile
multiples), picks DSE-guided default tile factors, invokes the kernel, and
crops the result.  On non-TPU backends the kernel runs in interpret mode."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.offsets import make_phase_plan
from ...core.tiling import DeconvGeometry, out_size
from .kernel import deconv2d_pallas_call


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def default_tiles(oh: int, ow: int, ci: int, co: int, stride: int):
    """DSE-guided defaults: stride-aligned spatial tiles close to the MXU
    native 8x128 register shape; full output when small."""
    t_oh = min(_round_up(oh, stride), _round_up(32, stride))
    t_ow = min(_round_up(ow, stride), _round_up(32, stride))
    t_ci = min(ci, 128)
    t_co = min(co, 128)
    return t_oh, t_ow, t_ci, t_co


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "padding", "t_oh", "t_ow", "t_ci", "t_co", "interpret",
    ),
)
def deconv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
    t_oh: Optional[int] = None,
    t_ow: Optional[int] = None,
    t_ci: Optional[int] = None,
    t_co: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Transposed conv y = deconv(x, w) + b via the reverse-loop kernel.

    x: (N, IH, IW, CI); w: (K, K, CI, CO); b: (CO,) or None.
    Output: (N, OH, OW, CO), OH = (IH-1)*S + K - 2P.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, ih, iw, ci = x.shape
    k, _, _, co = w.shape
    s = stride
    oh = out_size(ih, k, s, padding)
    ow = out_size(iw, k, s, padding)
    plan = make_phase_plan(k, s, padding)

    dt_oh, dt_ow, dt_ci, dt_co = default_tiles(oh, ow, ci, co, s)
    t_oh = t_oh or dt_oh
    t_ow = t_ow or dt_ow
    t_ci = t_ci or dt_ci
    t_co = t_co or dt_co

    # pad output grid to tile multiples; phase grid rows per padded output
    ohp = _round_up(oh, t_oh)
    owp = _round_up(ow, t_ow)
    n_h_pad = ohp // s
    n_w_pad = owp // s

    # halo padding (enhancement 3: all address arithmetic resolved up front)
    pad_l = plan.left_halo
    pad_rh = max(0, (n_h_pad - 1 + plan.delta_max) - (ih - 1))
    pad_rw = max(0, (n_w_pad - 1 + plan.delta_max) - (iw - 1))
    cip = _round_up(ci, t_ci)
    cop = _round_up(co, t_co)
    xp = jnp.pad(
        x, ((0, 0), (pad_l, pad_rh), (pad_l, pad_rw), (0, cip - ci))
    )
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cip - ci), (0, cop - co)))
    bb = b if b is not None else jnp.zeros((co,), dtype=x.dtype)
    bp = jnp.pad(bb, (0, cop - co)).reshape(1, cop).astype(x.dtype)

    y = deconv2d_pallas_call(
        xp, wp, bp,
        plan=plan,
        ohp=ohp, owp=owp,
        t_oh=t_oh, t_ow=t_ow, t_ci=t_ci, t_co=t_co,
        pad_l=pad_l,
        interpret=interpret,
    )
    return y[:, :oh, :ow, :co]
