"""Public wrapper for the deconv2d Pallas kernel.

`deconv2d` is a thin plan dispatcher: the preferred fast path takes a
pre-built `plan.DeconvPlan` (geometry, tiles, fused epilogue all pinned
at plan time) and goes straight into the jit'd `_deconv2d_jit`, which
performs the halo / channel padding and invokes the kernel.  The legacy
surface — explicit tile kwargs, or none at all — resolves tiles
(explicit overrides > autotuner > clamped fallback heuristic) into an
ad-hoc plan and routes through the same path; passing tile kwargs
directly is deprecated in favor of building the plan once.

On non-TPU backends the kernel runs in interpret mode."""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from ...core.offsets import make_phase_plan
from ...core.tiling import DeconvGeometry, out_size
from .kernel import deconv2d_pallas_call

_warned_tile_kwargs = set()
_suppress_tile_warnings = 0


class suppress_tile_warnings:
    """Context manager for the library's own supported legacy surfaces
    (``generator_apply(tile_overrides=...)`` and friends): they forward
    tile kwargs into the wrappers on the user's behalf, and must not nag
    the user about an expansion the user never wrote."""

    def __enter__(self):
        global _suppress_tile_warnings
        _suppress_tile_warnings += 1

    def __exit__(self, *exc):
        global _suppress_tile_warnings
        _suppress_tile_warnings -= 1


def warn_legacy_tiles(fn_name: str) -> None:
    """One DeprecationWarning per wrapper per process for direct tile
    kwargs — the call still works (routed through the plan path), but the
    plan API is where new capability (int4, mixed precision) lands."""
    if _suppress_tile_warnings or fn_name in _warned_tile_kwargs:
        return
    _warned_tile_kwargs.add(fn_name)
    warnings.warn(
        f"passing tile kwargs (t_oh/t_ow/t_ci/t_co/t_n) directly to "
        f"{fn_name} is deprecated: build a repro.plan.DeconvPlan once "
        f"(plan.build_layer_plan) and pass it via plan=",
        DeprecationWarning, stacklevel=3)


def check_layer_plan(plan, x: jax.Array, w: jax.Array, backend: str,
                     fn_name: str) -> None:
    """Fail loudly when a plan is executed against data it was not built
    for — the pinned-configuration contract."""
    n, ih, iw, ci = x.shape
    k, _, wci, co = w.shape
    g = plan.geometry
    if (ih, iw, ci, co, k) != (g.in_h, g.in_w, g.c_in, g.c_out, g.kernel) \
            or wci != g.c_in:
        raise ValueError(
            f"{fn_name}: plan geometry {g} does not match x{x.shape} / "
            f"w{w.shape}")
    if plan.backend != backend:
        raise ValueError(
            f"{fn_name}: plan was built for backend={plan.backend!r}")
    if plan.tiles is None:
        raise ValueError(f"{fn_name}: plan has no resolved tiles")


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def halo_pad_geometry(n: int, ih: int, iw: int, ci: int, co: int,
                      plan, t_oh: int, t_ow: int, t_ci: int, t_co: int,
                      t_n: int):
    """Host-side padded geometry shared by the f32 and int8 jit wrappers.

    Returns ``(oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop, t_n,
    np_)``: the true output extents, the tile-multiple output grid, the
    halo padding that keeps every per-tile window in bounds (enhancement
    3: all address arithmetic resolved ahead of the kernel), the channel
    tiles' padded extents, the batch tile clamped to the batch, and the
    t_n-multiple padded batch.  One implementation, two kernels — the
    padded geometry (and the final un-padding slice) can never drift
    between the precisions."""
    oh = out_size(ih, plan.kernel_size, plan.stride, plan.padding)
    ow = out_size(iw, plan.kernel_size, plan.stride, plan.padding)
    ohp = _round_up(oh, t_oh)
    owp = _round_up(ow, t_ow)
    n_h_pad = ohp // plan.stride
    n_w_pad = owp // plan.stride
    pad_l = plan.left_halo
    pad_rh = max(0, (n_h_pad - 1 + plan.delta_max) - (ih - 1))
    pad_rw = max(0, (n_w_pad - 1 + plan.delta_max) - (iw - 1))
    cip = _round_up(ci, t_ci)
    cop = _round_up(co, t_co)
    t_n = min(t_n, n) if n > 0 else 1
    np_ = _round_up(n, t_n)
    return oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop, t_n, np_


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "padding", "t_oh", "t_ow", "t_ci", "t_co", "t_n",
        "activation", "interpret",
    ),
)
def _deconv2d_jit(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    t_n: int,
    activation: Optional[str],
    interpret: bool,
) -> jax.Array:
    n, ih, iw, ci = x.shape
    k, _, _, co = w.shape
    plan = make_phase_plan(k, stride, padding)

    # padded output grid + halo padding (enhancement 3: all address
    # arithmetic resolved up front; the per-tile windows the kernel
    # streams stay in bounds by construction)
    (oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop, t_n,
     np_) = halo_pad_geometry(n, ih, iw, ci, co, plan, t_oh, t_ow, t_ci,
                              t_co, t_n)
    xp = jnp.pad(
        x, ((0, np_ - n), (pad_l, pad_rh), (pad_l, pad_rw), (0, cip - ci))
    )
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cip - ci), (0, cop - co)))
    bb = b if b is not None else jnp.zeros((co,), dtype=x.dtype)
    bp = jnp.pad(bb, (0, cop - co)).reshape(1, cop).astype(x.dtype)

    y = deconv2d_pallas_call(
        xp, wp, bp,
        plan=plan,
        ohp=ohp, owp=owp,
        t_oh=t_oh, t_ow=t_ow, t_ci=t_ci, t_co=t_co, t_n=t_n,
        activation=activation,
        interpret=interpret,
    )
    return y[:n, :oh, :ow, :co]


def resolve_tiles(
    x: jax.Array,
    w: jax.Array,
    stride: int,
    padding: int,
    t_oh: Optional[int],
    t_ow: Optional[int],
    t_ci: Optional[int],
    t_co: Optional[int],
    t_n: Optional[int] = None,
    backend: str = "pallas",
    autotune: bool = True,
    out_dtype_bytes: Optional[int] = None,
):
    """Fill unspecified tile factors (shared by dense and sparse wrappers).

    The batch tile ``t_n`` is resolved jointly with the spatial/channel
    tiles against the caller's batch size (``x.shape[0]``): the autotuner
    DSE scores candidates by MXU row fill + amortized weight traffic.
    Explicitly passing all four legacy factors but not ``t_n`` keeps the
    per-image grid (t_n=1) — the pre-batch-fusion behavior."""
    n, ih, iw, ci = x.shape
    k, _, _, co = w.shape
    if None not in (t_oh, t_ow, t_ci, t_co):
        return t_oh, t_ow, t_ci, t_co, (t_n or 1)
    geom = DeconvGeometry(ih, iw, ci, co, k, stride, padding)
    if autotune:
        from ..autotune import choose_tiles

        c = choose_tiles(geom, x.dtype, backend=backend, batch=n,
                         out_dtype_bytes=out_dtype_bytes)
    else:
        from ..autotune import fallback_tiles

        c = fallback_tiles(geom, jnp.dtype(x.dtype).itemsize, batch=n,
                           out_dtype_bytes=out_dtype_bytes)
    return (t_oh or c.t_oh, t_ow or c.t_ow, t_ci or c.t_ci, t_co or c.t_co,
            t_n or c.t_n)


def deconv2d(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    stride: Optional[int] = None,
    padding: Optional[int] = None,
    t_oh: Optional[int] = None,
    t_ow: Optional[int] = None,
    t_ci: Optional[int] = None,
    t_co: Optional[int] = None,
    t_n: Optional[int] = None,
    activation: Optional[str] = None,
    interpret: Optional[bool] = None,
    autotune: bool = True,
    plan=None,
) -> jax.Array:
    """Transposed conv y = act(deconv(x, w) + b) via the reverse-loop kernel.

    x: (N, IH, IW, CI); w: (K, K, CI, CO); b: (CO,) or None.
    Output: (N, OH, OW, CO), OH = (IH-1)*S + K - 2P.
    `activation` ("relu"/"tanh"/None) runs fused in the kernel's flush phase.

    **Plan fast path** — ``plan`` is a `repro.plan.DeconvPlan`: stride,
    padding, the full tile assignment and the fused activation all come
    pre-resolved from the plan; nothing is re-decided here.  An explicit
    ``activation`` argument overrides the plan's.

    **Legacy path** — without a plan, ``stride``/``padding`` are required;
    unspecified tile factors come from the DSE autotuner cache/model
    (`autotune=False` selects the clamped fixed heuristic), explicit tile
    kwargs are deprecated, and the resolved choice routes through the same
    jit as the plan path (bit-identical executables).  ``t_n`` is the
    batch tile: each grid program owns ``t_n`` images and the tap matmuls
    contract over ``t_n * T_OH/S * T_OW/S`` rows (the batch is zero-padded
    to a ``t_n`` multiple and sliced back).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if plan is not None:
        check_layer_plan(plan, x, w, "pallas", "deconv2d")
        t = plan.tiles
        if activation is None:
            activation = plan.activation
        return _deconv2d_jit(
            x, w, b, plan.geometry.stride, plan.geometry.padding,
            t.t_oh, t.t_ow, t.t_ci, t.t_co, t.t_n, activation, interpret,
        )
    if stride is None or padding is None:
        raise TypeError("deconv2d needs stride and padding (or a plan=)")
    if any(v is not None for v in (t_oh, t_ow, t_ci, t_co, t_n)):
        warn_legacy_tiles("deconv2d")
    t_oh, t_ow, t_ci, t_co, t_n = resolve_tiles(
        x, w, stride, padding, t_oh, t_ow, t_ci, t_co, t_n,
        backend="pallas", autotune=autotune,
    )
    return _deconv2d_jit(
        x, w, b, stride, padding, t_oh, t_ow, t_ci, t_co, t_n, activation,
        interpret,
    )
