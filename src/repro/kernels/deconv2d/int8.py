"""int8 batch-fused reverse-loop deconvolution Pallas kernel.

The quantized twin of `kernel.py` — same grid (disjoint output tiles with
the batch folded into the MXU row dimension), same Eq. 5 halo-window
BlockSpecs, same trace-time phase plan — with the paper's low-precision
datapath mapped onto the TPU MXU:

* **int8 inputs and weights, int32 accumulation.**  Every tap matmul
  contracts int8 x int8 into an int32 accumulator — integer-exact, so
  the kernel is bit-comparable against an integer reference (no float
  reassociation in the reduction), and the MXU runs at its doubled int8
  rate while the HBM stream drops to a quarter of f32.
* **Fused requant epilogue.**  The flush phase applies the one multiply
  post-training quantization needs — ``y = acc * (s_x * s_w[c]) + b`` with
  the per-output-channel combined scale streamed like the bias — then the
  activation, then either casts to f32 (last layer) or *re-quantizes* to
  int8 with the next layer's calibrated input scale (``out_scale``), so a
  chained generator never materializes an f32 activation in HBM between
  quantized layers.  This sits in exactly the epilogue slot the f32
  kernel uses for bias + ReLU/tanh.

Scales come from `quant.calibrate` (statistical observers); tiles come
from the dtype-aware autotuner (int8 byte width in the VMEM/traffic
models, int8 MXU peak in the roofline).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.offsets import PhasePlan, make_phase_plan
from ...core.tiling import HaloTile, halo_tile
from ...quant.qmath import QMAX, quantize_symmetric
from .kernel import COMPILER_PARAMS, apply_activation, x_halo_blockspec


def requant_epilogue(acc_i32: jax.Array, scale: jax.Array, bias: jax.Array,
                     activation: Optional[str],
                     out_scale: Optional[float]) -> jax.Array:
    """The fused epilogue math, shared verbatim with the parity reference:
    dequantize the int32 accumulator through the combined per-channel
    scale, add bias, apply the activation, then (optionally) re-quantize
    to int8 at the next layer's input scale — through the same
    `quant.qmath` round/clip every other quantization call site uses."""
    y = acc_i32.astype(jnp.float32) * scale + bias
    y = apply_activation(y, activation)
    if out_scale is None:
        return y
    return quantize_symmetric(y, out_scale)


def _deconv2d_int8_kernel(
    x_ref,      # (T_N, T_IH, T_IW, T_CI)  VMEM int8 halo windows
    w_ref,      # (K, K, T_CI, T_CO)       VMEM int8 (batch-stationary)
    s_ref,      # (1, T_CO)                VMEM f32 combined s_x * s_w
    b_ref,      # (1, T_CO)                VMEM f32 bias
    o_ref,      # (T_N, T_OH, T_OW, T_CO)  VMEM int8 or f32
    acc_ref,    # (T_N, T_OH/S, S, T_OW/S, S, T_CO) int32 scratch
    *,
    plan: PhasePlan,
    ht_h: HaloTile,
    ht_w: HaloTile,
    t_oh: int,
    t_ow: int,
    n_ci_tiles: int,
    activation: Optional[str],
    out_scale: Optional[float],
):
    s = plan.stride
    th, tw = t_oh // s, t_ow // s
    t_n = x_ref.shape[0]
    ci_idx = pl.program_id(4)

    @pl.when(ci_idx == 0)
    def _init():
        # bias lives in the f32 requant epilogue, not the integer
        # accumulator: the accumulator stays exactly sum(q_x * q_w)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.int32)

    t_ci = x_ref.shape[3]
    t_co = w_ref.shape[3]
    for ph in range(s):
        for pw in range(s):
            acc = jnp.zeros((t_n * th * tw, t_co), dtype=jnp.int32)
            for kh, dh in plan.taps[ph]:
                for kw, dw in plan.taps[pw]:
                    r0 = ht_h.local_offset(dh)
                    c0 = ht_w.local_offset(dw)
                    xs = x_ref[:, r0:r0 + th, c0:c0 + tw, :]
                    acc = acc + jnp.dot(
                        xs.reshape(t_n * th * tw, t_ci),
                        w_ref[kh, kw],
                        preferred_element_type=jnp.int32,
                    )
            acc_ref[:, :, ph, :, pw, :] += acc.reshape(t_n, th, tw, t_co)

    @pl.when(ci_idx == n_ci_tiles - 1)
    def _flush():
        acc = acc_ref[...].reshape(t_n, t_oh, t_ow, t_co)
        o_ref[...] = requant_epilogue(
            acc, s_ref[0], b_ref[0], activation, out_scale)


def deconv2d_int8_pallas_call(
    x_padded: jax.Array,     # (N, IHp, IWp, CIp)  int8, host-padded
    w: jax.Array,            # (K, K, CIp, COp)    int8
    scale: jax.Array,        # (1, COp)            f32 combined s_x * s_w
    b: jax.Array,            # (1, COp)            f32
    *,
    plan: PhasePlan,
    ohp: int,
    owp: int,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    t_n: int = 1,
    activation: Optional[str] = None,
    out_scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    n, ihp, iwp, cip = x_padded.shape
    k = w.shape[0]
    cop = w.shape[3]
    s = plan.stride
    assert x_padded.dtype == jnp.int8 and w.dtype == jnp.int8
    assert t_oh % s == 0 and t_ow % s == 0, "tiles must be stride-aligned"
    assert cip % t_ci == 0 and cop % t_co == 0
    assert n % t_n == 0, "batch must be padded to a t_n multiple"
    ht_h = halo_tile(t_oh, k, s, plan.padding)
    ht_w = halo_tile(t_ow, k, s, plan.padding)
    n_tiles_h = ohp // t_oh
    n_tiles_w = owp // t_ow
    assert ihp >= ht_h.min_padded_extent(n_tiles_h), "input under-padded (h)"
    assert iwp >= ht_w.min_padded_extent(n_tiles_w), "input under-padded (w)"
    n_ci = cip // t_ci
    grid = (n // t_n, n_tiles_h, n_tiles_w, cop // t_co, n_ci)
    out_dtype = jnp.int8 if out_scale is not None else jnp.float32

    kernel = functools.partial(
        _deconv2d_int8_kernel,
        plan=plan,
        ht_h=ht_h,
        ht_w=ht_w,
        t_oh=t_oh,
        t_ow=t_ow,
        n_ci_tiles=n_ci,
        activation=activation,
        out_scale=out_scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            x_halo_blockspec(ht_h, ht_w, t_ci, t_n),
            pl.BlockSpec(
                (k, k, t_ci, t_co),
                lambda nb, oh, ow, co, ci: (0, 0, ci, co),
            ),
            pl.BlockSpec((1, t_co), lambda nb, oh, ow, co, ci: (0, co)),
            pl.BlockSpec((1, t_co), lambda nb, oh, ow, co, ci: (0, co)),
        ],
        out_specs=pl.BlockSpec(
            (t_n, t_oh, t_ow, t_co),
            lambda nb, oh, ow, co, ci: (nb, oh, ow, co),
        ),
        out_shape=jax.ShapeDtypeStruct((n, ohp, owp, cop), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((t_n, t_oh // s, s, t_ow // s, s, t_co), jnp.int32)
        ],
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "parallel", "arbitrary",
            ),
        ),
        interpret=interpret,
        name="deconv2d_int8_halo_reverse_loop",
    )(x_padded, w, scale, b)


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "padding", "t_oh", "t_ow", "t_ci", "t_co", "t_n",
        "activation", "out_scale", "interpret",
    ),
)
def _deconv2d_int8_jit(
    x: jax.Array,
    w: jax.Array,
    scale: jax.Array,
    b: Optional[jax.Array],
    stride: int,
    padding: int,
    t_oh: int,
    t_ow: int,
    t_ci: int,
    t_co: int,
    t_n: int,
    activation: Optional[str],
    out_scale: Optional[float],
    interpret: bool,
) -> jax.Array:
    n, ih, iw, ci = x.shape
    k, _, _, co = w.shape
    plan = make_phase_plan(k, stride, padding)
    from .ops import halo_pad_geometry

    (oh, ow, ohp, owp, pad_l, pad_rh, pad_rw, cip, cop, t_n,
     np_) = halo_pad_geometry(n, ih, iw, ci, co, plan, t_oh, t_ow, t_ci,
                              t_co, t_n)
    # symmetric (zero-point-free) quantization: int8 zero IS real zero, so
    # halo/channel/batch padding needs no offset handling
    xp = jnp.pad(
        x, ((0, np_ - n), (pad_l, pad_rh), (pad_l, pad_rw), (0, cip - ci))
    )
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cip - ci), (0, cop - co)))
    sp = jnp.pad(scale.astype(jnp.float32),
                 (0, cop - co)).reshape(1, cop)
    bb = b if b is not None else jnp.zeros((co,), jnp.float32)
    bp = jnp.pad(bb.astype(jnp.float32), (0, cop - co)).reshape(1, cop)

    y = deconv2d_int8_pallas_call(
        xp, wp, sp, bp,
        plan=plan,
        ohp=ohp, owp=owp,
        t_oh=t_oh, t_ow=t_ow, t_ci=t_ci, t_co=t_co, t_n=t_n,
        activation=activation,
        out_scale=out_scale,
        interpret=interpret,
    )
    return y[:n, :oh, :ow, :co]


def deconv2d_int8(
    x: jax.Array,
    w: jax.Array,
    scale: jax.Array,
    b: Optional[jax.Array],
    stride: Optional[int] = None,
    padding: Optional[int] = None,
    t_oh: Optional[int] = None,
    t_ow: Optional[int] = None,
    t_ci: Optional[int] = None,
    t_co: Optional[int] = None,
    t_n: Optional[int] = None,
    activation: Optional[str] = None,
    out_scale: Optional[float] = None,
    interpret: Optional[bool] = None,
    autotune: bool = True,
    plan=None,
) -> jax.Array:
    """Quantized transposed conv through the int8 reverse-loop kernel.

    x: (N, IH, IW, CI) int8; w: (K, K, CI, CO) int8; scale: (CO,) f32 —
    the combined ``x_scale * w_scale`` requant factor per output channel
    (see `quant.calibrate.quantize_params`); b: (CO,) f32 or None.
    ``out_scale`` (a static float) re-quantizes the activated output to
    int8 for the next quantized layer; ``None`` emits f32.

    ``plan`` (a `repro.plan.DeconvPlan` built at precision int8) pins the
    whole epilogue — tiles, activation AND requant out_scale — and skips
    tile resolution entirely.  Without a plan, unspecified tile factors
    resolve through the dtype-aware autotuner — the int8 byte width flows
    into the VMEM/traffic models and the int8 MXU peak into the roofline
    ranking — and explicit tile kwargs are deprecated.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from .ops import check_layer_plan, resolve_tiles, warn_legacy_tiles

    if plan is not None:
        check_layer_plan(plan, x, w, "pallas", "deconv2d_int8")
        t = plan.tiles
        if activation is None:
            activation = plan.activation
        if out_scale is None:
            out_scale = plan.out_scale
        return _deconv2d_int8_jit(
            x, w, jnp.asarray(scale), b, plan.geometry.stride,
            plan.geometry.padding, t.t_oh, t.t_ow, t.t_ci, t.t_co, t.t_n,
            activation, out_scale, interpret,
        )
    if stride is None or padding is None:
        raise TypeError(
            "deconv2d_int8 needs stride and padding (or a plan=)")
    if any(v is not None for v in (t_oh, t_ow, t_ci, t_co, t_n)):
        warn_legacy_tiles("deconv2d_int8")
    t_oh, t_ow, t_ci, t_co, t_n = resolve_tiles(
        x, w, stride, padding, t_oh, t_ow, t_ci, t_co, t_n,
        backend="pallas", autotune=autotune,
        # no out_scale -> the epilogue emits f32: the autotuner must
        # price the output block at 4 bytes, not the streamed int8 width
        out_dtype_bytes=(4 if out_scale is None else None),
    )
    return _deconv2d_int8_jit(
        x, w, jnp.asarray(scale), b, stride, padding, t_oh, t_ow, t_ci,
        t_co, t_n, activation, out_scale, interpret,
    )
