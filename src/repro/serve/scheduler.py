"""Deadline-aware scheduling for the async serving frontend.

Two pieces, both deliberately engine-agnostic and side-effect free so
they are unit-testable without a worker thread:

* `ServiceModel` — per (precision, bucket) service-time estimates: an
  EMA over measured dispatch wall clocks, seeded from the engine's own
  monitors (`DcnnServeEngine.service_estimate`, i.e. the per-bucket
  `dist.fault.StragglerMonitor` EMAs and the healthy `bucket_stats`
  means).  This is the shared capacity signal: admission control asks it
  "can this request make its SLO at all?", the scheduler asks "at which
  precision?", and the frontend scales it down when a device-loss remesh
  shrinks the mesh.
* `EdfScheduler` — earliest-deadline-first within tenant priority class:
  requests order by (tenant priority, absolute deadline, arrival), and
  per request the scheduler picks the cheapest acceptable *precision* —
  fp32 when its predicted completion meets the deadline, the pinned int8
  plan chain when only the quantized path can make it (graceful
  degradation: reduced-precision deconv is the lever traded for latency,
  per "Hardware-Efficient Deconvolution-Based GAN for Edge Computing"),
  and None when even int8 would bust the SLO — the caller sheds typed
  instead of burning device time on a guaranteed deadline miss.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

FP32 = "fp32"
INT8 = "int8"


class ServiceModel:
    """Per (precision, bucket) dispatch-time estimates.

    ``observe`` feeds measured wall clocks (EMA, recent-weighted);
    ``override`` pins an estimate exactly (tests and benches make
    scheduling decisions deterministic with it); ``scale`` multiplies
    every estimate — the capacity-shrink lever the frontend pulls after
    an elastic remesh (half the devices ≈ double the per-dispatch time
    until fresh measurements take over).  Thread-safe: the worker
    observes while callers' admission checks read."""

    def __init__(self, decay: float = 0.6):
        self.decay = decay
        self._est: Dict[Tuple[str, int], float] = {}
        self._lock = threading.Lock()

    def observe(self, precision: str, bucket: int, seconds: float) -> None:
        with self._lock:
            key = (precision, int(bucket))
            prev = self._est.get(key)
            self._est[key] = (seconds if prev is None
                              else self.decay * prev
                              + (1.0 - self.decay) * seconds)

    def override(self, precision: str, bucket: int, seconds: float) -> None:
        with self._lock:
            self._est[(precision, int(bucket))] = float(seconds)

    def scale(self, factor: float) -> None:
        with self._lock:
            for k in self._est:
                self._est[k] *= factor

    def estimate(self, precision: str, bucket: int) -> Optional[float]:
        with self._lock:
            return self._est.get((precision, int(bucket)))

    def seed_from_engine(self, precision: str, engine) -> None:
        """Pull whatever the engine already learned (straggler EMAs /
        healthy bucket means) without overwriting fresher local data."""
        with self._lock:
            for b in engine.buckets:
                est = engine.service_estimate(b)
                if est is not None:
                    self._est.setdefault((precision, int(b)), est)

    def snapshot(self) -> Dict[str, float]:
        """{"precision/bucket": seconds} view for stats()/bench JSON."""
        with self._lock:
            return {f"{p}/b{b}": s for (p, b), s in sorted(self._est.items())}

    # -- derived quantities --------------------------------------------
    def row_seconds(self, precision: str) -> Optional[float]:
        """Best known per-row service time (min over buckets of est/b) —
        the backlog-estimation rate; None with no data."""
        with self._lock:
            rates = [s / b for (p, b), s in self._est.items()
                     if p == precision and b > 0]
        return min(rates) if rates else None

    def service_seconds(self, precision: str, rows: int,
                        buckets: Sequence[int]) -> Optional[float]:
        """Predicted dispatch time for a ``rows``-row request chunked over
        ``buckets`` (greedy largest-first, mirroring the engine's chunk
        planner closely enough for admission).  Falls back to the best
        per-row rate for buckets without direct estimates; None when the
        model knows nothing about this precision yet (the caller then
        admits optimistically — no data must not mean reject-everything).
        """
        if rows <= 0:
            return 0.0
        buckets = sorted(int(b) for b in buckets)
        if not buckets:
            return None
        total, remaining = 0.0, rows
        row_rate = self.row_seconds(precision)
        while remaining > 0:
            b = next((x for x in buckets if x >= remaining), buckets[-1])
            est = self.estimate(precision, b)
            if est is None:
                if row_rate is None:
                    return None
                est = row_rate * b
            total += est
            remaining -= b
        return total


class EdfScheduler:
    """Earliest-deadline-first within tenant class, with precision as the
    degrade lever.

    ``precisions`` lists what the frontend actually pinned plans for, in
    preference order (fp32 first); ``safety`` inflates estimates so a
    request predicted to *just* fit is not dispatched into a miss."""

    def __init__(self, model: ServiceModel, buckets: Sequence[int],
                 precisions: Sequence[str] = (FP32,), safety: float = 1.2):
        if not precisions or precisions[0] != FP32:
            raise ValueError(
                f"precisions must lead with '{FP32}' (the undegraded "
                f"path); got {tuple(precisions)}")
        self.model = model
        self.buckets = tuple(int(b) for b in buckets)
        self.precisions = tuple(precisions)
        self.safety = safety

    @staticmethod
    def order(pending: List, now: Optional[float] = None) -> List:
        """EDF within tenant class: sort by (tenant priority, absolute
        deadline, arrival).  Deadline-less requests sort after deadlined
        ones of the same class (batch work yields to latency work)."""
        return sorted(
            pending,
            key=lambda r: (r.tenant.priority,
                           r.deadline if r.deadline is not None
                           else float("inf"),
                           r.rid))

    def feasible_precision(self, req, now: float,
                           backlog_s: float = 0.0) -> Optional[str]:
        """The cheapest-degradation precision predicted to meet the
        request's deadline: fp32 if it fits, else (tenant permitting)
        each degraded precision in order, else None — shed, don't
        dispatch a guaranteed miss.  Unknown estimates admit
        optimistically at fp32 (the model learns from the dispatch)."""
        if req.deadline is None:
            return self.precisions[0]
        allowed = (self.precisions if req.tenant.allow_degrade
                   else self.precisions[:1])
        for precision in allowed:
            est = self.model.service_seconds(precision, req.rows,
                                             self.buckets)
            if est is None:
                return precision
            if now + backlog_s + self.safety * est <= req.deadline:
                return precision
        return None
