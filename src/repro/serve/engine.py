"""Batched serving engine: prefill + KV-cache decode with slot-based
continuous batching.

`ServeEngine` keeps a fixed batch of sequence slots; finished sequences free
their slot and queued requests are admitted at the next step (continuous
batching).  The decode step is a single compiled function over the whole
slot batch — the production pattern for TPU serving.

`DcnnServeEngine` is the paper's own serving path: batched z -> image
generation through a selectable deconvolution backend."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.dcnn import DcnnConfig, generator_apply
from ..models.transformer import ModelConfig, apply_lm, init_cache
from .sampling import sample


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int
    out: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_len: int, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        def prefill(params, tokens):
            cache = init_cache(cfg, batch_size, max_len)
            logits, cache, _ = apply_lm(params, cfg, tokens, mode="prefill",
                                        cache=cache)
            return logits[:, -1], cache

        def decode(params, cache, tokens):
            logits, cache, _ = apply_lm(params, cfg, tokens, mode="decode",
                                        cache=cache)
            return logits[:, -1], cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 eos_id: int = -1) -> np.ndarray:
        """prompts: (B, S) int32 (B == engine batch).  Static batch path."""
        assert prompts.shape[0] == self.batch
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        toks = []
        self.key, k = jax.random.split(self.key)
        nxt = sample(logits, k, self.temperature)
        toks.append(np.asarray(nxt))
        for _ in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, cache, nxt[:, None])
            self.key, k = jax.random.split(self.key)
            nxt = sample(logits, k, self.temperature)
            toks.append(np.asarray(nxt))
        return np.stack(toks, axis=1)

    # ------------------------------------------------------------------
    # continuous batching: slot scheduler over queued requests
    # ------------------------------------------------------------------
    def serve(self, requests: List[Request]) -> List[Request]:
        """Processes requests with slot reuse.  Prompts are padded into the
        fixed slot batch; finished slots admit queued requests."""
        queue = list(requests)
        done: List[Request] = []
        while queue:
            active = queue[: self.batch]
            queue = queue[self.batch:]
            s_max = max(len(r.prompt) for r in active)
            pad = np.zeros((self.batch, s_max), np.int32)
            for i, r in enumerate(active):
                pad[i, s_max - len(r.prompt):] = r.prompt  # left-pad
            budget = max(r.max_new_tokens for r in active)
            out = self.generate(pad, budget)
            for i, r in enumerate(active):
                r.out = out[i, : r.max_new_tokens]
                done.append(r)
        return done


class DcnnServeEngine:
    """The paper's inference workload: batched image generation."""

    def __init__(self, cfg: DcnnConfig, params, backend: str = "pallas"):
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self._fn = jax.jit(
            lambda p, z: generator_apply(p, cfg, z, backend=backend))

    def generate(self, z: np.ndarray) -> np.ndarray:
        return np.asarray(self._fn(self.params, jnp.asarray(z)))
