"""Batched serving engine: prefill + KV-cache decode with slot-based
continuous batching.

`ServeEngine` keeps a fixed batch of sequence slots; finished sequences free
their slot and queued requests are admitted at the next step (continuous
batching).  The decode step is a single compiled function over the whole
slot batch — the production pattern for TPU serving.

`DcnnServeEngine` is the paper's own serving path: batched z -> image
generation through a selectable deconvolution backend."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.dcnn import DcnnConfig, generator_apply
from ..models.transformer import ModelConfig, apply_lm, init_cache
from .sampling import sample


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int
    out: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_len: int, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        def prefill(params, tokens):
            cache = init_cache(cfg, batch_size, max_len)
            logits, cache, _ = apply_lm(params, cfg, tokens, mode="prefill",
                                        cache=cache)
            return logits[:, -1], cache

        def decode(params, cache, tokens):
            logits, cache, _ = apply_lm(params, cfg, tokens, mode="decode",
                                        cache=cache)
            return logits[:, -1], cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 eos_id: int = -1) -> np.ndarray:
        """prompts: (B, S) int32 (B == engine batch).  Static batch path."""
        assert prompts.shape[0] == self.batch
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        toks = []
        self.key, k = jax.random.split(self.key)
        nxt = sample(logits, k, self.temperature)
        toks.append(np.asarray(nxt))
        for _ in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, cache, nxt[:, None])
            self.key, k = jax.random.split(self.key)
            nxt = sample(logits, k, self.temperature)
            toks.append(np.asarray(nxt))
        return np.stack(toks, axis=1)

    # ------------------------------------------------------------------
    # continuous batching: slot scheduler over queued requests
    # ------------------------------------------------------------------
    def serve(self, requests: List[Request]) -> List[Request]:
        """Processes requests with slot reuse.  Prompts are padded into the
        fixed slot batch; finished slots admit queued requests."""
        queue = list(requests)
        done: List[Request] = []
        while queue:
            active = queue[: self.batch]
            queue = queue[self.batch:]
            s_max = max(len(r.prompt) for r in active)
            pad = np.zeros((self.batch, s_max), np.int32)
            for i, r in enumerate(active):
                pad[i, s_max - len(r.prompt):] = r.prompt  # left-pad
            budget = max(r.max_new_tokens for r in active)
            out = self.generate(pad, budget)
            for i, r in enumerate(active):
                r.out = out[i, : r.max_new_tokens]
                done.append(r)
        return done


class DcnnServeEngine:
    """The paper's inference workload: batched image generation.

    The default path is the fused halo-streaming Pallas kernel chain
    (bias + activation in the kernel epilogue, per-tile Eq. 5 input
    streaming).  Tile factors are resolved once at engine construction —
    eagerly, so the autotuner may refine with on-device timing
    (``refine=True``) and persist the choices; the jitted generator then
    sees only static, pre-resolved tiles."""

    def __init__(self, cfg: DcnnConfig, params, backend: str = "pallas",
                 autotune: bool = True, refine: bool = False):
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self.tile_choices = None
        sparse_plans = None
        if backend in ("pallas", "pallas_sparse"):
            # resolve tiles once, eagerly: autotuned (cache/model/timed) or
            # the clamped fixed heuristic when autotune=False — either way
            # the jitted generator sees only pre-resolved static tiles.
            from ..kernels.autotune import choose_tiles, fallback_tiles

            if autotune:
                self.tile_choices = {
                    i: choose_tiles(g, cfg.jdtype, backend=backend,
                                    refine=refine)
                    for i, g in enumerate(cfg.geometries())
                }
            else:
                self.tile_choices = {
                    i: fallback_tiles(g, cfg.jdtype.itemsize)
                    for i, g in enumerate(cfg.geometries())
                }
            if backend == "pallas_sparse":
                # the zero-skip schedule is static per network: build it once
                # from the concrete weights instead of on every generate()
                from ..kernels.deconv2d_sparse import make_sparse_plan

                sparse_plans = {
                    i: make_sparse_plan(
                        np.asarray(params[f"l{i}"]["w"]), l.stride, l.padding,
                        self.tile_choices[i].t_ci, self.tile_choices[i].t_co)
                    for i, l in enumerate(cfg.layers)
                }
        # with plans + tiles pre-resolved, no backend needs concrete weights
        # at trace time, so the whole generator compiles as one function.
        self._fn = jax.jit(
            lambda p, z: generator_apply(
                p, cfg, z, backend=backend,
                tile_overrides=self.tile_choices,
                sparse_plans=sparse_plans))

    def generate(self, z: np.ndarray) -> np.ndarray:
        return np.asarray(self._fn(self.params, jnp.asarray(z)))
