"""Batched serving engines.

`ServeEngine` (LM path) keeps a fixed batch of sequence slots with
*continuous batching*: a finished sequence frees its slot and a queued
request is admitted into it mid-flight — the in-flight slots keep their
accumulated tokens and continue decoding.  The decode step is a single
compiled function over the whole slot batch.

`DcnnServeEngine` is the paper's own serving path: batched z -> image
generation through a selectable deconvolution backend, run as a real
throughput engine — request batches are padded to a fixed set of
power-of-two *buckets* so the generator compiles once per bucket (never
per request shape), each bucket's tile assignment (including the batch
tile ``t_n``) is resolved against that bucket's batch size, and a
``submit``/``collect`` micro-batching queue coalesces small requests into
the largest fitting bucket."""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.fault import Heartbeat, StragglerMonitor
from ..dist.inject import DeviceLossError, TransientCallError
from ..models.dcnn import DcnnConfig, generator_apply
from ..models.transformer import ModelConfig, apply_lm, init_cache
from ..obs import clock as obsclock
from ..obs import metrics as obsmetrics
from ..obs import trace as obstrace
from .config import EngineConfig
from .errors import AdmissionRejected, DeadlineExceeded, EngineDegraded
from .sampling import sample


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int
    out: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 max_len: int, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        # scheduler observability (reset per serve() call)
        self.prefill_steps = 0
        self.decode_steps = 0
        self.sample_steps = 0

        def prefill(params, tokens):
            cache = init_cache(cfg, batch_size, max_len)
            logits, cache, _ = apply_lm(params, cfg, tokens, mode="prefill",
                                        cache=cache)
            return logits[:, -1], cache

        def decode(params, cache, tokens):
            logits, cache, _ = apply_lm(params, cfg, tokens, mode="decode",
                                        cache=cache)
            return logits[:, -1], cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 eos_id: int = -1) -> np.ndarray:
        """prompts: (B, S) int32 (B == engine batch).  Static batch path."""
        assert prompts.shape[0] == self.batch
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        toks = []
        self.key, k = jax.random.split(self.key)
        nxt = sample(logits, k, self.temperature)
        toks.append(np.asarray(nxt))
        for _ in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, cache, nxt[:, None])
            self.key, k = jax.random.split(self.key)
            nxt = sample(logits, k, self.temperature)
            toks.append(np.asarray(nxt))
        return np.stack(toks, axis=1)

    # ------------------------------------------------------------------
    # continuous batching: slot scheduler over queued requests
    # ------------------------------------------------------------------
    def serve(self, requests: List[Request]) -> List[Request]:
        """Continuous batching over the fixed slot batch.

        A request is admitted the moment a slot frees — mid-flight, not at
        chunk boundaries — so a long request no longer holds short ones
        hostage (the pre-fix behavior ran static chunks at the chunk-max
        budget).  Admission (re)prefills the *accumulated histories* of
        every active slot, left-padded so all slots share the scalar cache
        position; in-flight slots keep their generated tokens and continue
        from exactly where they were (greedy decoding is bit-identical to
        running each request alone).  Between admissions all slots advance
        through the single compiled decode step.  Each request generates
        exactly its own ``max_new_tokens`` — no slot burns steps on
        another slot's budget.

        Left-pad tokens are ordinary tokens to the (causal, unmasked)
        model — the same property the chunked scheduler already had for
        mixed-length prompts — so a request admitted mid-flight decodes
        the oracle continuation of its *padded* history (pinned by
        tests/test_serve.py::test_continuous_batching_midflight_admission),
        and an admission whose prompt is *longer* than every in-flight
        history re-pads the in-flight slots too, perturbing their
        remaining continuation (in-flight decoding is bit-stable only
        while the slot stays at the longest history).  Each admission also
        re-prefills at a new (batch, s_max) shape, i.e. one XLA compile
        per distinct admission length; length-bucketing the prefill would
        bound that but — without a pad mask — padding is semantics, so it
        stays exact-shape until the model grows pad masking.
        """
        queue = list(requests)
        done: List[Request] = []
        slots: List[Optional[dict]] = [None] * self.batch
        self.prefill_steps = self.decode_steps = self.sample_steps = 0
        nxt = None
        cache = None
        while queue or any(s is not None for s in slots):
            admitted = False
            for i in range(self.batch):
                while slots[i] is None and queue:
                    r = queue.pop(0)
                    if r.max_new_tokens <= 0:
                        # zero-budget request: complete without a slot (the
                        # slot loop tests `left == 0` only after a decrement,
                        # so admitting it would never free the slot)
                        r.out = np.zeros((0,), np.int32)
                        done.append(r)
                        continue
                    slots[i] = {
                        "req": r,
                        "hist": [int(t) for t in np.asarray(r.prompt)],
                        "left": int(r.max_new_tokens),
                        "gen": [],
                    }
                    admitted = True
            if not any(s is not None for s in slots):
                break  # every remaining request was zero-budget
            if admitted:
                # re-prefill the active histories (left-padded: every slot
                # sits at the same cache position, which is what the shared
                # scalar cache["pos"] requires)
                s_max = max(len(s["hist"]) for s in slots if s is not None)
                worst = s_max + max(s["left"] for s in slots
                                    if s is not None)
                assert worst <= self.max_len, (
                    f"history+budget ({worst}) exceeds max_len "
                    f"({self.max_len}); the KV cache would overflow")
                pad = np.zeros((self.batch, s_max), np.int32)
                for i, s in enumerate(slots):
                    if s is not None:
                        pad[i, s_max - len(s["hist"]):] = s["hist"]
                logits, cache = self._prefill(self.params, jnp.asarray(pad))
                self.prefill_steps += 1
            else:
                logits, cache = self._decode(self.params, cache, nxt[:, None])
                self.decode_steps += 1
            self.key, k = jax.random.split(self.key)
            nxt = sample(logits, k, self.temperature)
            self.sample_steps += 1
            nxt_np = np.asarray(nxt)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                tok = int(nxt_np[i])
                s["gen"].append(tok)
                s["hist"].append(tok)
                s["left"] -= 1
                if s["left"] == 0:
                    s["req"].out = np.asarray(s["gen"], np.int32)
                    done.append(s["req"])
                    slots[i] = None   # freed: admitted from queue next step
        return done


def pow2_buckets(max_batch: int) -> Tuple[int, ...]:
    """1, 2, 4, ... up to (and including) max_batch."""
    if max_batch < 1:
        raise ValueError("max_batch must be >= 1")
    out = [1]
    while out[-1] < max_batch:
        out.append(min(out[-1] * 2, max_batch))
    return tuple(sorted(set(out)))


def shard_aligned_buckets(buckets: Sequence[int], n_shards: int
                          ) -> Tuple[int, ...]:
    """Round every bucket up to a multiple of the data-shard count (so each
    device owns an equal sub-batch) and dedupe.  n_shards=1 is identity."""
    if n_shards <= 1:
        return tuple(sorted(set(int(b) for b in buckets)))
    up = lambda b: -(-int(b) // n_shards) * n_shards
    return tuple(sorted({up(b) for b in buckets}))


class DcnnServeEngine:
    """The paper's inference workload: batched image generation, served
    through compile-once batch buckets.

    * **Bucketing** — request batches are decomposed by a cost-aware
      chunk plan (`plan_chunks`: padded rows vs per-call overhead), so a
      mixed-size request stream compiles at most ``len(buckets)``
      generator executables — never one per batch shape.
    * **Per-bucket tiles** — for the pallas backends each bucket's tile
      assignment is resolved against that bucket's batch size, letting the
      autotuner pick the batch tile ``t_n`` jointly with the spatial and
      channel tiles (MXU row fill + weight amortization).  Executables are
      built lazily on first use, or eagerly with ``warmup=True`` (which
      also runs one zero-batch through each to pay compile + first-dma
      cost before traffic arrives).
    * **Donated inputs** — on TPU the z buffer is donated to the compiled
      generator, so steady-state serving does not hold two copies of the
      input batch (no-op on CPU, where donation is unimplemented).
    * **Micro-batching queue** — ``submit`` enqueues request rows;
      ``drain`` coalesces everything pending into one generate() over the
      largest fitting buckets; ``collect`` returns a request's images
      (draining on demand).

    * **Mesh sharding** — with ``mesh=`` each bucket's batch is sharded
      along the data axis (`dist.sharding` rules): params are replicated
      via `tree_shardings`, the z batch splits per `batch_pspec`, buckets
      are rounded up to multiples of the device count so every device owns
      an equal sub-batch, and the autotuner resolves tiles (incl. ``t_n``)
      against the *per-device* sub-batch geometry.  ``stats`` /
      ``throughput()`` then report per-device rates.

    * **Quantized serving** — ``precision="int8"`` quantizes the params
      once at construction (self-calibrating on the z ~ N(0,1) serving
      distribution unless a pre-computed ``quant_cfg`` is given) and
      serves every bucket through the int8 batch-fused kernel chain:
      int32 accumulation, fused requant epilogue, activations int8 in
      HBM between layers.  Tiles are autotuned at the int8 dtype (v3
      cache), and the mesh path replicates the quantized tree exactly
      like fp32 params.

    * **Plan/execute** — every bucket serves a pinned `plan.NetworkPlan`
      (tiles, epilogues, quant scales, zero-skip schedules resolved ONCE
      at plan-build time; ``plan_stats`` counts builds and their wall
      clock).  `from_config` accepts a pre-built/deserialized plan so a
      deployment executes exactly the configuration it validated.

    * **Fault tolerance** — every bucket dispatch runs guarded: an
      optional `dist.inject.FaultInjector` hook fires scripted faults, a
      transient call failure retries with bounded exponential backoff
      (then fails typed as `EngineDegraded`), an optional
      `dist.fault.Heartbeat` armed around the call records stalls, and a
      per-bucket `StragglerMonitor` flags steady-state calls slower than
      ``straggler_factor`` x their EMA.  A detected **device loss**
      triggers elastic recovery (`_remesh`): shrink onto the surviving
      prefix via `dist.fault.elastic_mesh`, re-align buckets to the new
      device count, `reshard_tree` the replicated params, re-plan every
      bucket (autotune cache hits via plan hashes keep this fast) and
      ASSERT via `plan.executable_fingerprints` that every per-device
      batch re-derived the validated plan hash — then re-run the
      interrupted chunk and keep serving.  `submit` takes a per-request
      deadline; an expired ticket fails typed (`DeadlineExceeded`) at
      drain instead of executing stale work, and a drain whose
      generate() fails restores every ticket to the queue.  All of it is
      observable through ``fault_stats``.

    ``trace_counts`` maps bucket -> number of times its generator was
    traced (== compiled); tests pin the no-per-request-recompilation
    guarantee on it."""

    def __init__(self, cfg: DcnnConfig, params, backend: str = "pallas",
                 autotune: bool = True, refine: bool = False,
                 max_batch: int = 64,
                 buckets: Optional[Sequence[int]] = None,
                 warmup: bool = False, donate: bool = True,
                 mesh=None, rules=None, call_overhead_rows: int = 8,
                 precision: str = "fp32", quant_cfg=None,
                 calib_batch: int = 64, calib_seed: int = 0,
                 calib_strategy: str = "mean_ksigma"):
        # deprecation shim (one release): the kwarg sprawl folds into an
        # EngineConfig and routes through the plan-driven setup
        warnings.warn(
            "DcnnServeEngine(cfg, params, **kwargs) is deprecated: build a "
            "serve.EngineConfig and use DcnnServeEngine.from_config(config, "
            "params, plan=...)", DeprecationWarning, stacklevel=2)
        config = EngineConfig(
            model=cfg, backend=backend, precision=precision,
            quant_cfg=quant_cfg, mesh=mesh, rules=rules, autotune=autotune,
            refine=refine, max_batch=max_batch,
            buckets=None if buckets is None else tuple(buckets),
            warmup=warmup, donate=donate,
            call_overhead_rows=call_overhead_rows, calib_batch=calib_batch,
            calib_seed=calib_seed, calib_strategy=calib_strategy)
        self._setup(config, params, None)

    @classmethod
    def from_config(cls, cfg: EngineConfig, params, plan=None,
                    fault_injector=None, metrics=None) -> "DcnnServeEngine":
        """The plan/execute constructor: ``cfg`` is a `serve.EngineConfig`
        and ``plan`` an optional pinned `plan.NetworkPlan` (e.g. loaded
        from JSON) for the bucket whose per-device batch matches
        ``plan.batch`` — remaining buckets plan themselves on first use.
        An int8 plan also supplies the calibration when ``cfg.quant_cfg``
        is None, so a pinned deployment never re-calibrates.
        ``fault_injector`` is an optional `dist.inject.FaultInjector`
        hooked before every bucket dispatch (deterministic fault drills;
        never needed in production).  ``metrics`` is an optional shared
        `obs.MetricsRegistry` — the async frontend passes one registry to
        every per-precision engine so the deployment's series land in one
        place; without it the engine makes its own."""
        self = cls.__new__(cls)
        self._setup(cfg, params, plan, fault_injector, metrics)
        return self

    def _setup(self, config: EngineConfig, params, plan,
               fault_injector=None, metrics=None) -> None:
        from ..workloads import resolve_model, workload_name_for

        # a string model is a registry lookup (typed UnknownWorkloadError
        # on a typo — never a silent fallback); a DcnnConfig passes through
        cfg = resolve_model(config.model)
        self.config = config
        self.cfg = cfg
        self.workload = workload_name_for(cfg)
        self.backend = config.backend
        # chunk-planning knob: one kernel dispatch is costed like computing
        # this many extra rows (trades padded-row waste against call count)
        self.call_overhead_rows = config.call_overhead_rows
        if config.precision not in ("fp32", "int8"):
            raise ValueError(f"unknown precision {config.precision!r}; "
                             "expected 'fp32' or 'int8'")
        if config.precision == "int8" and config.backend != "pallas":
            raise ValueError(
                "precision='int8' runs the dense int8 Pallas kernel; "
                f"backend={config.backend!r} has no quantized variant")
        self.precision = config.precision
        self.quant_cfg = config.quant_cfg
        if plan is not None:
            if (plan.backend, plan.precision) != (self.backend,
                                                  self.precision):
                raise ValueError(
                    f"plan was built for backend={plan.backend!r} / "
                    f"precision={plan.precision!r}; the engine config says "
                    f"{self.backend!r} / {self.precision!r}")
            plan.validate_for(cfg)
            # a stale zero-skip schedule (plan pinned, checkpoint since
            # re-pruned) would silently skip nonzero blocks; params are
            # still concrete here, so this is the place to catch it
            plan.verify_sparse_tables(params)
            if self.precision == "int8":
                if self.quant_cfg is None:
                    # serve exactly the calibration the plan pinned
                    self.quant_cfg = plan.quant_config()
                elif plan.quant_config() != self.quant_cfg:
                    # the params would be quantized with one scale set
                    # while the plan's pinned requant epilogues use
                    # another — silently wrong images; fail loudly
                    raise ValueError(
                        "EngineConfig.quant_cfg and the pinned plan carry "
                        "different calibrations; drop one of them (the "
                        "plan's scales are authoritative for its "
                        "executables)")
        if self.precision == "int8":
            from ..quant.calibrate import calibrate, quantize_params
            from ..workloads import calibration_input
            if self.quant_cfg is None:
                # self-calibrate on the serving input distribution — a
                # fixed-seed batch (z ~ N(0,1) latents, or the registered
                # workload's synthesized inputs for image-rooted towers)
                # through the fp32 reference chain, observed by the
                # chosen strategy.  Same (seed, batch) routing as
                # build_network_plan, so scales agree with pinned plans.
                z_cal = calibration_input(cfg, seed=config.calib_seed,
                                          batch=config.calib_batch)
                self.quant_cfg = calibrate(params, cfg, z_cal,
                                           strategy=config.calib_strategy)
            params = quantize_params(params, cfg, self.quant_cfg)
        mesh = config.mesh
        self.mesh = mesh
        if mesh is not None:
            from ..dist.sharding import (data_axis_size, make_rules,
                                         replicated_specs, tree_shardings)
            self.rules = (config.rules if config.rules is not None
                          else make_rules("tp"))
            self.n_devices = data_axis_size(mesh, self.rules)
            # params live replicated on the mesh from the start: steady-state
            # serving never re-transfers them per call
            self._param_shardings = tree_shardings(
                mesh, self.rules, params, replicated_specs(params))
            params = jax.device_put(params, self._param_shardings)
        else:
            self.rules = config.rules
            self.n_devices = 1
            self._param_shardings = None
        self.params = params
        self.buckets = shard_aligned_buckets(
            config.buckets if config.buckets else
            pow2_buckets(config.max_batch), self.n_devices)
        if self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive: {self.buckets}")
        self.max_bucket = self.buckets[-1]
        self._autotune = config.autotune
        self._refine = config.refine
        # donation is a TPU win (steady-state z buffers are reused); on CPU
        # jax warns that donation is unimplemented, so gate on the backend
        self._donate = config.donate and jax.default_backend() == "tpu"
        # typed observability: every legacy dict below (stats, bucket_stats,
        # plan_stats, fault_stats) keeps its exact shape for existing
        # callers AND dual-writes the shared registry at the same sites,
        # labeled (net, precision[, bucket]) so one registry can hold a
        # whole multi-engine deployment.  Spans go to the process tracer
        # (no-ops unless obs.trace.enable() ran).
        self.metrics = (metrics if metrics is not None
                        else obsmetrics.MetricsRegistry())
        self._tracer = obstrace.get_tracer()
        self._mlabels = {"net": cfg.name, "workload": self.workload,
                         "precision": self.precision}
        self._m_dispatch = self.metrics.histogram(
            "engine.dispatch_seconds",
            "healthy steady-state dispatch wall clock (Table II samples)")
        self._m_plan_build = self.metrics.histogram(
            "engine.plan_build_seconds", "NetworkPlan build wall clock")
        self._m_tainted = self.metrics.counter(
            "engine.tainted_calls",
            "steady dispatches excluded from Table II (transient retries)")
        self._m_fault = self.metrics.counter(
            "engine.fault_events", "fault-path events by kind (label: event)")
        self._m_generate_calls = self.metrics.counter(
            "engine.generate_calls", "generate() invocations")
        self._m_images = self.metrics.counter(
            "engine.images", "useful (unpadded) images generated")
        self._m_padded = self.metrics.counter(
            "engine.padded_images", "padded rows burned on bucket alignment")
        self._m_devices = self.metrics.gauge(
            "engine.device_count", "devices serving this engine")
        self._m_devices.set(self.n_devices, **self._mlabels)
        self._fns: Dict[int, Callable] = {}
        self.plans: Dict[int, object] = {}
        self.tile_choices: Dict[int, Optional[dict]] = {}
        self.trace_counts: Dict[int, int] = {}
        self._sparse_plan_memo: Dict[tuple, tuple] = {}
        # queue entries are (ticket, rows, absolute deadline or None).
        # _qlock guards the queue state (submit/collect/shed may run from
        # concurrent caller threads under the async frontend); _drain_lock
        # serializes drains so two threads never run generate() on the
        # same engine at once; _inflight names tickets a drain has taken
        # off the queue but not yet resolved, so a concurrent collect
        # waits for that drain instead of misreporting "already
        # collected".
        self._qlock = threading.Lock()
        self._drain_lock = threading.Lock()
        self._inflight: Set[int] = set()
        self._pending: List[Tuple[int, np.ndarray, Optional[float]]] = []
        self._results: Dict[int, np.ndarray] = {}
        self._failures: Dict[int, Exception] = {}
        self._next_id = 0
        self.stats = {"generate_calls": 0, "images": 0, "padded_images": 0,
                      "device_count": self.n_devices}
        # fault-tolerance machinery: injector hook, per-bucket straggler
        # monitors over the steady-state call timings, optional stall
        # heartbeat, and the observable event counters the bench reports
        self.fault_injector = fault_injector
        self._stragglers: Dict[int, StragglerMonitor] = {}
        self._dispatches = 0
        self.fault_stats = {
            "retries": 0, "transient_failures": 0, "stragglers": 0,
            "heartbeat_fires": 0, "deadline_expired": 0, "shed": 0,
            "remesh_events": [],
        }
        self._heartbeat = None
        if config.heartbeat_timeout_s is not None:
            self._heartbeat = Heartbeat(config.heartbeat_timeout_s,
                                        self._on_stall)
            self._heartbeat.disarm()   # armed per dispatched call only
        # plan-build observability: serving must pay planning once per
        # bucket, never per call (bench pins this)
        self.plan_stats = {"builds": 0, "build_seconds": 0.0}
        if plan is not None:
            # static DRC before anything compiles: a pinned plan that
            # drifted from the code (stale tiles, broken requant chain,
            # foreign mesh) is rejected here with the rule-by-rule
            # report, not discovered as a mid-serve crash.  Weight-digest
            # checking already happened via verify_sparse_tables above.
            from ..analysis.check.plan_drc import check_network_plan
            check_network_plan(
                plan, n_devices=self.n_devices,
                buckets=self.buckets).raise_if_failed()
            seeded = [b for b in self.buckets
                      if self.shard_batch(b) == plan.batch]
            if not seeded:
                raise ValueError(
                    f"plan.batch={plan.batch} matches no bucket's "
                    f"per-device batch (buckets={self.buckets}, "
                    f"{self.n_devices} devices)")
            for b in seeded:
                self.plans[b] = plan
        # per-bucket serving observability: wall-clock + image counters so
        # the engine *learns* throughput (global and per-device) per bucket
        self.bucket_stats: Dict[int, Dict[str, float]] = {}
        if config.warmup:
            for b in self.buckets:
                self._warmup_bucket(b)

    # -- per-bucket executable construction ----------------------------
    def shard_batch(self, bucket: int) -> int:
        """The batch one device actually runs for a bucket (== the bucket
        on a single device); tile choices are fitted to this, not to the
        global bucket."""
        return bucket // self.n_devices

    def _plan_for(self, bucket: int):
        """The bucket's pinned `NetworkPlan`, built on first use.

        Planning — autotune cache interaction, quant-scale wiring,
        zero-skip schedule construction (memoized across buckets sharing
        channel tiles) — happens exactly once per bucket; `generate`
        executes the pinned plan with zero per-call re-planning."""
        if bucket not in self.plans:
            from ..plan import build_network_plan

            t0 = obsclock.now()
            self.plans[bucket] = build_network_plan(
                self.cfg,
                batch=self.shard_batch(bucket),
                backend=self.backend,
                precision=self.precision,
                params=self.params,
                quant_cfg=self.quant_cfg,
                autotune=self._autotune,
                refine=self._refine,
                sparse_table_cache=self._sparse_plan_memo,
            )
            dt = obsclock.now() - t0
            self.plan_stats["builds"] += 1
            self.plan_stats["build_seconds"] += dt
            self._m_plan_build.observe(dt, bucket=bucket, **self._mlabels)
            self._tracer.complete(f"plan_build b{bucket}", t0, t0 + dt,
                                  cat="engine", bucket=bucket,
                                  **self._mlabels)
        return self.plans[bucket]

    def _get_fn(self, bucket: int) -> Callable:
        if bucket not in self._fns:
            plan = self._plan_for(bucket)
            self.tile_choices[bucket] = plan.tile_overrides()

            if self.precision == "int8":
                from ..quant.infer import quantized_generator_apply

                def apply(p, z, _plan=plan):
                    return quantized_generator_apply(
                        p, self.cfg, self.quant_cfg, z, plan=_plan)
            else:
                def apply(p, z, _plan=plan):
                    return generator_apply(p, self.cfg, z, plan=_plan)

            if self.mesh is not None:
                # SPMD: every device runs the same per-shard executable on
                # its bucket/n_devices rows (the tiles above were fitted to
                # exactly that sub-batch).  check_rep=False: pallas_call has
                # no replication rule.
                from jax.experimental.shard_map import shard_map
                from jax.sharding import NamedSharding, PartitionSpec as P

                from ..dist.sharding import batch_pspec

                baxes = self.rules.get("batch", "data")
                apply = shard_map(apply, mesh=self.mesh,
                                  in_specs=(P(), P(baxes)),
                                  out_specs=P(baxes), check_rep=False)
                z_sh = NamedSharding(
                    self.mesh, batch_pspec(self.mesh, self.rules, bucket, 2))
                img_sh = NamedSharding(
                    self.mesh, batch_pspec(self.mesh, self.rules, bucket, 4))
                shardings = dict(
                    in_shardings=(self._param_shardings, z_sh),
                    out_shardings=img_sh)
            else:
                shardings = {}

            def fn(p, z, _b=bucket, _apply=apply):
                # tracing happens exactly once per compilation: the counter
                # is the no-per-request-recompilation acceptance probe
                self.trace_counts[_b] = self.trace_counts.get(_b, 0) + 1
                return _apply(p, z)

            self._fns[bucket] = jax.jit(
                fn, **shardings,
                **(dict(donate_argnums=(1,)) if self._donate else {}))
        return self._fns[bucket]

    def _warmup_bucket(self, bucket: int) -> None:
        fn = self._get_fn(bucket)
        z = jnp.zeros((bucket,) + self.cfg.input_shape, self.cfg.jdtype)
        jax.block_until_ready(fn(self.params, z))

    # -- guarded dispatch + elastic recovery ---------------------------
    def _on_stall(self) -> None:
        # heartbeat callback: a dispatched call has been silent past the
        # configured timeout.  Record it (the Heartbeat catches callback
        # errors, but there is nothing to raise into — the stalled call
        # owns the thread).  This runs on the watcher thread, so the
        # counter bump takes _qlock like every other fault_stats write.
        with self._qlock:
            self.fault_stats["heartbeat_fires"] += 1
        self._m_fault.inc(event="heartbeat_fires", **self._mlabels)
        self._tracer.instant("heartbeat_fire", cat="fault", **self._mlabels)

    def close(self) -> None:
        """Release the stall-watcher thread (no-op without a heartbeat)."""
        if self._heartbeat is not None:
            self._heartbeat.close()

    def _dispatch(self, bucket: int, chunk: np.ndarray):
        """One guarded bucket dispatch: injector hook, heartbeat armed
        around the call, bounded retry-with-backoff on transient
        failures, straggler detection on the steady-state wall clock.

        Returns ``(images, seconds, steady, retried)`` where ``steady``
        means the call did not trace (compile) and ``retried`` means at
        least one transient-failure retry preceded the success — only
        steady samples feed the timing stats and the straggler EMA, and
        retried ones are tagged so they never mix into the healthy
        run-to-run CV samples (Table II accounting).  `TransientCallError`
        is retried up to ``max_retries`` times then raised as
        `EngineDegraded`; `DeviceLossError` escapes to `generate`, which
        remeshes."""
        fn = self._get_fn(bucket)
        attempts = self.config.max_retries + 1
        for attempt in range(attempts):
            if self._heartbeat is not None:
                self._heartbeat.arm()
            try:
                traces_before = self.trace_counts.get(bucket, 0)
                # the injector hook sits inside the timed window: an
                # injected SlowCall is a slow *dispatch*, visible to the
                # straggler monitor exactly like a real one
                t0 = obsclock.now()
                if self.fault_injector is not None:
                    self.fault_injector.before_call(bucket)
                y = np.asarray(fn(self.params, jnp.asarray(chunk)))
                dt = obsclock.now() - t0
            except TransientCallError as e:
                with self._qlock:
                    self.fault_stats["transient_failures"] += 1
                self._m_fault.inc(event="transient_failures", **self._mlabels)
                self._tracer.instant("transient_failure", cat="fault",
                                     bucket=bucket, attempt=attempt,
                                     **self._mlabels)
                if attempt + 1 >= attempts:
                    raise EngineDegraded(
                        f"bucket-{bucket} call failed {attempts} "
                        "time(s); retries exhausted") from e
                with self._qlock:
                    self.fault_stats["retries"] += 1
                self._m_fault.inc(event="retries", **self._mlabels)
                self._tracer.instant("retry", cat="fault", bucket=bucket,
                                     attempt=attempt, **self._mlabels)
                time.sleep(self.config.retry_backoff_s * (2 ** attempt))
                continue
            finally:
                if self._heartbeat is not None:
                    self._heartbeat.disarm()
            self._dispatches += 1
            steady = self.trace_counts.get(bucket, 0) == traces_before
            retried = attempt > 0
            if steady and not retried:
                # a dispatch that needed retries is not a healthy sample:
                # it must not seed the straggler baseline either
                mon = self._stragglers.setdefault(
                    bucket, StragglerMonitor(
                        factor=self.config.straggler_factor,
                        warmup_steps=self.config.straggler_warmup))
                if mon.observe(self._dispatches, dt):
                    with self._qlock:
                        self.fault_stats["stragglers"] += 1
                    self._m_fault.inc(event="stragglers", **self._mlabels)
                    self._tracer.instant("straggler", cat="fault",
                                         bucket=bucket, seconds=dt,
                                         **self._mlabels)
            self._tracer.complete(f"dispatch b{bucket}", t0, t0 + dt,
                                  cat="engine", bucket=bucket, steady=steady,
                                  retried=retried, **self._mlabels)
            return y, dt, steady, retried

    def _remesh(self, keep: int) -> None:
        """Elastic recovery from device loss: shrink onto the surviving
        ``keep``-device prefix, re-align the bucket set to the new
        device count, reshard the (replicated) params, and re-plan every
        bucket — recording `plan.executable_fingerprints` before/after
        so "same plan for the same per-device batch" is ASSERTED, not
        assumed.  A hash mismatch means the rebuilt executables are not
        the ones that were validated, and the engine refuses to serve
        them."""
        if self.mesh is None or not self.config.elastic:
            raise EngineDegraded(
                "device loss without an elastic mesh: nothing to shrink "
                "onto (serve with mesh=... and elastic=True)")
        from ..dist.fault import elastic_mesh, reshard_tree
        from ..dist.sharding import (data_axis_size, replicated_specs,
                                     tree_shardings)
        from ..plan import executable_fingerprints

        t0 = obsclock.now()
        devs = list(self.mesh.devices.flat)
        if not 1 <= keep <= len(devs):
            raise EngineDegraded(
                f"cannot remesh: {keep} survivor(s) of {len(devs)} "
                "device(s)")
        before = executable_fingerprints(self.plans.values())
        devices_before = self.n_devices
        self.mesh = elastic_mesh(
            devs[:keep], model_parallel=self.mesh.shape.get("model", 1))
        self.n_devices = data_axis_size(self.mesh, self.rules)
        self._param_shardings = tree_shardings(
            self.mesh, self.rules, self.params,
            replicated_specs(self.params))
        self.params = reshard_tree(self.params, self._param_shardings)
        self.buckets = shard_aligned_buckets(
            self.config.buckets if self.config.buckets
            else pow2_buckets(self.config.max_batch), self.n_devices)
        self.max_bucket = self.buckets[-1]
        # stale executables/plans/tiles were fitted to the old device
        # count; re-plan everything up front (recovery pays it once)
        self._fns.clear()
        self.tile_choices.clear()
        self._stragglers.clear()
        self.plans = {}
        for b in self.buckets:
            self._plan_for(b)
        after = executable_fingerprints(self.plans.values())
        matches = {sb: after[sb] == h for sb, h in before.items()
                   if sb in after}
        self.stats["device_count"] = self.n_devices
        # timing samples from the pre-loss mesh describe a capacity that
        # no longer exists: mixing them into post-loss rates/CV would
        # report a throughput nobody can have.  Snapshot them into the
        # remesh event (observability) and start the accounting fresh.
        stats_before = {b: dict(s) for b, s in self.bucket_stats.items()}
        self.bucket_stats = {}
        event = {
            "bucket_stats_before": stats_before,
            "devices_before": devices_before,
            "devices_after": self.n_devices,
            "buckets": list(self.buckets),
            "plan_hashes_before": before,
            "plan_hashes_after": after,
            "plan_hash_matches": matches,
            "seconds": obsclock.now() - t0,
        }
        with self._qlock:
            self.fault_stats["remesh_events"].append(event)
        self._m_fault.inc(event="remesh_events", **self._mlabels)
        self._m_devices.set(self.n_devices, **self._mlabels)
        self._tracer.instant("remesh", cat="fault",
                             devices_before=devices_before,
                             devices_after=self.n_devices,
                             seconds=event["seconds"], **self._mlabels)
        if not all(matches.values()):
            raise EngineDegraded(
                f"post-remesh plan hash mismatch {matches}: the "
                "shrunken mesh did not re-derive the validated "
                "executables")

    def bucket_for(self, n: int) -> int:
        """Smallest bucket covering n requests (largest bucket if n exceeds
        them all — the caller then chunks)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_bucket

    def plan_chunks(self, n: int) -> List[Tuple[int, int]]:
        """Chunk plan for an n-row batch: ``[(take, bucket), ...]`` with
        ``sum(take) == n``.

        Full max-bucket chunks are sliced first; the sub-max tail is then
        planned *cost-aware*: at each level the smallest covering bucket
        (one padded call) competes with slicing the largest exact-fitting
        bucket and recursing, costed as computed rows plus
        ``call_overhead_rows`` per kernel dispatch.  So a 36-row tail at
        buckets 1..64 runs 32+4 (the pre-fix loop ran one 64-row call —
        28 padded rows), while a 63-row tail stays one padded 64-call
        instead of fragmenting into six row-starved small-bucket calls."""
        if n < 0:
            raise ValueError(f"negative batch: {n}")
        plan: List[Tuple[int, int]] = []
        remaining = n
        while remaining >= self.max_bucket:
            plan.append((self.max_bucket, self.max_bucket))
            remaining -= self.max_bucket
        plan.extend(self._plan_tail(remaining))
        return plan

    def _plan_cost(self, plan: List[Tuple[int, int]]) -> int:
        return sum(b for _, b in plan) + self.call_overhead_rows * len(plan)

    def _plan_tail(self, r: int) -> List[Tuple[int, int]]:
        """Cost-aware plan for a tail below the largest bucket (recursion
        depth is bounded by len(buckets): each slice at least halves what
        the remaining buckets can cover)."""
        if r == 0:
            return []
        cover = self.bucket_for(r)
        best = [(r, cover)] if cover >= r else None
        fit = [b for b in self.buckets if b <= r]
        if fit:
            b = max(fit)
            cand = [(b, b)] + self._plan_tail(r - b)
            if best is None or self._plan_cost(cand) < self._plan_cost(best):
                best = cand
        assert best is not None, (r, self.buckets)
        return best

    # -- synchronous path ----------------------------------------------
    def generate(self, z: np.ndarray) -> np.ndarray:
        """z: (B, z_dim) for ANY B: chunked/padded to the bucket set via
        `plan_chunks`, so no batch size ever triggers a recompile.

        Fault path: a transient dispatch failure retries inside
        `_dispatch`; a detected device loss remeshes onto the survivors
        (`_remesh`), then the interrupted chunk — plus everything still
        queued behind it — re-plans against the post-loss bucket set and
        re-runs, so the call completes on the shrunken mesh instead of
        raising."""
        z = np.asarray(z, dtype=self.cfg.dtype)
        n = z.shape[0]
        t_gen = obsclock.now()
        outs: List[np.ndarray] = []
        i = 0
        chunks = self.plan_chunks(n)
        while chunks:
            take, bucket = chunks[0]
            chunk = z[i:i + take]
            pad = bucket - take
            if pad:
                chunk = np.concatenate(
                    [chunk, np.zeros((pad,) + z.shape[1:], z.dtype)],
                    axis=0)
            try:
                y, dt, steady, retried = self._dispatch(bucket, chunk)
            except DeviceLossError as e:
                self._remesh(e.keep)
                chunks = self.plan_chunks(n - i)
                continue
            chunks.pop(0)
            if pad:
                self.stats["padded_images"] += pad
                self._m_padded.inc(pad, **self._mlabels)
            if steady:
                # steady-state call: a call that traced (compiled) would
                # poison the learned rates by orders of magnitude
                bs = self.bucket_stats.setdefault(
                    bucket, {"calls": 0, "images": 0, "seconds": 0.0,
                             "sumsq_seconds": 0.0, "tainted_calls": 0,
                             "tainted_seconds": 0.0})
                if retried:
                    # outcome-tagged: a dispatch that needed transient
                    # retries is real work but not a healthy run — its
                    # wall clock stays out of the Table II mean/std/CV
                    # samples (which are *run-to-run variation of the
                    # healthy path*, the paper's predictability claim)
                    bs["tainted_calls"] += 1
                    bs["tainted_seconds"] += dt
                    self._m_tainted.inc(bucket=bucket, **self._mlabels)
                else:
                    bs["calls"] += 1
                    bs["images"] += take
                    # running first/second moments of the per-call wall
                    # clock (the paper's Table II mean/std methodology)
                    # — O(1) state, not a per-call sample list a
                    # long-lived engine would grow without bound
                    bs["seconds"] += dt
                    bs["sumsq_seconds"] += dt * dt
                    self._m_dispatch.observe(dt, bucket=bucket,
                                             **self._mlabels)
            outs.append(y[:take])
            i += take
        self.stats["generate_calls"] += 1
        self.stats["images"] += n
        self._m_generate_calls.inc(**self._mlabels)
        self._m_images.inc(n, **self._mlabels)
        self._tracer.complete("generate", t_gen, obsclock.now(),
                              cat="engine", rows=n, **self._mlabels)
        return (np.concatenate(outs, axis=0) if len(outs) != 1
                else outs[0])

    def throughput(self) -> Dict[int, Dict[str, float]]:
        """Learned per-bucket *steady-state* serving rates (compiling
        calls are excluded from the timers): useful images/s overall and
        per device (the mesh analogue of the paper's per-PE utilization),
        plus run-to-run variation — mean, std and CV (std/mean) of the
        per-call wall clock over repeated calls, the paper's Table II
        methodology already used by `benchmarks.common.time_fn`.

        Samples are outcome-tagged: only *healthy* dispatches (no
        transient-failure retries, same mesh) feed the mean/std/CV;
        retried dispatches surface as ``tainted_calls`` /
        ``tainted_seconds`` alongside, and a device-loss remesh resets
        the accounting entirely (the pre-loss snapshot lives in the
        remesh event)."""
        out = {}
        for bucket, bs in self.bucket_stats.items():
            if bs["seconds"] <= 0.0:
                continue
            rate = bs["images"] / bs["seconds"]
            mean_s = bs["seconds"] / bs["calls"]
            var = max(0.0, bs["sumsq_seconds"] / bs["calls"] - mean_s ** 2)
            std_s = var ** 0.5
            out[bucket] = {
                "img_per_s": rate,
                "img_per_s_per_device": rate / self.n_devices,
                "calls": bs["calls"],
                "mean_s": mean_s,
                "std_s": std_s,
                "cv": std_s / max(mean_s, 1e-12),
                "tainted_calls": bs.get("tainted_calls", 0),
                "tainted_seconds": bs.get("tainted_seconds", 0.0),
            }
        return out

    def service_estimate(self, bucket: int) -> Optional[float]:
        """Best current estimate of one steady dispatch's wall clock for
        ``bucket``: the per-bucket `StragglerMonitor` EMA when it has
        observations (tracks drift, ignores outliers), else the healthy
        mean from ``bucket_stats``, else None (no data yet).  This is the
        capacity signal the SLO frontend's admission control and
        deadline-aware scheduler run on."""
        mon = self._stragglers.get(bucket)
        if mon is not None and mon.estimate() is not None:
            return mon.estimate()
        bs = self.bucket_stats.get(bucket)
        if bs and bs["calls"] > 0:
            return bs["seconds"] / bs["calls"]
        return None

    # -- micro-batching queue --------------------------------------------
    def submit(self, z: np.ndarray,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue a request of one or more z rows; returns a ticket id.

        ``deadline_s`` (default: `EngineConfig.default_deadline_s`)
        bounds how long the ticket may wait in the queue: a drain that
        reaches it past the deadline fails it with `DeadlineExceeded`
        instead of executing stale work (`collect` raises the typed
        error).  Thread-safe: concurrent submitters get distinct
        tickets."""
        z = np.asarray(z, dtype=self.cfg.dtype)
        if z.ndim == len(self.cfg.input_shape):
            z = z[None]
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        deadline = (None if deadline_s is None
                    else obsclock.now() + deadline_s)
        with self._qlock:
            rid = self._next_id
            self._next_id += 1
            self._pending.append((rid, z, deadline))
        return rid

    def shed(self, rid: int, reason: str = "") -> bool:
        """Remove a still-pending ticket from the queue and fail it typed
        (`AdmissionRejected`) — the backpressure lever: load-shedding a
        ticket that will not make its deadline must resolve it, never
        silently drop it (a dropped ticket is a caller blocked forever).
        Returns False if the ticket is no longer pending (already
        draining, resolved, or never issued)."""
        with self._qlock:
            for i, (t, _, _) in enumerate(self._pending):
                if t == rid:
                    del self._pending[i]
                    self.fault_stats["shed"] += 1
                    self._failures[rid] = AdmissionRejected(
                        reason or f"ticket {rid} shed before execution",
                        stage="shed")
                    self._m_fault.inc(event="shed", **self._mlabels)
                    self._tracer.instant("shed", cat="fault", rid=rid,
                                         **self._mlabels)
                    return True
        return False

    def drain(self) -> None:
        """Run everything pending as one coalesced stream: all queued rows
        are concatenated and generated through the cost-aware
        `plan_chunks`, so ten 3-image requests run as a few large-bucket
        calls, not ten bucket-4 calls.

        Failure semantics: a ticket whose deadline already passed fails
        typed (`DeadlineExceeded`, raised at `collect`) without being
        executed, and if the coalesced generate() itself fails, every
        drained ticket is RESTORED to the queue before the error
        propagates — a fault mid-generate must not silently drop the
        queue (the pre-fix behavior lost every queued request).

        Thread-safe: drains are serialized (two threads never run
        generate() on one engine concurrently) and in-flight tickets are
        tracked so a concurrent `collect` waits for the owning drain
        instead of misreporting the ticket as already collected."""
        with self._drain_lock:
            self._drain_locked()

    def _drain_locked(self) -> None:
        with self._qlock:
            if not self._pending:
                return
            reqs, self._pending = self._pending, []
            live = []
            now = obsclock.now()
            for rid, z, deadline in reqs:
                if deadline is not None and now > deadline:
                    self.fault_stats["deadline_expired"] += 1
                    self._failures[rid] = DeadlineExceeded(
                        f"ticket {rid} missed its deadline by "
                        f"{now - deadline:.3f}s before execution")
                    self._m_fault.inc(event="deadline_expired",
                                      **self._mlabels)
                    self._tracer.instant("deadline_expired", cat="fault",
                                         rid=rid, **self._mlabels)
                else:
                    live.append((rid, z, deadline))
                    self._inflight.add(rid)
        if not live:
            return
        rows = np.concatenate([z for _, z, _ in live], axis=0)
        try:
            imgs = self.generate(rows)
        except Exception:
            with self._qlock:
                self._pending = live + self._pending
                self._inflight.difference_update(r for r, _, _ in live)
            raise
        with self._qlock:
            ofs = 0
            for rid, z, _ in live:
                self._results[rid] = imgs[ofs:ofs + len(z)]
                ofs += len(z)
                self._inflight.discard(rid)

    def collect(self, rid: int,
                timeout_s: Optional[float] = None) -> np.ndarray:
        """Images for ticket ``rid`` (drains the queue if still pending).

        Raises the ticket's typed failure (e.g. `DeadlineExceeded`,
        `AdmissionRejected`) if it failed, and a KeyError that
        distinguishes a ticket this engine never issued from one whose
        result was already handed out.

        ``timeout_s`` bounds the wait end-to-end: a ticket that cannot
        resolve in time — another thread's drain still owns it, or its
        dispatch was shed / lost mid-remesh and nothing will ever
        deliver it — raises `DeadlineExceeded` at expiry instead of
        blocking forever (the pre-fix behavior for a vanished ticket was
        an unbounded wait under concurrent draining)."""
        deadline = (None if timeout_s is None
                    else obsclock.now() + timeout_s)

        def expired() -> bool:
            return deadline is not None and obsclock.now() >= deadline

        while True:
            with self._qlock:
                if rid in self._failures:
                    raise self._failures.pop(rid)
                if rid in self._results:
                    return self._results.pop(rid)
                pending = any(t == rid for t, _, _ in self._pending)
                inflight = rid in self._inflight
                issued = 0 <= rid < self._next_id
            if not issued:
                raise KeyError(f"unknown ticket {rid}: this engine never "
                               "issued it")
            if pending:
                # drive the queue ourselves; honor the timeout while
                # waiting for another thread's drain to release the lock
                if deadline is None:
                    self.drain()
                    continue
                remaining = deadline - obsclock.now()
                if remaining <= 0 or not self._drain_lock.acquire(
                        timeout=remaining):
                    raise DeadlineExceeded(
                        f"ticket {rid} still pending after "
                        f"{timeout_s:.3f}s (queue busy)")
                try:
                    self._drain_locked()
                finally:
                    self._drain_lock.release()
                continue
            if inflight:
                # another thread's drain owns it: it will resolve (or be
                # restored to pending) when that drain finishes
                if expired():
                    raise DeadlineExceeded(
                        f"ticket {rid} still in flight after "
                        f"{timeout_s:.3f}s")
                time.sleep(0.001)
                continue
            # issued, but neither pending, in flight, nor resolved
            if deadline is None:
                raise KeyError(
                    f"ticket {rid} was already collected (results are "
                    "handed out exactly once)")
            if expired():
                raise DeadlineExceeded(
                    f"ticket {rid} did not resolve within {timeout_s:.3f}s "
                    "(dispatch shed or lost mid-remesh)")
            time.sleep(0.001)

    @property
    def total_compiles(self) -> int:
        return sum(self.trace_counts.values())
