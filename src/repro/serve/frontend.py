"""Async multi-tenant SLO frontend over the bucketed serving engines.

`AsyncServeFrontend` is the overload-robust entry point the ROADMAP's
"millions of users" item calls for, in the style of MaxText's MLPerf
``OfflineInference``: a background worker thread drains a bounded request
queue into coalesced waves over *pinned per-bucket executables* — here
one `DcnnServeEngine` per precision, each holding one `plan.NetworkPlan`
per bucket, so the frontend's cache is plans per bucket x precision.

The control loop per request:

* **submit** — `admission.AdmissionController` gates up front: a full
  queue rejects immediately (backpressure), and a request whose
  predicted completion (queue backlog + `scheduler.ServiceModel`
  estimate) busts its SLO even on the degraded int8 path is refused
  typed (`AdmissionRejected`) instead of queued toward a guaranteed
  deadline miss.
* **schedule** — the worker orders the queue earliest-deadline-first
  within tenant priority class (`scheduler.EdfScheduler`) and picks the
  wave's precision: fp32 when it makes the deadline, the pinned int8
  chain when only reduced precision can (graceful degradation; the
  request is tagged ``downgraded``), a typed late shed when nothing can.
* **dispatch** — one coalesced `generate` per wave; measured wall clocks
  feed the `ServiceModel` (healthy dispatches only).  A `DeviceLoss`
  rides the engine's elastic re-bucketing from PR 6 — the interrupted
  wave completes on the shrunken mesh bit-identically (plan-hash
  parity), and the frontend scales its capacity estimates down by the
  lost-device ratio so admission starts shedding at the new capacity.
  A dispatch failure (`EngineDegraded` after exhausted retries) requeues
  the wave's requests while their deadlines hold and sheds the rest
  typed — never a hang, never a silent drop.

`stats()` reports per-tenant p50/p99/CV over completed-request latency
plus shed/downgrade/requeue counters — the serving bench's ``slo``
section is this dict over an offered-load sweep.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..obs import clock as obsclock
from ..obs import metrics as obsmetrics
from ..obs import trace as obstrace
from .admission import AdmissionController, TenantClass
from .errors import (AdmissionRejected, DeadlineExceeded, EngineDegraded,
                     EngineError)
from .scheduler import FP32, EdfScheduler, ServiceModel


class _FrontendRequest:
    """One admitted request: rows + deadline + resolution slot."""

    __slots__ = ("rid", "tenant", "z", "rows", "submit_t", "deadline",
                 "precision_hint", "precision", "downgraded", "requeues",
                 "event", "result", "error", "qspan")

    def __init__(self, rid: int, tenant: TenantClass, z: np.ndarray,
                 submit_t: float, deadline: Optional[float]):
        self.rid = rid
        self.tenant = tenant
        self.z = z
        self.rows = int(z.shape[0])
        self.submit_t = submit_t
        self.deadline = deadline
        self.precision_hint = FP32
        self.precision: Optional[str] = None
        self.downgraded = False
        self.requeues = 0
        self.event = threading.Event()
        self.result: Optional[np.ndarray] = None
        self.error: Optional[Exception] = None
        # open queue_wait trace handle (begun at submit, ended when the
        # worker picks or sheds the request; None while not queued)
        self.qspan = None


def _tenant_zero() -> Dict[str, object]:
    return {"admitted": 0, "completed": 0, "downgraded": 0, "requeued": 0,
            "shed_admission": 0, "shed_late": 0, "shed_requeue": 0,
            "latencies_s": []}


class AsyncServeFrontend:
    """Async submit/result over one `DcnnServeEngine` per precision.

    ``engines`` maps precision -> engine; "fp32" is mandatory (the
    undegraded path) and every engine must share one bucket set, so the
    scheduler's per-bucket estimates apply across precisions.  All
    engine dispatch happens on the single worker thread; callers only
    touch the queue (thread-safe) and their own request's event."""

    def __init__(self, engines: Dict[str, "object"],
                 tenants: Sequence[TenantClass], *,
                 max_queue_rows: int = 256, safety: float = 1.2,
                 max_requeues: int = 1,
                 model: Optional[ServiceModel] = None, start: bool = True,
                 metrics: Optional[obsmetrics.MetricsRegistry] = None):
        if FP32 not in engines:
            raise ValueError(
                "AsyncServeFrontend needs a 'fp32' engine (the undegraded "
                f"path); got precisions {tuple(engines)}")
        self._engines = dict(engines)
        self._precisions = (FP32,) + tuple(
            p for p in engines if p != FP32)
        buckets = {p: tuple(e.buckets) for p, e in engines.items()}
        if len(set(buckets.values())) != 1:
            raise ValueError(
                f"engines must share one bucket set, got {buckets}: the "
                "scheduler's per-bucket estimates could not transfer "
                "across precisions")
        self._buckets = engines[FP32].buckets
        self._max_bucket = engines[FP32].max_bucket
        self._input_shape = engines[FP32].cfg.input_shape
        self._dtype = engines[FP32].cfg.dtype
        self._workload = getattr(engines[FP32], "workload",
                                 engines[FP32].cfg.name)
        if not tenants:
            raise ValueError("at least one TenantClass is required")
        self._tenants: Dict[str, TenantClass] = {}
        for t in tenants:
            if t.name in self._tenants:
                raise ValueError(f"duplicate tenant class {t.name!r}")
            self._tenants[t.name] = t

        # typed observability, dual-written beside the legacy per-tenant
        # dicts at the same sites (tests assert exact equality).  Pass the
        # engines' shared registry (see from_config) so the whole stack's
        # series — engine dispatch histograms included — land in one place.
        self.metrics = (metrics if metrics is not None
                        else obsmetrics.MetricsRegistry())
        self._tracer = obstrace.get_tracer()
        self._m_req = self.metrics.counter(
            "frontend.requests",
            "request outcomes by tenant (labels: tenant, outcome)")
        self._m_latency = self.metrics.histogram(
            "frontend.request_latency_seconds",
            "submit-to-completion latency (labels: tenant, precision)")
        self._m_qwait = self.metrics.histogram(
            "frontend.queue_wait_seconds",
            "submit-to-wave-pick queue wait (label: tenant)")
        self._m_qrows = self.metrics.gauge(
            "frontend.queue_rows", "rows currently queued")

        self._model = model if model is not None else ServiceModel()
        for precision, eng in self._engines.items():
            self._model.seed_from_engine(precision, eng)
        self._sched = EdfScheduler(self._model, self._buckets,
                                   self._precisions, safety=safety)
        self._admission = AdmissionController(self._sched, max_queue_rows)
        self._max_requeues = max_requeues

        # queue state under _cond's lock; request registry + per-tenant
        # stats under _slock (lock order: _cond before _slock)
        self._cond = threading.Condition()
        self._queue: List[_FrontendRequest] = []
        self._inflight: List[_FrontendRequest] = []
        self._stop = False
        self._next_rid = 0
        self._slock = threading.Lock()
        self._requests: Dict[int, _FrontendRequest] = {}
        self._tenant_stats: Dict[str, Dict] = {
            name: _tenant_zero() for name in self._tenants}
        self._remeshes = 0
        self._worker_errors: List[BaseException] = []
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="serve-frontend")
        self._started = False
        if start:
            self.start()

    # ------------------------------------------------------------------
    # construction helpers
    # ------------------------------------------------------------------
    @classmethod
    def from_config(cls, cfg, params, tenants,
                    precisions: Sequence[str] = (FP32, "int8"),
                    plan=None, prime: int = 0,
                    fault_injector=None, **kwargs) -> "AsyncServeFrontend":
        """Build one engine per precision from a single `EngineConfig`
        (``cfg.precision`` is overridden per variant; a pinned ``plan``
        seeds the engine whose precision it matches).  ``prime`` > 0 runs
        that many measured warmup dispatches per bucket x precision
        before the worker starts — the service model the offered-load
        admission decisions need (without it the first requests admit
        optimistically while estimates are learned from live traffic).
        ``fault_injector`` is wired into the fp32 engine (drills)."""
        from .engine import DcnnServeEngine

        # one registry for the whole deployment: every per-precision
        # engine and the frontend record into the same series space
        metrics = kwargs.pop("metrics", None)
        if metrics is None:
            metrics = obsmetrics.MetricsRegistry()
        engines = {}
        for precision in precisions:
            ecfg = (cfg if cfg.precision == precision
                    else dataclasses.replace(cfg, precision=precision))
            engines[precision] = DcnnServeEngine.from_config(
                ecfg, params,
                plan=(plan if plan is not None
                      and plan.precision == precision else None),
                fault_injector=(fault_injector if precision == FP32
                                else None),
                metrics=metrics)
        self = cls(engines, tenants, start=False, metrics=metrics, **kwargs)
        if prime:
            self.prime(reps=prime)
        self.start()
        return self

    def start(self) -> None:
        # check-and-set under _cond: two racing start() calls must not
        # both see _started False (Thread.start raises on the loser)
        with self._cond:
            if self._started:
                return
            self._started = True
        self._worker.start()

    def prime(self, reps: int = 2) -> None:
        """Measured warmup: compile every bucket x precision and feed
        ``reps`` steady dispatch timings into the service model.  Call
        before serving traffic (engine dispatch is single-threaded: the
        worker owns it once started and traffic is flowing)."""
        for precision, eng in self._engines.items():
            for b in eng.buckets:
                z = np.zeros((b,) + self._input_shape, self._dtype)
                for r in range(reps + 1):
                    t0 = obsclock.now()
                    eng.generate(z)
                    dt = obsclock.now() - t0
                    if r:  # first call pays compile: not a steady sample
                        self._model.observe(precision, b, dt)

    # ------------------------------------------------------------------
    # caller API
    # ------------------------------------------------------------------
    def submit(self, z: np.ndarray, tenant: str = "default",
               slo_ms: Optional[float] = None) -> int:
        """Admit a request (rows of z) for ``tenant``; returns a request
        id for `result`.  ``slo_ms`` overrides the tenant's default SLO.
        Raises `AdmissionRejected` when the bounded queue is full or the
        predicted completion busts the SLO at every allowed precision."""
        t = self._tenants.get(tenant)
        if t is None:
            raise ValueError(f"unknown tenant {tenant!r}; classes: "
                             f"{sorted(self._tenants)}")
        z = np.asarray(z, dtype=self._dtype)
        if z.ndim == len(self._input_shape):
            z = z[None]
        if z.shape[0] == 0:
            raise ValueError("empty request: z has no rows")
        now = obsclock.now()
        slo = slo_ms if slo_ms is not None else t.slo_ms
        deadline = None if slo is None else now + slo / 1e3
        req = _FrontendRequest(-1, t, z, now, deadline)
        with self._cond:
            if self._stop:
                raise RuntimeError("frontend is closed")
            queued_rows = (sum(r.rows for r in self._queue)
                           + sum(r.rows for r in self._inflight))
            backlog_s = self._backlog_seconds_locked()
            try:
                req.precision_hint = self._admission.admit(
                    req, queued_rows, backlog_s, now)
            except AdmissionRejected as e:
                with self._slock:
                    self._tenant_stats[t.name]["shed_admission"] += 1
                self._m_req.inc(tenant=t.name, outcome="shed_admission")
                self._tracer.instant("admission_rejected", cat="frontend",
                                     tenant=t.name, stage=e.stage,
                                     rows=req.rows)
                raise
            req.rid = self._next_rid
            self._next_rid += 1
            self._queue.append(req)
            with self._slock:
                self._requests[req.rid] = req
                self._tenant_stats[t.name]["admitted"] += 1
            self._m_req.inc(tenant=t.name, outcome="admitted")
            self._m_qrows.set(queued_rows + req.rows)
            req.qspan = self._tracer.begin("queue_wait", cat="frontend",
                                           rid=req.rid, tenant=t.name,
                                           rows=req.rows)
            self._cond.notify()
        self._tracer.complete("submit", now, obsclock.now(), cat="frontend",
                              rid=req.rid, tenant=t.name, rows=req.rows,
                              precision_hint=req.precision_hint)
        return req.rid

    def result(self, rid: int,
               timeout_s: Optional[float] = None) -> np.ndarray:
        """Block for request ``rid``'s images (or its typed failure).
        Results are handed out exactly once.  ``timeout_s`` bounds the
        wait: expiry raises `DeadlineExceeded` without consuming the
        request (a later `result` call can still pick it up)."""
        t0 = obsclock.now()
        with self._slock:
            req = self._requests.get(rid)
        if req is None:
            raise KeyError(f"unknown request {rid}: never admitted, or "
                           "its result was already handed out")
        if not req.event.wait(timeout_s):
            raise DeadlineExceeded(
                f"request {rid} unresolved after {timeout_s:.3f}s")
        with self._slock:
            self._requests.pop(rid, None)
        self._tracer.complete("collect", t0, obsclock.now(), cat="frontend",
                              rid=rid, tenant=req.tenant.name,
                              failed=req.error is not None)
        if req.error is not None:
            raise req.error
        return req.result

    def drain(self, timeout_s: Optional[float] = None) -> None:
        """Block until the queue and in-flight wave are empty."""
        deadline = (None if timeout_s is None
                    else obsclock.now() + timeout_s)
        while True:
            with self._cond:
                if not self._queue and not self._inflight:
                    return
            if deadline is not None and obsclock.now() >= deadline:
                raise DeadlineExceeded(
                    f"frontend not drained within {timeout_s:.3f}s")
            time.sleep(0.002)

    def close(self, drain: bool = True, timeout_s: float = 60.0) -> None:
        """Stop the worker.  ``drain=True`` (default) serves everything
        still queued first; ``drain=False`` resolves queued requests
        typed (`AdmissionRejected`, stage="shutdown") — a shutdown never
        silently drops a caller."""
        doomed: List[_FrontendRequest] = []
        with self._cond:
            self._stop = True
            started = self._started
            if not drain:
                doomed, self._queue = self._queue, []
            self._cond.notify_all()
        for req in doomed:
            self._tracer.end(req.qspan, outcome="shutdown")
            req.qspan = None
            self._resolve_error(req, AdmissionRejected(
                f"request {req.rid} dropped by frontend shutdown",
                stage="shutdown"), counter=None)
        if started:
            self._worker.join(timeout=timeout_s)
        for eng in self._engines.values():
            eng.close()

    def stats(self) -> Dict:
        """Per-tenant latency percentiles + shed/downgrade counters and
        the frontend-global capacity picture."""
        with self._slock:
            tenants = {}
            for name, st in self._tenant_stats.items():
                lat = np.asarray(st["latencies_s"], dtype=np.float64)
                row = {k: v for k, v in st.items() if k != "latencies_s"}
                row["shed"] = (st["shed_admission"] + st["shed_late"]
                               + st["shed_requeue"])
                if lat.size:
                    mean = float(lat.mean())
                    row.update(
                        p50_ms=float(np.percentile(lat, 50)) * 1e3,
                        p99_ms=float(np.percentile(lat, 99)) * 1e3,
                        mean_ms=mean * 1e3,
                        cv=float(lat.std() / max(mean, 1e-12)),
                    )
                tenants[name] = row
            remeshes = self._remeshes
        with self._cond:
            queue_rows = sum(r.rows for r in self._queue)
            inflight_rows = sum(r.rows for r in self._inflight)
        return {
            "workload": self._workload,
            "tenants": tenants,
            "queue_rows": queue_rows,
            "inflight_rows": inflight_rows,
            "remeshes": remeshes,
            "precisions": list(self._precisions),
            "buckets": list(self._buckets),
            "estimates_s": self._model.snapshot(),
        }

    def reset_stats(self) -> None:
        """Zero the per-tenant counters/latency samples (offered-load
        sweeps measure each load point fresh); capacity estimates and
        pinned plans are kept — they are state, not statistics."""
        with self._slock:
            for name in self._tenant_stats:
                self._tenant_stats[name] = _tenant_zero()
        # keep the registry's frontend series in lockstep with the legacy
        # dicts (engine series are cumulative state and stay)
        self._m_req.reset()
        self._m_latency.reset()
        self._m_qwait.reset()

    def plan_fingerprints(self) -> Dict[str, str]:
        """{"b{batch}/{precision}": stable hash} over every pinned
        NetworkPlan across the precision-variant engines (see
        `plan.variant_fingerprints`) — what a deployment compares across
        hosts to prove "same executable everywhere"."""
        from ..plan import variant_fingerprints

        return variant_fingerprints(
            p for eng in self._engines.values()
            for p in eng.plans.values())

    # ------------------------------------------------------------------
    # worker
    # ------------------------------------------------------------------
    def _backlog_seconds_locked(self) -> float:
        total = 0.0
        for req in self._queue + self._inflight:
            est = self._model.service_seconds(
                req.precision_hint or FP32, req.rows, self._buckets)
            if est is not None:
                total += est
        return total

    def _resolve_error(self, req: _FrontendRequest, error: Exception,
                       counter: Optional[str]) -> None:
        req.error = error
        if counter is not None:
            with self._slock:
                self._tenant_stats[req.tenant.name][counter] += 1
            self._m_req.inc(tenant=req.tenant.name, outcome=counter)
        self._tracer.instant("request_failed", cat="frontend", rid=req.rid,
                             tenant=req.tenant.name,
                             error=type(error).__name__)
        req.event.set()

    def _record_completion(self, req: _FrontendRequest, precision: str,
                           done_t: float) -> None:
        req.precision = precision
        req.downgraded = precision != FP32
        with self._slock:
            st = self._tenant_stats[req.tenant.name]
            st["completed"] += 1
            if req.downgraded:
                st["downgraded"] += 1
            st["latencies_s"].append(done_t - req.submit_t)
        self._m_req.inc(tenant=req.tenant.name, outcome="completed")
        if req.downgraded:
            self._m_req.inc(tenant=req.tenant.name, outcome="downgraded")
        self._m_latency.observe(done_t - req.submit_t,
                                tenant=req.tenant.name, precision=precision)
        req.event.set()

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stop:
                    self._cond.wait(timeout=0.05)
                if self._stop and not self._queue:
                    break
                wave, precision, sheds = self._pick_wave_locked()
                self._inflight = list(wave)
                self._m_qrows.set(sum(r.rows for r in self._queue))
            picked_t = obsclock.now()
            for req in wave:
                self._tracer.end(req.qspan, outcome="dispatched")
                req.qspan = None
                self._m_qwait.observe(picked_t - req.submit_t,
                                      tenant=req.tenant.name)
            for req in sheds:
                self._tracer.end(req.qspan, outcome="shed_late")
                req.qspan = None
            for req in sheds:
                self._resolve_error(req, AdmissionRejected(
                    f"request {req.rid} ({req.tenant.name}) can no longer "
                    "meet its deadline in queue; shed before dispatch "
                    "(never a post-dispatch DeadlineExceeded)",
                    stage="late"), counter="shed_late")
            if not wave:
                continue
            try:
                self._dispatch_wave(wave, precision)
            except Exception as e:   # worker must never die: that's a hang
                self._worker_errors.append(e)
                for req in wave:
                    if not req.event.is_set():
                        self._resolve_error(req, EngineDegraded(
                            f"frontend worker error: {e!r}"),
                            counter="shed_requeue")
            finally:
                with self._cond:
                    self._inflight = []
                    self._cond.notify_all()

    def _pick_wave_locked(self):
        """EDF order the queue, shed requests that can no longer make
        their deadlines, and cut one wave: the head request fixes the
        precision, following same-precision requests coalesce until the
        largest bucket is full (one dispatch per wave keeps per-request
        latency equal to wave latency — predictable, per Table II)."""
        now = obsclock.now()
        ordered = EdfScheduler.order(self._queue)
        wave: List[_FrontendRequest] = []
        sheds: List[_FrontendRequest] = []
        precision: Optional[str] = None
        rows = 0
        for req in ordered:
            choice = (None
                      if req.deadline is not None and now > req.deadline
                      else self._sched.feasible_precision(req, now))
            if choice is None:
                sheds.append(req)
                continue
            if precision is None:
                precision = choice
            if choice != precision:
                continue          # different precision: next wave
            if rows and rows + req.rows > self._max_bucket:
                continue          # wave bounded to one largest-bucket call
            wave.append(req)
            rows += req.rows
        for req in wave + sheds:
            self._queue.remove(req)
        return wave, precision, sheds

    def _dispatch_wave(self, wave: List[_FrontendRequest],
                       precision: str) -> None:
        eng = self._engines[precision]
        remesh_before = len(eng.fault_stats["remesh_events"])
        retries_before = eng.fault_stats["retries"]
        z = (wave[0].z if len(wave) == 1
             else np.concatenate([r.z for r in wave], axis=0))
        t0 = obsclock.now()
        try:
            imgs = eng.generate(z)
        except Exception as err:
            self._check_remesh(eng, remesh_before)
            self._requeue_or_shed(wave, err)
            return
        done_t = obsclock.now()
        self._tracer.complete("wave_dispatch", t0, done_t, cat="frontend",
                              precision=precision, rows=int(len(z)),
                              reqs=len(wave))
        remeshed = self._check_remesh(eng, remesh_before)
        retried = eng.fault_stats["retries"] != retries_before
        if not remeshed and not retried and len(z) <= self._max_bucket:
            # healthy dispatch at a known bucket: feed the capacity model
            # (a wave that rode a remesh or retries is not a healthy
            # sample — same outcome-tagging rule as engine.bucket_stats)
            self._model.observe(precision, eng.bucket_for(len(z)),
                                done_t - t0)
        ofs = 0
        for req in wave:
            req.result = imgs[ofs:ofs + req.rows]
            ofs += req.rows
            self._record_completion(req, precision, done_t)

    def _check_remesh(self, eng, remesh_before: int) -> bool:
        """Scale capacity estimates down by the lost-device ratio after
        an elastic remesh: admission must start shedding at the shrunken
        capacity *now*, not after estimates drift there."""
        events = eng.fault_stats["remesh_events"]
        if len(events) == remesh_before:
            return False
        for ev in events[remesh_before:]:
            factor = ev["devices_before"] / max(1, ev["devices_after"])
            self._model.scale(factor)
        with self._slock:
            self._remeshes += len(events) - remesh_before
        return True

    def _requeue_or_shed(self, wave: List[_FrontendRequest],
                         err: Exception) -> None:
        """Dispatch failed typed: requeue requests whose deadlines still
        hold (bounded by max_requeues), shed the rest — every request
        resolves, in both directions."""
        now = obsclock.now()
        requeue: List[_FrontendRequest] = []
        for req in wave:
            if (req.requeues < self._max_requeues
                    and (req.deadline is None or now < req.deadline)):
                req.requeues += 1
                requeue.append(req)
            else:
                typed = (err if isinstance(err, EngineError)
                         else EngineDegraded(f"dispatch failed: {err!r}"))
                self._resolve_error(req, typed, counter="shed_requeue")
        if requeue:
            with self._slock:
                for req in requeue:
                    self._tenant_stats[req.tenant.name]["requeued"] += 1
            for req in requeue:
                self._m_req.inc(tenant=req.tenant.name, outcome="requeued")
                req.qspan = self._tracer.begin(
                    "queue_wait", cat="frontend", rid=req.rid,
                    tenant=req.tenant.name, rows=req.rows,
                    requeue=req.requeues)
            with self._cond:
                self._queue[:0] = requeue
                self._cond.notify()
