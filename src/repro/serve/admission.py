"""Admission control for the async serving frontend.

The overload contract is *reject up front, typed* — a request that
cannot meet its SLO even on the degraded int8 path must be refused at
`submit` (`AdmissionRejected`), not accepted into a queue where it will
burn device time and fail anyway.  Two gates:

* **Backpressure** — the request queue is bounded in rows; a full queue
  rejects immediately.  Combined with the frontend's bounded worker this
  caps memory and tail latency instead of letting overload grow an
  unbounded backlog (the paper's predictability claim, Table II, is a
  statement about admitted work).
* **Predictive SLO check** — predicted completion (now + queue backlog +
  safety x service estimate from `scheduler.ServiceModel`) is tested
  against the request deadline at fp32 first, then at each degraded
  precision the tenant allows; only if none fits is the request shed.

`TenantClass` is the multi-tenant knob: per-class SLO default, priority
(scheduling order), and whether the class tolerates precision
degradation (a preview tenant might; a fidelity-critical one won't).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .errors import AdmissionRejected
from .scheduler import EdfScheduler


@dataclasses.dataclass(frozen=True)
class TenantClass:
    """One request class sharing SLO/priority/degrade policy.

    * ``slo_ms``        — default per-request latency budget (None: no
                          deadline; batch work that yields to SLO work).
    * ``priority``      — scheduling class, lower first; EDF orders
                          within a class.
    * ``allow_degrade`` — whether the scheduler may serve this tenant
                          through the pinned int8 plans when fp32 cannot
                          make the deadline."""

    name: str
    slo_ms: Optional[float] = None
    priority: int = 1
    allow_degrade: bool = True

    def __post_init__(self):
        if self.slo_ms is not None and self.slo_ms <= 0:
            raise ValueError(f"tenant {self.name!r}: slo_ms must be "
                             f"positive, got {self.slo_ms}")


class AdmissionController:
    """The submit-time gate; shares the `EdfScheduler` (and through it
    the `ServiceModel`) with dispatch so admission and scheduling agree
    on what "can make it" means."""

    def __init__(self, scheduler: EdfScheduler, max_queue_rows: int = 256):
        if max_queue_rows < 1:
            raise ValueError("max_queue_rows must be >= 1")
        self.max_queue_rows = max_queue_rows
        self._sched = scheduler

    def admit(self, req, queued_rows: int, backlog_s: float,
              now: float) -> str:
        """Return the precision the request is predicted to need, or
        raise `AdmissionRejected` (typed, with the gate that fired)."""
        if queued_rows + req.rows > self.max_queue_rows:
            raise AdmissionRejected(
                f"queue full: {queued_rows} rows pending against a "
                f"{self.max_queue_rows}-row bound (backpressure — back "
                "off and resubmit)", stage="queue_full")
        precision = self._sched.feasible_precision(req, now, backlog_s)
        if precision is None:
            raise AdmissionRejected(
                f"request of {req.rows} row(s) for tenant "
                f"{req.tenant.name!r} cannot meet its SLO "
                f"({(req.deadline - now) * 1e3:.1f} ms budget against a "
                f"{backlog_s * 1e3:.1f} ms backlog) even at the most "
                "degraded precision; rejected before burning device "
                "time", stage="predicted_slo")
        return precision
