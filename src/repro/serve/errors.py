"""Typed serving failures.

The fault-tolerant engine's contract is *complete or fail typed*: a
request either returns images or raises one of these — it never hangs on
a dead mesh and never silently drops a queued ticket.  The dist-level
call faults (`dist.inject.TransientCallError` / `DeviceLossError`) are
inputs to the engine's recovery machinery; these are what escapes it.
"""
from __future__ import annotations


class EngineError(RuntimeError):
    """Base class for `DcnnServeEngine` failures."""


class DeadlineExceeded(EngineError):
    """The per-request deadline passed before the request executed; the
    ticket was failed instead of serving stale work.  Submit again (or
    raise the deadline)."""


class AdmissionRejected(EngineError):
    """The request was refused *before* burning device time: the bounded
    queue is full (backpressure), or the predicted completion time —
    queue backlog plus the service-time estimate at the cheapest
    precision the tenant allows — would bust its SLO, or the scheduler
    shed it after a failure-requeue could no longer make the deadline.
    ``stage`` says which gate fired ("queue_full", "predicted_slo",
    "late", "requeue", "shed", "shutdown").  Back off and resubmit, or
    relax the SLO."""

    def __init__(self, message: str, stage: str = "shed"):
        super().__init__(message)
        self.stage = stage


class EngineDegraded(EngineError):
    """The engine cannot currently honor the request: transient-failure
    retries exhausted, a device loss with no elastic mesh to shrink
    onto, or post-remesh re-planning that did not re-derive the
    validated executables.  The queue is intact — pending tickets stay
    pending and a later drain retries them."""
