"""Typed serving failures.

The fault-tolerant engine's contract is *complete or fail typed*: a
request either returns images or raises one of these — it never hangs on
a dead mesh and never silently drops a queued ticket.  The dist-level
call faults (`dist.inject.TransientCallError` / `DeviceLossError`) are
inputs to the engine's recovery machinery; these are what escapes it.
"""
from __future__ import annotations


class EngineError(RuntimeError):
    """Base class for `DcnnServeEngine` failures."""


class DeadlineExceeded(EngineError):
    """The per-request deadline passed before the request executed; the
    ticket was failed instead of serving stale work.  Submit again (or
    raise the deadline)."""


class EngineDegraded(EngineError):
    """The engine cannot currently honor the request: transient-failure
    retries exhausted, a device loss with no elastic mesh to shrink
    onto, or post-remesh re-planning that did not re-derive the
    validated executables.  The queue is intact — pending tickets stay
    pending and a later drain retries them."""
