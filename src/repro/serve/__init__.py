"""Serving engines (LM continuous batching + DCNN bucketed plan/execute)."""
from .config import EngineConfig
from .engine import (DcnnServeEngine, Request, ServeEngine, pow2_buckets,
                     shard_aligned_buckets)

__all__ = [
    "EngineConfig", "DcnnServeEngine", "Request", "ServeEngine",
    "pow2_buckets", "shard_aligned_buckets",
]
