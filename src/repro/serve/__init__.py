"""Serving engines (LM continuous batching + DCNN bucketed plan/execute,
with typed fault/deadline semantics) and the SLO-aware async frontend
(admission control, EDF scheduling, graceful precision degradation)."""
from .admission import AdmissionController, TenantClass
from .config import EngineConfig
from .engine import (DcnnServeEngine, Request, ServeEngine, pow2_buckets,
                     shard_aligned_buckets)
from .errors import (AdmissionRejected, DeadlineExceeded, EngineDegraded,
                     EngineError)
from .frontend import AsyncServeFrontend
from .scheduler import EdfScheduler, ServiceModel

__all__ = [
    "EngineConfig", "DcnnServeEngine", "Request", "ServeEngine",
    "pow2_buckets", "shard_aligned_buckets",
    "AsyncServeFrontend", "TenantClass", "AdmissionController",
    "EdfScheduler", "ServiceModel",
    "AdmissionRejected", "DeadlineExceeded", "EngineDegraded", "EngineError",
]
