"""Serving engines (LM continuous batching + DCNN bucketed plan/execute,
with typed fault/deadline semantics)."""
from .config import EngineConfig
from .engine import (DcnnServeEngine, Request, ServeEngine, pow2_buckets,
                     shard_aligned_buckets)
from .errors import DeadlineExceeded, EngineDegraded, EngineError

__all__ = [
    "EngineConfig", "DcnnServeEngine", "Request", "ServeEngine",
    "pow2_buckets", "shard_aligned_buckets",
    "DeadlineExceeded", "EngineDegraded", "EngineError",
]
