"""Engine configuration for the DCNN serving path.

`EngineConfig` is the one place the serving knobs live — the ~12
interacting kwargs `DcnnServeEngine.__init__` had accreted (backend,
precision, calibration, bucketing, mesh, donation, ...) collapsed into a
frozen dataclass.  Build one, hand it to `DcnnServeEngine.from_config`
together with the params and (optionally) a pinned `plan.NetworkPlan`;
the old keyword constructor survives one release as a deprecation shim
that builds this config internally.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything a `DcnnServeEngine` needs besides params and plans.

    * ``model``     — the tower being served: a `models.dcnn.DcnnConfig`
                      or a registered `repro.workloads` name ("mnist",
                      "sr", ...).  Unknown names raise a typed
                      `workloads.UnknownWorkloadError` at engine
                      construction — never a silent fallback.
    * ``backend``   — deconv formulation ("pallas", "pallas_sparse",
                      "reverse_loop", "xla").
    * ``precision`` — "fp32" or "int8" (the calibrated Pallas chain).
    * ``quant_cfg`` — pre-computed `quant.QuantConfig`; None self-
                      calibrates with the ``calib_*`` knobs (or takes the
                      calibration pinned in a provided NetworkPlan).
    * ``mesh``/``rules`` — optional jax Mesh + sharding rules: buckets
                      shard over the data axis, params replicate.
    * ``buckets``/``max_batch`` — explicit bucket set, or power-of-two
                      buckets up to ``max_batch``.
    * ``autotune``/``refine`` — tile resolution policy for plan building.
    * ``warmup``    — eagerly build + run every bucket at construction.
    * ``donate``    — donate z buffers to the compiled generator on TPU.
    * ``call_overhead_rows`` — chunk-planning cost of one extra dispatch.

    Fault-tolerance knobs (`serve.errors` / `dist.fault` semantics):

    * ``max_retries``/``retry_backoff_s`` — bounded retry with
      exponential backoff for transient bucket-call failures; exhausted
      retries raise `EngineDegraded` instead of looping.
    * ``heartbeat_timeout_s`` — when set, a `dist.fault.Heartbeat` is
      armed around every dispatched call: a call silent longer than this
      is recorded as a stall in ``fault_stats`` (None: no watcher
      thread).
    * ``straggler_factor``/``straggler_warmup`` — per-bucket
      `StragglerMonitor` over the steady-state per-call wall clock (the
      same samples `throughput()` reports); flagged calls count into
      ``fault_stats["stragglers"]``.
    * ``default_deadline_s`` — queue deadline applied to `submit` when
      the caller gives none; an expired ticket fails typed
      (`DeadlineExceeded`) instead of executing stale work.
    * ``elastic`` — on a detected device loss, remesh onto the
      survivors, re-align buckets and re-plan (False: fail degraded).
    """

    model: Any
    backend: str = "pallas"
    precision: str = "fp32"
    quant_cfg: Any = None
    mesh: Any = None
    rules: Any = None
    autotune: bool = True
    refine: bool = False
    max_batch: int = 64
    buckets: Optional[Tuple[int, ...]] = None
    warmup: bool = False
    donate: bool = True
    call_overhead_rows: int = 8
    calib_batch: int = 64
    calib_seed: int = 0
    calib_strategy: str = "mean_ksigma"
    max_retries: int = 2
    retry_backoff_s: float = 0.05
    heartbeat_timeout_s: Optional[float] = None
    straggler_factor: float = 3.0
    straggler_warmup: int = 3
    default_deadline_s: Optional[float] = None
    elastic: bool = True
